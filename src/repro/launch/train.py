"""Training launcher: runs the sharded train step for an assigned arch.

On a pod this launches the real mesh; on this CPU container use --smoke for a
reduced config (full configs are exercised via launch.dryrun, which lowers
and compiles them against the production mesh without allocating).

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --smoke \
      --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import TRAIN_4K, get_arch, reduced
from repro.distributed.sharding import make_policy
from repro.launch.mesh import make_production_mesh
from repro.models.model import LM, ExecConfig
from repro.training import (AdamWConfig, DataConfig, TrainConfig,
                            batch_at_step, init_train_state, latest_step,
                            load, make_train_step, save)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", action="store_true")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.smoke:
        arch = reduced(arch)
        policy = None
        batch, seq = 8, 64
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        policy = make_policy(arch, TRAIN_4K, mesh)
        batch, seq = TRAIN_4K.global_batch, TRAIN_4K.seq_len

    from repro.distributed.sharding import NO_POLICY
    model = LM(arch, policy or NO_POLICY,
               ExecConfig(loss_chunk=min(512, seq)))
    tcfg = TrainConfig(adamw=AdamWConfig(total_steps=args.steps),
                       microbatches=args.microbatches,
                       grad_compression=args.compression)
    dcfg = DataConfig(vocab=arch.vocab, seq_len=seq, global_batch=batch,
                      family=arch.family.value, d_model=arch.d_model,
                      n_frontend_tokens=arch.n_frontend_tokens)
    step_fn = jax.jit(make_train_step(model, tcfg))

    start = latest_step(args.ckpt) if args.ckpt else None
    params, opt = init_train_state(model, jax.random.key(0), tcfg)
    if start:
        restored, _ = load(args.ckpt, start, {"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"[train] resumed at step {start}")
    start = start or 0
    t0 = time.perf_counter()
    for i in range(start, args.steps):
        params, opt, m = step_fn(params, opt, batch_at_step(dcfg, i))
        if (i + 1) % 10 == 0:
            print(f"[train] step {i+1} loss={float(m['loss']):.4f} "
                  f"({(time.perf_counter()-t0)/(i+1-start):.2f}s/step)")
        if args.ckpt and (i + 1) % 50 == 0:
            save(args.ckpt, i + 1, {"params": params, "opt": opt})
    print("[train] done")


if __name__ == "__main__":
    main()
