"""Production mesh construction.

Defined as functions (not module-level constants) so importing never touches
jax device state. The dry-run launcher forces 512 host platform devices
*before* any jax import; everything else sees the real device count."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — run "
            "via launch.dryrun (it forces XLA host device count) or on a pod")
    import numpy as np
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_worker_mesh(n_model: int = 1):
    """Small TP mesh for one serving worker (e.g. 4 chips TP)."""
    devices = jax.devices()[:n_model]
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(1, n_model),
                             ("data", "model"))
