import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # CPU backend has no bf16 GEMM, so it inserts bf16->f32 weight converts;
    # LICM then hoists them out of the layer scan, materializing fp32 copies
    # of ALL layers' weights at once. That is a CPU-compile artifact (TPU
    # does bf16 natively) and would poison the memory analysis — keep the
    # converts inside the loop:
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
    "while-loop-expensive-invariant-code-motion")

# ^ MUST precede every other import (jax locks the device count on first
# init). Everything below may import jax.
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (ASSIGNED_ARCHS, SHAPES_BY_NAME, get_arch,  # noqa: E402
                           shape_applicable)
from repro.distributed.hlo_analysis import analyze_hlo  # noqa: E402
from repro.distributed.sharding import make_policy      # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.models.model import LM, ExecConfig           # noqa: E402
from repro.training.optimizer import AdamWConfig        # noqa: E402
from repro.training.train_step import TrainConfig, make_train_step  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape) cell against the
production mesh using ShapeDtypeStruct stand-ins (no real allocation), then
record memory_analysis / cost_analysis / HLO collective bytes for the
roofline (EXPERIMENTS.md §Dry-run, §Roofline).

  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
      --shape train_4k [--multi-pod] [--out reports/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

# per-cell execution overrides: microbatching etc. chosen so the cell fits
# 16 GiB/chip (tuning log in EXPERIMENTS.md §Perf)
CELL_OVERRIDES: Dict[str, Dict[str, Any]] = {
    # 90B weights TP-16 alone are 11.25 GiB/chip; serve cells need layer-wise
    # FSDP gathering to fit beside the 32k KV cache (16 GiB HBM).
    "llama-3.2-vision-90b/decode_32k": {"policy": {"params_mode": "fsdp"}},
    "llama-3.2-vision-90b/prefill_32k": {"policy": {"params_mode": "fsdp"}},
    # Dense-family training runs pure ZeRO-3 (1 seq/chip + remat): no
    # microbatching needed — and microbatches below the chip count would
    # break batch sharding (each microbatch must still divide 256/512).
    # [§Perf iterations 3-4]
}


def _shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def input_specs(arch, shape, *, dtype=jnp.int32):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    logical: Dict[str, Any] = {}
    if shape.kind == "train":
        if arch.family.value == "audio":
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, arch.d_model),
                                                   jnp.bfloat16)
            logical["embeds"] = ("batch", None, None)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            logical["tokens"] = ("batch", None)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        logical["labels"] = ("batch", None)
    elif shape.kind == "prefill":
        if arch.family.value == "audio":
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, arch.d_model),
                                                   jnp.bfloat16)
            logical["embeds"] = ("batch", None, None)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            logical["tokens"] = ("batch", None)
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((b,), jnp.int32)
        logical["tokens"] = ("batch",)
    if arch.family.value == "vlm":
        specs["frontend"] = jax.ShapeDtypeStruct(
            (b, arch.n_frontend_tokens, arch.d_model), jnp.bfloat16)
        logical["frontend"] = ("batch", None, None)
    return specs, logical


def _exec_cfg(arch, shape, overrides) -> ExecConfig:
    return ExecConfig(
        use_pallas=False,              # jnp reference paths lower on any
        kv_chunk=overrides.get("kv_chunk", 512),   # backend; pallas is the
        scan_layers=True,                          # TPU-runtime fast path
        remat=(shape.kind == "train"),
        loss_chunk=overrides.get("loss_chunk", 512),
        recent_window=overrides.get("recent_window", 256),
    )


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool = False,
               policy_overrides: Optional[dict] = None,
               exec_overrides: Optional[dict] = None):
    """Returns (lowered, model, policy, meta) for one cell."""
    arch = get_arch(arch_name)
    shape = SHAPES_BY_NAME[shape_name]
    overrides = dict(CELL_OVERRIDES.get(f"{arch_name}/{shape_name}", {}))
    pod_key = f"{arch_name}/{shape_name}@{'pod2' if multi_pod else 'pod1'}"
    overrides.update(CELL_OVERRIDES.get(pod_key, {}))
    overrides.update(exec_overrides or {})
    pol_kw = dict(overrides.pop("policy", {}))
    pol_kw.update(policy_overrides or {})
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = make_policy(arch, shape, mesh, **pol_kw)
    model = LM(arch, policy, _exec_cfg(arch, shape, overrides))
    specs, logical = input_specs(arch, shape)
    in_sh = {k: NamedSharding(mesh, policy.spec_for_shape(v, specs[k].shape))
             for k, v in logical.items()}
    pspecs = _shardings(mesh, model.param_specs())
    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))

    if shape.kind == "train":
        micro = overrides.get("microbatches", 1)
        tcfg = TrainConfig(adamw=AdamWConfig(), microbatches=micro)
        step = make_train_step(model, tcfg)
        from repro.training.optimizer import init_opt_state
        opt_shape = jax.eval_shape(
            lambda p: init_opt_state(p, compression=False), params_shape)
        # opt state shards like params
        opt_sh = type(opt_shape)(
            step=NamedSharding(mesh, P()),
            mu=pspecs, nu=pspecs, master=pspecs, ef=None)
        jitted = jax.jit(step, in_shardings=(pspecs, opt_sh, in_sh),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_shape, opt_shape, specs)
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, tokens=batch.get("tokens"),
                                 embeds=batch.get("embeds"),
                                 frontend=batch.get("frontend"),
                                 s_max=shape.seq_len)
        jitted = jax.jit(prefill_step, in_shardings=(pspecs, in_sh))
        lowered = jitted.lower(params_shape, specs)
    else:
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        cache_sh = _shardings(mesh, model.cache_specs(shape.global_batch,
                                                      shape.seq_len))

        def serve_step(params, cache, batch):
            return model.decode_step(params, cache, batch["tokens"])
        jitted = jax.jit(serve_step,
                         in_shardings=(pspecs, cache_sh, in_sh),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_shape, cache_shape, specs)
    meta = {"arch": arch_name, "shape": shape_name, "multi_pod": multi_pod,
            "n_devices": mesh.devices.size, "params": arch.param_count(),
            "active_params": arch.param_count(active_only=True),
            "attn_mode": policy.attn_mode, "params_mode": policy.params_mode,
            "overrides": overrides}
    return lowered, model, policy, meta


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: str = "reports/dryrun", verbose: bool = True,
             policy_overrides: Optional[dict] = None,
             exec_overrides: Optional[dict] = None,
             tag: str = "") -> Dict[str, Any]:
    t0 = time.time()
    res: Dict[str, Any] = {"arch": arch_name, "shape": shape_name,
                           "multi_pod": multi_pod, "ok": False, "tag": tag}
    try:
        lowered, model, policy, meta = lower_cell(
            arch_name, shape_name, multi_pod=multi_pod,
            policy_overrides=policy_overrides, exec_overrides=exec_overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # jax returns [dict] on some versions
            cost = cost[0] if cost else None
        hlo_text = compiled.as_text()
        hlo = analyze_hlo(hlo_text)
        if out_dir:
            import gzip
            hdir = os.path.join(out_dir, "hlo")
            os.makedirs(hdir, exist_ok=True)
            pod_ = "pod2" if multi_pod else "pod1"
            sfx = f"_{tag}" if tag else ""
            with gzip.open(os.path.join(
                    hdir, f"{arch_name}_{shape_name}_{pod_}{sfx}.txt.gz"),
                    "wt") as zf:
                zf.write(hlo_text)
        res.update(meta)
        res.update({
            "ok": True,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops": cost.get("flops", 0.0) if cost else 0.0,
            "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else 0.0,
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_size_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "peak_bytes_per_device": getattr(mem, "peak_memory_in_bytes", 0),
            "resident_bytes_per_device":
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0),
            "collectives": hlo["collectives"],
            "collective_bytes": hlo["total_collective_bytes"],
            "hlo_dot_flops": hlo["dot_flops"],
            "hlo_bytes": hlo["hbm_bytes"],
        })
        if verbose:
            print(f"[dryrun] {arch_name}/{shape_name} "
                  f"{'pod2' if multi_pod else 'pod1'} OK "
                  f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
                  f"mem/dev={res['peak_bytes_per_device']/2**30:.2f}GiB "
                  f"coll={res['collective_bytes']/2**30:.2f}GiB")
    except Exception as e:   # noqa: BLE001 — a failing cell is a data point
        res["error"] = f"{type(e).__name__}: {e}"
        res["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch_name}/{shape_name} FAILED: {res['error']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        pod = "pod2" if multi_pod else "pod1"
        suffix = f"_{tag}" if tag else ""
        fn = os.path.join(out_dir,
                          f"{arch_name}_{shape_name}_{pod}{suffix}.json")
        with open(fn, "w") as f:
            json.dump({k: v for k, v in res.items() if k != "traceback"},
                      f, indent=1)
    return res


def iter_cells(multi_pod: bool):
    for a in ASSIGNED_ARCHS:
        arch = get_arch(a)
        for sname, shape in SHAPES_BY_NAME.items():
            if shape_applicable(arch, shape):
                yield a, sname


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    if args.all:
        for mp in meshes:
            for a, s in iter_cells(mp):
                r = run_cell(a, s, multi_pod=mp, out_dir=args.out)
                failures += 0 if r["ok"] else 1
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            r = run_cell(args.arch, args.shape, multi_pod=mp,
                         out_dir=args.out)
            failures += 0 if r["ok"] else 1
    print(f"[dryrun] done, failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
