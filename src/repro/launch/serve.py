"""Serving launcher.

On a real TPU deployment each Aladdin worker is one TP slice (the submesh
size from Eq. 5-6's optimal config); this launcher assembles the cluster,
runs the Aladdin control loop, and serves a synthetic Poisson workload (or
stdin-submitted requests with --interactive).

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b \
      --rate 2 --duration 30 [--policy aladdin|jsq] [--workers 2]

On this CPU container the model is automatically reduced (--full to disable).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.request import Request
from repro.core.slo import SLO
from repro.core.worker_config import TPU_V5E, optimal_worker_config
from repro.models.model import LM
from repro.serving.cluster import ClusterConfig, ServingCluster
from repro.serving.engine import EngineConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--policy", default="aladdin")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--ttft", type=float, default=10.0)
    ap.add_argument("--atgt", type=float, default=2.0)
    ap.add_argument("--full", action="store_true",
                    help="use the full-size config (needs a real pod)")
    ap.add_argument("--autoscale", action="store_true")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    try:
        cfg = optimal_worker_config(arch, TPU_V5E, SLO(args.ttft, args.atgt))
        print(f"[serve] Eq.5-6 optimal worker: {cfg.n_accelerators} chips "
              f"({cfg.bound}-bound)")
    except ValueError as e:
        print(f"[serve] worker config: {e}")
    if not args.full:
        arch = reduced(arch, n_layers=2, d_model=64, vocab=256)
    model = LM(arch)
    params = model.init(jax.random.key(0))
    cluster = ServingCluster(
        arch, params, SLO(args.ttft, args.atgt),
        engine_cfg=EngineConfig(max_batch=4, page_size=8, n_pages=256,
                                max_pages_per_seq=32),
        cfg=ClusterConfig(policy=args.policy, autoscale=args.autoscale,
                          max_workers=max(args.workers * 2, 4)),
        n_workers=args.workers)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    n = 0
    next_arrival = t0 + rng.exponential(1.0 / args.rate)
    while time.perf_counter() - t0 < args.duration:
        now = time.perf_counter()
        while now >= next_arrival:
            r = Request(l_in=int(rng.integers(8, 48)), l_pred=0,
                        l_real=int(rng.integers(4, 16)), arrival=now)
            r.tokens = [int(x) for x in rng.integers(2, arch.vocab, r.l_in)]
            cluster.submit(r)
            n += 1
            next_arrival += rng.exponential(1.0 / args.rate)
        cluster.heartbeat()
    cluster.run_until_drained()
    print(f"[serve] {len(cluster.finished)}/{n} finished | attainment "
          f"{cluster.attainment():.2f} | workers={len(cluster.workers)} | "
          f"decode fit err={cluster.perf.max_rel_err.get('decode', -1):.3f}")


if __name__ == "__main__":
    main()
