"""Training step: loss + grad with microbatch accumulation (lax.scan), remat,
bf16 params / fp32 AdamW master state, optional int8 gradient compression
bracketing the cross-pod all-reduce."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.model import LM
from repro.training.optimizer import (AdamWConfig, OptState, apply_adamw,
                                      compressed_grads_with_ef,
                                      init_opt_state)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    microbatches: int = 1           # grad-accumulation steps per train step
    grad_compression: bool = False  # int8 + error feedback


def _split_microbatches(batch: Dict[str, Any], n: int) -> Dict[str, Any]:
    """(B, ...) -> (n, B/n, ...)."""
    def sp(t):
        b = t.shape[0]
        assert b % n == 0, (b, n)
        return t.reshape((n, b // n) + t.shape[1:])
    return jax.tree.map(sp, batch)


def loss_and_grads(model: LM, params, batch, microbatches: int = 1):
    """Mean loss + grads, accumulated over microbatches via lax.scan."""
    def lfn(p, mb):
        loss, metrics = model.train_loss(p, mb)
        return loss, metrics

    if microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(lfn, has_aux=True)(
            params, batch)
        return loss, grads, metrics

    mbs = _split_microbatches(batch, microbatches)

    def body(carry, mb):
        acc, loss_acc = carry
        (loss, metrics), g = jax.value_and_grad(lfn, has_aux=True)(params, mb)
        acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), acc, g)
        return (acc, loss_acc + loss), metrics

    zeros = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), params)
    (gsum, loss_sum), metrics = jax.lax.scan(body, (zeros, 0.0), mbs)
    grads = jax.tree.map(lambda t: t / microbatches, gsum)
    last_metrics = jax.tree.map(lambda t: t[-1], metrics)
    return loss_sum / microbatches, grads, last_metrics


def make_train_step(model: LM, cfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).
    Pure; jit it with in_shardings from the model's param specs."""

    def train_step(params, opt_state: OptState, batch):
        loss, grads, metrics = loss_and_grads(model, params, batch,
                                              cfg.microbatches)
        if cfg.grad_compression and opt_state.ef is not None:
            grads, new_ef = compressed_grads_with_ef(grads, opt_state.ef)
            opt_state = opt_state._replace(ef=new_ef)
        new_params, new_opt, od = apply_adamw(cfg.adamw, grads, opt_state,
                                              params)
        metrics = dict(metrics)
        metrics.update(od)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def init_train_state(model: LM, key, cfg: TrainConfig):
    params = model.init(key)
    opt = init_opt_state(params, compression=cfg.grad_compression)
    return params, opt
