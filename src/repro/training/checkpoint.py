"""Sharded checkpointing with elastic re-sharding (no external deps).

Layout: <dir>/step_<N>/
    manifest.json            — tree structure, shapes, dtypes, step
    arrays/<leaf_id>.npy     — one file per leaf (per-host shard files in a
                               multi-host deployment; single host here)

Restart-stability: save is atomic (tmp dir + rename); ``latest_step`` scans
complete checkpoints only. ``load`` re-shards onto whatever mesh/policy the
new job uses — leaves are stored unsharded-logical, so loading a 512-chip
checkpoint on 256 chips (elastic scale-down) just changes in_shardings."""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None
         ) -> str:
    flat, _ = _flatten_with_paths(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (key, leaf) in enumerate(flat):
        dtype = str(jnp.asarray(leaf).dtype)
        if dtype == "bfloat16":       # numpy has no bf16: store fp32
            arr = np.asarray(jnp.asarray(leaf).astype(jnp.float32))
        else:
            arr = np.asarray(leaf)
        np.save(os.path.join(tmp, "arrays", f"{i}.npy"), arr)
        manifest["leaves"].append(
            {"key": key, "file": f"{i}.npy", "shape": list(arr.shape),
             "dtype": dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") \
                and os.path.exists(os.path.join(ckpt_dir, d,
                                                "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def load(ckpt_dir: str, step: int, like: Any,
         shardings: Any = None) -> Tuple[Any, Dict]:
    """Load into the structure of ``like`` (tree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching tree of NamedSharding
    for elastic re-sharding via jax.device_put."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = _flatten_with_paths(like)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in _flatten_with_paths(shardings)[0]]
    leaves = []
    for i, (key, leaf) in enumerate(flat_like):
        meta = by_key[key]
        arr = np.load(os.path.join(path, "arrays", meta["file"]))
        want_dtype = getattr(leaf, "dtype", None) or meta["dtype"]
        out = jnp.asarray(arr).astype(want_dtype)
        if shard_flat is not None and shard_flat[i] is not None:
            out = jax.device_put(out, shard_flat[i])
        leaves.append(out)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
    return tree, manifest.get("extra", {})
