"""Training substrate: AdamW + accumulation, sharded checkpointing with
elastic re-sharding, deterministic data pipeline, int8 grad compression."""
from repro.training.checkpoint import latest_step, load, save        # noqa: F401
from repro.training.data import DataConfig, batch_at_step, data_iterator  # noqa: F401
from repro.training.optimizer import (AdamWConfig, OptState,          # noqa: F401
                                      apply_adamw, init_opt_state)
from repro.training.train_step import (TrainConfig, init_train_state,  # noqa: F401
                                       loss_and_grads, make_train_step)
