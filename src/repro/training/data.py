"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step) — a restart at step N reproduces
the exact stream (checkpoint/restart stability), and each host can generate
its own shard without coordination (host-sharded loading at scale)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    family: str = "dense"         # audio -> embeds, vlm -> tokens+frontend
    d_model: int = 0
    n_frontend_tokens: int = 0


def batch_at_step(cfg: DataConfig, step: int) -> Dict[str, jnp.ndarray]:
    rng = np.random.default_rng((cfg.seed << 20) ^ step)
    # zipf-ish token stream with some structure (repeated n-grams) so the
    # model has something to learn in the examples
    base = rng.zipf(1.3, size=(cfg.global_batch, cfg.seq_len + 1))
    toks = (base % (cfg.vocab - 2)) + 2
    out: Dict[str, jnp.ndarray] = {}
    labels = toks[:, 1:]
    if cfg.family == "audio":
        emb = rng.standard_normal((cfg.global_batch, cfg.seq_len,
                                   cfg.d_model)).astype(np.float32)
        out["embeds"] = jnp.asarray(emb)
    else:
        out["tokens"] = jnp.asarray(toks[:, :-1])
    out["labels"] = jnp.asarray(labels)
    if cfg.family == "vlm":
        fe = rng.standard_normal((cfg.global_batch, cfg.n_frontend_tokens,
                                  cfg.d_model)).astype(np.float32)
        out["frontend"] = jnp.asarray(fe)
    return out


def data_iterator(cfg: DataConfig, start_step: int = 0
                  ) -> Iterator[Dict[str, jnp.ndarray]]:
    step = start_step
    while True:
        yield batch_at_step(cfg, step)
        step += 1
