"""AdamW with fp32 master state over bf16 params (pure JAX, no optax dep),
global-norm clipping, cosine schedule, and optional int8 gradient compression
with error feedback for the cross-pod all-reduce."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any            # fp32 first moment
    nu: Any            # fp32 second moment
    master: Any        # fp32 master params
    ef: Optional[Any] = None   # error-feedback residual (compression)


def schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params, compression: bool = False) -> OptState:
    f32 = lambda t: jnp.zeros(t.shape, jnp.float32)
    master = jax.tree.map(lambda t: t.astype(jnp.float32), params)
    ef = jax.tree.map(f32, params) if compression else None
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(f32, params),
                    nu=jax.tree.map(f32, params), master=master, ef=ef)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(t.astype(jnp.float32)))
                        for t in jax.tree.leaves(tree)))


def apply_adamw(cfg: AdamWConfig, grads, state: OptState, params
                ) -> Tuple[Any, OptState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, m):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mh = mu / b1c
        nh = nu / b2c
        decay = cfg.weight_decay if m.ndim >= 2 else 0.0
        m_new = m - lr * (mh / (jnp.sqrt(nh) + cfg.eps) + decay * m)
        return m_new, mu, nu

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    flat_m = treedef.flatten_up_to(state.master)
    outs = [upd(g, mu, nu, m)
            for g, mu, nu, m in zip(flat_g, flat_mu, flat_nu, flat_m)]
    new_master = treedef.unflatten([o[0] for o in outs])
    new_mu = treedef.unflatten([o[1] for o in outs])
    new_nu = treedef.unflatten([o[2] for o in outs])
    flat_p = treedef.flatten_up_to(params)
    new_params = treedef.unflatten(
        [m.astype(p.dtype) for m, p in zip([o[0] for o in outs], flat_p)])
    new_state = OptState(step=step, mu=new_mu, nu=new_nu, master=new_master,
                         ef=state.ef)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback (cross-pod all-reduce trick)
# ---------------------------------------------------------------------------
def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_grads_with_ef(grads, ef):
    """Apply error feedback: quantize (g + residual), return dequantized grads
    plus the new residual. In production the int8 payload is what crosses the
    pod-level DCN all-reduce; here compression/decompression brackets it."""
    def one(g, e):
        tot = g.astype(jnp.float32) + e
        q, s = compress_int8(tot)
        deq = decompress_int8(q, s)
        return deq, tot - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
