"""HLO text analysis: collective-byte accounting with while-loop trip-count
correction (cost_analysis does not expose collective traffic; scan bodies
appear once in the HLO but execute trip-count times).

Parses ``compiled.as_text()``:
  1. split the module into named computations;
  2. find every collective op (all-reduce / all-gather / reduce-scatter /
     all-to-all / collective-permute, sync or async-start) and the byte size
     of its result shape(s);
  3. build the call graph; computations reached through a ``while`` op have
     their collective bytes multiplied by the loop trip count (from the
     canonical scan condition `compare(iter, C), direction=LT`).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_RE = re.compile(
    r"=\s*(.*?)\s*(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DOT_RE = re.compile(
    r"=\s*\w+\[([\d,]*)\][^=]*?\bdot\(.*?lhs_contracting_dims={([\d,]*)}")
_DOT_LHS_RE = re.compile(r"dot\(\s*%?[\w\.\-]+\s*,")
_CONV_RE = re.compile(r"=\s*\w+\[([\d,]*)\][^=]*?\bconvolution\(")


def shape_bytes(shape_str: str) -> int:
    """Total bytes over every shape in the string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    for line in hlo.splitlines():
        s = line.strip()
        if s.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", s)
            if m:
                return m.group(1)
    return None


def _trip_count(while_line: str, comps: Dict[str, List[str]]) -> int:
    m = re.search(r'"known_trip_count":\s*{"n":\s*"?(\d+)"?}', while_line)
    if m:
        return int(m.group(1))
    m = re.search(r"trip_count=(\d+)", while_line)
    if m:
        return int(m.group(1))
    m = re.search(r"condition=%?([\w\.\-]+)", while_line)
    if m and m.group(1) in comps:
        consts = []
        for line in comps[m.group(1)]:
            for mc in re.finditer(r"constant\((\d+)\)", line):
                consts.append(int(mc.group(1)))
        if consts:
            return max(consts)
    return 1


def _dot_flops(line: str) -> float:
    """2 * prod(result dims) * prod(contracted dims) for a dot; the
    contracted sizes are read from the lhs operand shape named in the line
    (operand shapes are embedded in scheduled HLO as %name = shape earlier,
    so fall back to result*contract heuristics via the lhs shape literal if
    present on the line)."""
    m = re.search(r"=\s*\w+\[([\d,]*)\]\S*\s+dot\(", line)
    if not m:
        return 0.0
    res_dims = [int(d) for d in m.group(1).split(",") if d]
    out = 1.0
    for d in res_dims:
        out *= d
    # contracted size: find lhs shape within the line (operands usually carry
    # inline shapes in verbose HLO; in scheduled HLO they don't, so use the
    # contracting dim sizes from metadata if present)
    mc = re.search(r"lhs_contracting_dims={([\d,]*)}", line)
    lhs_shape = re.search(r"dot\(\s*%?[\w\.\-]+\s*=?\s*\w*\[([\d,]*)\]", line)
    contract = 0.0
    if mc and lhs_shape:
        dims = [int(d) for d in lhs_shape.group(1).split(",") if d]
        idx = [int(i) for i in mc.group(1).split(",") if i]
        contract = 1.0
        for i in idx:
            if i < len(dims):
                contract *= dims[i]
    return 2.0 * out * max(contract, 1.0)


def analyze_hlo(hlo: str, operand_shapes: Optional[Dict[str, str]] = None
                ) -> Dict:
    comps = _split_computations(hlo)
    # operand shape table: %name = type[...] anywhere in the module
    shape_of: Dict[str, str] = {}
    for mm in re.finditer(r"%([\w\.\-]+)\s*=\s*(\([^=]*?\)|\w+\[[\d,]*\])",
                          hlo):
        shape_of[mm.group(1)] = mm.group(2)
    coll_by_comp: Dict[str, Dict[str, float]] = {}
    count_by_comp: Dict[str, int] = {}
    flops_by_comp: Dict[str, float] = {}
    bytes_by_comp: Dict[str, float] = {}
    _free_ops = ("parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "after-all", "partition-id", "replica-id",
                 "iota")
    # ops whose operands are indexed, not streamed: count result side only
    _result_only = ("dynamic-slice", "gather", "dynamic-update-slice",
                    "scatter", "while", "conditional", "call")
    for name, lines in comps.items():
        d: Dict[str, float] = {}
        c = 0
        fl = 0.0
        byt = 0.0
        for line in lines:
            # post-fusion HBM traffic: result + operand bytes per instruction
            im = re.match(r"(?:ROOT\s+)?%[\w\.\-]+\s*=\s*"
                          r"(\([^=]*?\)|\w+\[[\d,]*\]\S*)\s+([\w\-]+)\(",
                          line)
            if im and im.group(2) not in _free_ops:
                op_bytes = shape_bytes(im.group(1))
                if im.group(2) not in _result_only:
                    args = line.split("(", 1)[1].split(")", 1)[0]
                    for om in re.finditer(r"%([\w\.\-]+)", args):
                        op_bytes += shape_bytes(shape_of.get(om.group(1), ""))
                elif im.group(2) == "dynamic-update-slice":
                    # in-place: traffic ~= 2x the update operand
                    ops_ = re.findall(r"%([\w\.\-]+)",
                                      line.split("(", 1)[1])
                    if len(ops_) >= 2:
                        op_bytes = 2 * shape_bytes(shape_of.get(ops_[1], ""))
                byt += op_bytes
            m = COLLECTIVE_RE.search(line)
            if m:
                op = m.group(2)
                b = shape_bytes(m.group(1))
                if op == "all-reduce":
                    b *= 2.0            # ring: reduce-scatter + all-gather
                elif op == "reduce-scatter":
                    # traffic ~= input size; result is the 1/n shard
                    om = re.search(r"reduce-scatter\(\s*%([\w\.\-]+)", line)
                    if om:
                        b = shape_bytes(shape_of.get(om.group(1), "")) or b
                d[op] = d.get(op, 0.0) + b
                c += 1
            # operands may carry inline shapes (jax>=0.4.3x verbose HLO):
            #   dot(f32[8,4096]{1,0} %call.20, ...) — prefer the inline lhs
            # shape, fall back to the module-wide %name -> shape table
            dm = re.search(r"=\s*\w+\[([\d,]*)\]\S*\s+dot\("
                           r"\s*(?:(\w+\[[\d,]*\])\S*\s+)?%([\w\.\-]+)",
                           line)
            if dm:
                res_dims = [int(x) for x in dm.group(1).split(",") if x]
                out = 1.0
                for x in res_dims:
                    out *= x
                mc = re.search(r"lhs_contracting_dims={([\d,]*)}", line)
                contract = 1.0
                lhs = dm.group(2) or shape_of.get(dm.group(3), "")
                ls = _SHAPE_RE.search(lhs)
                if mc and ls:
                    dims = [int(x) for x in ls.group(2).split(",") if x]
                    for i in [int(i) for i in mc.group(1).split(",") if i]:
                        if i < len(dims):
                            contract *= dims[i]
                fl += 2.0 * out * contract
            cm = _CONV_RE.search(line)
            if cm:
                res_dims = [int(x) for x in cm.group(1).split(",") if x]
                out = 1.0
                for x in res_dims:
                    out *= x
                km = re.search(r"window={size=([\dx]+)", line)
                ksz = 1.0
                if km:
                    for x in km.group(1).split("x"):
                        ksz *= int(x)
                fl += 2.0 * out * ksz
        coll_by_comp[name] = d
        count_by_comp[name] = c
        flops_by_comp[name] = fl
        bytes_by_comp[name] = byt

    entry = _entry_name(hlo)
    mult: Dict[str, float] = {name: 0.0 for name in comps}

    def visit(name: str, m: float, depth: int = 0) -> None:
        if name not in comps or depth > 16:
            return
        mult[name] = mult.get(name, 0.0) + m
        for line in comps[name]:
            if re.search(r"=\s*\S.*\s+while\(", line):
                tc = _trip_count(line, comps)
                for role in ("body", "condition"):
                    rm = re.search(role + r"=%?([\w\.\-]+)", line)
                    if rm:
                        visit(rm.group(1), m * (tc if role == "body" else 1),
                              depth + 1)
                continue
            for cm in re.finditer(
                    r"(?:to_apply|calls)=%?([\w\.\-]+)", line):
                visit(cm.group(1), m, depth + 1)
            bm = re.search(r"branch_computations={([^}]*)}", line)
            if bm:
                for callee in bm.group(1).split(","):
                    visit(callee.strip().lstrip("%"), m, depth + 1)

    if entry:
        visit(entry, 1.0)
    total: Dict[str, float] = {}
    n_ops = 0.0
    dot_flops = 0.0
    hbm_bytes = 0.0
    # fused computations are bodies of fusion ops; their internals are VMEM,
    # not HBM traffic — exclude them from the byte accounting (the fusion op
    # itself, in its caller, carries the operand/result traffic).
    for name, d in coll_by_comp.items():
        m = mult.get(name, 0.0)
        if m == 0.0 and (d or flops_by_comp[name]):
            m = 1.0          # conservatively count unreached computations once
        n_ops += count_by_comp[name] * m
        dot_flops += flops_by_comp[name] * m
        if not name.startswith(("fused_computation", "wrapped_", "region_")):
            hbm_bytes += bytes_by_comp[name] * m
        for k, v in d.items():
            total[k] = total.get(k, 0.0) + v * m
    return {"collectives": {k: float(v) for k, v in total.items()},
            "total_collective_bytes": float(sum(total.values())),
            "collective_op_executions": float(n_ops),
            "dot_flops": float(dot_flops),
            "hbm_bytes": float(hbm_bytes),
            "computations": len(comps)}
