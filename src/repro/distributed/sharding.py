"""Logical-axis sharding policy.

Models annotate activations/params with *logical* axis names
("batch", "seq_q", "heads", "d_ff", ...). A ``Policy`` maps logical names to
mesh axes; changing the mapping (one dict) re-shards the whole model — this is
the lever the §Perf hillclimbing turns.

Default mappings per (arch, shape) are chosen by ``make_policy``:

  * train/prefill attention:  "heads" -> model  if n_heads % model_size == 0
                              else sequence-parallel ("seq_q" -> model)
  * decode:                   KV cache sequence-sharded ("kv_seq" -> model,
                              + "data" too when batch == 1), which gives
                              flash-decoding combines via GSPMD partial
                              softmax reductions — no head-divisibility
                              constraint, and the 500k cache fits.
  * params:                   "tp": TP dims over model, replicated over data
                              "fsdp": + largest non-TP dim over data
  * MoE:                      "experts" -> model (expert parallelism)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisMap = dict[str, Tuple[str, ...]]


def _divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


@dataclasses.dataclass
class Policy:
    mesh: Optional[Mesh] = None
    rules: AxisMap = dataclasses.field(default_factory=dict)
    params_mode: str = "tp"          # "tp" | "fsdp"
    # informational knobs read by model code
    attn_mode: str = "heads"         # "heads" | "seq"
    moe_impl: str = "auto"

    # -- resolution ----------------------------------------------------------
    def spec(self, logical: Sequence[Optional[str]]) -> P:
        if self.mesh is None:
            return P()
        used: set = set()
        out = []
        for name in logical:
            axes = self.rules.get(name, ()) if name else ()
            axes = tuple(a for a in axes if a not in used
                         and a in self.mesh.axis_names)
            used.update(axes)
            if len(axes) == 0:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
            else:
                out.append(tuple(axes))
        return P(*out)

    def spec_for_shape(self, logical: Sequence[Optional[str]],
                       shape: Sequence[int]) -> P:
        """Like spec(), but drops axes that do not divide the dim size —
        required for jit in_shardings (which, unlike constraints, rejects
        uneven sharding)."""
        if self.mesh is None:
            return P()
        used: set = set()
        out = []
        for name, dim in zip(logical, shape):
            axes = self.rules.get(name, ()) if name else ()
            axes = tuple(a for a in axes if a not in used
                         and a in self.mesh.axis_names)
            # longest prefix of the axis tuple that divides the dim (e.g.
            # batch 256 over (pod, data, model)=512 falls back to
            # (pod, data)=32)
            while axes:
                size = int(np.prod([self.mesh.shape[a] for a in axes]))
                if size > 0 and dim % size == 0:
                    break
                axes = axes[:-1]
            if axes:
                used.update(axes)
                out.append(axes[0] if len(axes) == 1 else tuple(axes))
            else:
                out.append(None)
        return P(*out)

    def constrain(self, x, logical: Sequence[Optional[str]]):
        """with_sharding_constraint if a mesh is active, else no-op."""
        if self.mesh is None or x is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(logical)))

    def sharding(self, logical: Sequence[Optional[str]]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical))

    def axis_size(self, name: str) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a]
                            for a in self.rules.get(name, ())
                            if a in self.mesh.axis_names] or [1]))

    @property
    def model_size(self) -> int:
        return self.axis_size("heads") or 1


NO_POLICY = Policy()


def make_policy(arch, shape, mesh: Optional[Mesh], *,
                params_mode: Optional[str] = None,
                attn_mode: Optional[str] = None,
                decode_kv: Optional[str] = None,
                mlp_mode: str = "tp",
                train_mode: Optional[str] = None) -> Policy:
    """Default sharding policy for an (arch x shape) cell on ``mesh``.

    mesh axes: ("data", "model") or ("pod", "data", "model").
    """
    if mesh is None:
        return Policy()
    axis_names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    if shape is not None and shape.global_batch == 1:
        batch_axes = ()            # batch==1: leave batch unsharded, give the
                                   # data/pod axes to the KV sequence instead
    model_ax = ("model",) if "model" in axis_names else ()
    msize = mesh.shape["model"] if "model" in axis_names else 1
    kind = shape.kind if shape is not None else "train"

    # attention sharding: TP over query heads by default (GSPMD pads uneven
    # head counts, e.g. 40 over 16; the padding waste shows up honestly in
    # the roofline useful-ratio). KV heads are sharded only when they divide
    # the axis — otherwise replicated, which keeps the flash chunk scan local
    # (slicing a sharded KV re-gathers it per chunk) and keeps KV-grad
    # all-reduces small for GQA. "seq" remains as an experimental override.
    if attn_mode is None:
        attn_mode = "heads" if arch.n_heads else "seq"
    # params: training always wants FSDP (optimizer state!); decode of very
    # large models too (weights gathered layer-by-layer inside scan).
    if params_mode is None:
        big = arch.param_count() * 2 > 12e9 * (mesh.shape.get("data", 1))
        params_mode = "fsdp" if (kind == "train" or big) else "tp"
    if decode_kv is None:
        # batch==1 long-context: spread KV over every axis we have
        decode_kv = "all" if (shape is not None and shape.global_batch == 1) \
            else "model"

    rules: AxisMap = {
        "batch": batch_axes,
        "d_ff": model_ax,
        "d_inner": model_ax,           # mamba heads/channels
        "ssm_heads": model_ax,
        "experts": model_ax,
        "vocab": model_ax,
        "embed": (),                   # activations' d_model stays unsharded
        "kv_heads": model_ax if (attn_mode == "heads"
                                 and _divides(arch.n_kv_heads, msize)) else (),
        "heads": model_ax if attn_mode == "heads" else (),
        "seq_q": model_ax if attn_mode == "seq" else (),
        "kv_seq": (batch_axes + model_ax) if decode_kv == "all" else model_ax,
        "frontend_seq": model_ax,
        # param-only logical dims
        "p_tp": model_ax,              # tensor-parallel weight dim
        "p_embed_in": (),              # contracting dims of weights
        "p_fsdp": batch_axes if params_mode == "fsdp" else (),
        "p_layers": (),
    }
    if mlp_mode == "sp":
        rules["d_ff"] = ()
    # Training of non-MoE archs: pure ZeRO-3/FSDP — batch over EVERY mesh
    # axis (1 seq/chip on 16x16), weights fully sharded and gathered
    # layer-by-layer inside the scan, NO tensor parallelism. Kills the
    # per-layer activation all-reduces that dominated the TP-train baseline
    # (90B: 4.8 TB -> weight-gather-only traffic). MoE training keeps the
    # model axis for expert parallelism.  [§Perf iteration 3]
    if train_mode is None:
        train_mode = "fsdp_pure" if (kind == "train"
                                     and arch.moe is None) else "tp"
    if train_mode == "fsdp_pure" and kind == "train":
        # batch axes ordered so the divisibility prefix-fallback lands on
        # 256-way sharding (1 seq/chip) on BOTH meshes: on 2x16x16 the pod
        # axis falls off the batch (grads still reduce over it via the
        # pod-sharded weights) — this avoids grad-accumulation microbatching,
        # which would re-gather all ZeRO-3 weights once per microbatch.
        # [§Perf iterations 3/6]
        data_first = tuple(a for a in ("data",) if a in axis_names)
        pod = tuple(a for a in ("pod",) if a in axis_names)
        rules.update({
            "batch": data_first + model_ax + pod,
            "p_fsdp": pod + data_first + model_ax,
            "p_tp": (),
            "d_ff": (), "d_inner": (), "ssm_heads": (),
            "heads": (), "kv_heads": (), "seq_q": (), "vocab": (),
        })
        attn_mode = "data"
    return Policy(mesh=mesh, rules=rules, params_mode=params_mode,
                  attn_mode=attn_mode)
