"""jit'd public wrapper: picks the Pallas kernel on TPU, the memory-bounded
jnp reference elsewhere (CPU dry-run / tests use ref or interpret mode)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import (     # noqa: F401 (re-export)
    attention_dense_ref, flash_attention_ref)


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:                                  # pragma: no cover
        return False


def flash_attention(q, k, v, *, causal: bool = True, q_offset=0,
                    kv_len: Optional[jnp.ndarray] = None,
                    scale: Optional[float] = None,
                    kv_chunk: int = 256,
                    use_pallas: Optional[bool] = None,
                    interpret: bool = False) -> jnp.ndarray:
    """Causal (or cross) batched GQA attention. See ref.py for semantics."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas and kv_len is None and isinstance(q_offset, int):
        return flash_attention_pallas(q, k, v, scale=scale, causal=causal,
                                      q_offset=q_offset, interpret=interpret)
    return flash_attention_ref(q, k, v, causal=causal, q_offset=q_offset,
                               kv_len=kv_len, scale=scale, kv_chunk=kv_chunk)


__all__ = ["flash_attention", "flash_attention_pallas", "flash_attention_ref",
           "attention_dense_ref"]
