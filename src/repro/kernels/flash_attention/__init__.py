from repro.kernels.flash_attention.ops import (                      # noqa: F401
    attention_dense_ref, flash_attention, flash_attention_pallas,
    flash_attention_ref)
