"""Pallas TPU flash-attention kernel (prefill/train hot-spot).

TPU-native adaptation: explicit VMEM tiling via BlockSpec, MXU-aligned
(block_q x head_dim) @ (head_dim x block_k) matmuls, fp32 running-softmax
carried in VMEM scratch across the innermost (KV) grid dimension. Causal
masking is applied per-tile and fully-masked tiles short-circuit via
``pl.when`` (the tile is still scheduled; the MXU work is skipped).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — KV is the minormost
dimension so the (m, l, acc) scratch carries across it, matching the
multiple-visit accumulation pattern from the Pallas TPU docs. GQA is handled
in the K/V index_maps (each q head reads its kv head; no HBM replication).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_STAT_LANES = 128   # fp32 VMEM lane width for the m/l statistics tiles


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  q_offset: int, num_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + q_offset
    k_start = ki * block_k
    live = jnp.bool_(True) if not causal else (q_start + block_q - 1 >= k_start)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (block_k, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[:, :1]                        # lanes hold equal values
        l_prev = l_scr[:, :1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                       # (block_q, block_k)
        alpha = jnp.exp(m_prev - m_new)              # (block_q, 1)
        l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[:, :1], 1e-37)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, scale: Optional[float] = None,
                           causal: bool = True, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D). Returns (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    nq, nk = sq // block_q, skv // block_k

    qT = q.swapaxes(1, 2)        # (B, H, S, D): clean 2D VMEM tiles
    kT = k.swapaxes(1, 2)
    vT = v.swapaxes(1, 2)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, q_offset=q_offset, num_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, h, qi, ki: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, h, qi, ki, g=group: (bi, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, h, qi, ki, g=group: (bi, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, h, qi, ki: (bi, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _STAT_LANES), jnp.float32),
            pltpu.VMEM((block_q, _STAT_LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qT, kT, vT)
    return out.swapaxes(1, 2)
