"""Pure-jnp oracle for causal/cross flash attention with GQA.

This is also the *production dry-run path*: it is memory-bounded (lax.scan over
KV chunks with a running-softmax carry), so 32k-token prefill never
materializes an (Sq, Skv) score matrix, and it is written in purely *logical*
terms so GSPMD can shard Sq over the `model` mesh axis (sequence-parallel
prefill) regardless of head-count divisibility.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D)."""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d)) \
        .reshape(b, s, h * n_rep, d)


def attention_dense_ref(q, k, v, *, causal: bool = True,
                        q_offset: int = 0,
                        kv_len: Optional[jnp.ndarray] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """O(Sq*Skv)-memory reference. Ground truth for both the pallas kernel and
    the chunked implementation below.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); Hq % Hkv == 0.
    q_offset: global position of q[0] (for chunked prefill / decode).
    kv_len: optional (B,) valid KV lengths.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    scale = scale if scale is not None else d ** -0.5
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((b, 1, sq, skv), dtype=bool)
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(skv)[None, :]
        mask &= (qpos >= kpos)[None, None]
    if kv_len is not None:
        mask &= (jnp.arange(skv)[None, :] < kv_len[:, None])[:, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "kv_chunk", "scale_none"))
def _flash_chunked(q, k, v, q_offset, kv_len, scale, *, causal, kv_chunk,
                   scale_none):
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    n_rep = hq // hkv
    if scale_none:
        scale = d ** -0.5
    n_chunks = skv // kv_chunk
    qpos = jnp.arange(sq)[:, None] + q_offset  # (Sq, 1) global positions

    def body(carry, inputs):
        m, l, acc = carry
        kc, vc, k0 = inputs          # kc: (B, Ckv, Hkv, D); k0: chunk start
        kc = _repeat_kv(kc, n_rep)
        vc = _repeat_kv(vc, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                       preferred_element_type=jnp.float32) * scale
        kpos = k0 + jnp.arange(kv_chunk)[None, :]
        mask = jnp.ones((b, 1, sq, kv_chunk), dtype=bool)
        if causal:
            mask &= (qpos >= kpos)[None, None]
        if kv_len is not None:
            mask &= (kpos[None] < kv_len[:, None, None])[:, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, hq, sq), dtype=jnp.float32)
    acc0 = jnp.zeros((b, hq, sq, d), dtype=jnp.float32)
    ks = k.reshape(b, n_chunks, kv_chunk, hkv, d).swapaxes(0, 1)
    vs = v.reshape(b, n_chunks, kv_chunk, hkv, d).swapaxes(0, 1)
    k0s = jnp.arange(n_chunks) * kv_chunk
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (ks, vs, k0s))
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)   # (B, Sq, Hq, D)


def flash_attention_ref(q, k, v, *, causal: bool = True, q_offset=0,
                        kv_len: Optional[jnp.ndarray] = None,
                        scale: Optional[float] = None,
                        kv_chunk: int = 256) -> jnp.ndarray:
    """Memory-bounded flash attention (chunked over KV via lax.scan)."""
    skv = k.shape[1]
    kv_chunk = min(kv_chunk, skv)
    if skv % kv_chunk:                       # fall back for ragged chunking
        return attention_dense_ref(q, k, v, causal=causal, q_offset=q_offset,
                                   kv_len=kv_len, scale=scale)
    q_offset = jnp.asarray(q_offset)
    return _flash_chunked(q, k, v, q_offset, kv_len,
                          jnp.float32(scale if scale is not None else 0.0),
                          causal=causal, kv_chunk=kv_chunk,
                          scale_none=scale is None)
