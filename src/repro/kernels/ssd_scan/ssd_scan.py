"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU-native adaptation of the SSD algorithm (arXiv:2405.21060): the
within-chunk quadratic term is a pair of (Q x N)/(Q x Q) MXU matmuls per
chunk, and the cross-chunk recurrence is carried in a (P, N) fp32 VMEM
scratch across the minormost grid dimension (chunks) — no HBM round-trip for
the state between chunks. All decay factors are <= 1 (A < 0), so the kernel
is overflow-safe without log-space gymnastics.

Grid: (batch, heads, n_chunks).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, init_ref,
                y_ref, fin_ref, state_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = init_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0].astype(jnp.float32)              # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)[:, None]   # (Q, 1)
    a = a_ref[0, 0]                                     # scalar (negative)
    bm = b_ref[0, :, 0].astype(jnp.float32)             # (Q, N)
    cm = c_ref[0, :, 0].astype(jnp.float32)             # (Q, N)
    d = d_ref[0, 0]

    dA = dt * a                                         # (Q, 1) log-decay
    cum = jnp.cumsum(dA, axis=0)                        # (Q, 1)
    # L[i, j] = exp(sum_{k=j+1..i} dA_k), lower-triangular
    seg = cum - cum.T                                   # (Q, Q)
    tril = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tril, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * L
    y_intra = jax.lax.dot(scores, dt * x,
                          preferred_element_type=jnp.float32)     # (Q, P)

    state = state_scr[...]                              # (P, N)
    y_inter = jax.lax.dot_general(cm * jnp.exp(cum), state,
                                  (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    decay_end = jnp.exp(cum[-1:] - cum)                 # (Q, 1), <= 1
    contrib = jax.lax.dot_general(x, bm * (decay_end * dt),
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(cum[-1, 0]) + contrib

    y_ref[0, :, 0] = (y_intra + y_inter + d * x).astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _fin():
        fin_ref[0, 0] = state_scr[...].astype(fin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, Bm, Cm, D,
                    init_state: Optional[jnp.ndarray] = None,
                    *, chunk: int = 64,
                    interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,H,P); dt: (B,S,H); A,D: (H,); Bm,Cm: (B,S,G,N).
    Returns (y: (B,S,H,P), final_state: (B,H,P,N))."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    A2 = A.reshape(h, 1)
    D2 = D.reshape(h, 1)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, fin = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci, r=rep: (bi, ci, hi // r, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci, r=rep: (bi, ci, hi // r, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A2, Bm, Cm, D2, init_state)
    return y, fin
