from repro.kernels.ssd_scan.ops import (                             # noqa: F401
    ssd_chunked_ref, ssd_decode_step, ssd_ref, ssd_scan, ssd_scan_pallas)
