"""Public SSD ops: backend dispatch + single-token recurrent step."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ref import ssd_chunked_ref, ssd_ref, _expand_groups
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:                                  # pragma: no cover
        return False


def ssd_scan(x, dt, A, Bm, Cm, D, init_state=None, *, chunk: int = 64,
             use_pallas: Optional[bool] = None,
             interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence SSD (prefill/train). See ref.py for shapes."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    chunk = min(chunk, x.shape[1])
    if x.shape[1] % chunk:
        return ssd_ref(x, dt, A, Bm, Cm, D, init_state)
    if use_pallas:
        return ssd_scan_pallas(x, dt, A, Bm, Cm, D, init_state, chunk=chunk,
                               interpret=interpret)
    return ssd_chunked_ref(x, dt, A, Bm, Cm, D, init_state, chunk=chunk)


def ssd_decode_step(state, x, dt, A, Bm, Cm, D
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token recurrence. state: (B,H,P,N); x: (B,H,P); dt: (B,H);
    Bm/Cm: (B,G,N). Returns (y: (B,H,P), new_state)."""
    h = x.shape[1]
    Bh = _expand_groups(Bm[:, None], h)[:, 0]          # (B,H,N)
    Ch = _expand_groups(Cm[:, None], h)[:, 0]
    dA = jnp.exp(dt * A)                               # (B,H)
    dBx = (dt[..., None, None] * x[..., None]) * Bh[:, :, None, :]
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch) + x * D[None, :, None]
    return y.astype(x.dtype), new_state


__all__ = ["ssd_scan", "ssd_scan_pallas", "ssd_ref", "ssd_chunked_ref",
           "ssd_decode_step"]
