"""Mamba-2 SSD oracles.

``ssd_ref``          — sequential recurrence over time (ground truth).
``ssd_chunked_ref``  — the SSD block-decomposition (state-space duality,
                       arXiv:2405.21060 §6) in pure jnp: quadratic *within*
                       chunks (MXU-friendly), linear recurrence *across*
                       chunks. This is the production dry-run path.
``ssd_step``         — single-token recurrent update (decode path).

Shapes (multi-head SSD, ngroups shared B/C like GQA):
  x:  (B, S, H, P)      dt: (B, S, H)      A: (H,) (negative)
  Bm: (B, S, G, N)      Cm: (B, S, G, N)   D: (H,)
  state: (B, H, P, N)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _expand_groups(m: jnp.ndarray, h: int) -> jnp.ndarray:
    """(B, S, G, N) -> (B, S, H, N)."""
    b, s, g, n = m.shape
    rep = h // g
    return jnp.broadcast_to(m[:, :, :, None, :], (b, s, g, rep, n)) \
        .reshape(b, s, h, n)


def ssd_ref(x, dt, A, Bm, Cm, D,
            init_state: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential oracle: y_t = C_t . h_t + D*x_t with
    h_t = exp(dt_t A) h_{t-1} + dt_t * B_t (x) x_t."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    Bh = _expand_groups(Bm, h)
    Ch = _expand_groups(Cm, h)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp                         # (B,H,P), (B,H), (B,H,N)
        dA = jnp.exp(dtt * A)                         # (B,H)
        dBx = (dtt[..., None, None] * xt[..., None]) * bt[:, :, None, :]
        state = state * dA[..., None, None] + dBx     # (B,H,P,N)
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    xs = (x.swapaxes(0, 1), dt.swapaxes(0, 1),
          Bh.swapaxes(0, 1), Ch.swapaxes(0, 1))
    final, ys = jax.lax.scan(step, init_state, xs)
    y = ys.swapaxes(0, 1) + x * D[None, None, :, None]
    return y.astype(x.dtype), final


def _segsum(t: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} t[..., k]
    (NEG_INF-free lower-triangular log-decay matrix)."""
    s = t.shape[-1]
    cum = jnp.cumsum(t, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]      # sum_{k=j+1..i}
    mask = jnp.tril(jnp.ones((s, s), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked_ref(x, dt, A, Bm, Cm, D,
                    init_state: Optional[jnp.ndarray] = None,
                    chunk: int = 64) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD block decomposition. S % chunk == 0."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    Bh = _expand_groups(Bm, h).astype(jnp.float32)
    Ch = _expand_groups(Cm, h).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    # chunked views: (B, C, Q, H, ...)
    xc = xf.reshape(b, c, chunk, h, p)
    dtc = dt.reshape(b, c, chunk, h).astype(jnp.float32)
    Bc = Bh.reshape(b, c, chunk, h, n)
    Cc = Ch.reshape(b, c, chunk, h, n)

    dA = dtc * A                                      # (B,C,Q,H) log-decay
    dA_cum = jnp.cumsum(dA, axis=2)                   # within-chunk cumsum
    dA_tot = dA_cum[:, :, -1]                         # (B,C,H)

    # 1) intra-chunk (quadratic, "attention-like"):
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))    # (B,C,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc) * L
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp",
                         scores, dtc[..., None] * xc)

    # 2) chunk states: decay-to-end weighted outer products
    decay_end = jnp.exp(dA_tot[:, :, None, :] - dA_cum)      # (B,C,Q,H)
    states = jnp.einsum("bcqhn,bcqhp->bchpn",
                        Bc * (decay_end * dtc)[..., None], xc)

    # 3) inter-chunk recurrence over C
    def step(prev, inp):
        st, tot = inp                                 # (B,H,P,N), (B,H)
        new = prev * jnp.exp(tot)[..., None, None] + st
        return new, prev                              # emit state *entering* chunk

    (final, entry_states) = jax.lax.scan(
        step, init_state, (states.swapaxes(0, 1), dA_tot.swapaxes(0, 1)))
    entry_states = entry_states.swapaxes(0, 1)        # (B,C,H,P,N)

    # 4) inter-chunk output: contribution of the entering state
    decay_in = jnp.exp(dA_cum)                        # (B,C,Q,H)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp",
                         Cc * decay_in[..., None], entry_states)

    y = (y_intra + y_inter).reshape(b, s, h, p) + xf * D[None, None, :, None]
    return y.astype(x.dtype), final


def ssd_step(state, conv_state, xzbcdt, params) -> None:
    """Placeholder: the full per-token mamba block step lives in
    repro.models.mamba2 (needs conv + gating context)."""
    raise NotImplementedError
