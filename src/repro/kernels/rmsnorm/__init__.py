from repro.kernels.rmsnorm.ops import rmsnorm, rmsnorm_pallas, rmsnorm_ref  # noqa: F401
