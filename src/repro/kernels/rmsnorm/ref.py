"""Oracle for the fused RMSNorm (+ optional residual-add) kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray,
                residual: Optional[jnp.ndarray] = None,
                eps: float = 1e-5) -> jnp.ndarray:
    """y = rmsnorm(x + residual) * w, computed in fp32, cast back."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * w.astype(jnp.float32)).astype(dt)
