"""Pallas TPU fused RMSNorm (+ residual add).

The decode hot loop runs 2 norms per layer on (B, D) activations; fusing the
residual add + fp32 mean-square + scale into one VMEM pass saves two HBM
round-trips of the activation per call. Rows are tiled (block_rows, D) so a
row's full feature dim sits in VMEM (D <= ~16k fp32 fits easily)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _rmsnorm_res_kernel(x_ref, r_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm_pallas(x, w, residual: Optional[jnp.ndarray] = None,
                   *, eps: float = 1e-5, block_rows: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    """x: (..., D); w: (D,). Rows flattened and tiled."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        block_rows = 1
    grid = (rows // block_rows,)
    w2 = w.reshape(1, d)
    if residual is None:
        out = pl.pallas_call(
            functools.partial(_rmsnorm_kernel, eps=eps),
            grid=grid,
            in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                      pl.BlockSpec((1, d), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
            interpret=interpret,
        )(x2, w2)
    else:
        out = pl.pallas_call(
            functools.partial(_rmsnorm_res_kernel, eps=eps),
            grid=grid,
            in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                      pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                      pl.BlockSpec((1, d), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
            interpret=interpret,
        )(x2, residual.reshape(rows, d), w2)
    return out.reshape(shape)
