"""Public fused-RMSNorm op with backend dispatch."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.rmsnorm.rmsnorm import rmsnorm_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:                                  # pragma: no cover
        return False


def rmsnorm(x, w, residual: Optional[jnp.ndarray] = None, *,
            eps: float = 1e-5, use_pallas: Optional[bool] = None,
            interpret: bool = False) -> jnp.ndarray:
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return rmsnorm_pallas(x, w, residual, eps=eps, interpret=interpret)
    return rmsnorm_ref(x, w, residual, eps)


__all__ = ["rmsnorm", "rmsnorm_pallas", "rmsnorm_ref"]
