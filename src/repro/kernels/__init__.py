"""Pallas TPU kernels for the serving hot-spots, each with a pure-jnp oracle:

  flash_attention/   prefill & train attention (GQA, causal, VMEM-tiled)
  decode_attention/  paged decode attention (block-table indirection) +
                     flash-decoding partial/merge primitives
  ssd_scan/          Mamba-2 SSD chunked scan (state carried in VMEM)

On CPU (this container) kernels run under interpret=True in tests; the model
zoo uses the jnp references, which are themselves memory-bounded production
paths for the GSPMD dry-run.
"""
