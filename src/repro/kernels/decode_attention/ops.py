"""Public decode-attention ops: paged (engine path) + partial/merge helpers
(model dry-run path)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    paged_decode_attention_pallas)
from repro.kernels.decode_attention.ref import (    # noqa: F401 (re-export)
    attend_partial, decode_attention_ref, merge_partials, paged_decode_ref)


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:                                  # pragma: no cover
        return False


def paged_decode_attention(q, k_pages, v_pages, block_table, lengths, *,
                           scale: Optional[float] = None,
                           use_pallas: Optional[bool] = None,
                           interpret: bool = False) -> jnp.ndarray:
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas:
        return paged_decode_attention_pallas(
            q, k_pages, v_pages, block_table, lengths, scale=scale,
            interpret=interpret)
    return paged_decode_ref(q, k_pages, v_pages, block_table, lengths, scale)


__all__ = ["paged_decode_attention", "paged_decode_attention_pallas",
           "paged_decode_ref", "decode_attention_ref", "attend_partial",
           "merge_partials"]
