from repro.kernels.decode_attention.ops import (                     # noqa: F401
    attend_partial, decode_attention_ref, merge_partials,
    paged_decode_attention, paged_decode_attention_pallas, paged_decode_ref)
