"""Pallas TPU paged decode-attention kernel (flash-decoding over a page pool).

The KV cache lives in HBM as a global page pool ``(n_pages, page, Hkv, D)``;
each sequence owns a list of pages (block table). The kernel walks a
sequence's pages (scalar-prefetched block table drives the BlockSpec index
map, i.e. page indirection happens at DMA-issue time, the TPU analogue of
vLLM's gather inside the CUDA kernel), computing a running flash-softmax
over the query-head group of each KV head in VMEM scratch.

Grid: (batch, kv_heads, max_pages) — pages minormost so (m, l, acc) scratch
carries across a sequence's pages. Pages past ``lengths[b]`` are skipped with
``pl.when`` (their block-table entries must alias a valid page id, e.g. 0).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_STAT_LANES = 128


def _decode_kernel(block_table_ref, lengths_ref,      # scalar-prefetch
                   q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, page_size: int, max_pages: int, group: int):
    bi = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[bi]
    page_start = pi * page_size

    @pl.when(page_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (group, D)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)        # (group, page)
        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(l_prev * alpha
                                      + p.sum(axis=-1, keepdims=True),
                                      l_scr.shape)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(pi == max_pages - 1)
    def _finalize():
        l = jnp.maximum(l_scr[:, :1], 1e-37)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention_pallas(q, k_pages, v_pages, block_table, lengths,
                                  *, scale: Optional[float] = None,
                                  interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, D); k/v_pages: (n_pages, page, Hkv, D);
    block_table: (B, max_pages) int32; lengths: (B,) int32 -> (B, Hq, D)."""
    b, hq, d = q.shape
    n_pages, page_size, hkv, _ = k_pages.shape
    assert hq % hkv == 0
    group = hq // hkv
    max_pages = block_table.shape[1]
    scale = float(scale if scale is not None else d ** -0.5)

    # (B, Hkv, group, D) so a (group, D) q tile maps to one kv head.
    qg = q.reshape(b, hkv, group, d)
    # Pages laid out (page, Hkv, D); block index map picks (page_id, head).
    kernel = functools.partial(_decode_kernel, scale=scale,
                               page_size=page_size, max_pages=max_pages,
                               group=group)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda bi, h, pi, bt, ln: (bi, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda bi, h, pi, bt, ln: (bt[bi, pi], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, d),
                         lambda bi, h, pi, bt, ln: (bt[bi, pi], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda bi, h, pi, bt, ln: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, _STAT_LANES), jnp.float32),
            pltpu.VMEM((group, _STAT_LANES), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        interpret=interpret,
    )(block_table, lengths, qg, k_pages, v_pages)
    return out.reshape(b, hq, d)
