"""Pure-jnp oracles for decode attention.

Three entry points:
  * ``decode_attention_ref``  — contiguous cache, masked by per-seq lengths.
  * ``paged_decode_ref``      — vLLM-style paged cache + block table.
  * ``attend_partial`` / ``merge_partials`` — flash-decoding building blocks
    (partial softmax states (m, l, o) and their associative merge), used by the
    model decode path to combine the seq-sharded "big" KV shard with the small
    replicated "recent" append buffer.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv_heads(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hq, D)."""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d)) \
        .reshape(b, s, h * n_rep, d)


def attend_partial(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   valid: Optional[jnp.ndarray] = None,
                   scale: Optional[float] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Partial flash state over one KV segment.

    q: (B, Hq, D); k, v: (B, S, Hkv, D); valid: (B, S) bool or None.
    Returns m, l: (B, Hq); o: (B, Hq, D) — unnormalized (o = sum p*v).

    GQA is computed with a grouped einsum (q reshaped to (B, Hkv, G, D)) so
    the KV tensor is never head-broadcast: repeating KV heads of a
    sequence-sharded cache forces GSPMD to all-gather the whole cache
    (measured 64 GiB x layers in the baseline).  [§Perf iteration 5]
    """
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if valid is not None:
        logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = logits.max(axis=-1)                              # (B, Hkv, G)
    p = jnp.exp(logits - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return (m.reshape(b, hq), l.reshape(b, hq), o.reshape(b, hq, d))


def merge_partials(parts: Sequence[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]
                   ) -> jnp.ndarray:
    """Associative merge of flash states; returns normalized (B, Hq, D)."""
    m, l, o = parts[0]
    for m2, l2, o2 in parts[1:]:
        m_new = jnp.maximum(m, m2)
        a1 = jnp.exp(m - m_new)
        a2 = jnp.exp(m2 - m_new)
        l = l * a1 + l2 * a2
        o = o * a1[..., None] + o2 * a2[..., None]
        m = m_new
    return o / jnp.maximum(l, 1e-37)[..., None]


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, lengths: jnp.ndarray,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, Hq, D); caches: (B, S, Hkv, D); lengths: (B,). -> (B, Hq, D)."""
    s = k_cache.shape[1]
    valid = jnp.arange(s)[None, :] < lengths[:, None]
    part = attend_partial(q, k_cache, v_cache, valid, scale)
    return merge_partials([part]).astype(q.dtype)


def paged_decode_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                     v_pages: jnp.ndarray, block_table: jnp.ndarray,
                     lengths: jnp.ndarray,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """Paged decode attention.

    q:           (B, Hq, D)
    k/v_pages:   (n_pages, page_size, Hkv, D)  — global page pool
    block_table: (B, max_pages) int32          — page ids per sequence
    lengths:     (B,) int32                    — valid tokens per sequence
    """
    b, hq, d = q.shape
    _, page_size, hkv, _ = k_pages.shape
    max_pages = block_table.shape[1]
    # Gather this batch's pages into contiguous (B, S, Hkv, D).
    k = k_pages[block_table].reshape(b, max_pages * page_size, hkv, d)
    v = v_pages[block_table].reshape(b, max_pages * page_size, hkv, d)
    return decode_attention_ref(q, k, v, lengths, scale)
