"""qwen2.5-32b — dense GQA with QKV bias.

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064. [hf:Qwen/Qwen2.5 family]
"""
from repro.configs.base import ArchConfig, Family, register

QWEN2P5_32B = register(ArchConfig(
    name="qwen2.5-32b",
    family=Family.DENSE,
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27648,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-0.5B (hf; scaled per assignment)",
))
