"""Architecture + shape configuration system.

Every assigned architecture is a declarative ``ArchConfig``; the model zoo in
``repro.models`` builds a concrete JAX model from it.  Shapes (the assigned
(arch x input-shape) cells) are ``ShapeSpec``s; ``launch.dryrun`` iterates the
cross product.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    AUDIO = "audio"
    VLM = "vlm"


class PosEmb(str, enum.Enum):
    ROPE = "rope"
    SINUSOIDAL = "sinusoidal"
    NONE = "none"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 0
    n_shared_experts: int = 0     # always-on shared experts
    d_expert: int = 0             # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    n_dense_layers: int = 0       # leading layers that stay dense (DeepSeek-style)
    d_shared: int = 0             # shared-expert hidden size (0 -> d_expert * n_shared)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # mamba2 "P" (per-head channels)
    chunk: int = 256              # SSD chunk length
    ngroups: int = 1


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int                  # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    pos_emb: PosEmb = PosEmb.ROPE
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"             # silu -> SwiGLU; gelu -> GeGLU
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: attention block applied every `attn_every` layers (shared weights,
    # Zamba2-style); 0 = attention in every layer (dense), -1 = no attention (ssm)
    attn_every: int = 0
    shared_attn_block: bool = False
    # vlm: cross-attention to image tokens every `cross_attn_every` layers
    cross_attn_every: int = 0
    n_frontend_tokens: int = 0    # image/audio-frontend tokens (stub input)
    # data type for params/activations
    param_dtype: str = "bfloat16"
    # source note for provenance
    source: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attn_layers(self) -> Sequence[int]:
        """Indices of layers that contain (self-)attention."""
        if self.attn_every == -1:
            return ()
        if self.attn_every == 0:
            return tuple(range(self.n_layers))
        return tuple(i for i in range(self.n_layers)
                     if (i % self.attn_every) == (self.attn_every - 1))

    @property
    def cross_attn_layers(self) -> Sequence[int]:
        if self.cross_attn_every <= 0:
            return ()
        return tuple(i for i in range(self.n_layers)
                     if (i % self.cross_attn_every) == (self.cross_attn_every - 1))

    @property
    def n_attn_layers(self) -> int:
        return len(self.attn_layers)

    @property
    def is_subquadratic(self) -> bool:
        """True if per-token decode state does not grow linearly in every layer
        (SSM / hybrid archs): eligible for the long_500k shape."""
        return self.family in (Family.SSM, Family.HYBRID)

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.head_dim

    # ---- parameter counting (used for roofline MODEL_FLOPS = 6ND) ----------
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        n_params = 0
        # embeddings (+ untied head)
        n_params += self.vocab * d
        if not self.tie_embeddings:
            n_params += self.vocab * d
        attn_set = set(self.attn_layers)
        cross_set = set(self.cross_attn_layers)
        per_attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        shared_attn_counted = False
        for i in range(L):
            n_params += 2 * d  # norms
            if i in attn_set:
                if self.shared_attn_block:
                    if not shared_attn_counted:
                        n_params += per_attn
                        shared_attn_counted = True
                else:
                    n_params += per_attn
            if i in cross_set:
                n_params += per_attn
            if self.ssm is not None and (self.family == Family.SSM or
                                         (self.family == Family.HYBRID and i not in attn_set)):
                di, s = self.d_inner, self.ssm
                nh = self.n_ssm_heads
                # in_proj: z, x, B, C, dt
                n_params += d * (2 * di + 2 * s.ngroups * s.d_state + nh)
                n_params += s.d_conv * (di + 2 * s.ngroups * s.d_state)  # conv1d
                n_params += 2 * nh  # A_log, D
                n_params += di * d  # out_proj
            if self.d_ff > 0 and (self.moe is None or i < (self.moe.n_dense_layers or 0)
                                  or self.family != Family.MOE):
                n_params += 3 * d * self.d_ff  # SwiGLU: gate, up, down
            elif self.moe is not None and self.family == Family.MOE \
                    and i >= (self.moe.n_dense_layers or 0):
                m = self.moe
                n_experts = m.top_k if active_only else m.n_experts
                n_params += n_experts * 3 * d * m.d_expert
                if m.n_shared_experts:
                    d_sh = m.d_shared or m.d_expert * m.n_shared_experts
                    n_params += 3 * d * d_sh
                n_params += d * m.n_experts  # router
        return n_params

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache bytes appended per generated/prefilled token (all layers)."""
        hd = self.resolved_head_dim
        n_attn = self.n_attn_layers + len(self.cross_attn_layers) * 0  # cross KV is fixed-size
        return n_attn * 2 * self.n_kv_heads * hd * dtype_bytes

    def ssm_state_bytes(self, dtype_bytes: int = 4) -> int:
        """Constant per-sequence recurrent state bytes (SSM/hybrid)."""
        if self.ssm is None:
            return 0
        n_ssm = self.n_layers - (self.n_attn_layers if self.family == Family.HYBRID else 0)
        if self.family == Family.SSM:
            n_ssm = self.n_layers
        per_layer = self.n_ssm_heads * self.ssm.head_dim * self.ssm.d_state \
            + (self.d_inner + 2 * self.ssm.ngroups * self.ssm.d_state) * self.ssm.d_conv
        return n_ssm * per_layer * dtype_bytes


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    kind: str                     # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeSpec("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeSpec("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeSpec("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeSpec("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shape_applicable(arch: ArchConfig, shape: ShapeSpec) -> bool:
    """long_500k needs sub-quadratic decode; everything else always applies."""
    if shape.name == "long_500k":
        return arch.is_subquadratic
    return True


def reduced(arch: ArchConfig, n_layers: int = 2, d_model: int = 64,
            vocab: int = 256, n_heads: int = 4, n_kv_heads: int = 2,
            d_ff: int = 128) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests and the real engine."""
    kw: dict = dict(
        name=arch.name + "-smoke", n_layers=n_layers, d_model=d_model,
        vocab=vocab, head_dim=0,
    )
    if arch.n_heads:
        kw.update(n_heads=n_heads,
                  n_kv_heads=min(n_kv_heads, n_heads) if arch.n_kv_heads < arch.n_heads else n_heads)
    else:
        kw.update(n_heads=0, n_kv_heads=0)
    kw["d_ff"] = d_ff if arch.d_ff else 0
    if arch.moe is not None:
        kw["moe"] = dataclasses.replace(
            arch.moe, n_experts=min(arch.moe.n_experts, 8),
            top_k=min(arch.moe.top_k, 2), d_expert=d_ff,
            n_shared_experts=min(arch.moe.n_shared_experts, 1),
            d_shared=d_ff if arch.moe.n_shared_experts else 0,
            n_dense_layers=min(arch.moe.n_dense_layers, 1))
        kw["d_ff"] = 0 if arch.family == Family.MOE else d_ff
    if arch.ssm is not None:
        kw["ssm"] = dataclasses.replace(arch.ssm, d_state=16, head_dim=16, chunk=32)
    if arch.attn_every:
        kw["attn_every"] = 2 if arch.attn_every > 0 else -1
    if arch.cross_attn_every:
        kw["cross_attn_every"] = 2
        kw["n_frontend_tokens"] = 16
    if arch.n_frontend_tokens and not arch.cross_attn_every:
        kw["n_frontend_tokens"] = 16
    return dataclasses.replace(arch, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    from repro import configs  # noqa: F401  (ensures registration modules imported)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> dict[str, ArchConfig]:
    from repro import configs  # noqa: F401
    return dict(_REGISTRY)
