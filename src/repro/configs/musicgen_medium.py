"""musicgen-medium — decoder-only transformer over EnCodec tokens.

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.  [arXiv:2306.05284]
The EnCodec frontend (RVQ codebooks, delay pattern) is a STUB: ``input_specs``
provides precomputed frame embeddings; the backbone is the transformer only.
MusicGen uses GELU MLP + sinusoidal positions (no RoPE).
"""
from repro.configs.base import ArchConfig, Family, PosEmb, register

MUSICGEN_MEDIUM = register(ArchConfig(
    name="musicgen-medium",
    family=Family.AUDIO,
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    pos_emb=PosEmb.SINUSOIDAL,
    act="gelu",
    n_frontend_tokens=0,          # frames arrive as embeddings via input stub
    source="arXiv:2306.05284 (hf)",
))
