"""llama-3.2-vision-90b — VLM: text decoder with cross-attn image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
[hf:meta-llama/Llama-3.2-11B-Vision, scaled per assignment]
The vision tower is a STUB: ``input_specs`` provides precomputed patch
embeddings (already projected to d_model); every 5th layer cross-attends
to them (20 cross-attention sites).
"""
from repro.configs.base import ArchConfig, Family, register

LLAMA_3P2_VISION_90B = register(ArchConfig(
    name="llama-3.2-vision-90b",
    family=Family.VLM,
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    rope_theta=5e5,
    cross_attn_every=5,
    n_frontend_tokens=1601,       # 1 tile x (1600 patches + cls), pre-projected
    source="hf:meta-llama/Llama-3.2-11B-Vision (unverified)",
))
