"""Config registry: importing this package registers all architectures."""
from repro.configs.base import (                                    # noqa: F401
    ALL_SHAPES, ArchConfig, Family, MoEConfig, PosEmb, SHAPES_BY_NAME,
    SSMConfig, ShapeSpec, all_archs, get_arch, reduced, register,
    shape_applicable, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
)

# Assigned architecture pool (10) --------------------------------------------
from repro.configs.mamba2_1p3b import MAMBA2_1P3B                   # noqa: F401
from repro.configs.moonshot_v1_16b_a3b import MOONSHOT_V1_16B       # noqa: F401
from repro.configs.qwen2_moe_a2p7b import QWEN2_MOE_A2P7B           # noqa: F401
from repro.configs.musicgen_medium import MUSICGEN_MEDIUM           # noqa: F401
from repro.configs.qwen2p5_32b import QWEN2P5_32B                   # noqa: F401
from repro.configs.mistral_nemo_12b import MISTRAL_NEMO_12B         # noqa: F401
from repro.configs.phi4_mini_3p8b import PHI4_MINI_3P8B             # noqa: F401
from repro.configs.granite_3_8b import GRANITE_3_8B                 # noqa: F401
from repro.configs.zamba2_7b import ZAMBA2_7B                       # noqa: F401
from repro.configs.llama_3p2_vision_90b import LLAMA_3P2_VISION_90B # noqa: F401

# The paper's own models ------------------------------------------------------
from repro.configs.llama2_paper import LLAMA2_7B, LLAMA2_13B, LLAMA2_70B  # noqa: F401

ASSIGNED_ARCHS = (
    "mamba2-1.3b",
    "moonshot-v1-16b-a3b",
    "qwen2-moe-a2.7b",
    "musicgen-medium",
    "qwen2.5-32b",
    "mistral-nemo-12b",
    "phi4-mini-3.8b",
    "granite-3-8b",
    "zamba2-7b",
    "llama-3.2-vision-90b",
)
