"""zamba2-7b — hybrid: Mamba2 backbone + shared attention block. [arXiv:2411.15242]

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64.
Every 6th layer applies the single *shared* full-attention block (Zamba2's
shared transformer block); all other layers are Mamba2. The shared block also
carries the d_ff=14336 SwiGLU MLP.
"""
from repro.configs.base import ArchConfig, Family, SSMConfig, register

ZAMBA2_7B = register(ArchConfig(
    name="zamba2-7b",
    family=Family.HYBRID,
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256, ngroups=1),
    attn_every=6,                 # layers 5, 11, ..., 77 -> 13 attention sites
    shared_attn_block=True,
    source="arXiv:2411.15242 (unverified)",
))
