"""The paper's own evaluation models (Llama-2 chat family, Table 2).

Used by the worker-configuration benchmark (Table 3) and the cluster simulator;
not part of the assigned (arch x shape) dry-run matrix.
"""
from repro.configs.base import ArchConfig, Family, register

LLAMA2_7B = register(ArchConfig(
    name="llama2-7b", family=Family.DENSE, n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=32, d_ff=11008, vocab=32000,
    source="arXiv:2307.09288"))

LLAMA2_13B = register(ArchConfig(
    name="llama2-13b", family=Family.DENSE, n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=40, d_ff=13824, vocab=32000,
    source="arXiv:2307.09288"))

LLAMA2_70B = register(ArchConfig(
    name="llama2-70b", family=Family.DENSE, n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=28672, vocab=32000,
    source="arXiv:2307.09288"))
