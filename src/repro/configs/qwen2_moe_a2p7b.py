"""qwen2-moe-a2.7b (Qwen1.5-MoE-A2.7B) — 4 shared + 60 routed top-4.

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.configs.base import ArchConfig, Family, MoEConfig, register

QWEN2_MOE_A2P7B = register(ArchConfig(
    name="qwen2-moe-a2.7b",
    family=Family.MOE,
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab=151936,
    qkv_bias=True,
    moe=MoEConfig(n_experts=60, top_k=4, n_shared_experts=4, d_expert=1408,
                  d_shared=5632, n_dense_layers=0),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B (hf)",
))
