"""mistral-nemo-12b — dense GQA, 128k context, explicit head_dim=128.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
[hf:mistralai/Mistral-Nemo-Base-2407]
"""
from repro.configs.base import ArchConfig, Family, register

MISTRAL_NEMO_12B = register(ArchConfig(
    name="mistral-nemo-12b",
    family=Family.DENSE,
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    rope_theta=1e6,
    source="hf:mistralai/Mistral-Nemo-Base-2407 (hf)",
))
