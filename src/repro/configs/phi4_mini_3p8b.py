"""phi4-mini-3.8b — RoPE SwiGLU GQA.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064. [arXiv:2412.08905]
"""
from repro.configs.base import ArchConfig, Family, register

PHI4_MINI_3P8B = register(ArchConfig(
    name="phi4-mini-3.8b",
    family=Family.DENSE,
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200064,
    head_dim=128,
    tie_embeddings=True,
    source="arXiv:2412.08905 (hf)",
))
