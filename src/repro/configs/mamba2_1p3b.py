"""mamba2-1.3b — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048 attn-free d_ff=0 vocab=50280 ssm_state=128.
Pure Mamba-2 stack: no attention, no FFN (the Mamba block subsumes it).
"""
from repro.configs.base import ArchConfig, Family, PosEmb, SSMConfig, register

MAMBA2_1P3B = register(ArchConfig(
    name="mamba2-1.3b",
    family=Family.SSM,
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    pos_emb=PosEmb.NONE,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256, ngroups=1),
    attn_every=-1,
    source="arXiv:2405.21060 (unverified)",
))
