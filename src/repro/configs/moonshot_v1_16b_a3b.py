"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) — DeepSeek-V3-style MoE.

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64 routed experts
top-6 + 2 shared experts, first layer dense. [hf:moonshotai/Moonlight-16B-A3B]
"""
from repro.configs.base import ArchConfig, Family, MoEConfig, register

MOONSHOT_V1_16B = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family=Family.MOE,
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,                      # FFN is MoE in all non-dense layers
    vocab=163840,
    head_dim=128,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2, d_expert=1408,
                  d_shared=2816, n_dense_layers=1),
    source="hf:moonshotai/Moonlight-16B-A3B (hf)",
))
