"""Output-length prediction-error re-balancing (paper §4.3, Algorithm 2).

Each worker accumulates an error state:
  l_e  — signed accumulated output-length error of its outstanding requests
         (underestimates add the *re-predicted* remainder l'_pred; finished
         overestimates add l_real - l_pred < 0),
  b_e  — signed batch-size error (underestimated requests are still occupying
         a slot they were not expected to: +1; early finishers: -1).

Per Eq. 4 a worker's decode-latency budget line is  k2·C + c2·b = T_dec - c3,
so the *equivalent latency error* of worker i is  err_i = k2·l_e_i + c2·b_e_i
(the paper's distance-to-origin |c_i|/sqrt(α² + β²) is err_i up to the common
normalization 1/sqrt(k2² + c2²)). Re-balancing moves not-yet-started new
requests from positive-error (over-utilized) workers to negative-error ones,
greedily minimizing Σ|err_i| while preserving feasibility."""
from __future__ import annotations

from typing import Dict, List

from repro.core.placement import WorkerState
from repro.core.request import Request


class ErrorTracker:
    """Maintains (l_e, b_e) per worker from request completion events."""

    def __init__(self):
        self.l_e: Dict[int, float] = {}
        self.b_e: Dict[int, float] = {}

    def _ensure(self, wid: int):
        self.l_e.setdefault(wid, 0.0)
        self.b_e.setdefault(wid, 0.0)

    def on_finish(self, r: Request) -> None:
        """Request finished: if earlier than predicted, record overestimate."""
        if r.worker is None:
            return
        self._ensure(r.worker)
        if r.l_real < r.l_pred:
            self.l_e[r.worker] += (r.l_real - r.l_pred)
            self.b_e[r.worker] -= 1

    def on_underrun(self, r: Request, new_pred: int) -> None:
        """Request exceeded its prediction; re-predicted to new_pred."""
        if r.worker is None:
            return
        self._ensure(r.worker)
        self.l_e[r.worker] += new_pred
        self.b_e[r.worker] += 1
        r.repredicted = True
        r.l_pred = r.l_out + new_pred

    def decay(self, f: float = 0.5) -> None:
        """Forget old error after each heartbeat's re-balance acted on it."""
        for k in self.l_e:
            self.l_e[k] *= f
            self.b_e[k] *= f

    def err(self, wid: int, k2: float, c2: float) -> float:
        return k2 * self.l_e.get(wid, 0.0) + c2 * self.b_e.get(wid, 0.0)


def rebalance(workers: List[WorkerState], tracker: ErrorTracker,
              max_moves: int = 64) -> int:
    """Algorithm 2: adjust placement of new (not yet started) requests.
    Returns the number of moves made."""
    if len(workers) < 2:
        return 0
    # per-worker coefficients: in a heterogeneous fleet the same token error
    # costs different latency on different hardware (its own Eq. 4 line)
    coef = {w.id: (w.perf.decode.k2, w.perf.decode.c2) for w in workers}
    errs = {w.id: tracker.err(w.id, *coef[w.id]) for w in workers}
    moves = 0
    improved = True
    while improved and moves < max_moves:
        improved = False
        # most over-utilized worker with a movable new request
        for src in sorted(workers, key=lambda w: -errs[w.id]):
            if errs[src.id] <= 0 or not src.new_batch:
                continue
            # candidate destinations: most under-utilized first
            for dst in sorted(workers, key=lambda w: errs[w.id]):
                if dst.id == src.id or errs[dst.id] >= errs[src.id]:
                    continue
                moved = False
                for r in list(src.new_batch):
                    if r.cached_len > 0:
                        # a prefix-cache grant is only redeemable on the
                        # worker holding the blocks: moving the request
                        # would both void the discount and let dst's
                        # feasibility check see a prefill dst cannot price
                        continue
                    k2s, c2s = coef[src.id]
                    k2d, c2d = coef[dst.id]
                    new_src = errs[src.id] - (k2s * r.l_pred + c2s)
                    new_dst = errs[dst.id] + (k2d * r.l_pred + c2d)
                    if abs(new_src) + abs(new_dst) + 1e-12 < \
                            abs(errs[src.id]) + abs(errs[dst.id]) \
                            and dst.feasible([r]):
                        src.unplace(r)
                        dst.place(r)
                        errs[src.id] = new_src
                        errs[dst.id] = new_dst
                        moves += 1
                        moved = improved = True
                        break
                if moved:
                    break
            if improved:
                break
    return moves
