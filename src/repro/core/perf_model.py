"""Continuous-batching performance models (paper §3, Eqs. 1-4).

All three models are linear with learnable coefficients, fitted online from
execution traces (the paper's workflow step "continuously update the
performance model according to the worker's execution traces"):

  Eq. 1  kv(t)          = h * t + j                  (bytes per context token)
  Eq. 2  t_pre(L)       = k1 * L + c1                (L = total batched input)
  Eq. 3  t_d(b, l_ave)  = (k2 * l_ave + c2) * b + c3
                        =  k2 * C + c2 * b + c3      (C = total context)
  Eq. 4  C_max(b)       = (T_dec - c3 - c2 * b) / k2 (total-context budget)

The decode model is fitted in the (C, b) parameterization — identical to the
paper's but numerically better conditioned than (l_ave, b).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class KVModel:
    h: float = 0.0
    j: float = 0.0

    def __call__(self, tokens) -> np.ndarray:
        return self.h * np.asarray(tokens, dtype=np.float64) + self.j

    @staticmethod
    def fit(tokens: Sequence[float], kv_bytes: Sequence[float]) -> "KVModel":
        A = np.stack([np.asarray(tokens, np.float64),
                      np.ones(len(tokens))], axis=1)
        (h, j), *_ = np.linalg.lstsq(A, np.asarray(kv_bytes, np.float64),
                                     rcond=None)
        return KVModel(float(h), float(j))


@dataclasses.dataclass
class PrefillModel:
    k1: float = 0.0
    c1: float = 0.0

    def __call__(self, total_input) -> np.ndarray:
        return self.k1 * np.asarray(total_input, np.float64) + self.c1

    def max_total_input(self, t_pre_budget: float) -> float:
        """Invert Eq. 2: largest Σ l_in admissible within the TTFT budget."""
        if self.k1 <= 0:
            return float("inf")
        return max((t_pre_budget - self.c1) / self.k1, 0.0)

    @staticmethod
    def fit(total_inputs, times) -> "PrefillModel":
        A = np.stack([np.asarray(total_inputs, np.float64),
                      np.ones(len(times))], axis=1)
        (k1, c1), *_ = np.linalg.lstsq(A, np.asarray(times, np.float64),
                                       rcond=None)
        return PrefillModel(float(k1), float(c1))


@dataclasses.dataclass
class DecodeModel:
    k2: float = 0.0
    c2: float = 0.0
    c3: float = 0.0

    def __call__(self, batch, total_context) -> np.ndarray:
        b = np.asarray(batch, np.float64)
        c = np.asarray(total_context, np.float64)
        return self.k2 * c + self.c2 * b + self.c3

    def iteration_time(self, batch, total_context):
        return self(batch, total_context)

    def max_total_context(self, batch: float, t_dec: float) -> float:
        """Eq. 4: the total-context budget at batch size b under ATGT t_dec."""
        if self.k2 <= 0:
            return float("inf")
        return max((t_dec - self.c3 - self.c2 * batch) / self.k2, 0.0)

    def max_batch(self, t_dec: float, l_ave: float) -> int:
        """Largest b with t_d(b, b*l_ave) <= t_dec (used by Eq. 6's B)."""
        denom = self.k2 * l_ave + self.c2
        if denom <= 0:
            return 10 ** 9
        return max(int((t_dec - self.c3) / denom), 0)

    @staticmethod
    def fit(batches, total_contexts, times) -> "DecodeModel":
        A = np.stack([np.asarray(total_contexts, np.float64),
                      np.asarray(batches, np.float64),
                      np.ones(len(times))], axis=1)
        (k2, c2, c3), *_ = np.linalg.lstsq(A, np.asarray(times, np.float64),
                                           rcond=None)
        return DecodeModel(float(k2), float(c2), float(c3))


@dataclasses.dataclass
class PerfModel:
    """Bundle of the three fitted models + fit diagnostics."""
    kv: KVModel = dataclasses.field(default_factory=KVModel)
    prefill: PrefillModel = dataclasses.field(default_factory=PrefillModel)
    decode: DecodeModel = dataclasses.field(default_factory=DecodeModel)
    max_rel_err: dict = dataclasses.field(default_factory=dict)

    # ---- online refit from traces ------------------------------------------
    def update_from_traces(self, traces: "TraceBuffer") -> None:
        """Trimmed refit: JIT-compile events produce latency outliers; fit,
        drop points with residual > 5x the median absolute residual, refit."""
        def trimmed(fit, xs_cols, ys):
            m = fit(*xs_cols, ys)
            pred = m(*xs_cols) if len(xs_cols) > 1 else m(xs_cols[0])
            res = np.abs(np.asarray(pred) - np.asarray(ys, np.float64))
            med = np.median(res) + 1e-12
            keep = res <= 5 * med
            if keep.sum() >= max(4, 0.5 * len(ys)) and not keep.all():
                cols = [np.asarray(c)[keep] for c in xs_cols]
                ys2 = np.asarray(ys, np.float64)[keep]
                m = fit(*cols, ys2)
                pred = m(*cols) if len(cols) > 1 else m(cols[0])
                return m, _max_rel_err(pred, ys2)
            return m, _max_rel_err(pred, ys)

        if len(traces.prefill_inputs) >= 4:
            self.prefill, err = trimmed(
                lambda x, y: PrefillModel.fit(x, y),
                [traces.prefill_inputs], traces.prefill_times)
            self.max_rel_err["prefill"] = err
        if len(traces.decode_batches) >= 6:
            self.decode, err = trimmed(
                lambda b, c, y: DecodeModel.fit(b, c, y),
                [traces.decode_batches, traces.decode_contexts],
                traces.decode_times)
            self.max_rel_err["decode"] = err
        if len(traces.kv_tokens) >= 4:
            self.kv = KVModel.fit(traces.kv_tokens, traces.kv_bytes)
            pred = self.kv(traces.kv_tokens)
            self.max_rel_err["kv"] = _max_rel_err(pred, traces.kv_bytes)


def _max_rel_err(pred, actual) -> float:
    actual = np.asarray(actual, np.float64)
    pred = np.asarray(pred, np.float64)
    denom = np.maximum(np.abs(actual), 1e-12)
    return float(np.max(np.abs(pred - actual) / denom))


@dataclasses.dataclass
class TraceBuffer:
    """Rolling buffer of worker execution traces (workflow steps 3/4)."""
    cap: int = 4096
    prefill_inputs: list = dataclasses.field(default_factory=list)
    prefill_times: list = dataclasses.field(default_factory=list)
    decode_batches: list = dataclasses.field(default_factory=list)
    decode_contexts: list = dataclasses.field(default_factory=list)
    decode_times: list = dataclasses.field(default_factory=list)
    kv_tokens: list = dataclasses.field(default_factory=list)
    kv_bytes: list = dataclasses.field(default_factory=list)

    def record_prefill(self, total_input: int, t: float) -> None:
        self.prefill_inputs.append(total_input)
        self.prefill_times.append(t)
        self._trim()

    def record_decode(self, batch: int, total_context: int, t: float) -> None:
        self.decode_batches.append(batch)
        self.decode_contexts.append(total_context)
        self.decode_times.append(t)
        self._trim()

    def record_kv(self, tokens: int, nbytes: float) -> None:
        self.kv_tokens.append(tokens)
        self.kv_bytes.append(nbytes)
        self._trim()

    def _trim(self) -> None:
        for name in ("prefill_inputs", "prefill_times", "decode_batches",
                     "decode_contexts", "decode_times", "kv_tokens",
                     "kv_bytes"):
            lst = getattr(self, name)
            if len(lst) > self.cap:
                del lst[: len(lst) - self.cap]


def analytic_perf_model(arch, hw_tflops: float = 197.0,
                        hw_hbm_gbs: float = 819.0, n_chips: int = 1,
                        efficiency: float = 0.5) -> PerfModel:
    """First-principles seed model (used by the simulator before any traces
    exist): prefill is compute-bound (6*N_active FLOPs/token), decode is
    weight+KV bandwidth-bound."""
    n_active = arch.param_count(active_only=True)
    flops_tok = 2.0 * n_active
    peak = hw_tflops * 1e12 * n_chips * efficiency
    bw = hw_hbm_gbs * 1e9 * n_chips * efficiency
    kv_tok = arch.kv_bytes_per_token()
    weight_bytes = 2.0 * arch.param_count()
    k1 = flops_tok / peak
    # decode iteration: read all weights once (+c3) and each context token's
    # KV (k2 per context token); c2 = per-sequence fixed overhead.
    k2 = kv_tok / bw
    c3 = weight_bytes / bw
    c2 = flops_tok / peak
    return PerfModel(kv=KVModel(h=float(kv_tok), j=float(arch.ssm_state_bytes())),
                     prefill=PrefillModel(k1=float(k1), c1=1e-3),
                     decode=DecodeModel(k2=float(k2), c2=float(c2),
                                        c3=float(c3)))
