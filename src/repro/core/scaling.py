"""Worker-count autoscaling (paper §5.2) + change-point detection.

Above an arrival-rate floor R the required worker count is linear in the
arrival rate:  N_w = ceil(k5 * r_a + c5)  (Eq. 7), with (k5, c5) learned from
(rate, workers-needed) history. Below R the length-distribution sample is too
small (SEM = sigma/sqrt(n)) to trust the linear fit, so the scaler falls back
to the most recent empirical requirement plus head-room.

Demand change points are detected on the arrival-rate stream with a simple
two-window mean-shift test; each cluster heartbeat with a change point (or a
drifted prediction) triggers reconfiguration.

``split_spot_mix`` extends the worker-count decision with a price class: given
a total capacity target, the spot discount and the preemption hazard, it
returns the cheapest (on-demand, spot) split whose *expected surviving*
capacity still covers the target."""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np

# History window: keep at most this many observations; halve when exceeded.
HISTORY_MAX = 4096


@dataclasses.dataclass
class AutoscalerConfig:
    heartbeat: float = 10.0            # seconds between scaling decisions
    min_workers: int = 1
    max_workers: int = 4096
    sem_target: float = 0.1            # SEM/sigma floor defining R
    headroom: float = 1.10             # spare capacity when below R
    change_window: int = 8             # heartbeats per mean-shift window
    change_z: float = 3.0              # z-score to declare a change point


class Autoscaler:
    def __init__(self, cfg: AutoscalerConfig = AutoscalerConfig()):
        self.cfg = cfg
        self.history: List[Tuple[float, int]] = []   # (rate, workers needed)
        self.rates: List[float] = []
        self.k5: Optional[float] = None
        self.c5: Optional[float] = None
        # running Σx, Σy, Σxy, Σx² for an O(1) two-parameter least squares
        # per observation (rebuilt only when the history window is trimmed)
        self._sums = [0.0, 0.0, 0.0, 0.0]

    # ---- Eq. 7 fit -----------------------------------------------------------
    def observe(self, rate: float, workers_needed: int) -> None:
        self.history.append((rate, workers_needed))
        self.rates.append(rate)
        x, y = float(rate), float(workers_needed)
        s = self._sums
        s[0] += x
        s[1] += y
        s[2] += x * y
        s[3] += x * x
        if len(self.history) > HISTORY_MAX:
            del self.history[:HISTORY_MAX // 2]
            self._sums = [sum(r for r, _ in self.history),
                          sum(float(n) for _, n in self.history),
                          sum(r * n for r, n in self.history),
                          sum(r * r for r, _ in self.history)]
            s = self._sums
        if len(self.rates) > HISTORY_MAX:
            # change_point() only looks at the last 2*change_window entries,
            # so dropping the old half never alters its verdict
            del self.rates[:HISTORY_MAX // 2]
        n = len(self.history)
        if n >= 4:
            det = n * s[3] - s[0] * s[0]
            if abs(det) > 1e-12:
                self.k5 = (n * s[2] - s[0] * s[1]) / det
                self.c5 = (s[1] * s[3] - s[0] * s[2]) / det

    def rate_floor(self) -> float:
        """R: smallest rate whose per-heartbeat sample keeps SEM below
        sem_target * sigma.  SEM = sigma/sqrt(n) <= sem_target * sigma needs
        n >= 1/sem_target^2 samples; with n = r * heartbeat the length sigma
        cancels, so the floor depends only on (sem_target, heartbeat)."""
        n_min = 1.0 / (self.cfg.sem_target ** 2)
        return n_min / max(self.cfg.heartbeat, 1e-9)

    def predict_workers(self, rate: float,
                        last_needed: Optional[int] = None) -> int:
        cfg = self.cfg
        if self.k5 is not None and rate > self.rate_floor():
            n = math.ceil(self.k5 * rate + self.c5)
        elif last_needed is not None:
            n = math.ceil(last_needed * cfg.headroom)
        else:
            n = cfg.min_workers
        return int(min(max(n, cfg.min_workers), cfg.max_workers))

    # ---- change-point detection -----------------------------------------------
    def change_point(self) -> bool:
        w = self.cfg.change_window
        if len(self.rates) < 2 * w:
            return False
        a = np.asarray(self.rates[-2 * w:-w], np.float64)
        b = np.asarray(self.rates[-w:], np.float64)
        pooled = math.sqrt((a.var() + b.var()) / 2 + 1e-12)
        z = abs(b.mean() - a.mean()) / (pooled / math.sqrt(w) + 1e-12)
        return z > self.cfg.change_z


# ---- SLO-feedback gain control -----------------------------------------------

@dataclasses.dataclass
class FeedbackConfig:
    """Closed-loop correction on *observed* SLO attainment.

    The open-loop policies (reactive, forecast) size the fleet from demand
    estimates alone; when the rate model is miscalibrated (drifted
    seasonality, burst regime change) they either violate SLOs or
    over-provision. The feedback controller multiplies the open-loop target
    by a gain driven by the windowed attainment the cluster actually
    delivered:

      * attainment below ``slo_target - deadband`` → multiply the gain by
        ``boost`` (fast multiplicative attack on misses), at most once per
        ``attack_cooldown`` seconds (default: the window length) — the
        misses that triggered a boost stay *in* the window for a while, and
        re-boosting on the same stale evidence every epoch would race the
        gain to ``max_gain`` before the extra capacity could even boot;
      * attainment at or above ``slo_target + deadband`` → subtract
        ``decay`` (slow additive release while the SLO saturates), down to
        ``min_gain`` — below 1.0 this shaves open-loop over-provisioning;
      * inside the deadband → hold (hysteresis: no oscillation on a flat
        trace).

    ``window`` is the attainment observation window in seconds;
    ``min_samples`` keeps the controller inert until the window holds a
    meaningful sample. An infinite ``deadband`` disables both thresholds,
    making the closed loop bit-for-bit identical to its open-loop base."""
    slo_target: float = 0.99
    deadband: float = 0.005
    boost: float = 1.3
    decay: float = 0.02
    max_gain: float = 3.0
    min_gain: float = 1.0
    window: float = 30.0
    min_samples: int = 8
    attack_cooldown: Optional[float] = None   # None: one boost per window


class AttainmentController:
    """The MIAD gain state machine of :class:`FeedbackConfig` (multiplicative
    increase on SLO misses, additive decrease on saturation).

    Pure arithmetic over (ok, total) observations — no simulator types — so
    its hysteresis and monotonicity properties are unit-testable in
    isolation (tests/test_feedback.py)."""

    def __init__(self, cfg: Optional[FeedbackConfig] = None):
        self.cfg = cfg if cfg is not None else FeedbackConfig()
        self.gain = 1.0
        self._last_attack = -math.inf

    def observe(self, t: float, ok: int, total: int) -> float:
        """Fold one windowed (ok, total) attainment sample, observed at
        time ``t``, into the gain."""
        cfg = self.cfg
        if total < cfg.min_samples:
            return self.gain
        att = ok / total
        lo = cfg.slo_target - cfg.deadband
        hi = cfg.slo_target + cfg.deadband
        if math.isfinite(hi):
            # a reachable release threshold even when target+deadband > 1
            hi = min(hi, 1.0)
        cooldown = cfg.attack_cooldown if cfg.attack_cooldown is not None \
            else cfg.window
        if att < lo:
            if t - self._last_attack >= cooldown:
                self.gain = min(self.gain * cfg.boost, cfg.max_gain)
                self._last_attack = t
        elif att >= hi:
            self.gain = max(self.gain - cfg.decay, cfg.min_gain)
        return self.gain

    def apply(self, target: int) -> int:
        """Scale an open-loop worker target by the current gain. Gain 1.0
        returns the target untouched — the exact open-loop integer."""
        if self.gain == 1.0:
            return target
        return max(int(math.ceil(target * self.gain)), 0)


# ---- spot / on-demand mix planning -------------------------------------------

@dataclasses.dataclass
class SpotMixConfig:
    """Economics of a preemptible capacity pool next to the on-demand one.

    ``hazard`` is the per-worker per-second reclaim rate; ``horizon`` is the
    exposure window the planner must survive — the time until a replacement
    decision can take effect (scaling epoch + provisioning delay), over which
    a spot worker survives with probability ``exp(-hazard * horizon)``.
    ``discount`` is the spot price as a fraction of on-demand. Spot capacity
    is worth buying only while ``discount / survival < 1`` — i.e. a unit of
    *expected surviving* spot capacity (one worker inflated by 1/survival)
    still bills below one on-demand worker.

    ``max_spot_frac`` caps the capacity share served from spot: reclaims are
    correlated in real markets (capacity crunches take out whole pools), so
    some on-demand base always remains. ``spot_frac`` forces a fixed split
    (tests and what-if sweeps); None lets the economics decide."""
    discount: float = 0.35
    hazard: float = 1.0 / 1800.0
    horizon: float = 15.0
    max_spot_frac: float = 0.7
    spot_frac: Optional[float] = None

    def survival(self) -> float:
        return math.exp(-self.hazard * max(self.horizon, 0.0))


def split_spot_mix(target: int, mix: SpotMixConfig) -> Tuple[int, int]:
    """Cheapest (n_on_demand, n_spot) covering ``target`` expected capacity.

    A share of the target (at most ``max_spot_frac``) is assigned to spot and
    inflated by 1/survival so the *expected* surviving spot workers still
    cover that share at the end of the exposure horizon; the rest stays
    on-demand. When spot is uneconomical (discount / survival >= 1, i.e. the
    attrition premium eats the discount) the split is all on-demand."""
    if target <= 0:
        return 0, 0
    p = mix.survival()
    if p <= 1e-9:
        return target, 0       # even a forced share can't survive the horizon
    if mix.spot_frac is not None:
        share = int(round(target * min(max(mix.spot_frac, 0.0), 1.0)))
    elif mix.discount / p >= 1.0:
        return target, 0
    else:
        share = int(target * mix.max_spot_frac)
    if share <= 0:
        return target, 0
    n_spot = int(math.ceil(share / max(p, 1e-9)))
    if mix.spot_frac is None and \
            (target - share) + n_spot * mix.discount >= target:
        # the ceil() inflation ate the discount at this scale (near the
        # break-even ratio, small targets round the attrition premium up
        # past the saving) — honor the "cheapest split" contract
        return target, 0
    return target - share, n_spot
