"""Worker-count autoscaling (paper §5.2) + change-point detection.

Above an arrival-rate floor R the required worker count is linear in the
arrival rate:  N_w = ceil(k5 * r_a + c5)  (Eq. 7), with (k5, c5) learned from
(rate, workers-needed) history. Below R the length-distribution sample is too
small (SEM = sigma/sqrt(n)) to trust the linear fit, so the scaler falls back
to the most recent empirical requirement plus head-room.

Demand change points are detected on the arrival-rate stream with a simple
two-window mean-shift test; each cluster heartbeat with a change point (or a
drifted prediction) triggers reconfiguration."""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np

# History window: keep at most this many observations; halve when exceeded.
HISTORY_MAX = 4096


@dataclasses.dataclass
class AutoscalerConfig:
    heartbeat: float = 10.0            # seconds between scaling decisions
    min_workers: int = 1
    max_workers: int = 4096
    sem_target: float = 0.1            # SEM/sigma floor defining R
    headroom: float = 1.10             # spare capacity when below R
    change_window: int = 8             # heartbeats per mean-shift window
    change_z: float = 3.0              # z-score to declare a change point


class Autoscaler:
    def __init__(self, cfg: AutoscalerConfig = AutoscalerConfig()):
        self.cfg = cfg
        self.history: List[Tuple[float, int]] = []   # (rate, workers needed)
        self.rates: List[float] = []
        self.k5: Optional[float] = None
        self.c5: Optional[float] = None
        # running Σx, Σy, Σxy, Σx² for an O(1) two-parameter least squares
        # per observation (rebuilt only when the history window is trimmed)
        self._sums = [0.0, 0.0, 0.0, 0.0]

    # ---- Eq. 7 fit -----------------------------------------------------------
    def observe(self, rate: float, workers_needed: int) -> None:
        self.history.append((rate, workers_needed))
        self.rates.append(rate)
        x, y = float(rate), float(workers_needed)
        s = self._sums
        s[0] += x
        s[1] += y
        s[2] += x * y
        s[3] += x * x
        if len(self.history) > HISTORY_MAX:
            del self.history[:HISTORY_MAX // 2]
            self._sums = [sum(r for r, _ in self.history),
                          sum(float(n) for _, n in self.history),
                          sum(r * n for r, n in self.history),
                          sum(r * r for r, _ in self.history)]
            s = self._sums
        if len(self.rates) > HISTORY_MAX:
            # change_point() only looks at the last 2*change_window entries,
            # so dropping the old half never alters its verdict
            del self.rates[:HISTORY_MAX // 2]
        n = len(self.history)
        if n >= 4:
            det = n * s[3] - s[0] * s[0]
            if abs(det) > 1e-12:
                self.k5 = (n * s[2] - s[0] * s[1]) / det
                self.c5 = (s[1] * s[3] - s[0] * s[2]) / det

    def rate_floor(self) -> float:
        """R: smallest rate whose per-heartbeat sample keeps SEM below
        sem_target * sigma.  SEM = sigma/sqrt(n) <= sem_target * sigma needs
        n >= 1/sem_target^2 samples; with n = r * heartbeat the length sigma
        cancels, so the floor depends only on (sem_target, heartbeat)."""
        n_min = 1.0 / (self.cfg.sem_target ** 2)
        return n_min / max(self.cfg.heartbeat, 1e-9)

    def predict_workers(self, rate: float,
                        last_needed: Optional[int] = None) -> int:
        cfg = self.cfg
        if self.k5 is not None and rate > self.rate_floor():
            n = math.ceil(self.k5 * rate + self.c5)
        elif last_needed is not None:
            n = math.ceil(last_needed * cfg.headroom)
        else:
            n = cfg.min_workers
        return int(min(max(n, cfg.min_workers), cfg.max_workers))

    # ---- change-point detection -----------------------------------------------
    def change_point(self) -> bool:
        w = self.cfg.change_window
        if len(self.rates) < 2 * w:
            return False
        a = np.asarray(self.rates[-2 * w:-w], np.float64)
        b = np.asarray(self.rates[-w:], np.float64)
        pooled = math.sqrt((a.var() + b.var()) / 2 + 1e-12)
        z = abs(b.mean() - a.mean()) / (pooled / math.sqrt(w) + 1e-12)
        return z > self.cfg.change_z
