"""SLO-aware request placement (paper §4.2).

The MIP's constraints, evaluated per worker:

  (b) decode-latency budget:  Σ_j (l_in_j + γ·l_pred_j)  ≤  θ · C_max(b)
      with C_max from Eq. 4 and b the post-placement batch size;
  (c) TTFT budget:            t_pre(Σ new l_in)          ≤  T_pre;
  (d) preemption budget:      t_pre(Σ new l_in)          ≤  θ · min_j slack_j,
      slack_j = T_dec·(l_out_j − 1) − t_dec_j (decode time the ongoing
      requests have "banked" against the ATGT SLO; ATGT divides by
      l_out − 1, the first token being TTFT's);
  (e) per-iteration KV:       peak over future iterations of Σ kv_j(·) ≤ M.

Algorithm 1 (best-fit): rank workers by capacity_norm (L2 norm of batch size
and weighted context) descending, place on the first feasible one, else open
a new worker. ``exact_min_workers`` (core/mip.py) is the brute-force
reference used in tests to certify near-optimality.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core.perf_model import PerfModel
from repro.core.request import Request
from repro.core.slo import SLO


@dataclasses.dataclass
class PlacementConfig:
    gamma: float = 0.5      # strictness knob γ in (b): weight on l_pred
    theta: float = 0.9      # prediction-error head-room θ in (b)/(d)
    kv_capacity: float = 0.0          # M, bytes per worker
    max_batch: int = 512              # engine hard cap on batch slots
    split_phase: bool = False         # decode-pool worker: no prefill runs
                                      # here, so (c)/(d) do not apply


class WorkerState:
    """Scheduler-side view of one serving worker.

    ``cfg`` and ``perf`` are per-worker: a heterogeneous fleet mixes workers
    whose KV capacity, batch cap and latency models differ (e.g. A100 TP=4
    next to V100 TP=8 — each built from its own Eq. 5-6 search)."""

    def __init__(self, wid: int, cfg: PlacementConfig, perf: PerfModel,
                 slo: SLO):
        self.id = wid
        self.cfg = cfg
        self.perf = perf
        self.slo = slo
        self.ongoing: List[Request] = []    # decoding (or placed) requests
        self.new_batch: List[Request] = []  # placed this heartbeat, not begun
        self.alive = True
        self.draining = False               # straggler mitigation
        # cached Σ (l_in + γ·l_pred) over ongoing+new_batch; validated against
        # the list lengths so external list mutation forces a recompute, and
        # updated incrementally by place/unplace (which keep lengths AND the
        # sum in sync even when a re-balance move leaves lengths unchanged
        # on net). l_pred re-predictions must call mark_dirty().
        self._wctx = 0.0
        self._wctx_key: Optional[tuple] = None

    # ---- aggregate views ----------------------------------------------------
    @property
    def batch_size(self) -> int:
        return len(self.ongoing) + len(self.new_batch)

    def mark_dirty(self) -> None:
        """Invalidate cached aggregates after an in-place request mutation
        (e.g. Algorithm 2 re-prediction rewriting l_pred)."""
        self._wctx_key = None

    def _wctx_now(self) -> float:
        key = (len(self.ongoing), len(self.new_batch))
        if self._wctx_key != key:
            g = self.cfg.gamma
            self._wctx = sum(r.l_in + g * r.l_pred
                             for r in self.ongoing + self.new_batch)
            self._wctx_key = key
        return self._wctx

    def weighted_context(self, gamma: Optional[float] = None) -> float:
        if gamma is None or gamma == self.cfg.gamma:
            return self._wctx_now()
        return sum(r.l_in + gamma * r.l_pred
                   for r in self.ongoing + self.new_batch)

    def capacity_norm(self) -> float:
        """L2 norm of (batch size, weighted context) — the worker 'load' used
        to rank bins in Algorithm 1 (normalized so both terms are O(1))."""
        b = self.batch_size / max(self.cfg.max_batch, 1)
        cmax = self.perf.decode.max_total_context(1, self.slo.atgt) or 1.0
        c = self.weighted_context() / max(cmax, 1.0)
        return math.hypot(b, c)

    # ---- constraints ---------------------------------------------------------
    #
    # Multi-tenant traces stamp per-request SLO budgets (Request.slo_ttft /
    # slo_atgt); constraints (b)-(d) then budget each decision against the
    # strictest budget among the requests it actually affects. Untagged
    # requests carry ``inf`` budgets and every path below short-circuits to
    # the scalar ``self.slo`` arithmetic — the legacy float image is
    # untouched (and for a single tenant the tagged budgets *equal* the
    # planning SLO, so the comparisons see identical floats either way).

    def _tagged(self, reqs: Sequence[Request]) -> bool:
        return any(r.slo_atgt != math.inf for r in reqs)

    def _constraint_b(self, reqs: Sequence[Request]) -> bool:
        b = self.batch_size + len(reqs)
        if b > self.cfg.max_batch:
            return False
        if self._tagged(reqs):
            # Eq. 4's budget holds for the whole batch at the strictest
            # member ATGT: min over ongoing + new batch + candidates
            atgt = min(min((r.slo_atgt for r in reqs)),
                       min((m.slo_atgt for m in
                            self.ongoing + self.new_batch),
                           default=math.inf))
            if atgt == math.inf:
                atgt = self.slo.atgt
        else:
            atgt = self.slo.atgt
        budget = self.perf.decode.max_total_context(b, atgt)
        w = self.weighted_context() + sum(
            r.l_in + self.cfg.gamma * r.l_pred for r in reqs)
        return w <= self.cfg.theta * budget

    def _prefill_time(self, total_new: float) -> float:
        p = self.perf.prefill
        return p.k1 * total_new + p.c1   # scalar Eq. 2 (hot path: no numpy)

    def _constraint_c(self, reqs: Sequence[Request]) -> bool:
        # a prefix-cache hit (cached_len > 0, granted on THIS worker) only
        # prefills the new tokens — the TTFT/preemption budgets price that
        # shorter prefill. cached_len == 0 (every single-shot request)
        # leaves the integer sum, and hence the float image, untouched.
        total_new = sum(r.l_in - r.cached_len for r in self.new_batch) + \
            sum(r.l_in - r.cached_len for r in reqs)
        if self._tagged(reqs):
            # the joint prefill delays every new-batch member, so it must
            # fit the tightest TTFT budget among them and the candidates
            ttft = min(min((r.slo_ttft for r in reqs)),
                       min((m.slo_ttft for m in self.new_batch),
                           default=math.inf))
            if ttft == math.inf:
                ttft = self.slo.ttft
        else:
            ttft = self.slo.ttft
        return self._prefill_time(total_new) <= ttft

    def _constraint_d(self, reqs: Sequence[Request]) -> bool:
        if not self.ongoing:
            return True
        # ATGT divides decode time by (l_out - 1) — the first token is paid
        # by TTFT — so the banked slack is atgt*(l_out - 1), not atgt*l_out:
        # budgeting against l_out lets every stalled request finish up to
        # l_real/(l_real-1) over the SLO (a scale-invariant miss tail)
        if self._tagged(reqs):
            slack = min((self.slo.atgt if m.slo_atgt == math.inf
                         else m.slo_atgt) * max(m.l_out - 1, 0)
                        - m.t_decode_spent for m in self.ongoing)
        else:
            slack = min(self.slo.atgt * max(r.l_out - 1, 0)
                        - r.t_decode_spent for r in self.ongoing)
        total_new = sum(r.l_in - r.cached_len for r in self.new_batch) + \
            sum(r.l_in - r.cached_len for r in reqs)
        return self._prefill_time(total_new) <= \
            self.cfg.theta * max(slack, 0.0)

    def kv_peak(self, extra: Sequence[Request] = ()) -> float:
        """Constraint (e): peak KV demand over future iterations.

        Each request j contributes kv(context_j + k) at future iteration k and
        drops to zero after remaining_pred_j steps; the total is piecewise
        monotone between finish events, so the peak is attained just before
        some request finishes (or at k=0 when over-capacity already). The KV
        model is linear (Eq. 1), so each candidate peak is h·Σcontext_alive
        + n_alive·(h·k + j) over the suffix of requests outliving step k —
        O(b log b) overall instead of O(b²) kv-model evaluations."""
        reqs = list(self.ongoing) + self.new_batch + list(extra)
        if not reqs:
            return 0.0
        h, j = self.perf.kv.h, self.perf.kv.j
        items = sorted((r.remaining_pred, r.context) for r in reqs)
        n = len(items)
        suffix_ctx = 0.0
        suffix = [0.0] * (n + 1)       # suffix[i] = Σ context of items[i:]
        for i in range(n - 1, -1, -1):
            suffix_ctx += items[i][1]
            suffix[i] = suffix_ctx
        peak = h * suffix[0] + j * n
        i = 0
        for k in sorted({max(rem, 1) for rem, _ in items}):
            while i < n and items[i][0] < k:
                i += 1                 # drop requests finished before step k
            if i == n:
                break
            tot = h * (suffix[i] + (n - i) * k) + j * (n - i)
            if tot > peak:
                peak = tot
        return peak

    def _constraint_e(self, reqs: Sequence[Request]) -> bool:
        # theta pads the *predicted* KV trajectory against underestimates
        # (the w vectors in (e) are built from l_pred, so they carry the
        # same prediction error theta exists to absorb).
        return self.kv_peak(reqs) <= self.cfg.theta * self.cfg.kv_capacity

    def kv_now(self, extra: Sequence[Request] = ()) -> float:
        """Current KV usage (what a vLLM-style admission check sees)."""
        h, j = self.perf.kv.h, self.perf.kv.j
        own = len(self.ongoing) + len(self.new_batch)
        return h * sum(r.context for r in self.ongoing + self.new_batch) \
            + j * own + sum(h * r.l_in + j for r in extra)

    def _admit_naive(self, reqs: Sequence[Request]) -> bool:
        """Baseline admission: current KV + the new prompts fit, batch slot
        free. No future-peak, no latency awareness."""
        return (self.kv_now(reqs) <= self.cfg.kv_capacity
                and self.batch_size + len(reqs) <= self.cfg.max_batch)

    def feasible(self, reqs: Sequence[Request]) -> bool:
        if not self.alive or self.draining:
            return False
        if self.cfg.split_phase:
            return self._constraint_b(reqs) and self._constraint_e(reqs)
        return (self._constraint_b(reqs) and self._constraint_c(reqs)
                and self._constraint_d(reqs) and self._constraint_e(reqs))

    # ---- mutation ------------------------------------------------------------
    def place(self, r: Request) -> None:
        self._wctx_now()
        r.worker = self.id
        self.new_batch.append(r)
        self._wctx += r.l_in + self.cfg.gamma * r.l_pred
        self._wctx_key = (len(self.ongoing), len(self.new_batch))

    def unplace(self, r: Request) -> None:
        self._wctx_now()
        self.new_batch.remove(r)
        r.worker = None
        r.cached_len = 0    # a prefix-cache grant is void off this worker
        self._wctx -= r.l_in + self.cfg.gamma * r.l_pred
        self._wctx_key = (len(self.ongoing), len(self.new_batch))


# ---- vectorized scoring (struct-of-arrays engine) ----------------------------
#
# Array twins of the per-worker constraint/scoring methods above, shared by
# ``serving.fastsim``. They replicate the scalar code's floating-point
# operation ORDER exactly (multiply-then-add chains, sequential suffix
# accumulation), so a placement decision computed on arrays is bit-for-bit
# the decision the WorkerState methods would have made.


def kv_peak_arrays(rem: np.ndarray, ctx: np.ndarray, h: float,
                   j: float) -> float:
    """Vectorized :meth:`WorkerState.kv_peak`: peak future KV demand of a
    batch described by int arrays ``rem`` (remaining predicted tokens) and
    ``ctx`` (current context) — identical value to the scalar suffix scan."""
    n = int(rem.shape[0])
    if n == 0:
        return 0.0
    order = np.lexsort((ctx, rem))          # == sorted((rem, ctx)) tuples
    rem_s = rem[order]
    ctx_s = ctx[order]
    # suffix[i] = Σ ctx_s[i:], accumulated high-index-first like the scalar
    # loop (integer-valued, so the float image is exact either way)
    suffix = np.cumsum(ctx_s[::-1])[::-1]
    peak = h * float(suffix[0]) + j * n
    ks = np.unique(np.maximum(rem_s, 1))
    i = np.searchsorted(rem_s, ks, side="left")
    valid = i < n
    if valid.any():
        iv = i[valid]
        kv = ks[valid]
        tot = h * (suffix[iv] + (n - iv) * kv) + j * (n - iv)
        m = float(tot.max())
        if m > peak:
            peak = m
    return peak


def decode_budget_arrays(batch: np.ndarray, atgt, k2: np.ndarray,
                         c2: np.ndarray, c3: np.ndarray) -> np.ndarray:
    """Vectorized Eq. 4 across workers: ``max_total_context(batch, atgt)``
    per worker (inf where k2 <= 0), matching the scalar op order
    ``((atgt - c3) - c2*b) / k2`` then ``max(. , 0.0)``. ``atgt`` is a
    scalar, or a per-worker vector of effective (strictest-member) ATGT
    budgets in multi-tenant runs."""
    out = np.full(batch.shape, np.inf)
    pos = k2 > 0
    if pos.any():
        a = atgt[pos] if np.ndim(atgt) else atgt
        out[pos] = np.maximum(
            (a - c3[pos] - c2[pos] * batch[pos]) / k2[pos], 0.0)
    return out


def slack_arrays(l_out: np.ndarray, tds: np.ndarray, mask: np.ndarray,
                 atgt) -> np.ndarray:
    """Vectorized constraint-(d) banked slack: per-worker min over ongoing
    members of ``atgt*max(l_out-1, 0) - t_decode_spent`` for a padded
    (W, B) member layout; +inf where a worker has no ongoing requests.
    ``atgt`` is a scalar, or a (W, B) per-member budget array in
    multi-tenant runs (broadcast leaves the scalar image unchanged)."""
    vals = atgt * np.maximum(l_out - 1, 0) - tds
    vals = np.where(mask, vals, np.inf)
    return vals.min(axis=1)


def best_fit_order(norms: np.ndarray) -> np.ndarray:
    """Algorithm 1's ranking: capacity_norm descending, ties in worker-list
    order (``sorted(..., reverse=True)`` never reorders equal keys, and
    neither does a stable argsort of the negated key)."""
    return np.argsort(-norms, kind="stable")


def jsq_order(batch_sizes: np.ndarray) -> np.ndarray:
    """JSQ's ranking: batch size ascending, ties in worker-list order."""
    return np.argsort(batch_sizes, kind="stable")


def best_fit_place(workers: List[WorkerState], req: Request,
                   allow_new: bool = True,
                   new_worker_factory=None) -> Optional[WorkerState]:
    """Algorithm 1. Returns the worker the request was placed on (possibly a
    newly opened one), or None if allow_new=False and nothing fits."""
    ranked = sorted((w for w in workers if w.alive and not w.draining),
                    key=lambda w: w.capacity_norm(), reverse=True)
    for w in ranked:
        if w.feasible([req]):
            w.place(req)
            return w
    if allow_new and new_worker_factory is not None:
        w = new_worker_factory()
        workers.append(w)
        w.place(req)
        return w
    return None


def jsq_place(workers: List[WorkerState], req: Request, allow_new=True,
              new_worker_factory=None) -> Optional[WorkerState]:
    """Baseline: join-the-shortest-queue (by batch size), respecting only the
    KV-capacity constraint (what vLLM-style admission does)."""
    live = [w for w in workers if w.alive and not w.draining]
    for w in sorted(live, key=lambda w: w.batch_size):
        if w._admit_naive([req]):
            w.place(req)
            return w
    if allow_new and new_worker_factory is not None:
        w = new_worker_factory()
        workers.append(w)
        w.place(req)
        return w
    return None


def power_of_two_place(workers: List[WorkerState], req: Request, rng,
                       allow_new=True, new_worker_factory=None
                       ) -> Optional[WorkerState]:
    """Baseline: power-of-two-choices by predicted load [paper ref 10]."""
    live = [w for w in workers if w.alive and not w.draining]
    if len(live) >= 2:
        i, j = rng.choice(len(live), size=2, replace=False)
        cands = sorted((live[i], live[j]), key=lambda w: w.weighted_context())
    else:
        cands = live
    for w in cands:
        if w._admit_naive([req]):
            w.place(req)
            return w
    # fall back to any feasible live worker before opening a new one
    for w in sorted(live, key=lambda w: w.weighted_context()):
        if w in cands:
            continue
        if w._admit_naive([req]):
            w.place(req)
            return w
    if allow_new and new_worker_factory is not None:
        w = new_worker_factory()
        workers.append(w)
        w.place(req)
        return w
    return None
