"""Optimal worker (tensor-parallel) configuration (paper §4.1, Eqs. 5-6).

Search over TP degrees N_g for the one maximizing per-accelerator decode
throughput:

    t_compute(N_g) = k4 / N_g + c4                      (Eq. 5)
    t_comm(N_g)    = c_comm * (N_g - 1) / N_g           (All-reduce overhead)
    M(N_g)         = N_g * mem - model_bytes            (KV capacity)
    T_max(N_g)     = min( M / (N_g * m_r * t_iter),     (KV-bound)
                          B_slo / (N_g * T_dec) )       (SLO-bound)   (Eq. 6)

where t_iter = t_compute + t_comm at the KV-full batch size and B_slo is the
largest batch whose decode iteration meets the ATGT SLO (via Eq. 3/4).
The optimum is arrival-rate independent (§4.1), so it is computed once per
(model, hardware, SLO) and reused while autoscaling the worker *count*."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.perf_model import (DecodeModel, KVModel, PerfModel,
                                   PrefillModel)


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    mem_bytes: float                 # HBM per accelerator
    peak_flops: float                # bf16/fp16 FLOP/s per accelerator
    hbm_bw: float                    # bytes/s
    link_bw: float                   # effective all-reduce bytes/s
    link_latency: float = 10e-6      # per collective op
    max_group: int = 16              # largest TP degree offered


# The paper's A100 testbed is PCIe-connected (its §6.1); effective ring
# all-reduce bandwidth on PCIe 4.0 is ~8 GB/s with ~50us per op. The V100
# testbed is NVLink. TPU v5e ICI per-link ~50 GB/s, ~2us.
TPU_V5E = HardwareSpec("tpu-v5e", mem_bytes=16e9, peak_flops=197e12,
                       hbm_bw=819e9, link_bw=45e9, link_latency=2e-6,
                       max_group=16)
A100_80G = HardwareSpec("a100-80g", mem_bytes=80e9, peak_flops=312e12,
                        hbm_bw=2.0e12, link_bw=8e9, link_latency=25e-6,
                        max_group=8)
V100_32G = HardwareSpec("v100-32g", mem_bytes=32e9, peak_flops=125e12,
                        hbm_bw=0.9e12, link_bw=20e9, link_latency=20e-6,
                        max_group=8)


@dataclasses.dataclass
class WorkerConfig:
    n_accelerators: int
    kv_capacity: float               # M, bytes
    per_gpu_throughput: float        # T_max (req-iterations / s / accel)
    bound: str                       # "kv" | "slo"
    decode_model: DecodeModel


@dataclasses.dataclass
class WorkerSpec:
    """Everything the cluster simulator needs to know about one worker type.

    A heterogeneous fleet is a list of these; each simulated worker carries
    its own spec, so A100 TP=4 and V100 TP=8 workers coexist with their own
    latency models, KV capacities and accelerator costs. ``kv_capacity`` is
    in the same units the spec's KVModel outputs (token units for specs built
    by ``make_worker_spec``); ``kv_bytes_per_token`` is kept separately so
    the disaggregated simulator can price the prefill->decode KV transfer in
    bytes regardless of those units.

    ``price`` and ``preempt_hazard`` describe the worker's market class:
    on-demand capacity is ``price=1.0, preempt_hazard=0`` (the default);
    a spot/preemptible variant of the same hardware bills at a discount but
    can be reclaimed by the provider at any time — ``preempt_hazard`` is the
    per-worker per-second reclaim rate the mix planner
    (``core.scaling.split_spot_mix``) provisions against. Billed cost is
    always ``gpu_cost = n_accelerators * price``."""
    perf: PerfModel
    kv_capacity: float
    max_batch: int = 128
    n_accelerators: int = 1
    name: str = "worker"
    kv_bytes_per_token: float = 0.0
    price: float = 1.0               # $/accelerator-s relative to on-demand
    preempt_hazard: float = 0.0      # per-second reclaim rate (0 = on-demand)
    # LoRA multiplexing (multi-tenant serving): a base-model worker can hold
    # up to ``lora_slots`` resident adapters; each resident adapter eats
    # ``lora_overhead`` of ``kv_capacity`` (same units) for its weights, and
    # faulting a non-resident adapter in stalls the worker ``lora_swap_s``
    # seconds (weight fetch + load). ``lora_slots=0`` means the worker
    # cannot serve LoRA-tenant traffic at all.
    lora_slots: int = 0              # max resident adapters (0 = no LoRA)
    lora_overhead: float = 0.0       # kv_capacity units per resident adapter
    lora_swap_s: float = 0.0         # stall per adapter fault-in, seconds

    @property
    def gpu_cost(self) -> float:
        return float(self.n_accelerators) * self.price

    @property
    def is_spot(self) -> bool:
        return self.price < 1.0 or self.preempt_hazard > 0.0


def spot_variant(spec: WorkerSpec, price: float = 0.35,
                 preempt_hazard: float = 1.0 / 1800.0) -> WorkerSpec:
    """The preemptible twin of an on-demand worker type: same hardware and
    latency models, billed at ``price`` of on-demand, reclaimable at
    ``preempt_hazard`` per second."""
    return dataclasses.replace(spec, name=f"{spec.name}-spot", price=price,
                               preempt_hazard=preempt_hazard)


def make_worker_spec(arch, hw: HardwareSpec, slo,
                     n_g: Optional[int] = None,
                     mean_context: float = 1024.0,
                     max_batch: int = 128,
                     efficiency: float = 0.875,
                     prefill_efficiency: float = 0.5) -> WorkerSpec:
    """Build a simulator-ready WorkerSpec for ``arch`` on ``hw``.

    n_g=None runs the Eq. 5-6 search for the hardware's optimal TP degree;
    an explicit n_g models a fixed (possibly suboptimal) worker shape. The
    KV model is in token units (h=1), with capacity = M / kv-bytes-per-token,
    so constraint (e) compares token counts against a token budget."""
    if n_g is None:
        cfg = optimal_worker_config(arch, hw, slo, mean_context=mean_context,
                                    efficiency=efficiency)
        n_g, dm, M = cfg.n_accelerators, cfg.decode_model, cfg.kv_capacity
    else:
        M = n_g * hw.mem_bytes - 2.0 * arch.param_count()
        if M <= 0:
            raise ValueError(f"{arch.name} does not fit on {n_g}x {hw.name}")
        dm = _decode_model_for(arch, hw, n_g, efficiency)
    kv_tok = arch.kv_bytes_per_token()
    k1 = 2.0 * arch.param_count() / (n_g * hw.peak_flops * prefill_efficiency)
    perf = PerfModel(kv=KVModel(h=1.0, j=0.0),
                     prefill=PrefillModel(k1=k1, c1=0.01),
                     decode=dm)
    return WorkerSpec(perf=perf, kv_capacity=M / kv_tok, max_batch=max_batch,
                      n_accelerators=n_g, name=f"{hw.name}-tp{n_g}",
                      kv_bytes_per_token=kv_tok)


def _decode_model_for(arch, hw: HardwareSpec, n_g: int,
                      efficiency: float = 0.875) -> DecodeModel:
    """Analytic (k2, c2, c3) for a TP group of n_g accelerators (Eq. 5 with
    explicit comm terms): weights and KV reads split n_g ways; tensor
    parallelism pays 2 all-reduces per layer — a fixed latency per iteration
    (c3) and a ring-bandwidth cost per batched token (c2), both scaled by
    the (n_g - 1)/n_g ring factor."""
    n_active = arch.param_count(active_only=True)
    weight_bytes = 2.0 * arch.param_count()
    kv_tok = arch.kv_bytes_per_token()
    bw = hw.hbm_bw * efficiency
    peak = hw.peak_flops * efficiency
    ring = (n_g - 1) / max(n_g, 1)
    n_ar = 2 * arch.n_layers                 # attention + MLP all-reduce
    # per-token all-reduce payload: d_model bf16, x2 for ring traffic
    ar_bytes_tok = n_ar * arch.d_model * 2 * 2
    # ring all-reduce latency: 2*(n_g - 1) hops per op
    c3 = weight_bytes / (n_g * bw) \
        + n_ar * 2 * (n_g - 1) * hw.link_latency
    k2 = kv_tok / (n_g * bw)
    c2 = 2.0 * n_active / (n_g * peak) + ring * ar_bytes_tok / hw.link_bw
    return DecodeModel(k2=k2, c2=c2, c3=c3)


def optimal_worker_config(arch, hw: HardwareSpec, slo,
                          mean_context: float = 1024.0,
                          candidates: Optional[Sequence[int]] = None,
                          efficiency: float = 0.875,
                          kv_dtype_bytes: int = 2) -> WorkerConfig:
    """Pick N_g maximizing Eq. 6's per-accelerator throughput.
    kv_dtype_bytes=1 models an int8-quantized KV cache (serving.kv_quant):
    doubles the capacity M can hold and halves the decode KV-read slope k2."""
    model_bytes = 2.0 * arch.param_count()
    cands = candidates or [g for g in (1, 2, 4, 8, 16) if g <= hw.max_group]
    best: Optional[WorkerConfig] = None
    kv_scale = kv_dtype_bytes / 2.0
    for n_g in cands:
        M = n_g * hw.mem_bytes - model_bytes
        if M <= 0:
            continue
        dm = _decode_model_for(arch, hw, n_g, efficiency)
        dm = DecodeModel(k2=dm.k2 * kv_scale, c2=dm.c2, c3=dm.c3)
        kv_tok = arch.kv_bytes_per_token() * kv_scale
        m_r = kv_tok * mean_context + arch.ssm_state_bytes()   # per-request KV
        b_kv = max(M / max(m_r, 1.0), 1.0)                     # KV-full batch
        t_iter = dm(b_kv, b_kv * mean_context)
        thr_kv = b_kv / (n_g * t_iter)
        b_slo = dm.max_batch(slo.atgt, mean_context)
        thr_slo = b_slo / (n_g * slo.atgt)
        if thr_kv <= thr_slo:
            thr, bound = thr_kv, "kv"
        else:
            thr, bound = thr_slo, "slo"
        cfg = WorkerConfig(n_accelerators=n_g, kv_capacity=M,
                           per_gpu_throughput=thr, bound=bound,
                           decode_model=dm)
        if best is None or cfg.per_gpu_throughput > best.per_gpu_throughput:
            best = cfg
    if best is None:
        raise ValueError(
            f"{arch.name} does not fit on {hw.name} with <= "
            f"{hw.max_group} accelerators per worker")
    return best
