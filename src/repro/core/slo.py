"""Service-level objectives (paper §2.2).

TTFT  — time-to-first-token deadline for the prefill stage (constant per
        deployment; the paper sets it near the full-context prefill latency).
ATGT  — average token-generation time: decode_time / (l_out - 1) must stay
        below the target (the paper's alternative to over-strict TBT).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class SLO:
    ttft: float           # seconds
    atgt: float           # seconds per generated token
    attain_target: float = 1.0   # fraction of requests that must meet both

    def scaled(self, f: float) -> "SLO":
        return SLO(self.ttft * f, self.atgt * f, self.attain_target)


def slo_attainment(finished: Iterable, total: int, slo: "SLO") -> float:
    """Canonical SLO attainment: requests meeting BOTH deadlines over all
    requests offered (ok / total).  Unfinished requests count as misses.

    Every simulator result (colocated, disaggregated, autoscaled) must report
    this one definition, so cost comparisons across serving topologies can
    never drift apart on the metric itself."""
    ok = sum(1 for r in finished if r.slo_ok(slo))
    return ok / max(total, 1)


def slo_metric_ok(r, slo: "SLO", metric: str = "both") -> bool:
    """Per-request SLO verdict restricted to one dimension.

    ``ttft`` judges the prefill hop alone (what a disaggregated prefill
    side controls), ``atgt`` the decode stream alone (the decode side's
    job), ``both`` is the canonical :meth:`Request.slo_ok`. A dimension the
    request never exercised (no first token / single-token output) passes,
    matching ``slo_ok``'s convention."""
    if metric == "both":
        return r.slo_ok(slo)
    if metric == "ttft":
        v, budget = r.ttft(), slo.ttft
    elif metric == "atgt":
        v, budget = r.atgt(), slo.atgt
    else:
        raise ValueError(f"unknown SLO metric {metric!r}")
    return v is None or v <= budget


def windowed_attainment(finished: Iterable, slo: "SLO", t_now: float,
                        window: float, metric: str = "both",
                        ttft_pending: Iterable = ()) -> tuple:
    """Windowed observed attainment for the SLO-feedback controllers:
    (ok, total) over requests finished in ``[t_now - window, t_now]``
    judged by ``metric``, plus assured misses among ``ttft_pending`` —
    requests still waiting whose TTFT budget already expired (counted
    whenever the metric watches TTFT). Those keep the feedback signal
    alive in congestion collapse, when nothing finishes at all. One
    definition shared by every topology, so the per-side controllers of a
    disaggregated cluster and the colocated loop can never drift apart on
    the signal itself."""
    t0 = t_now - window
    ok = total = 0
    for r in finished:
        if r.t_finish is not None and r.t_finish >= t0:
            total += 1
            if slo_metric_ok(r, slo, metric):
                ok += 1
    if metric != "atgt":
        for r in ttft_pending:
            if r.t_first_token is None and t_now - r.arrival > slo.ttft:
                total += 1
    return ok, total


# The paper's Table 2 (A100 testbed), in seconds.
PAPER_SLOS = {
    "llama2-70b": SLO(ttft=1.6, atgt=0.075),
    "llama2-13b": SLO(ttft=0.6, atgt=0.030),
    "llama2-7b": SLO(ttft=0.4, atgt=0.015),
}
