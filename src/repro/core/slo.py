"""Service-level objectives (paper §2.2).

TTFT  — time-to-first-token deadline for the prefill stage (constant per
        deployment; the paper sets it near the full-context prefill latency).
ATGT  — average token-generation time: decode_time / (l_out - 1) must stay
        below the target (the paper's alternative to over-strict TBT).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class SLO:
    ttft: float           # seconds
    atgt: float           # seconds per generated token
    attain_target: float = 1.0   # fraction of requests that must meet both

    def scaled(self, f: float) -> "SLO":
        return SLO(self.ttft * f, self.atgt * f, self.attain_target)


def slo_attainment(finished: Iterable, total: int, slo: "SLO") -> float:
    """Canonical SLO attainment: requests meeting BOTH deadlines over all
    requests offered (ok / total).  Unfinished requests count as misses.

    Every simulator result (colocated, disaggregated, autoscaled) must report
    this one definition, so cost comparisons across serving topologies can
    never drift apart on the metric itself."""
    ok = sum(1 for r in finished if r.slo_ok(slo))
    return ok / max(total, 1)


# The paper's Table 2 (A100 testbed), in seconds.
PAPER_SLOS = {
    "llama2-70b": SLO(ttft=1.6, atgt=0.075),
    "llama2-13b": SLO(ttft=0.6, atgt=0.030),
    "llama2-7b": SLO(ttft=0.4, atgt=0.015),
}
