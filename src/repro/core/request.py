"""Request lifecycle shared by the scheduler, engine and simulator."""
from __future__ import annotations

import dataclasses
import enum
import itertools
import math
from typing import Optional

_ids = itertools.count()


class ReqState(str, enum.Enum):
    QUEUED = "queued"          # arrived, not yet placed
    PLACED = "placed"          # assigned to a worker, waiting for prefill
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"
    FAILED = "failed"          # worker died; will be re-queued


@dataclasses.dataclass
class Request:
    l_in: int                              # prompt length (known on arrival)
    l_pred: int                            # predicted output length
    l_real: int = 0                        # ground-truth output (sim/engine)
    arrival: float = 0.0
    id: int = dataclasses.field(default_factory=lambda: next(_ids))
    state: ReqState = ReqState.QUEUED
    worker: Optional[int] = None
    # progress
    l_out: int = 0                         # tokens generated so far
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    t_decode_spent: float = 0.0            # decode wall time so far
    t_prefill_start: Optional[float] = None
    repredicted: bool = False              # Alg. 2: re-predicted after overrun
    tokens: Optional[object] = None        # actual token ids (engine only)
    # spot-preemption recovery: the worker serving this request was reclaimed
    # mid-flight, its KV was lost, and the request re-entered the queue. The
    # generated-token count (l_out) is retained — recovery re-prefills the
    # prompt AND the tokens generated so far — and the stall from reclaim to
    # re-prefill completion is charged against the ATGT clock.
    preempt_count: int = 0                 # times reclaimed mid-flight
    t_preempted: Optional[float] = None    # pending reclaim stall start
    # multi-tenant serving: which TenantSpec this request belongs to (index
    # into Scenario.tenants), its admission priority (higher places first),
    # and its tenant's own SLO budgets. ``inf`` budgets mean "untagged":
    # every constraint falls back to the scenario-level planning SLO, so a
    # legacy scalar-SLO trace is arithmetically untouched by the tenant
    # plumbing.
    tenant: int = 0
    priority: int = 0
    slo_ttft: float = math.inf             # tenant TTFT budget, seconds
    slo_atgt: float = math.inf             # tenant ATGT budget, s/token
    # multi-turn sessions: which conversation this request is a turn of
    # (``-1`` = a single-shot request outside any session), its turn index,
    # and the cacheable-prefix potential — the previous turn's full context
    # (prompt + generated), which a worker holding that KV can skip
    # re-prefilling. ``cached_len`` is the *granted* reuse: stamped at
    # placement from the chosen worker's prefix cache, consumed by the
    # first prefill, and zeroed on any requeue/move (the grant is only
    # valid on the worker that holds the blocks). All four default to the
    # neutral values, so single-shot traces are arithmetically untouched.
    session_id: int = -1
    turn: int = 0
    prefix_len: int = 0                    # cacheable prefix, tokens
    cached_len: int = 0                    # granted prefix reuse, tokens

    # ---- derived ------------------------------------------------------------
    @property
    def deadline(self) -> float:
        """Absolute EDF deadline (arrival + tenant TTFT budget); ordering
        key only — constraints use the relative ``slo_ttft`` budget so the
        float image of a single-tenant run matches the scalar path."""
        return self.arrival + self.slo_ttft

    @property
    def context(self) -> int:
        """Current context length (prompt + generated)."""
        return self.l_in + self.l_out

    @property
    def remaining_pred(self) -> int:
        return max(self.l_pred - self.l_out, 0)

    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival

    def atgt(self) -> Optional[float]:
        """Average token-generation time over the decode phase (§2.2)."""
        if self.t_finish is None or self.l_real <= 1:
            return None
        return self.t_decode_spent / max(self.l_real - 1, 1)

    def slo_ok(self, slo) -> bool:
        t1, t2 = self.ttft(), self.atgt()
        ok = True
        if t1 is not None:
            ok &= t1 <= slo.ttft
        if t2 is not None:
            ok &= t2 <= slo.atgt
        return ok
