"""Distributed grouped scheduling (paper Appendix A).

At high arrival rates a single centralized best-fit scheduler exceeds the
millisecond placement budget (best-fit is O(n log n) per heartbeat batch).
Requests are round-robin sampled into N_group scheduler groups; group i only
places onto its own worker slice. Group sizing follows Eq. 8:

    1/(2e)  <=  r_i  <=  r(T_s),    sum r_i = r_a

- the lower bound keeps the extra-worker error below e (each group needs at
  least 1/(2e) workers; with ~half the groups rounding up one extra worker,
  the relative overhead stays under e);
- the upper bound keeps each group's scheduling latency under T_s, using the
  fitted t_sched(n) = a * n log n + b model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core.placement import WorkerState, best_fit_place
from repro.core.request import Request


@dataclasses.dataclass
class SchedLatencyModel:
    """t_sched(n) = a * n log2(n+1) + b, fitted from measurements."""
    a: float = 2e-6
    b: float = 1e-4

    def __call__(self, n: float) -> float:
        return self.a * n * math.log2(n + 1) + self.b

    def max_rate(self, t_s: float, heartbeat: float) -> float:
        """Largest per-heartbeat batch (as a rate) schedulable within t_s."""
        lo, hi = 1.0, 1e7
        for _ in range(64):
            mid = 0.5 * (lo + hi)
            if self(mid) <= t_s:
                lo = mid
            else:
                hi = mid
        return lo / heartbeat

    @staticmethod
    def fit(ns: Sequence[int], ts: Sequence[float]) -> "SchedLatencyModel":
        ns = np.asarray(ns, np.float64)
        A = np.stack([ns * np.log2(ns + 1), np.ones(len(ns))], axis=1)
        (a, b), *_ = np.linalg.lstsq(A, np.asarray(ts, np.float64), rcond=None)
        return SchedLatencyModel(float(a), float(b))


def choose_group_count(rate: float, n_workers: int, *, error_budget: float,
                       t_s: float, heartbeat: float,
                       lat: SchedLatencyModel) -> int:
    """Eq. 8: groups small enough for the latency bound, large enough for the
    utilization bound (>= 1/(2e) workers per group)."""
    min_rate = 1.0 / (2.0 * error_budget)           # r_i lower bound
    max_rate = max(lat.max_rate(t_s, heartbeat), min_rate)
    n_hi = max(int(rate / min_rate), 1)             # groups can't be smaller
    n_lo = max(int(math.ceil(rate / max_rate)), 1)  # need at least this many
    n = max(n_lo, 1)
    n = min(n, n_hi, max(n_workers, 1))
    return max(n, 1)


class GroupedScheduler:
    """Round-robin request router over per-group best-fit schedulers."""

    def __init__(self, workers: List[WorkerState], n_groups: int):
        self.n_groups = max(n_groups, 1)
        self.groups: List[List[WorkerState]] = [
            [] for _ in range(self.n_groups)]
        for i, w in enumerate(workers):
            self.groups[i % self.n_groups].append(w)
        self._rr = 0

    def route(self, req: Request) -> int:
        g = self._rr
        self._rr = (self._rr + 1) % self.n_groups
        return g

    def place(self, req: Request, new_worker_factory=None
              ) -> Optional[WorkerState]:
        g = self.route(req)
        w = best_fit_place(self.groups[g], req, allow_new=True,
                           new_worker_factory=new_worker_factory)
        return w

    @property
    def workers(self) -> List[WorkerState]:
        return [w for g in self.groups for w in g]
