"""Exact reference solver for the placement MIP (small instances only).

Branch-and-bound over request->worker assignments minimizing the number of
workers used, subject to the same constraints (b)-(e) as the heuristic. Used
by tests to certify Algorithm 1's near-optimality (best-fit is 1.7-competitive
for classical bin packing; the paper calls it near-optimal)."""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

from repro.core.placement import WorkerState
from repro.core.request import Request


def exact_min_workers(requests: Sequence[Request],
                      worker_factory: Callable[[int], WorkerState],
                      max_workers: int = 6) -> Optional[int]:
    """Smallest number of workers that can feasibly hold all requests
    (requests are placed as one heartbeat batch, like the MIP in §4.2).
    Returns None if infeasible within max_workers."""
    reqs = sorted(requests, key=lambda r: -(r.l_in + r.l_pred))

    for n in range(1, max_workers + 1):
        workers = [worker_factory(i) for i in range(n)]
        if _assign(reqs, 0, workers):
            return n
    return None


def _assign(reqs: List[Request], i: int,
            workers: List[WorkerState]) -> bool:
    if i == len(reqs):
        return True
    r = reqs[i]
    tried_empty = False
    for w in workers:
        if not w.new_batch and not w.ongoing:
            if tried_empty:          # symmetry breaking: empties are identical
                continue
            tried_empty = True
        if w.feasible([r]):
            w.place(r)
            if _assign(reqs, i + 1, workers):
                return True
            w.unplace(r)
    return False
