"""Aladdin's core: SLO-aware co-adaptive placement and scaling.

  perf_model     — Eqs. 1-4 (KV / prefill / decode latency models + fitting)
  worker_config  — Eqs. 5-6 (optimal TP degree per worker)
  placement      — §4.2 MIP constraints + Algorithm 1 best-fit (+ JSQ/Po2
                   baselines)
  rebalance      — §4.3 Algorithm 2 (prediction-error re-balancing)
  scaling        — §5.2 Eq. 7 autoscaler + change-point detection
  distributed_scheduler — Appendix A grouped scheduling
  mip            — exact reference solver (tests)
"""
from repro.core.perf_model import (DecodeModel, KVModel, PerfModel,           # noqa: F401
                                   PrefillModel, TraceBuffer,
                                   analytic_perf_model)
from repro.core.placement import (PlacementConfig, WorkerState,               # noqa: F401
                                  best_fit_place, jsq_place,
                                  power_of_two_place)
from repro.core.rebalance import ErrorTracker, rebalance                      # noqa: F401
from repro.core.request import ReqState, Request                              # noqa: F401
from repro.core.scaling import (AttainmentController, Autoscaler,             # noqa: F401
                                AutoscalerConfig, FeedbackConfig,
                                SpotMixConfig, split_spot_mix)
from repro.core.slo import (PAPER_SLOS, SLO, slo_attainment,                  # noqa: F401
                            slo_metric_ok, windowed_attainment)
from repro.core.worker_config import (A100_80G, TPU_V5E, V100_32G,            # noqa: F401
                                      HardwareSpec, WorkerConfig, WorkerSpec,
                                      make_worker_spec,
                                      optimal_worker_config, spot_variant)
from repro.core.distributed_scheduler import (GroupedScheduler,               # noqa: F401
                                              SchedLatencyModel,
                                              choose_group_count)
from repro.core.mip import exact_min_workers                                  # noqa: F401
