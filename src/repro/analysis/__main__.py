"""CLI: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean (baseline-accepted findings included), 1 findings or
stale baseline entries, 2 usage/parse error.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis.checkers import ALL_CHECKERS
from repro.analysis.core import Project, run_checkers
from repro.analysis.diagnostics import CODES, Baseline


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="simlint: repo-specific static analysis enforcing "
                    "the simulator's invariants")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files or directories to analyze (default: src)")
    p.add_argument("--baseline", type=Path, default=None,
                   help="tracked allowlist JSON; accepted findings pass, "
                        "stale entries fail")
    p.add_argument("--write-baseline", type=Path, default=None,
                   metavar="PATH",
                   help="write current findings to PATH as the new "
                        "baseline and exit 0")
    p.add_argument("--list-codes", action="store_true",
                   help="print the SIM00x registry and exit")
    p.add_argument("--select", action="append", default=None,
                   metavar="CODE", help="run only these codes")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_codes:
        for code, desc in CODES.items():
            print(f"{code}  {desc}")
        return 0

    checkers = [cls() for cls in ALL_CHECKERS]
    if args.select:
        checkers = [c for c in checkers if c.code in set(args.select)]
        if not checkers:
            print(f"simlint: no checker matches --select {args.select}",
                  file=sys.stderr)
            return 2

    try:
        project = Project.collect([Path(p) for p in args.paths])
    except (RuntimeError, OSError) as e:
        print(f"simlint: {e}", file=sys.stderr)
        return 2

    diags = run_checkers(project, checkers)

    if args.write_baseline is not None:
        Baseline.from_diagnostics(diags).save(args.write_baseline)
        print(f"simlint: wrote {len(diags)} finding(s) to "
              f"{args.write_baseline}")
        return 0

    baseline = Baseline()
    if args.baseline is not None:
        try:
            baseline = Baseline.load(args.baseline)
        except (OSError, ValueError) as e:
            print(f"simlint: cannot load baseline: {e}", file=sys.stderr)
            return 2

    fresh = [d for d in diags if not baseline.accepts(d)]
    for d in fresh:
        print(d.format())
    stale = baseline.stale_entries()
    for e in stale:
        print(f"simlint: stale baseline entry {e['code']} {e['path']} "
              f"{e['text']!r} matched nothing; remove it")

    n_files = len(project.files)
    if fresh or stale:
        print(f"simlint: {len(fresh)} finding(s), {len(stale)} stale "
              f"baseline entr{'y' if len(stale) == 1 else 'ies'} "
              f"across {n_files} file(s)")
        return 1
    accepted = len(diags) - len(fresh)
    suffix = f" ({accepted} baseline-accepted)" if accepted else ""
    print(f"simlint: clean — {n_files} file(s), "
          f"{len(checkers)} checker(s){suffix}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
