"""SIM002 — 64-bit precision is scoped, never process-global.

PR 6 settled the precision discipline: the compiled cores run in
float32/int32 by default and opt into doubles only under a scoped
``with enable_x64():`` block, so one import can never flip dtype
semantics for the rest of the process (and with it, the bit-for-bit
equivalence grid).  This checker flags the three escape hatches:
``jax.config.update("jax_enable_x64", ...)``, assignment to
``config.jax_enable_x64``, and a bare ``enable_x64()`` call used as a
statement instead of a ``with`` context.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import Checker, SourceFile, dotted_name
from repro.analysis.diagnostics import Diagnostic


class X64Scope(Checker):
    code = "SIM002"
    name = "x64-scope"

    def check_file(self, src: SourceFile) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call):
                fname = dotted_name(node.func)
                if fname.endswith("config.update") and node.args and \
                        isinstance(node.args[0], ast.Constant) and \
                        node.args[0].value == "jax_enable_x64":
                    diags.append(src.diag(
                        "SIM002", node,
                        "process-global `config.update(\"jax_enable_x64\""
                        ", ...)`; use a scoped `with enable_x64():` block"))
                elif fname.rsplit(".", 1)[-1] == "enable_x64":
                    parent = getattr(node, "parent", None)
                    in_with = isinstance(parent, ast.withitem)
                    if not in_with:
                        diags.append(src.diag(
                            "SIM002", node,
                            "`enable_x64()` outside a `with` statement "
                            "leaks 64-bit mode; use "
                            "`with enable_x64():`"))
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Attribute) and \
                            t.attr == "jax_enable_x64":
                        diags.append(src.diag(
                            "SIM002", node,
                            "direct assignment to `config.jax_enable_x64`"
                            "; use a scoped `with enable_x64():` block"))
        return diags
