"""SIM004 — causal clocks are stamped only by blessed helpers.

``t_first_token`` / ``t_finish`` / ``t_preempted`` and the ATGT
accumulator ``t_decode_spent`` define the attainment numbers every
benchmark gates on; both historical clock bugs came from ad-hoc writes
that bypassed the causal bookkeeping (admission-before-arrival, the
resumed-victim ATGT hole).  Writes to these fields — and element writes
into the vectorized cores' clock arrays — are therefore only legal
inside a short whitelist of helpers whose monotonicity is pinned by the
equivalence grid.  Everything else must route through those helpers.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import Checker, SourceFile, qualname_of
from repro.analysis.diagnostics import Diagnostic

CLOCK_ATTRS = {"t_first_token", "t_finish", "t_preempted",
               "t_decode_spent"}
# vectorized-core clock arrays: element writes only (whole-array
# (re)allocation in __init__ is setup, not a clock stamp)
CLOCK_ARRAYS = {"t_first", "t_fin", "tds", "t_w"}

# path suffix -> qualnames blessed to stamp clocks there
BLESSED = {
    "serving/simulator.py": {"SimWorker.advance_to",
                             # LoRA adapter fault-in: the swap stall
                             # charges ongoing members' ATGT clocks a
                             # non-negative delay (reference engine only)
                             "ColocatedTopology._lora_admit"},
    "serving/fastsim.py": {"_Engine._advance", "_Engine._step",
                           "_Engine.writeback",
                           # pooled/scaled lanes: boot resets and the
                           # per-beat lane-clock advance, pinned by the
                           # engine equivalence grid
                           "_Engine._spawn_lane", "_Engine._step_pooled"},
    "serving/fastsim_jax.py": {"run_colocated_jax",
                               # the chunked engine's writeback — the
                               # jax counterpart of _Engine.writeback
                               "_pooled_report"},
    "serving/disagg.py": {"PrefillSimWorker.advance_to"},
    "serving/lifecycle.py": {"mark_kv_loss", "mark_requeue"},
    "serving/engine.py": {"PagedEngine.step"},
    "serving/cluster.py": {"ServingCluster.inject_failure"},
}


def _blessed_here(rel: str, qualname: str) -> bool:
    for suffix, quals in BLESSED.items():
        if rel.endswith(suffix):
            return any(qualname == q or qualname.startswith(q + ".")
                       for q in quals)
    return False


class ClockMonotonicity(Checker):
    code = "SIM004"
    name = "clock-monotonicity"

    def applies(self, src: SourceFile) -> bool:
        return src.rel.startswith("src/") and "/analysis/" not in src.rel

    def check_file(self, src: SourceFile) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            else:
                continue
            for t in targets:
                field = None
                if isinstance(t, ast.Attribute) and \
                        t.attr in CLOCK_ATTRS:
                    field = t.attr
                elif isinstance(t, ast.Subscript):
                    base = t.value
                    name = (base.attr if isinstance(base, ast.Attribute)
                            else base.id if isinstance(base, ast.Name)
                            else "")
                    if name in CLOCK_ARRAYS:
                        field = f"{name}[...]"
                if field is None:
                    continue
                qual = qualname_of(node)
                if _blessed_here(src.rel, qual):
                    continue
                where = qual or "<module>"
                diags.append(src.diag(
                    "SIM004", node,
                    f"clock field `{field}` stamped outside the blessed "
                    f"helpers (in `{where}`); route through "
                    "SimWorker.advance_to / the engine writeback"))
        return diags
