"""The repo-specific checkers, one stable ``SIM00x`` code each."""
from repro.analysis.checkers.clocks import ClockMonotonicity
from repro.analysis.checkers.envelope import EnvelopeCoverage
from repro.analysis.checkers.jit_purity import JitPurity
from repro.analysis.checkers.shims import ShimFreeze
from repro.analysis.checkers.units import UnitSafety
from repro.analysis.checkers.x64_scope import X64Scope

ALL_CHECKERS = [JitPurity, X64Scope, UnitSafety, ClockMonotonicity,
                ShimFreeze, EnvelopeCoverage]

__all__ = ["ALL_CHECKERS", "ClockMonotonicity", "EnvelopeCoverage",
           "JitPurity", "ShimFreeze", "UnitSafety", "X64Scope"]
