"""SIM003 — dimensional safety over the serving/core arithmetic.

Both causal-clock bugs this repo has shipped (PR 2's constraint-(d)
off-by-one, PR 6's resumed-victim ATGT hole) were unit/time arithmetic
slips that type checkers cannot see because everything is a float.  The
code already encodes dimensions in its naming conventions — ``t_*`` /
``*_s`` / ``dur*`` are seconds, ``l_out`` / ``context`` / ``*_tokens``
are token counts, ``*gpu_s`` / ``gpu_seconds`` are billed GPU-seconds,
``price`` / ``*_cost`` are dollars — so this checker infers a dimension
per name and flags additions, subtractions, comparisons, and augmented
assignments whose two sides carry *different known* dimensions.
Constants and computed intermediates are wildcards; multiplication and
division legitimately change dimension and produce "unknown", so only a
provable seconds-vs-tokens (etc.) mix is reported.
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional

from repro.analysis.core import Checker, SourceFile, dotted_name
from repro.analysis.diagnostics import Diagnostic

# precedence matters: `gpu_s` must resolve before the generic `*_s`
DIM_PATTERNS = [
    ("price", re.compile(r"(^price$|^cost$|_cost$|_price$)")),
    ("gpu_seconds", re.compile(r"(gpu_s$|gpu_seconds$)")),
    ("tokens", re.compile(
        r"(^l_(in|out|real|pred)$|_tokens$|^tokens$"
        r"|^(ctx|context|total_in|tot_in|newsum)$)")),
    ("seconds", re.compile(
        r"(^t$|^t[0-9]$|^t_|_s$|^dur|^ttft$|^atgt$|^arrival$|^horizon$"
        r"|^heartbeat$|^hb$|^seg$|^tail$|^duration$|^elapsed$|^deadline$"
        r"|^boot_delay$|^notice$)")),
]

_PASSTHROUGH = {"min", "max", "abs", "maximum", "minimum", "where",
                "sum", "float", "round", "clip"}


def dim_of_name(name: str) -> Optional[str]:
    for dim, pat in DIM_PATTERNS:
        if pat.search(name):
            return dim
    return None


def dim_of(node: ast.AST) -> Optional[str]:
    """Infer the dimension of an expression; None = unknown/wildcard."""
    if isinstance(node, ast.Name):
        return dim_of_name(node.id)
    if isinstance(node, ast.Attribute):
        return dim_of_name(node.attr)
    if isinstance(node, ast.Subscript):
        return dim_of(node.value)
    if isinstance(node, ast.UnaryOp):
        return dim_of(node.operand)
    if isinstance(node, ast.Starred):
        return dim_of(node.value)
    if isinstance(node, ast.IfExp):
        a, b = dim_of(node.body), dim_of(node.orelse)
        return a if a == b else None
    if isinstance(node, ast.Call):
        tail = dotted_name(node.func).rsplit(".", 1)[-1]
        if tail in _PASSTHROUGH:
            dims = {d for d in (dim_of(a) for a in node.args)
                    if d is not None}
            return dims.pop() if len(dims) == 1 else None
        return None
    if isinstance(node, ast.BinOp):
        if isinstance(node.op, (ast.Add, ast.Sub)):
            a, b = dim_of(node.left), dim_of(node.right)
            return a or b               # known-wins propagation
        return None                     # mult/div change dimension
    return None


class UnitSafety(Checker):
    code = "SIM003"
    name = "unit-safety"

    def applies(self, src: SourceFile) -> bool:
        return "repro/serving/" in src.rel or "repro/core/" in src.rel

    def _flag(self, src: SourceFile, node: ast.AST, a: str, b: str,
              what: str) -> Diagnostic:
        return src.diag(
            "SIM003", node,
            f"{what} mixes dimensions: {a} vs {b} (inferred from naming "
            "conventions); convert explicitly or rename")

    def check_file(self, src: SourceFile) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.BinOp) and \
                    isinstance(node.op, (ast.Add, ast.Sub)):
                a, b = dim_of(node.left), dim_of(node.right)
                if a and b and a != b:
                    op = "+" if isinstance(node.op, ast.Add) else "-"
                    diags.append(self._flag(src, node, a, b, f"`{op}`"))
            elif isinstance(node, ast.Compare):
                left = node.left
                for cmp_op, right in zip(node.ops, node.comparators):
                    if isinstance(cmp_op, (ast.In, ast.NotIn, ast.Is,
                                           ast.IsNot)):
                        left = right
                        continue
                    a, b = dim_of(left), dim_of(right)
                    if a and b and a != b:
                        diags.append(self._flag(src, node, a, b,
                                                "comparison"))
                    left = right
            elif isinstance(node, ast.AugAssign) and \
                    isinstance(node.op, (ast.Add, ast.Sub)):
                a, b = dim_of(node.target), dim_of(node.value)
                if a and b and a != b:
                    diags.append(self._flag(src, node, a, b,
                                            "augmented assignment"))
        return diags
