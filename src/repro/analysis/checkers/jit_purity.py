"""SIM001 — jit purity and the fastsim_jax performance contract.

The compiled beat loop stays fast only while every per-beat update is a
single-element ``.at[i].set(..., mode="drop")`` into a lane-resident
carry; a bulk scatter (slice/``reshape``/``arange``-shaped index) costs
~50ns *per element of the index* per beat on CPU XLA, which is exactly
the regression the performance-contract docstring forbids.  Python-level
``if``/``for`` on traced values and ``float()``/``int()``/``np.*``
coercions of tracers are concretization errors waiting for the next
``jit`` — or silent per-call retraces.

Traced scope is discovered structurally: function defs (and lambdas)
passed as the cond/body of ``lax.while_loop``/``fori_loop``/``scan``,
and Pallas kernels reaching ``pl.pallas_call`` directly or through
``functools.partial``.  Positional parameters of a traced function are
tracers; keyword-only parameters are static configuration (the Pallas
idiom) and are exempt, as are closure names bound outside traced scope.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import (Checker, SourceFile, dotted_name,
                                 names_in)
from repro.analysis.diagnostics import Diagnostic

# index-producing calls that make a scatter "bulk" (index size scales
# with the trace / lane count instead of being one element)
BULK_INDEX_PRODUCERS = {
    "reshape", "ravel", "flatten", "arange", "nonzero", "flatnonzero",
    "argsort", "take", "repeat", "tile", "broadcast_to", "concatenate",
    "stack", "where",
}
# NB: bare ``jnp.where(cond, a, b)`` three-arg select is fine and common
# in scalar index computation; only single-arg where (nonzero-like) is a
# bulk producer.  _is_bulk_call() below makes that distinction.

SCATTER_METHODS = {"set", "add", "mul", "min", "max", "multiply",
                   "divide", "power", "apply"}

_LOOP_FUNCS = {"while_loop": (0, 1), "fori_loop": (2,), "scan": (0,)}


def _is_bulk_call(call: ast.Call) -> bool:
    tail = dotted_name(call.func).rsplit(".", 1)[-1]
    if isinstance(call.func, ast.Attribute) and call.func.attr in \
            BULK_INDEX_PRODUCERS:
        tail = call.func.attr
    if tail not in BULK_INDEX_PRODUCERS:
        return False
    if tail == "where":
        return len(call.args) == 1        # nonzero-style where only
    return True


class _TracedFunc:
    def __init__(self, node, kind: str, inherited: Set[str]):
        self.node = node
        self.kind = kind                  # "loop_body" | "kernel"
        args = node.args
        pos = [a.arg for a in (*args.posonlyargs, *args.args)]
        kwonly = {a.arg for a in args.kwonlyargs}
        self.traced: Set[str] = (set(pos) | set(inherited)) - kwonly
        self.static: Set[str] = kwonly
        # local name -> RHS expr (one-level dataflow for index analysis)
        self.assigns: Dict[str, ast.AST] = {}
        self._close(node)

    def _close(self, node) -> None:
        """Fixpoint: locals assigned from traced expressions are traced."""
        body = node.body if isinstance(node.body, list) else [node.body]
        changed = True
        while changed:
            changed = False
            for stmt in ast.walk(ast.Module(body=body, type_ignores=[])):
                if isinstance(stmt, (ast.FunctionDef, ast.Lambda)) \
                        and stmt is not node:
                    continue
                targets: List[ast.AST] = []
                value: Optional[ast.AST] = None
                if isinstance(stmt, ast.Assign):
                    targets, value = stmt.targets, stmt.value
                elif isinstance(stmt, ast.AnnAssign) and stmt.value:
                    targets, value = [stmt.target], stmt.value
                elif isinstance(stmt, ast.AugAssign):
                    targets, value = [stmt.target], stmt.value
                if value is None:
                    continue
                tainted = bool(names_in(value) & self.traced)
                for t in targets:
                    names = [t] if isinstance(t, ast.Name) else [
                        e for e in ast.walk(t) if isinstance(e, ast.Name)]
                    for n in names:
                        if n.id in self.static:
                            continue
                        if isinstance(t, ast.Name):
                            self.assigns[n.id] = value
                        if tainted and n.id not in self.traced:
                            self.traced.add(n.id)
                            changed = True

    def is_traced_expr(self, node: ast.AST) -> bool:
        return bool(names_in(node) & self.traced)


class JitPurity(Checker):
    code = "SIM001"
    name = "jit-purity"

    def applies(self, src: SourceFile) -> bool:
        return src.rel.endswith("fastsim_jax.py") or "kernels/" in src.rel

    # -- traced-scope discovery ------------------------------------------

    def _resolve_def(self, call: ast.Call, arg: ast.AST):
        """Resolve a cond/body/kernel argument to its FunctionDef/Lambda."""
        if isinstance(arg, ast.Lambda):
            return arg
        if isinstance(arg, ast.Call):     # functools.partial(kernel, ...)
            tail = dotted_name(arg.func).rsplit(".", 1)[-1]
            if tail == "partial" and arg.args:
                return self._resolve_def(call, arg.args[0])
            return None
        if not isinstance(arg, ast.Name):
            return None
        # walk outward through enclosing scopes looking for the def, or
        # a local binding like ``kernel = functools.partial(_kernel, ...)``
        scope = getattr(call, "parent", None)
        while scope is not None:
            if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Module)):
                for n in ast.walk(scope):
                    if isinstance(n, ast.FunctionDef) and n.name == arg.id:
                        return n
                    if (isinstance(n, ast.Assign)
                            and len(n.targets) == 1
                            and isinstance(n.targets[0], ast.Name)
                            and n.targets[0].id == arg.id
                            and isinstance(n.value, ast.Call)):
                        return self._resolve_def(call, n.value)
            scope = getattr(scope, "parent", None)
        return None

    def _discover(self, src: SourceFile) -> List[_TracedFunc]:
        roots: List[Tuple[ast.AST, str]] = []
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            tail = dotted_name(node.func).rsplit(".", 1)[-1]
            if tail in _LOOP_FUNCS:
                for i in _LOOP_FUNCS[tail]:
                    if i < len(node.args):
                        fn = self._resolve_def(node, node.args[i])
                        if fn is not None:
                            roots.append((fn, "loop_body"))
            elif tail == "pallas_call" and node.args:
                fn = self._resolve_def(node, node.args[0])
                if fn is not None:
                    roots.append((fn, "kernel"))

        # nested defs inside a traced function are traced too, inheriting
        # the parent's traced names as closure
        out: List[_TracedFunc] = []
        seen = set()

        def add(node, kind: str, inherited: Set[str]) -> None:
            if id(node) in seen:
                return
            seen.add(id(node))
            tf = _TracedFunc(node, kind, inherited)
            out.append(tf)
            body = node.body if isinstance(node.body, list) else []
            for stmt in body:
                for sub in ast.walk(stmt):
                    if isinstance(sub, (ast.FunctionDef, ast.Lambda)):
                        add(sub, kind, tf.traced)

        for fn, kind in roots:
            add(fn, kind, set())
        return out

    # -- the three rules --------------------------------------------------

    def check_file(self, src: SourceFile) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for tf in self._discover(src):
            diags.extend(self._check_func(src, tf))
        return diags

    def _own_nodes(self, tf: _TracedFunc):
        """Walk tf's body, skipping nested function subtrees (they are
        checked as their own traced funcs)."""
        body = tf.node.body
        stack = list(body) if isinstance(body, list) else [body]
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)) and node is not tf.node:
                continue
            yield node
            stack.extend(ast.iter_child_nodes(node))

    def _index_is_bulk(self, idx: ast.AST, tf: _TracedFunc,
                       depth: int = 0) -> bool:
        if depth > 2:
            return False
        for sub in ast.walk(idx):
            if isinstance(sub, ast.Slice):
                return True
            if isinstance(sub, ast.Constant) and sub.value is Ellipsis:
                return True
            if isinstance(sub, ast.Call) and _is_bulk_call(sub):
                return True
        # one-level dataflow: a bare Name index resolved through a local
        # assignment whose RHS is bulk-shaped
        names = ([idx] if isinstance(idx, ast.Name) else
                 list(idx.elts) if isinstance(idx, ast.Tuple) else [])
        for n in names:
            if isinstance(n, ast.Name) and n.id in tf.assigns:
                if self._index_is_bulk(tf.assigns[n.id], tf, depth + 1):
                    return True
        return False

    def _check_func(self, src: SourceFile,
                    tf: _TracedFunc) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for node in self._own_nodes(tf):
            # Rule A — bulk scatter into a carry, loop bodies only (the
            # post-loop flush outside the beat loop is explicitly allowed
            # by the performance contract).
            if (tf.kind == "loop_body" and isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in SCATTER_METHODS
                    and isinstance(node.func.value, ast.Subscript)
                    and isinstance(node.func.value.value, ast.Attribute)
                    and node.func.value.value.attr == "at"):
                idx = node.func.value.slice
                if self._index_is_bulk(idx, tf):
                    diags.append(src.diag(
                        "SIM001", node,
                        "bulk scatter `.at[...]."
                        f"{node.func.attr}` inside a compiled loop body "
                        "(~50ns/element/beat on CPU XLA); keep per-beat "
                        "updates single-element, flush after the loop"))
            # Rule B — Python branching on traced values
            if isinstance(node, (ast.If, ast.While)) and \
                    tf.is_traced_expr(node.test):
                kw = "while" if isinstance(node, ast.While) else "if"
                diags.append(src.diag(
                    "SIM001", node,
                    f"Python `{kw}` on a traced value inside a compiled "
                    "function; use jnp.where / lax.cond / pl.when"))
            if isinstance(node, ast.For) and \
                    isinstance(node.iter, ast.Name) and \
                    node.iter.id in tf.traced:
                diags.append(src.diag(
                    "SIM001", node,
                    "Python `for` over a traced array inside a compiled "
                    "function; use lax.scan / fori_loop"))
            # Rule C — tracer concretization
            if isinstance(node, ast.Call):
                fname = dotted_name(node.func)
                coercer = (isinstance(node.func, ast.Name)
                           and node.func.id in ("float", "int", "bool"))
                numpy_call = fname.split(".")[0] in ("np", "numpy")
                if (coercer or numpy_call) and any(
                        tf.is_traced_expr(a) for a in node.args):
                    what = node.func.id if coercer else fname
                    diags.append(src.diag(
                        "SIM001", node,
                        f"`{what}(...)` concretizes a traced value inside "
                        "a compiled function; use jnp equivalents"))
        return diags
