"""SIM005 — the deprecation shims are frozen, not load-bearing.

PR 4 pinned the legacy entry points (``simulate`` /
``min_workers_for_slo`` in ``simulator.py``, ``simulate_disaggregated``
/ ``min_cost_disagg`` in ``disagg.py``) bit-for-bit behind the
``Scenario`` API and marked them ``.. deprecated::``.  They exist so old
callers keep working — new ``src/`` code importing them re-entrenches
the very surface the shims are meant to retire.  The deprecated set is
derived from the ``.. deprecated::`` docstring markers themselves, so
deprecating a new entry point automatically starts guarding it.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.core import (Checker, Project, SourceFile,
                                 dotted_name)
from repro.analysis.diagnostics import Diagnostic

SHIM_MODULES = ("serving/simulator.py", "serving/disagg.py")
# used only when the project under analysis doesn't contain the shim
# modules themselves (e.g. single-file runs)
DEFAULT_DEPRECATED = {"simulate", "min_workers_for_slo",
                      "simulate_disaggregated", "min_cost_disagg"}
ALLOWED_IMPORTERS = ("repro/serving/__init__.py",)


def _deprecated_names(project: Project) -> Set[str]:
    names: Set[str] = set()
    saw_shim_module = False
    for src in project.files:
        if not any(src.rel.endswith(m) for m in SHIM_MODULES):
            continue
        saw_shim_module = True
        for node in src.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                doc = ast.get_docstring(node) or ""
                if ".. deprecated::" in doc:
                    names.add(node.name)
    return names if saw_shim_module else set(DEFAULT_DEPRECATED)


class ShimFreeze(Checker):
    code = "SIM005"
    name = "shim-freeze"

    def check_project(self, project: Project) -> List[Diagnostic]:
        deprecated = _deprecated_names(project)
        diags: List[Diagnostic] = []
        for src in project.files:
            if not src.rel.startswith("src/"):
                continue
            if any(src.rel.endswith(a) for a in ALLOWED_IMPORTERS):
                continue
            if any(src.rel.endswith(m) for m in SHIM_MODULES):
                continue            # the shims may reference themselves
            diags.extend(self._check_file(src, deprecated))
        return diags

    def _check_file(self, src: SourceFile,
                    deprecated: Set[str]) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        # module aliases that resolve to the shim modules / the package
        aliases: Dict[str, str] = {}
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name.startswith("repro.serving"):
                        aliases[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if not (mod == "repro.serving"
                        or mod.startswith("repro.serving.")):
                    continue
                for a in node.names:
                    if a.name in deprecated:
                        diags.append(src.diag(
                            "SIM005", node,
                            f"new src/ import of deprecated shim "
                            f"`{a.name}` from `{mod}`; call the "
                            "Scenario run()/optimize() API instead"))
                    elif mod.split(".")[-1] in ("simulator", "disagg",
                                                "serving"):
                        aliases[a.asname or a.name] = f"{mod}.{a.name}"
        if aliases:
            for node in ast.walk(src.tree):
                if not isinstance(node, ast.Attribute):
                    continue
                chain = dotted_name(node)
                if not chain or node.attr not in deprecated:
                    continue
                head = chain.split(".")[0]
                if head in aliases:
                    diags.append(src.diag(
                        "SIM005", node,
                        f"new src/ use of deprecated shim "
                        f"`{node.attr}` via `{chain}`; call the "
                        "Scenario run()/optimize() API instead"))
        return diags
