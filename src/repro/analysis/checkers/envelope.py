"""SIM006 — every scenario knob is inspected by an envelope validator.

The compiled cores (``fastsim.py`` / ``fastsim_jax.py``) run a strict
subset of what ``Scenario`` can express; the ``check_*_envelope``
validators are the fence that routes unsupported combinations back to
the reference loop instead of silently mis-simulating them.  That fence
only works if *every* field on the envelope-relevant dataclasses is
actually looked at by some validator — a new knob that no validator
inspects is exactly the "silently wrong compiled results" failure mode.
This checker cross-references each field of the enforced dataclasses in
``serving/api.py`` against the attribute reads of every
``check_*_envelope`` function in the tree and reports the orphans.
"""
from __future__ import annotations

import ast
import re
from typing import List, Set

from repro.analysis.core import Checker, Project, SourceFile
from repro.analysis.diagnostics import Diagnostic

VALIDATOR_RE = re.compile(r"^check_\w+_envelope$")
# the dataclasses whose every field must be validator-inspected: the
# Scenario root plus the topology/scaling classes the compiled cores
# accept (other topologies are rejected wholesale by isinstance checks,
# so their fields never reach a compiled core), and the multi-tenant
# TenantSpec (serving/tenants.py) whose per-class knobs feed the merged
# trace the compiled cores replay
# and the multi-turn SessionSpec (serving/workload.py) whose session
# shape drives the prefix-cache discount the compiled cores cannot price
ENFORCED = ("Scenario", "Colocated", "FixedScale", "TenantSpec",
            "SessionSpec")
# the modules whose ENFORCED dataclass definitions are scanned
ENFORCED_MODULES = ("serving/api.py", "serving/tenants.py",
                    "serving/workload.py")


def _validator_reads(project: Project) -> Set[str]:
    reads: Set[str] = set()
    for src in project.files:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.FunctionDef) and \
                    VALIDATOR_RE.match(node.name):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Attribute):
                        reads.add(sub.attr)
    return reads


class EnvelopeCoverage(Checker):
    code = "SIM006"
    name = "envelope-coverage"

    def check_project(self, project: Project) -> List[Diagnostic]:
        mods = [f for f in project.files
                if any(f.rel.endswith(m) for m in ENFORCED_MODULES)]
        if not any(m.rel.endswith("serving/api.py") for m in mods):
            return []
        reads = _validator_reads(project)
        if not reads:
            # no validators at all in scope: that is a different failure
            # (the run() plumbing is gone), not per-field coverage
            return []
        diags: List[Diagnostic] = []
        for mod in mods:
            for cls in mod.tree.body:
                if not isinstance(cls, ast.ClassDef) or \
                        cls.name not in ENFORCED:
                    continue
                diags.extend(self._check_class(mod, cls, reads))
        return diags

    def _check_class(self, api: SourceFile, cls: ast.ClassDef,
                     reads: Set[str]) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for stmt in cls.body:
            if not (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)):
                continue
            field = stmt.target.id
            if field.startswith("_") or field in reads:
                continue
            diags.append(api.diag(
                "SIM006", stmt,
                f"field `{cls.name}.{field}` is not inspected by any "
                "check_*_envelope validator; a compiled core could "
                "silently ignore it"))
        return diags
