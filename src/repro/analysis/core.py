"""Framework core: parsed sources, checker base class, the run loop.

A :class:`SourceFile` is one parsed module with parent links threaded
through the AST (``node.parent``) so checkers can look outward from a
match, plus the raw lines for suppression comments and baseline
fingerprints.  A :class:`Checker` visits files it :meth:`applies` to;
checkers that need the whole tree at once (envelope coverage) override
:meth:`check_project` instead.  :func:`run_checkers` is the single entry
the CLI and the tests share.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

from repro.analysis.diagnostics import (CODES, Diagnostic, is_suppressed,
                                        parse_suppressions)


def find_repo_root(start: Path) -> Path:
    """Nearest ancestor containing a .git dir (or pyproject.toml)."""
    cur = start.resolve()
    if cur.is_file():
        cur = cur.parent
    for cand in (cur, *cur.parents):
        if (cand / ".git").exists() or (cand / "pyproject.toml").exists():
            return cand
    return cur


def _link_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.parent = node  # type: ignore[attr-defined]


class SourceFile:
    """One parsed Python module."""

    def __init__(self, rel: str, text: str,
                 abspath: Optional[Path] = None):
        self.rel = rel                      # repo-relative posix path
        self.abspath = abspath
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=rel)
        _link_parents(self.tree)
        self.suppressions = parse_suppressions(self.lines)

    @classmethod
    def parse(cls, path: Path, root: Path) -> "SourceFile":
        rel = path.resolve().relative_to(root.resolve()).as_posix()
        return cls(rel, path.read_text(), abspath=path)

    @classmethod
    def from_source(cls, text: str, rel: str) -> "SourceFile":
        """Build an in-memory file for fixture tests."""
        return cls(rel, text)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def diag(self, code: str, node: ast.AST, message: str) -> Diagnostic:
        assert code in CODES, f"unknown diagnostic code {code}"
        lineno = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Diagnostic(code=code, path=self.rel, line=lineno, col=col,
                          message=message,
                          line_text=self.line_text(lineno))


class Project:
    """The set of files under analysis, with the repo root pinned."""

    def __init__(self, files: Sequence[SourceFile], root: Path):
        self.files = list(files)
        self.root = root

    @classmethod
    def collect(cls, paths: Sequence[Path],
                root: Optional[Path] = None) -> "Project":
        root = root or find_repo_root(paths[0] if paths else Path.cwd())
        seen = set()
        files: List[SourceFile] = []
        errors: List[str] = []
        for p in paths:
            candidates: Iterable[Path]
            if p.is_dir():
                candidates = sorted(p.rglob("*.py"))
            else:
                candidates = [p]
            for f in candidates:
                key = f.resolve()
                if key in seen or "__pycache__" in f.parts:
                    continue
                seen.add(key)
                try:
                    files.append(SourceFile.parse(f, root))
                except (SyntaxError, ValueError) as e:
                    errors.append(f"{f}: {e}")
        if errors:
            raise RuntimeError("failed to parse:\n" + "\n".join(errors))
        return cls(files, root)

    def get(self, rel: str) -> Optional[SourceFile]:
        for f in self.files:
            if f.rel == rel:
                return f
        return None


class Checker:
    """Base class: one stable code, one invariant."""

    code = ""        # SIM00x
    name = ""        # short slug for --list-codes

    def applies(self, src: SourceFile) -> bool:
        return True

    def check_file(self, src: SourceFile) -> List[Diagnostic]:
        return []

    def check_project(self, project: Project) -> List[Diagnostic]:
        """Default: run check_file over every applicable file.  Checkers
        needing cross-file state override this directly."""
        out: List[Diagnostic] = []
        for src in project.files:
            if self.applies(src):
                out.extend(self.check_file(src))
        return out


def run_checkers(project: Project,
                 checkers: Sequence[Checker]) -> List[Diagnostic]:
    """Run every checker, drop inline-suppressed findings, sort."""
    diags: List[Diagnostic] = []
    by_rel = {f.rel: f for f in project.files}
    for checker in checkers:
        for d in checker.check_project(project):
            src = by_rel.get(d.path)
            if src is not None and is_suppressed(d, src.suppressions):
                continue
            diags.append(d)
    diags.sort(key=lambda d: (d.path, d.line, d.col, d.code))
    return diags


# ---------------------------------------------------------------------------
# Shared AST helpers used by several checkers.

def qualname_of(node: ast.AST) -> str:
    """Dotted name for a def/class, e.g. ``_Engine._advance``."""
    parts: List[str] = []
    cur: Optional[ast.AST] = node
    while cur is not None and not isinstance(cur, ast.Module):
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            parts.append(cur.name)
        cur = getattr(cur, "parent", None)
    return ".".join(reversed(parts))


def enclosing_function(node: ast.AST) -> Optional[ast.AST]:
    cur = getattr(node, "parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            return cur
        cur = getattr(cur, "parent", None)
    return None


def dotted_name(node: ast.AST) -> str:
    """Render ``a.b.c`` attribute chains; '' when not a plain chain."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else ""
    return ""


def names_in(node: ast.AST) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}
