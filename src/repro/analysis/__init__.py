"""simlint — repo-specific static analysis for the simulator's invariants.

The three simulation engines (reference / vectorized / jax) agree only
because a set of invariants holds that ordinary linters cannot see: the
``fastsim_jax`` performance contract (never bulk-scatter into trace-sized
carries inside the beat loop), the scoped-``enable_x64()`` precision
discipline, dimensional consistency of the second/token/GPU-second
arithmetic, monotone causal clocks stamped only by blessed helpers, frozen
deprecation shims, and envelope validators that must inspect every scenario
knob before a compiled core is allowed to run it.  ``simlint`` enforces
those invariants at diff time — an AST pass over the tree instead of a 90s
smoke bench.

Run it as ``python -m repro.analysis [paths...]`` (or
``scripts/simlint.py``); CI runs it as a hard gate with the tracked
allowlist ``scripts/simlint_baseline.json``.  Diagnostics carry stable
``SIM00x`` codes (see ``--list-codes`` or the README); individual lines
can opt out with ``# simlint: ignore[SIM00x]``.
"""
from repro.analysis.core import (Checker, Project, SourceFile,  # noqa: F401
                                 run_checkers)
from repro.analysis.diagnostics import (CODES, Baseline,        # noqa: F401
                                        Diagnostic)

__all__ = ["Baseline", "Checker", "CODES", "Diagnostic", "Project",
           "SourceFile", "run_checkers"]
