"""Diagnostic model: stable codes, inline suppressions, tracked baseline.

Every finding is a :class:`Diagnostic` carrying one of the stable ``SIM00x``
codes from :data:`CODES`.  Two opt-out channels exist, with different jobs:

* ``# simlint: ignore[SIM003]`` on (or immediately above) the offending
  line — for idioms that are *correct by design* and should stay exempt
  next to the code they annotate.  A bare ``# simlint: ignore`` suppresses
  every code on that line.
* a baseline file (``scripts/simlint_baseline.json``) — for pre-existing
  findings accepted as-is when a checker lands.  Entries match on
  ``(code, path, stripped line text)`` so ordinary line drift does not
  invalidate them, and entries that no longer match anything fail the run
  (a stale allowlist is itself a finding: the debt it tracked is gone).
"""
from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

# The stable diagnostic registry. Codes are append-only: a retired checker
# keeps its code reserved so old suppressions/baselines never silently
# re-bind to a new rule.
CODES: Dict[str, str] = {
    "SIM001": ("jit purity / performance contract: no bulk scatters, "
               "Python branching, or tracer coercions inside compiled "
               "beat-loop bodies and Pallas kernels"),
    "SIM002": ("x64 scope: jax 64-bit precision may only be enabled via a "
               "scoped `with enable_x64():` block, never process-globally"),
    "SIM003": ("unit safety: additions/comparisons must not mix dimensions "
               "(seconds vs tokens vs GPU-seconds vs price) inferred from "
               "the repo's naming conventions"),
    "SIM004": ("clock monotonicity: request/worker clock fields are "
               "stamped only by the blessed simulation helpers"),
    "SIM005": ("shim freeze: no new src/ importers of the deprecated "
               "simulate/min_workers_for_slo/simulate_disaggregated/"
               "min_cost_disagg entry points"),
    "SIM006": ("envelope coverage: every Scenario/topology/scaling field "
               "must be inspected by a check_*_envelope validator before "
               "a compiled core may run the scenario"),
}

_IGNORE_RE = re.compile(
    r"#\s*simlint:\s*ignore(?:\[\s*([A-Z0-9,\s]+?)\s*\])?")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: a stable code anchored to a source line."""
    code: str
    path: str                  # repo-relative posix path
    line: int                  # 1-indexed
    col: int                   # 0-indexed (ast convention)
    message: str
    line_text: str = ""        # stripped source line (baseline fingerprint)

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: " \
               f"{self.code} {self.message}"

    @property
    def fingerprint(self) -> Tuple[str, str, str]:
        return (self.code, self.path, self.line_text)


def parse_suppressions(lines: Sequence[str]) -> Dict[int, Optional[Set[str]]]:
    """Map 1-indexed line number -> suppressed codes (``None`` = all codes).

    A suppression comment governs its own line; when it sits on a
    comment-only line it also governs the next line (annotate-above style).
    """
    out: Dict[int, Optional[Set[str]]] = {}

    def merge(lineno: int, codes: Optional[Set[str]]) -> None:
        if codes is None or out.get(lineno, set()) is None:
            out[lineno] = None if codes is None else codes
        else:
            out.setdefault(lineno, set()).update(codes)

    for i, text in enumerate(lines, start=1):
        m = _IGNORE_RE.search(text)
        if not m:
            continue
        codes = None if m.group(1) is None else {
            c.strip() for c in m.group(1).split(",") if c.strip()}
        merge(i, codes)
        if text.lstrip().startswith("#"):       # comment-only line: applies
            merge(i + 1, codes)                 # to the line it annotates
    return out


def is_suppressed(diag: Diagnostic,
                  suppressions: Dict[int, Optional[Set[str]]]) -> bool:
    codes = suppressions.get(diag.line, set())
    return codes is None or diag.code in (codes or set())


class Baseline:
    """The tracked allowlist of accepted pre-existing findings."""

    def __init__(self, entries: Optional[List[Dict]] = None):
        self.entries: List[Dict] = entries or []
        self._matched = [False] * len(self.entries)

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        with open(path) as f:
            data = json.load(f)
        if data.get("version") != 1:
            raise ValueError(f"{path}: unsupported baseline version "
                             f"{data.get('version')!r}")
        return cls(list(data.get("entries", [])))

    @classmethod
    def from_diagnostics(cls, diags: Sequence[Diagnostic],
                         reason: str = "accepted pre-existing finding") \
            -> "Baseline":
        seen = set()
        entries = []
        for d in sorted(diags, key=lambda d: (d.path, d.line, d.code)):
            if d.fingerprint in seen:
                continue
            seen.add(d.fingerprint)
            entries.append({"code": d.code, "path": d.path,
                            "text": d.line_text, "reason": reason})
        return cls(entries)

    def save(self, path: Path) -> None:
        payload = {"version": 1, "entries": self.entries}
        path.write_text(json.dumps(payload, indent=2) + "\n")

    def accepts(self, diag: Diagnostic) -> bool:
        for i, e in enumerate(self.entries):
            if (e["code"], e["path"], e["text"]) == diag.fingerprint:
                self._matched[i] = True
                return True
        return False

    def stale_entries(self) -> List[Dict]:
        """Entries that matched no finding this run — debt that no longer
        exists and must be removed from the allowlist."""
        return [e for i, e in enumerate(self.entries) if not self._matched[i]]
