"""Mixture-of-Experts FFN with TP-style expert parallelism.

Experts are sharded over the ``model`` mesh axis; activations are replicated
across it (they are only batch-sharded). Each model-rank computes the routed
assignments that land on *its* experts (sort -> truncate to static capacity ->
gather -> expert GEMMs -> scatter-add) and the rank outputs are combined with
a single ``psum`` — the same one all-reduce per layer a dense Megatron MLP
pays, but with only the top-k expert FLOPs. Capacity overflow drops tokens
(standard GShard semantics); the drop fraction is returned for monitoring.

Expert counts that do not divide the model axis (qwen2-moe's 60 over 16) are
padded with dummy experts whose router logits are -inf; they cost capacity
buffers but receive no tokens.

When no mesh is active (CPU smoke tests / the serving engine's tiny models)
the identical inner function runs with a single rank and no collectives.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import NO_POLICY, Policy
from repro.models.common import gated_mlp

NEG_INF = -1e30


def padded_experts(n_experts: int, ep: int) -> int:
    """Number of expert slots after padding to a multiple of the EP degree."""
    return ((n_experts + ep - 1) // ep) * ep


def _moe_local(x_flat, router_w, w_gate, w_up, w_down, *, top_k: int,
               n_real: int, n_pad: int, e_lo: int, capacity: int, act: str):
    """Routed-expert compute for experts [e_lo, e_lo + E_loc) held locally.

    x_flat: (T, D); router_w: (D, n_real); w_*: (E_loc, D, F) / (E_loc, F, D).
    Returns (out: (T, D) partial sum, aux: (2,) [load-balance loss, drops]).
    """
    t, d = x_flat.shape
    e_loc = w_gate.shape[0]
    logits = x_flat.astype(jnp.float32) @ router_w              # (T, n_real)
    if n_pad > n_real:
        logits = jnp.concatenate(
            [logits, jnp.full((t, n_pad - n_real), NEG_INF)], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, top_k)                  # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                                  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), top_k)
    flat_w = top_w.reshape(-1)
    local = (flat_e >= e_lo) & (flat_e < e_lo + e_loc)
    # sort so local assignments come first, grouped by expert
    sort_key = jnp.where(local, flat_e - e_lo, e_loc)
    order = jnp.argsort(sort_key, stable=True)
    k_max = e_loc * capacity
    order = order[:k_max]
    se = sort_key[order]                                        # (k_max,)
    st = flat_t[order]
    sw = flat_w[order]
    # rank within expert = index - first index of this expert
    first = jnp.searchsorted(se, jnp.arange(e_loc + 1))
    pos_in_e = jnp.arange(se.shape[0]) - first[jnp.clip(se, 0, e_loc)]
    valid = (se < e_loc) & (pos_in_e < capacity)
    slot = jnp.where(valid, se * capacity + pos_in_e, k_max)    # OOB -> drop

    gathered = x_flat[jnp.where(valid, st, 0)]                  # (k_max, D)
    disp = jnp.zeros((k_max + 1, d), x_flat.dtype).at[slot].set(
        jnp.where(valid[:, None], gathered, 0))[:k_max]
    disp = disp.reshape(e_loc, capacity, d)

    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = actf(jnp.einsum("ecd,edf->ecf", disp, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", disp, w_up)
    eo = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(k_max, d)

    contrib = eo[jnp.where(valid, slot, 0)] * \
        jnp.where(valid, sw, 0.0)[:, None].astype(eo.dtype)
    out = jnp.zeros((t, d), eo.dtype).at[jnp.where(valid, st, t - 1)].add(
        jnp.where(valid[:, None], contrib, 0))

    # aux: load-balance loss (Switch-style) over global router state + drops
    frac_tokens = jnp.zeros((n_pad,), jnp.float32) \
        .at[flat_e].add(1.0) / (t * top_k)
    frac_probs = probs.mean(0)
    lb_loss = n_real * jnp.sum(frac_tokens * frac_probs)
    n_local = local.sum()
    drops = jnp.maximum(n_local - valid.sum(), 0).astype(jnp.float32)
    return out, jnp.stack([lb_loss, drops])


def moe_ffn(x: jnp.ndarray, p: dict, arch, policy: Policy = NO_POLICY,
            capacity_factor: Optional[float] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux[2])."""
    moe = arch.moe
    b, s, d = x.shape
    cf = capacity_factor if capacity_factor is not None else moe.capacity_factor

    mesh = policy.mesh
    ep = policy.axis_size("experts")
    n_pad = padded_experts(moe.n_experts, max(ep, 1))
    assert p["w_gate"].shape[0] == n_pad, (p["w_gate"].shape, n_pad)
    if mesh is not None and ep > 1:
        e_loc = n_pad // ep
        t_loc = (b // max(policy.axis_size("batch"), 1)) * s
        capacity = max(int(t_loc * moe.top_k / moe.n_experts * cf), 4)

        def ranked(xb, rw, wg, wu, wd):
            t_ = xb.shape[0] * xb.shape[1]
            idx = jax.lax.axis_index("model")
            out, aux = _moe_local(
                xb.reshape(t_, d), rw, wg, wu, wd, top_k=moe.top_k,
                n_real=moe.n_experts, n_pad=n_pad, e_lo=idx * e_loc,
                capacity=capacity, act=arch.act)
            out = jax.lax.psum(out, "model")
            aux = jax.lax.psum(aux * jnp.array([1.0 / ep, 1.0]), "model")
            return out.reshape(xb.shape), aux

        batch_spec = policy.spec(("batch",))[0]
        out, aux = shard_map(
            ranked, mesh=mesh,
            in_specs=(P(batch_spec, None, None), P(),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=(P(batch_spec, None, None), P()),
            check_vma=False,
        )(x, p["router"].astype(jnp.float32), p["w_gate"], p["w_up"],
          p["w_down"])
        return out.astype(x.dtype), aux

    # single-rank path (no mesh / tiny models)
    capacity = max(int(b * s * moe.top_k / moe.n_experts * cf), 4)
    out, aux = _moe_local(
        x.reshape(b * s, d), p["router"].astype(jnp.float32),
        p["w_gate"], p["w_up"], p["w_down"], top_k=moe.top_k,
        n_real=moe.n_experts, n_pad=n_pad, e_lo=0, capacity=capacity,
        act=arch.act)
    return out.reshape(b, s, d).astype(x.dtype), aux


def shared_expert_ffn(x, p, arch, policy: Policy = NO_POLICY):
    """Always-on shared experts = one dense TP MLP of width d_shared."""
    return gated_mlp(x, p["sh_gate"], p["sh_up"], p["sh_down"], arch.act)
