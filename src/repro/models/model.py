"""Unified LM builder: one class covering all 10 assigned architectures.

A model is a sequence of *segments*; each segment is a stack of identical
layers run under ``lax.scan`` (keeps HLO small for 100-layer configs), with
heterogeneous patterns expressed as superblocks:

  dense/audio:  [dense x L]
  moe:          [dense x n_dense, moe x (L - n_dense)]
  ssm:          [mamba x L]
  hybrid:       [hyb_super x n_super (inner mamba + one SHARED attn block),
                 mamba x trailing]
  vlm:          [vlm_super x n_super (inner dense + one cross-attn layer)]

Three entry points (all pure functions over the param pytree):
  train_loss   — full causal pass + chunked softmax-xent (vocab TP)
  prefill      — full pass, returns last-position logits + staged KV caches
  decode_step  — one token through all layers (staged cache, flash-decoding)

Distribution is injected via a ``Policy`` (logical-axis constraints); params
carry logical axes in the template so the dry-run can derive in_shardings
without materializing anything.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Family, PosEmb
from repro.distributed.sharding import NO_POLICY, Policy
from repro.models.attention import (AttnCache, cross_attention_decode,
                                    cross_attention_full, flush_cache,
                                    self_attention_decode,
                                    self_attention_full)
from repro.models.common import gated_mlp, rms_norm, sinusoidal_pos
from repro.models.mamba2 import (MambaCache, make_mamba_cache,
                                 mamba_block_decode, mamba_block_full)
from repro.models.moe import moe_ffn, padded_experts, shared_expert_ffn


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    use_pallas: bool = False
    kv_chunk: int = 256
    scan_layers: bool = True
    remat: bool = False
    loss_chunk: int = 512          # seq chunk for the vocab-TP xent
    recent_window: int = 256       # decode append-buffer length
    capacity_factor: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    kind: str                      # dense | moe | mamba | hyb_super | vlm_super
    n: int                         # scan length
    inner: int = 1                 # inner plain layers per superblock


# =============================================================================
# parameter templates: leaf = (shape, logical_axes, scale)
# =============================================================================
Leaf = Tuple[Tuple[int, ...], Tuple[Optional[str], ...], float]


def _attn_leaves(arch: ArchConfig, prefix: str = "") -> Dict[str, Leaf]:
    d = arch.d_model
    hd = arch.resolved_head_dim
    qd, kvd = arch.n_heads * hd, arch.n_kv_heads * hd
    s = 1.0 / math.sqrt(d)
    leaves = {
        prefix + "wq": ((d, qd), ("p_fsdp", "p_tp"), s),
        prefix + "wk": ((d, kvd), ("p_fsdp", "p_tp"), s),
        prefix + "wv": ((d, kvd), ("p_fsdp", "p_tp"), s),
        prefix + "wo": ((qd, d), ("p_tp", "p_fsdp"), 1.0 / math.sqrt(qd)),
    }
    if arch.qkv_bias:
        leaves.update({
            prefix + "bq": ((qd,), ("p_tp",), 0.0),
            prefix + "bk": ((kvd,), ("p_tp",), 0.0),
            prefix + "bv": ((kvd,), ("p_tp",), 0.0),
        })
    return leaves


def _mlp_leaves(arch: ArchConfig, d_ff: int) -> Dict[str, Leaf]:
    d = arch.d_model
    return {
        "wg": ((d, d_ff), ("p_fsdp", "p_tp"), 1.0 / math.sqrt(d)),
        "wu": ((d, d_ff), ("p_fsdp", "p_tp"), 1.0 / math.sqrt(d)),
        "wd": ((d_ff, d), ("p_tp", "p_fsdp"), 1.0 / math.sqrt(d_ff)),
    }


def _dense_layer_leaves(arch: ArchConfig) -> Dict[str, Leaf]:
    d = arch.d_model
    out = {"ln1": ((d,), (None,), -1.0), "ln2": ((d,), (None,), -1.0)}
    out.update(_attn_leaves(arch))
    out.update(_mlp_leaves(arch, arch.d_ff))
    return out


def _moe_layer_leaves(arch: ArchConfig, ep: int) -> Dict[str, Leaf]:
    d = arch.d_model
    m = arch.moe
    e_pad = padded_experts(m.n_experts, ep)
    out = {"ln1": ((d,), (None,), -1.0), "ln2": ((d,), (None,), -1.0)}
    out.update(_attn_leaves(arch))
    s = 1.0 / math.sqrt(d)
    out.update({
        "router": ((d, m.n_experts), (None, None), s),
        "w_gate": ((e_pad, d, m.d_expert), ("experts", "p_fsdp", None), s),
        "w_up": ((e_pad, d, m.d_expert), ("experts", "p_fsdp", None), s),
        "w_down": ((e_pad, m.d_expert, d), ("experts", None, "p_fsdp"),
                   1.0 / math.sqrt(m.d_expert)),
    })
    if m.n_shared_experts:
        d_sh = m.d_shared or m.d_expert * m.n_shared_experts
        out.update({
            "sh_gate": ((d, d_sh), ("p_fsdp", "p_tp"), s),
            "sh_up": ((d, d_sh), ("p_fsdp", "p_tp"), s),
            "sh_down": ((d_sh, d), ("p_tp", "p_fsdp"), 1.0 / math.sqrt(d_sh)),
        })
    return out


def _mamba_layer_leaves(arch: ArchConfig) -> Dict[str, Leaf]:
    d = arch.d_model
    s_cfg = arch.ssm
    di = arch.d_inner
    nh = arch.n_ssm_heads
    gn = s_cfg.ngroups * s_cfg.d_state
    s = 1.0 / math.sqrt(d)
    return {
        "ln": ((d,), (None,), -1.0),
        "w_z": ((d, di), ("p_fsdp", "p_tp"), s),
        "w_x": ((d, di), ("p_fsdp", "p_tp"), s),
        "w_bc": ((d, 2 * gn), ("p_fsdp", None), s),
        "w_dt": ((d, nh), ("p_fsdp", "p_tp"), s),
        "dt_bias": ((nh,), ("p_tp",), 0.0),
        "conv_wx": ((s_cfg.d_conv, di), (None, "p_tp"), 0.5),
        "conv_bx": ((di,), ("p_tp",), 0.0),
        "conv_wbc": ((s_cfg.d_conv, 2 * gn), (None, None), 0.5),
        "conv_bbc": ((2 * gn,), (None,), 0.0),
        "A_log": ((nh,), ("p_tp",), -2.0),       # special init: log-uniform
        "D": ((nh,), ("p_tp",), -1.0),           # special init: ones
        "norm_w": ((di,), ("p_tp",), -1.0),
        "w_out": ((di, d), ("p_tp", "p_fsdp"), 1.0 / math.sqrt(di)),
    }


def _cross_layer_leaves(arch: ArchConfig) -> Dict[str, Leaf]:
    d = arch.d_model
    out = {"ln1": ((d,), (None,), -1.0), "ln2": ((d,), (None,), -1.0),
           "gate_attn": ((1,), (None,), 0.0), "gate_mlp": ((1,), (None,), 0.0)}
    out.update(_attn_leaves(arch))
    out.update(_mlp_leaves(arch, arch.d_ff))
    return out


def _stack(leaves: Dict[str, Leaf], *ns: int) -> Dict[str, Leaf]:
    out = {}
    for k, (shape, axes, scale) in leaves.items():
        out[k] = (tuple(ns) + shape, ("p_layers",) * len(ns) + axes, scale)
    return out


# =============================================================================
# the model
# =============================================================================
class LM:
    def __init__(self, arch: ArchConfig, policy: Policy = NO_POLICY,
                 exec_cfg: ExecConfig = ExecConfig()):
        self.arch = arch
        self.policy = policy
        self.cfg = exec_cfg
        self.dtype = jnp.bfloat16 if arch.param_dtype == "bfloat16" \
            else jnp.float32
        self.segments = self._build_segments()

    # -- segment layout -------------------------------------------------------
    def _build_segments(self) -> List[SegmentSpec]:
        a = self.arch
        if a.family in (Family.DENSE, Family.AUDIO):
            return [SegmentSpec("dense", a.n_layers)]
        if a.family == Family.MOE:
            nd = a.moe.n_dense_layers
            segs = []
            if nd:
                segs.append(SegmentSpec("dense_mlp", nd))
            segs.append(SegmentSpec("moe", a.n_layers - nd))
            return segs
        if a.family == Family.SSM:
            return [SegmentSpec("mamba", a.n_layers)]
        if a.family == Family.HYBRID:
            per = a.attn_every
            n_super = a.n_layers // per
            trailing = a.n_layers - n_super * per
            segs = [SegmentSpec("hyb_super", n_super, inner=per - 1)]
            if trailing:
                segs.append(SegmentSpec("mamba", trailing))
            return segs
        if a.family == Family.VLM:
            per = a.cross_attn_every
            n_super = a.n_layers // per
            assert n_super * per == a.n_layers, "vlm layers % cross_every != 0"
            return [SegmentSpec("vlm_super", n_super, inner=per - 1)]
        raise ValueError(a.family)

    # -- parameter template ---------------------------------------------------
    def param_template(self) -> Dict[str, Any]:
        a = self.arch
        ep = self.policy.axis_size("experts")
        d = a.d_model
        t: Dict[str, Any] = {
            # std 0.02 (GPT-2 convention); tied archs re-scale inputs by
            # sqrt(d), giving unit-variance residual streams either way
            "embed": ((a.vocab, d), ("p_fsdp", None), 0.02),
            "final_ln": ((d,), (None,), -1.0),
        }
        if not a.tie_embeddings:
            t["head"] = ((d, a.vocab), ("p_fsdp", "vocab"), 1.0 / math.sqrt(d))
        for i, seg in enumerate(self.segments):
            key = f"seg{i}"
            if seg.kind in ("dense", "dense_mlp"):
                if a.family == Family.MOE:   # leading dense layers of a MoE
                    leaves = {"ln1": ((d,), (None,), -1.0),
                              "ln2": ((d,), (None,), -1.0)}
                    leaves.update(_attn_leaves(a))
                    dff = a.moe.d_shared or a.moe.d_expert * 8
                    leaves.update(_mlp_leaves(a, dff))
                else:
                    leaves = _dense_layer_leaves(a)
                t[key] = _stack(leaves, seg.n)
            elif seg.kind == "moe":
                t[key] = _stack(_moe_layer_leaves(a, ep), seg.n)
            elif seg.kind == "mamba":
                t[key] = _stack(_mamba_layer_leaves(a), seg.n)
            elif seg.kind == "hyb_super":
                t[key] = {
                    "mamba": _stack(_mamba_layer_leaves(a), seg.n, seg.inner),
                    "attn": {**{k: v for k, v in _dense_layer_leaves(a).items()}},
                }
            elif seg.kind == "vlm_super":
                t[key] = {
                    "dense": _stack(_dense_layer_leaves(a), seg.n, seg.inner),
                    "cross": _stack(_cross_layer_leaves(a), seg.n),
                }
        return t

    def param_specs(self):
        """PartitionSpec tree matching init()'s structure (shape-aware: axes
        that do not divide a dim are dropped, as jit in_shardings requires)."""
        pol = self.policy
        return jax.tree.map(lambda leaf: pol.spec_for_shape(leaf[1], leaf[0]),
                            self.param_template(),
                            is_leaf=lambda x: isinstance(x, tuple)
                            and len(x) == 3 and isinstance(x[0], tuple))

    def init(self, key) -> Dict[str, Any]:
        tmpl = self.param_template()
        leaves, treedef = jax.tree.flatten(
            tmpl, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
            and isinstance(x[0], tuple))
        keys = jax.random.split(key, len(leaves))
        out = []
        for k, (shape, axes, scale) in zip(keys, leaves):
            if scale == -1.0:       # norm weights / D -> ones
                out.append(jnp.ones(shape, self.dtype))
            elif scale == -2.0:     # A_log -> log U[1, 16]
                u = jax.random.uniform(k, shape, jnp.float32, 1.0, 16.0)
                out.append(jnp.log(u).astype(jnp.float32))
            elif scale == 0.0:
                out.append(jnp.zeros(shape, self.dtype))
            else:
                out.append((jax.random.normal(k, shape, jnp.float32)
                            * scale).astype(self.dtype))
        return jax.tree.unflatten(treedef, out)

    # =========================================================================
    # layer bodies
    # =========================================================================
    def _dense_layer_full(self, x, p, positions, return_cache):
        a, pol = self.arch, self.policy
        h = rms_norm(x, p["ln1"], a.norm_eps)
        res = self_attention_full(h, p, a, pol, positions=positions,
                                  kv_chunk=self.cfg.kv_chunk,
                                  use_pallas=self.cfg.use_pallas,
                                  return_kv=return_cache)
        if return_cache:
            res, kv = res
        x = x + res
        h = rms_norm(x, p["ln2"], a.norm_eps)
        h = gated_mlp(h, p["wg"], p["wu"], p["wd"], a.act)
        h = pol.constrain(h, ("batch", "seq_q", None))
        x = x + h
        return (x, kv) if return_cache else (x, None)

    def _moe_layer_full(self, x, p, positions, return_cache):
        a, pol = self.arch, self.policy
        h = rms_norm(x, p["ln1"], a.norm_eps)
        res = self_attention_full(h, p, a, pol, positions=positions,
                                  kv_chunk=self.cfg.kv_chunk,
                                  use_pallas=self.cfg.use_pallas,
                                  return_kv=return_cache)
        if return_cache:
            res, kv = res
        x = x + res
        h = rms_norm(x, p["ln2"], a.norm_eps)
        out, aux = moe_ffn(h, p, a, pol, self.cfg.capacity_factor)
        if a.moe.n_shared_experts:
            out = out + shared_expert_ffn(h, p, a, pol)
        x = x + out
        return (x, kv, aux) if return_cache else (x, aux)

    def _moe_layer_decode(self, x, p, cache: AttnCache):
        a, pol = self.arch, self.policy
        h = rms_norm(x, p["ln1"], a.norm_eps)
        res, cache = self_attention_decode(h, cache, p, a, pol)
        x = x + res
        h = rms_norm(x, p["ln2"], a.norm_eps)
        out, _ = moe_ffn(h[:, None, :], p, a, pol, self.cfg.capacity_factor)
        out = out[:, 0]
        if a.moe.n_shared_experts:
            out = out + shared_expert_ffn(h, p, a, pol)
        return x + out, cache

    def _dense_layer_decode(self, x, p, cache: AttnCache):
        a, pol = self.arch, self.policy
        h = rms_norm(x, p["ln1"], a.norm_eps)
        res, cache = self_attention_decode(h, cache, p, a, pol)
        x = x + res
        h = rms_norm(x, p["ln2"], a.norm_eps)
        x = x + gated_mlp(h, p["wg"], p["wu"], p["wd"], a.act)
        return x, cache

    def _cross_layer_full(self, x, p, frontend, return_cache):
        a, pol = self.arch, self.policy
        h = rms_norm(x, p["ln1"], a.norm_eps)
        res = cross_attention_full(h, frontend, p, a, pol,
                                   use_pallas=self.cfg.use_pallas,
                                   return_kv=return_cache)
        if return_cache:
            res, kv = res
        x = x + jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(x.dtype) * res
        h = rms_norm(x, p["ln2"], a.norm_eps)
        h = gated_mlp(h, p["wg"], p["wu"], p["wd"], a.act)
        x = x + jnp.tanh(p["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * h
        return (x, kv) if return_cache else (x, None)

    def _cross_layer_decode(self, x, p, cross_kv):
        a = self.arch
        h = rms_norm(x, p["ln1"], a.norm_eps)
        res = cross_attention_decode(h, cross_kv, p, a, self.policy)
        x = x + jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(x.dtype) * res
        h = rms_norm(x, p["ln2"], a.norm_eps)
        h = gated_mlp(h, p["wg"], p["wu"], p["wd"], a.act)
        return x + jnp.tanh(p["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * h

    def _mamba_layer_full(self, x, p, return_cache):
        a = self.arch
        h = rms_norm(x, p["ln"], a.norm_eps)
        res = mamba_block_full(h, p, a, self.policy,
                               use_pallas=self.cfg.use_pallas,
                               return_cache=return_cache)
        if return_cache:
            res, cache = res
            return x + res, cache
        return x + res, None

    def _mamba_layer_decode(self, x, p, cache: MambaCache):
        a = self.arch
        h = rms_norm(x, p["ln"], a.norm_eps)
        res, cache = mamba_block_decode(h, cache, p, a, self.policy)
        return x + res, cache

    # =========================================================================
    # scan machinery
    # =========================================================================
    def _scan(self, body: Callable, carry, xs, length: int):
        if self.cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        if self.cfg.scan_layers and length > 1:
            return jax.lax.scan(body, carry, xs)
        ys = []
        for i in range(length):
            xi = jax.tree.map(lambda t: t[i], xs) if xs is not None else None
            carry, y = body(carry, xi)
            ys.append(y)
        ys = jax.tree.map(lambda *t: jnp.stack(t), *ys) \
            if ys and ys[0] is not None else None
        return carry, ys

    # =========================================================================
    # full-sequence forward (train / prefill)
    # =========================================================================
    def _embed_inputs(self, params, tokens=None, embeds=None):
        a = self.arch
        if embeds is None:
            embeds = params["embed"][tokens] * (1.0 if not a.tie_embeddings
                                                else math.sqrt(a.d_model))
        x = embeds.astype(self.dtype)
        if a.pos_emb == PosEmb.SINUSOIDAL:
            s = x.shape[1]
            x = x + sinusoidal_pos(jnp.arange(s), a.d_model).astype(x.dtype)
        return self.policy.constrain(x, ("batch", None, None))

    def _forward_full(self, params, x, frontend=None, return_cache=False):
        """x: (B, S, D) -> (hidden (B,S,D), caches, aux)."""
        b, s, _ = x.shape
        positions = jnp.arange(s)
        caches: List[Any] = []
        aux_sum = jnp.zeros((2,), jnp.float32)

        for i, seg in enumerate(self.segments):
            p = params[f"seg{i}"]
            if seg.kind in ("dense", "dense_mlp"):
                def body(carry, lp):
                    y, kv = self._dense_layer_full(carry, lp, positions,
                                                   return_cache)
                    return y, kv
                x, kvs = self._scan(body, x, p, seg.n)
                caches.append(kvs)
            elif seg.kind == "moe":
                def body(carry, lp):
                    out = self._moe_layer_full(carry, lp, positions,
                                               return_cache)
                    if return_cache:
                        y, kv, aux = out
                        return y, (kv, aux)
                    y, aux = out
                    return y, (None, aux)
                x, ys = self._scan(body, x, p, seg.n)
                kvs, auxs = ys
                caches.append(kvs)
                aux_sum = aux_sum + jax.tree.reduce(
                    lambda a_, b_: a_ + b_, jax.tree.map(
                        lambda t: t.sum(0) if t.ndim > 1 else t, auxs))
            elif seg.kind == "mamba":
                def body(carry, lp):
                    y, c = self._mamba_layer_full(carry, lp, return_cache)
                    return y, c
                x, cs = self._scan(body, x, p, seg.n)
                caches.append(cs)
            elif seg.kind == "hyb_super":
                shared = p["attn"]

                def body(carry, lp):
                    y = carry

                    def inner(c2, lp2):
                        y2, cc = self._mamba_layer_full(c2, lp2, return_cache)
                        return y2, cc
                    y, mcs = self._scan(inner, y, lp, seg.inner)
                    y, kv = self._dense_layer_full(y, shared, positions,
                                                   return_cache)
                    return y, (mcs, kv)
                x, ys = self._scan(body, x, p["mamba"], seg.n)
                caches.append(ys)
            elif seg.kind == "vlm_super":
                def body(carry, lp):
                    dense_p, cross_p = lp
                    y = carry

                    def inner(c2, lp2):
                        y2, kv = self._dense_layer_full(c2, lp2, positions,
                                                        return_cache)
                        return y2, kv
                    y, kvs = self._scan(inner, y, dense_p, seg.inner)
                    y, ckv = self._cross_layer_full(y, cross_p, frontend,
                                                    return_cache)
                    return y, (kvs, ckv)
                x, ys = self._scan(body, x, (p["dense"], p["cross"]), seg.n)
                caches.append(ys)
            else:
                raise ValueError(seg.kind)
        x = rms_norm(x, params["final_ln"], self.arch.norm_eps)
        return x, caches, aux_sum

    # -- losses ----------------------------------------------------------------
    def _head_weight(self, params):
        if self.arch.tie_embeddings:
            return params["embed"].T
        return params["head"]

    def train_loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """batch: {"tokens" (B,S) | "embeds" (B,S,D), "labels" (B,S),
        optional "frontend" (B,T,D)}. labels < 0 are masked."""
        x = self._embed_inputs(params, batch.get("tokens"),
                               batch.get("embeds"))
        h, _, aux = self._forward_full(params, x,
                                       frontend=batch.get("frontend"))
        h = self.policy.constrain(h, ("batch", None, None))
        labels = batch["labels"]
        w = self._head_weight(params)
        b, s, d = h.shape
        chunk = self.cfg.loss_chunk or s
        chunk = min(chunk, s)
        if s % chunk:
            chunk = s
        nc = s // chunk

        def body(carry, inputs):
            hc, lc = inputs                    # (nc axis leading)
            # keep w in bf16 through the (FSDP-gathered) matmul; accumulate
            # in f32 via preferred_element_type — casting w to f32 first
            # would double the gather traffic.  [§Perf iteration 4]
            logits = jax.lax.dot_general(
                hc, w, (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            logits = self.policy.constrain(logits, ("batch", None, "vocab"))
            lse = jax.nn.logsumexp(logits, axis=-1)
            onehot = lc[..., None] == jnp.arange(logits.shape[-1])[None, None]
            tgt = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
            mask = (lc >= 0)
            tok_loss = jnp.where(mask, lse - tgt, 0.0)
            return (carry[0] + tok_loss.sum(), carry[1] + mask.sum()), None

        hc = h.reshape(b, nc, chunk, d).swapaxes(0, 1)
        lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)
        (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                            jnp.zeros((), jnp.int32)),
                                     (hc, lc))
        loss = tot / jnp.maximum(cnt, 1)
        metrics = {"xent": loss, "lb_loss": aux[0], "moe_drops": aux[1]}
        if self.arch.moe is not None:
            loss = loss + 0.01 * aux[0] / max(self.arch.n_layers, 1)
        return loss, metrics

    # -- prefill ---------------------------------------------------------------
    def prefill(self, params, tokens=None, embeds=None, frontend=None,
                s_max: Optional[int] = None,
                logit_pos: Optional[int] = None):
        """Returns (logits (B, V) at logit_pos (default: last), cache).

        ``logit_pos`` supports length-bucketed prefill: causal attention makes
        tail padding inert for positions <= logit_pos."""
        x = self._embed_inputs(params, tokens, embeds)
        b, s, _ = x.shape
        s_max = s_max or s
        h, raw_caches, _ = self._forward_full(params, x, frontend=frontend,
                                              return_cache=True)
        pos = s - 1 if logit_pos is None else logit_pos
        logits = (h[:, pos].astype(jnp.float32)
                  @ self._head_weight(params).astype(jnp.float32))
        cache = self._package_cache(raw_caches, b, s, s_max)
        return logits, cache

    def _pad_kv(self, kv, s, s_max):
        k, v = kv
        # kv from scan: (L, B, S, Hkv, hd)
        pad = [(0, 0)] * k.ndim
        pad[-3] = (0, s_max - s)
        k = jnp.pad(k.astype(self.dtype), pad)
        v = jnp.pad(v.astype(self.dtype), pad)
        return k, v

    def _attn_cache_from_kv(self, kv, b, s, s_max):
        a = self.arch
        w = self.cfg.recent_window
        k, v = self._pad_kv(kv, s, s_max)
        lead = k.shape[:-4] if k.ndim > 4 else ()
        hd = a.resolved_head_dim
        zr = jnp.zeros(lead + (b, w, a.n_kv_heads, hd), self.dtype)
        return {"k_big": k, "v_big": v, "k_rec": zr, "v_rec": zr + 0,
                "big_len": jnp.asarray(s, jnp.int32),
                "rec_len": jnp.zeros((), jnp.int32)}

    def _package_cache(self, raw, b, s, s_max):
        out = []
        for seg, c in zip(self.segments, raw):
            if seg.kind in ("dense", "dense_mlp", "moe"):
                out.append(self._attn_cache_from_kv(c, b, s, s_max))
            elif seg.kind == "mamba":
                out.append(c)
            elif seg.kind == "hyb_super":
                mcs, kv = c
                out.append({"mamba": mcs,
                            "attn": self._attn_cache_from_kv(kv, b, s, s_max)})
            elif seg.kind == "vlm_super":
                kvs, ckv = c
                out.append({"dense": self._attn_cache_from_kv(kvs, b, s, s_max),
                            "cross_kv": ckv})
        return out

    def init_cache(self, batch: int, s_max: int, frontend_tokens: int = 0):
        """Zero cache (for dry-run decode cells and fresh generation)."""
        a = self.arch
        hd = a.resolved_head_dim
        w = self.cfg.recent_window
        dt = self.dtype

        def attn_cache(*lead):
            zb = jnp.zeros(lead + (batch, s_max, a.n_kv_heads, hd), dt)
            zr = jnp.zeros(lead + (batch, w, a.n_kv_heads, hd), dt)
            return {"k_big": zb, "v_big": zb + 0, "k_rec": zr, "v_rec": zr + 0,
                    "big_len": jnp.zeros((), jnp.int32),
                    "rec_len": jnp.zeros((), jnp.int32)}

        def mamba_cache(*lead):
            c = make_mamba_cache(batch, a)
            return jax.tree.map(
                lambda t: jnp.broadcast_to(t, lead + t.shape), c)

        out = []
        for seg in self.segments:
            if seg.kind in ("dense", "dense_mlp", "moe"):
                out.append(attn_cache(seg.n))
            elif seg.kind == "mamba":
                out.append(mamba_cache(seg.n))
            elif seg.kind == "hyb_super":
                out.append({"mamba": mamba_cache(seg.n, seg.inner),
                            "attn": attn_cache(seg.n)})
            elif seg.kind == "vlm_super":
                nf = frontend_tokens or a.n_frontend_tokens
                out.append({
                    "dense": attn_cache(seg.n, seg.inner),
                    "cross_kv": (jnp.zeros((seg.n, batch, nf, a.n_kv_heads,
                                            hd), dt),
                                 jnp.zeros((seg.n, batch, nf, a.n_kv_heads,
                                            hd), dt))})
        return out

    def cache_specs(self, batch: int, s_max: int, frontend_tokens: int = 0):
        """PartitionSpec tree matching init_cache(batch, s_max) (shape-aware
        so it is valid for jit in_shardings)."""
        a = self.arch
        pol = self.policy
        hd = a.resolved_head_dim
        w = self.cfg.recent_window

        def P_(logical, shape):
            return pol.spec_for_shape(logical, shape)

        def attn_spec(*lead):
            nl = (None,) * len(lead)
            big_shape = lead + (batch, s_max, a.n_kv_heads, hd)
            rec_shape = lead + (batch, w, a.n_kv_heads, hd)
            big = P_(nl + ("batch", "kv_seq", None, None), big_shape)
            rec = P_(nl + ("batch", None, None, None), rec_shape)
            return {"k_big": big, "v_big": big, "k_rec": rec, "v_rec": rec,
                    "big_len": P_((), ()), "rec_len": P_((), ())}

        def mamba_spec(*lead):
            nl = (None,) * len(lead)
            s_cfg = a.ssm
            nh = self.n_ssm_heads_like()
            return MambaCache(
                ssm_state=P_(nl + ("batch", "ssm_heads", None, None),
                             lead + (batch, nh, s_cfg.head_dim,
                                     s_cfg.d_state)),
                conv_x=P_(nl + ("batch", None, "d_inner"),
                          lead + (batch, s_cfg.d_conv - 1, a.d_inner)),
                conv_bc=P_(nl + ("batch", None, None),
                           lead + (batch, s_cfg.d_conv - 1,
                                   2 * s_cfg.ngroups * s_cfg.d_state)))

        out = []
        for seg in self.segments:
            if seg.kind in ("dense", "dense_mlp", "moe"):
                out.append(attn_spec(seg.n))
            elif seg.kind == "mamba":
                out.append(mamba_spec(seg.n))
            elif seg.kind == "hyb_super":
                out.append({"mamba": mamba_spec(seg.n, seg.inner),
                            "attn": attn_spec(seg.n)})
            elif seg.kind == "vlm_super":
                nf = frontend_tokens or a.n_frontend_tokens
                ckv = P_((None, "batch", "frontend_seq", None, None),
                         (seg.n, batch, nf, a.n_kv_heads, hd))
                out.append({"dense": attn_spec(seg.n, seg.inner),
                            "cross_kv": (ckv, ckv)})
        return out

    def n_ssm_heads_like(self) -> int:
        return self.arch.n_ssm_heads

    # -- decode ------------------------------------------------------------
    def _unpack_attn(self, c, idx=None):
        sel = (lambda t: t if idx is None else t[idx])
        return AttnCache(k_big=sel(c["k_big"]), v_big=sel(c["v_big"]),
                         k_recent=sel(c["k_rec"]), v_recent=sel(c["v_rec"]),
                         big_len=c["big_len"], recent_len=c["rec_len"])

    def decode_step(self, params, cache, tokens):
        """tokens: (B,) int32 -> (logits (B, V), new cache)."""
        a = self.arch
        x = params["embed"][tokens].astype(self.dtype)
        if a.tie_embeddings:
            x = x * math.sqrt(a.d_model)
        if a.pos_emb == PosEmb.SINUSOIDAL:
            c0 = cache[0]
            pos = c0["big_len"] + c0["rec_len"]
            x = x + sinusoidal_pos(pos[None], a.d_model)[0].astype(x.dtype)
        x = self.policy.constrain(x, ("batch", None))
        new_cache = []

        for i, seg in enumerate(self.segments):
            p = params[f"seg{i}"]
            c = cache[i]
            if seg.kind in ("dense", "dense_mlp", "moe"):
                step = self._moe_layer_decode if seg.kind == "moe" \
                    else self._dense_layer_decode

                def body(carry, inp):
                    lp, lc = inp
                    ac = AttnCache(k_big=lc[0], v_big=lc[1], k_recent=lc[2],
                                   v_recent=lc[3], big_len=c["big_len"],
                                   recent_len=c["rec_len"])
                    y, nc_ = step(carry, lp, ac)
                    return y, (nc_.k_recent, nc_.v_recent)
                xs = (p, (c["k_big"], c["v_big"], c["k_rec"], c["v_rec"]))
                x, recs = self._scan(body, x, xs, seg.n)
                new_cache.append({**c, "k_rec": recs[0], "v_rec": recs[1],
                                  "rec_len": c["rec_len"] + 1})
            elif seg.kind == "mamba":
                def body(carry, inp):
                    lp, lc = inp
                    y, nc_ = self._mamba_layer_decode(carry, lp, lc)
                    return y, nc_
                x, ncs = self._scan(body, x, (p, c), seg.n)
                new_cache.append(ncs)
            elif seg.kind == "hyb_super":
                shared = p["attn"]

                def body(carry, inp):
                    (mp, mc), lc = inp

                    def inner(c2, inp2):
                        lp2, lc2 = inp2
                        y2, nc2 = self._mamba_layer_decode(c2, lp2, lc2)
                        return y2, nc2
                    y, nmc = self._scan(inner, carry, (mp, mc), seg.inner)
                    ac = AttnCache(k_big=lc[0], v_big=lc[1], k_recent=lc[2],
                                   v_recent=lc[3],
                                   big_len=c["attn"]["big_len"],
                                   recent_len=c["attn"]["rec_len"])
                    y, nac = self._dense_layer_decode(y, shared, ac)
                    return y, (nmc, (nac.k_recent, nac.v_recent))
                ca = c["attn"]
                xs = ((p["mamba"], c["mamba"]),
                      (ca["k_big"], ca["v_big"], ca["k_rec"], ca["v_rec"]))
                x, ys = self._scan(body, x, xs, seg.n)
                nmc, recs = ys
                new_cache.append({
                    "mamba": nmc,
                    "attn": {**ca, "k_rec": recs[0], "v_rec": recs[1],
                             "rec_len": ca["rec_len"] + 1}})
            elif seg.kind == "vlm_super":
                cd = c["dense"]

                def body(carry, inp):
                    (dp, cp), (dc, ckv) = inp

                    def inner(c2, inp2):
                        lp2, lc2 = inp2
                        ac2 = AttnCache(k_big=lc2[0], v_big=lc2[1],
                                        k_recent=lc2[2], v_recent=lc2[3],
                                        big_len=cd["big_len"],
                                        recent_len=cd["rec_len"])
                        y2, nc2 = self._dense_layer_decode(c2, lp2, ac2)
                        return y2, (nc2.k_recent, nc2.v_recent)
                    y, recs = self._scan(
                        inner, carry,
                        ((dp), (dc[0], dc[1], dc[2], dc[3])), seg.inner)
                    y = self._cross_layer_decode(y, cp, ckv)
                    return y, recs
                xs = ((p["dense"], p["cross"]),
                      ((cd["k_big"], cd["v_big"], cd["k_rec"], cd["v_rec"]),
                       c["cross_kv"]))
                x, recs = self._scan(body, x, xs, seg.n)
                new_cache.append({
                    "dense": {**cd, "k_rec": recs[0], "v_rec": recs[1],
                              "rec_len": cd["rec_len"] + 1},
                    "cross_kv": c["cross_kv"]})
        x = rms_norm(x, params["final_ln"], a.norm_eps)
        logits = (x.astype(jnp.float32)
                  @ self._head_weight(params).astype(jnp.float32))
        logits = self.policy.constrain(logits, ("batch", "vocab"))
        return logits, new_cache

    def maybe_flush(self, cache):
        """Flush recent->big on every attention cache (call every
        recent_window steps from the serving loop)."""
        def flush_attn(c):
            ac = self._unpack_attn(c)
            nc = flush_cache(ac)
            return {"k_big": nc.k_big, "v_big": nc.v_big,
                    "k_rec": nc.k_recent, "v_rec": nc.v_recent,
                    "big_len": nc.big_len, "rec_len": nc.recent_len}

        out = []
        for seg, c in zip(self.segments, cache):
            if seg.kind in ("dense", "dense_mlp", "moe"):
                out.append(flush_attn(c))
            elif seg.kind == "mamba":
                out.append(c)
            elif seg.kind == "hyb_super":
                out.append({"mamba": c["mamba"], "attn": flush_attn(c["attn"])})
            elif seg.kind == "vlm_super":
                out.append({"dense": flush_attn(c["dense"]),
                            "cross_kv": c["cross_kv"]})
        return out
