"""Attention blocks (self / cross) for train, prefill and decode.

Written in purely logical terms; all distribution comes from the Policy's
sharding constraints. Decode uses the staged KV cache: a large read-only
sequence-sharded segment ("big") plus a small replicated append buffer
("recent"); the two partial flash states are merged explicitly
(flash-decoding). ``flush`` moves recent -> big outside the hot step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import NO_POLICY, Policy
from repro.kernels.decode_attention import attend_partial, merge_partials
from repro.kernels.flash_attention import flash_attention
from repro.models.common import rope

RECENT_WINDOW = 256     # decode append-buffer length between flushes


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AttnCache:
    """Staged decode cache for ONE attention site."""
    k_big: jnp.ndarray        # (B, S_max, Hkv, D) — sequence-sharded
    v_big: jnp.ndarray
    k_recent: jnp.ndarray     # (B, W, Hkv, D)     — replicated
    v_recent: jnp.ndarray
    big_len: jnp.ndarray      # () int32  — filled length of the big segment
    recent_len: jnp.ndarray   # () int32


def make_attn_cache(batch: int, s_max: int, n_kv: int, head_dim: int,
                    dtype=jnp.bfloat16, window: int = RECENT_WINDOW) -> AttnCache:
    z = lambda s: jnp.zeros(s, dtype)
    return AttnCache(
        k_big=z((batch, s_max, n_kv, head_dim)),
        v_big=z((batch, s_max, n_kv, head_dim)),
        k_recent=z((batch, window, n_kv, head_dim)),
        v_recent=z((batch, window, n_kv, head_dim)),
        big_len=jnp.zeros((), jnp.int32),
        recent_len=jnp.zeros((), jnp.int32),
    )


def _qkv(x, p, arch, policy: Policy, *, prefix: str = ""):
    """Project x: (B, S, D) -> q (B,S,Hq,hd), k, v (B,S,Hkv,hd)."""
    b, s, _ = x.shape
    hd = arch.resolved_head_dim
    q = x @ p[prefix + "wq"]
    k = x @ p[prefix + "wk"]
    v = x @ p[prefix + "wv"]
    if arch.qkv_bias:
        q = q + p[prefix + "bq"]
        k = k + p[prefix + "bk"]
        v = v + p[prefix + "bv"]
    q = q.reshape(b, s, arch.n_heads, hd)
    k = k.reshape(b, s, arch.n_kv_heads, hd)
    v = v.reshape(b, s, arch.n_kv_heads, hd)
    q = policy.constrain(q, ("batch", "seq_q", "heads", None))
    # K/V must NOT be sequence-sharded: the flash scan slices KV chunks, and
    # a dynamic-slice over a sharded dim makes GSPMD re-gather the full KV
    # every chunk (measured 28-62s collective terms in the baseline roofline).
    # Constraining them replicated-over-model (heads-sharded when divisible)
    # gathers once per layer instead.  [§Perf iteration 1]
    k = policy.constrain(k, ("batch", None, "kv_heads", None))
    v = policy.constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


def _apply_rope(arch, q, k, positions):
    if arch.pos_emb.value == "rope":
        q = rope(q, positions, arch.rope_theta)
        if k is not None:
            k = rope(k, positions, arch.rope_theta)
    return q, k


def self_attention_full(x, p, arch, policy: Policy = NO_POLICY, *,
                        positions: Optional[jnp.ndarray] = None,
                        kv_chunk: int = 256, use_pallas: bool = False,
                        return_kv: bool = False):
    """Causal full-sequence self-attention (train / prefill)."""
    b, s, d = x.shape
    q, k, v = _qkv(x, p, arch, policy)
    if positions is None:
        positions = jnp.arange(s)
    q, k = _apply_rope(arch, q, k, positions)
    out = flash_attention(q, k, v, causal=True, kv_chunk=kv_chunk,
                          use_pallas=use_pallas)
    out = policy.constrain(out, ("batch", "seq_q", "heads", None))
    out = out.reshape(b, s, -1) @ p["wo"]
    if return_kv:
        # storage sharding: the serve cache is sequence-sharded
        k = policy.constrain(k, ("batch", "kv_seq", None, None))
        v = policy.constrain(v, ("batch", "kv_seq", None, None))
        return out, (k, v)
    return out


def cross_attention_full(x, kv_src, p, arch, policy: Policy = NO_POLICY, *,
                         use_pallas: bool = False, return_kv: bool = False):
    """Cross-attention to frontend tokens (B, T, D_model)."""
    b, s, d = x.shape
    hd = arch.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, arch.n_heads, hd)
    k = (kv_src @ p["wk"]).reshape(b, kv_src.shape[1], arch.n_kv_heads, hd)
    v = (kv_src @ p["wv"]).reshape(b, kv_src.shape[1], arch.n_kv_heads, hd)
    q = policy.constrain(q, ("batch", "seq_q", "heads", None))
    k = policy.constrain(k, ("batch", "frontend_seq", "kv_heads", None))
    v = policy.constrain(v, ("batch", "frontend_seq", "kv_heads", None))
    out = flash_attention(q, k, v, causal=False, use_pallas=use_pallas)
    out = out.reshape(b, s, -1) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def self_attention_decode(x, cache: AttnCache, p, arch,
                          policy: Policy = NO_POLICY
                          ) -> Tuple[jnp.ndarray, AttnCache]:
    """One-token decode with the staged cache. x: (B, D) -> (B, D)."""
    b, d = x.shape
    pos = cache.big_len + cache.recent_len              # scalar position
    q, k, v = _qkv(x[:, None, :], p, arch, policy)
    q, k = _apply_rope(arch, q, k, pos[None])
    q = q[:, 0]                                         # (B, Hq, hd)
    k_new, v_new = k[:, 0], v[:, 0]                     # (B, Hkv, hd)

    # append to the (small, replicated) recent buffer — one-hot update keeps
    # the write local regardless of sharding
    w = cache.k_recent.shape[1]
    onehot = (jnp.arange(w) == cache.recent_len)[None, :, None, None]
    k_recent = jnp.where(onehot, k_new[:, None], cache.k_recent)
    v_recent = jnp.where(onehot, v_new[:, None], cache.v_recent)

    # two partial flash states: big (seq-sharded) + recent (replicated)
    s_max = cache.k_big.shape[1]
    valid_big = (jnp.arange(s_max) < cache.big_len)[None].repeat(b, 0)
    part_big = attend_partial(q, cache.k_big, cache.v_big, valid_big)
    valid_rec = (jnp.arange(w) <= cache.recent_len)[None].repeat(b, 0)
    part_rec = attend_partial(q, k_recent, v_recent, valid_rec)
    out = merge_partials([part_big, part_rec]).astype(x.dtype)

    out = policy.constrain(out, ("batch", "heads", None))
    out = out.reshape(b, -1) @ p["wo"]
    new_cache = dataclasses.replace(
        cache, k_recent=k_recent, v_recent=v_recent,
        recent_len=cache.recent_len + 1)
    return out, new_cache


def cross_attention_decode(x, cross_kv, p, arch, policy: Policy = NO_POLICY):
    """Decode-time cross-attention against the fixed prefill-computed KV."""
    b, d = x.shape
    hd = arch.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, arch.n_heads, hd)
    k, v = cross_kv
    part = attend_partial(q, k, v, None)
    out = merge_partials([part]).astype(x.dtype)
    return out.reshape(b, -1) @ p["wo"]


def flush_cache(cache: AttnCache) -> AttnCache:
    """Move the recent buffer into the big segment (amortized, outside the
    hot decode step). Dynamic-update-slice on the sequence-sharded big cache;
    runs once every RECENT_WINDOW tokens. Supports stacked (L, B, S, H, D)
    caches — the sequence dim is always -3."""
    nd = cache.k_big.ndim
    zero = jnp.zeros((), jnp.int32)
    starts = [zero] * nd
    starts[-3] = cache.big_len
    k_big = jax.lax.dynamic_update_slice(
        cache.k_big, cache.k_recent.astype(cache.k_big.dtype), starts)
    v_big = jax.lax.dynamic_update_slice(
        cache.v_big, cache.v_recent.astype(cache.v_big.dtype), starts)
    return dataclasses.replace(
        cache, k_big=k_big, v_big=v_big,
        big_len=cache.big_len + cache.recent_len,
        recent_len=jnp.zeros((), jnp.int32),
        k_recent=jnp.zeros_like(cache.k_recent),
        v_recent=jnp.zeros_like(cache.v_recent))
