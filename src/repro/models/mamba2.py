"""Mamba-2 block (SSD) — full-sequence (train/prefill) and recurrent decode.

Tensor-parallel layout: x/z/dt projections and the SSD heads are sharded over
the ``model`` axis ("d_inner"/"ssm_heads" logical axes); the B/C projections
(ngroups=1, shared across heads) are replicated — they are tiny, and keeping
them separate from the x path means the depthwise convs stay local under
sharding (no halo exchange across a mixed-sharded concat). The gated RMSNorm
reduces over the sharded d_inner dim; GSPMD turns that into a small
all-reduce of per-token scalars. out_proj is row-parallel.

Cache = (ssm_state (B,H,P,N) fp32, conv_x (B,d_conv-1,di), conv_bc (...,2GN)).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import NO_POLICY, Policy
from repro.kernels.ssd_scan import ssd_decode_step, ssd_scan


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MambaCache:
    ssm_state: jnp.ndarray     # (B, H, P, N) fp32
    conv_x: jnp.ndarray        # (B, d_conv-1, di)
    conv_bc: jnp.ndarray       # (B, d_conv-1, 2*G*N)


def make_mamba_cache(batch: int, arch) -> MambaCache:
    s = arch.ssm
    return MambaCache(
        ssm_state=jnp.zeros((batch, arch.n_ssm_heads, s.head_dim, s.d_state),
                            jnp.float32),
        conv_x=jnp.zeros((batch, s.d_conv - 1, arch.d_inner), jnp.bfloat16),
        conv_bc=jnp.zeros((batch, s.d_conv - 1, 2 * s.ngroups * s.d_state),
                          jnp.bfloat16),
    )


def _gated_rmsnorm(y, z, w, eps):
    dt = y.dtype
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dt)


def _causal_depthwise_conv(seq, w, b, state):
    """seq: (B, S, C); w: (d_conv, C); state: (B, d_conv-1, C) or None."""
    pad = w.shape[0] - 1
    if state is not None:
        inp = jnp.concatenate([state.astype(seq.dtype), seq], axis=1)
    else:
        inp = jnp.pad(seq, ((0, 0), (pad, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        inp, w[:, None, :].astype(seq.dtype), window_strides=(1,),
        padding="VALID", dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1])
    return out + b


def mamba_block_full(x, p, arch, policy: Policy = NO_POLICY, *,
                     use_pallas: bool = False,
                     init_cache: Optional[MambaCache] = None,
                     return_cache: bool = False):
    """x: (B, S, D) -> (B, S, D) [, MambaCache]."""
    s_cfg = arch.ssm
    b, s, d = x.shape
    di = arch.d_inner
    nh = arch.n_ssm_heads
    pad = s_cfg.d_conv - 1

    z = x @ p["w_z"]                                   # (B, S, di)
    xr = x @ p["w_x"]                                  # (B, S, di)
    bc = x @ p["w_bc"]                                 # (B, S, 2GN)
    dt_raw = x @ p["w_dt"] + p["dt_bias"]              # (B, S, nh)
    z = policy.constrain(z, ("batch", None, "d_inner"))
    xr = policy.constrain(xr, ("batch", None, "d_inner"))

    xc = jax.nn.silu(_causal_depthwise_conv(
        xr, p["conv_wx"], p["conv_bx"],
        None if init_cache is None else init_cache.conv_x))
    bcc = jax.nn.silu(_causal_depthwise_conv(
        bc, p["conv_wbc"], p["conv_bbc"],
        None if init_cache is None else init_cache.conv_bc))
    xc = policy.constrain(xc, ("batch", None, "d_inner"))

    gn = s_cfg.ngroups * s_cfg.d_state
    Bm, Cm = bcc[..., :gn], bcc[..., gn:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, final_state = ssd_scan(
        xc.reshape(b, s, nh, s_cfg.head_dim), dt, A,
        Bm.reshape(b, s, s_cfg.ngroups, s_cfg.d_state),
        Cm.reshape(b, s, s_cfg.ngroups, s_cfg.d_state),
        p["D"].astype(jnp.float32),
        init_state=None if init_cache is None else init_cache.ssm_state,
        chunk=s_cfg.chunk, use_pallas=use_pallas)
    y = y.reshape(b, s, di)
    y = _gated_rmsnorm(y, z, p["norm_w"], arch.norm_eps)
    out = y @ p["w_out"]
    if return_cache:
        take = lambda t: jnp.pad(t, ((0, 0), (max(pad - s, 0), 0), (0, 0))
                                 )[:, -pad:, :].astype(jnp.bfloat16)
        cache = MambaCache(ssm_state=final_state, conv_x=take(xr),
                           conv_bc=take(bc))
        return out, cache
    return out


def mamba_block_decode(x, cache: MambaCache, p, arch,
                       policy: Policy = NO_POLICY
                       ) -> Tuple[jnp.ndarray, MambaCache]:
    """One-token step. x: (B, D) -> (B, D)."""
    s_cfg = arch.ssm
    b, d = x.shape
    di = arch.d_inner
    nh = arch.n_ssm_heads

    z = x @ p["w_z"]                                   # (B, di)
    xr = x @ p["w_x"]
    bc = x @ p["w_bc"]
    dt_raw = x @ p["w_dt"] + p["dt_bias"]              # (B, nh)

    win_x = jnp.concatenate([cache.conv_x.astype(xr.dtype), xr[:, None]], 1)
    win_bc = jnp.concatenate([cache.conv_bc.astype(bc.dtype), bc[:, None]], 1)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", win_x,
                                p["conv_wx"].astype(xr.dtype)) + p["conv_bx"])
    bcc = jax.nn.silu(jnp.einsum("bkc,kc->bc", win_bc,
                                 p["conv_wbc"].astype(bc.dtype)) + p["conv_bbc"])

    gn = s_cfg.ngroups * s_cfg.d_state
    Bm, Cm = bcc[..., :gn], bcc[..., gn:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_state = ssd_decode_step(
        cache.ssm_state, xc.reshape(b, nh, s_cfg.head_dim), dt, A,
        Bm.reshape(b, s_cfg.ngroups, s_cfg.d_state),
        Cm.reshape(b, s_cfg.ngroups, s_cfg.d_state),
        p["D"].astype(jnp.float32))
    y = _gated_rmsnorm(y.reshape(b, di), z, p["norm_w"], arch.norm_eps)
    out = y @ p["w_out"]
    new_cache = MambaCache(ssm_state=new_state,
                           conv_x=win_x[:, 1:].astype(jnp.bfloat16),
                           conv_bc=win_bc[:, 1:].astype(jnp.bfloat16))
    return out, new_cache
