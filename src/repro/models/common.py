"""Shared model building blocks (pure functions over param pytrees)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm; on TPU dispatches to the fused Pallas kernel
    (kernels/rmsnorm), elsewhere the pure-jnp form below (identical math)."""
    try:
        if jax.default_backend() == "tpu":
            from repro.kernels.rmsnorm import rmsnorm_pallas
            return rmsnorm_pallas(x, w, eps=eps)
    except Exception:       # pragma: no cover — fall through to jnp
        pass
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, half)
    if angles.ndim == 2:          # (S, half) -> broadcast over batch
        angles = angles[None]
    angles = angles[..., :, None, :]                            # (B, S, 1, half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """(S,) or (B,S) -> (..., S, d_model) sinusoidal embedding."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


def gated_mlp(x: jnp.ndarray, wi_gate: jnp.ndarray, wi_up: jnp.ndarray,
              wo: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = actf(x @ wi_gate) * (x @ wi_up)
    return h @ wo


def init_dense(key, shape, scale: Optional[float] = None,
               dtype=jnp.bfloat16) -> jnp.ndarray:
    scale = scale if scale is not None else (1.0 / math.sqrt(shape[0]))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
