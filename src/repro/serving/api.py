"""One declarative Scenario API over every cluster simulator.

The repo's simulators grew as three disjoint entry points — ``simulate``
(colocated), ``simulate_disaggregated`` and ``simulate_autoscaled`` — which
made the paper's most interesting regime (autoscaled, SLO-aware
*disaggregated* pools under spot pricing) inexpressible. This module gives
the codebase exactly two verbs over one declarative description:

    report = run(Scenario(workload=..., fleet=FleetSpec(...), slo=...,
                          topology=Colocated() | Disaggregated(),
                          scaling=FixedScale(n) | Reactive() | Forecast(),
                          market=SpotMarket(...) | None))
    plan   = optimize(scenario, objective="cost")

Internally every combination runs one engine path: the existing causal
heartbeat loop (``simulator.run_heartbeat_loop``) drives a *topology*
(``ColocatedTopology`` or ``DisaggTopology``) whose worker containers are
either static (``FixedPool`` / fixed sides) or policy-scaled
(``forecast.ManagedPool``), with the spot market's reclaim events delivered
causally to whichever container owns the victims. That is what makes the
2 topologies x 3 scaling modes x {on-demand, spot} matrix composable —
including the cell none of the legacy entry points could express:
autoscaled disaggregated pools with asymmetric spot hazards, where a
decode-pool reclaim pays a full context re-prefill plus KV re-transfer
while a prefill-pool reclaim merely re-queues prompts.

The legacy entry points remain as thin deprecation shims that build the
equivalent ``Scenario`` and reproduce their pre-refactor metrics
bit-for-bit (tests/test_shim_goldens.py pins them).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.scaling import FeedbackConfig, SpotMixConfig
from repro.core.slo import SLO, slo_attainment
from repro.core.worker_config import WorkerSpec
from repro.serving.disagg import (DisaggConfig, DisaggResult, DisaggTopology,
                                  FixedDecodeSide, FixedPrefillSide,
                                  ManagedSide, PrefillSimWorker, pool_cost,
                                  ratio_pool_fn)
from repro.serving.forecast import (EpochStat, FeedbackPolicy, ForecastConfig,
                                    ForecastPolicy, ManagedPool,
                                    ReactivePolicy, ScaleSimConfig,
                                    ScaleSimResult, SeasonalNaiveForecaster,
                                    SpotMarket)
from repro.serving.lifecycle import mark_requeue
from repro.serving.length_predictor import LengthPredictor
from repro.serving.simulator import (ColocatedTopology, FixedPool, SimConfig,
                                     SimResult, SimWorker,
                                     make_worker_state, run_heartbeat_loop)
from repro.serving.tenants import (TenantSpec, materialize_tenants,
                                   planning_slo, tenant_attainment,
                                   tenant_rows)
from repro.serving.workload import clone_trace

# ---- scenario vocabulary -----------------------------------------------------


@dataclasses.dataclass
class PoolSpec:
    """One worker type in the fleet: its spec, how many to start with, and
    which tier it serves (``serve`` for colocated, ``prefill``/``decode``
    for a disaggregated topology). Under ``FixedScale`` the count IS the
    pool size; under ``Reactive``/``Forecast`` it seeds ``initial_workers``
    and the policy owns the count from there.

    ``tenants`` expresses LoRA/multi-tenant *placement as a decision*: None
    (the default) is a shared pool every tenant may place on; a list of
    tenant names makes the pool dedicated — only those tenants' requests
    are eligible for its workers. ``optimize()`` on a multi-tenant scenario
    searches shared-vs-dedicated pool assignments through this field."""
    spec: WorkerSpec
    count: int = 0
    role: str = "serve"
    tenants: Optional[Sequence[str]] = None


@dataclasses.dataclass
class FleetSpec:
    """The worker types a scenario may buy, grouped by role."""
    pools: Sequence[PoolSpec] = dataclasses.field(default_factory=list)

    def for_role(self, role: str) -> List[PoolSpec]:
        sel = [p for p in self.pools if p.role == role]
        if not sel and role == "serve":
            # a role-less fleet serves the colocated tier
            sel = [p for p in self.pools if p.role not in ("prefill",
                                                           "decode")]
        return sel


@dataclasses.dataclass
class Colocated:
    """Single-tier topology: prefill and decode share every worker
    (the classic ``simulate`` world, including split_phase decode-only
    fleets for Fig. 12).

    Multi-turn sessions (``workload.session_trace``) add two decisions:
    ``router`` — ``"sticky"`` prefers each session's previous worker while
    it passes every placement constraint (falling through to ``policy``
    otherwise), ``"blind"`` ignores affinity — and ``prefix_cache`` —
    ``"lru"`` lets workers keep finished session contexts in spare KV so a
    returning turn prefills only its new tokens, ``"off"`` disables reuse.
    ``cache_tokens`` caps the per-worker cache footprint (None = spare-KV
    pressure only). Single-shot traces are untouched by all three knobs;
    the compiled cores reject session scenarios (reference engine only)."""
    heartbeat: float = 0.25
    policy: str = "aladdin"            # aladdin | jsq | po2
    split_phase: bool = False
    rebalance: bool = True
    gamma: float = 0.5
    theta: float = 0.9
    max_batch: int = 128
    router: str = "blind"              # blind | sticky (session affinity)
    prefix_cache: str = "lru"          # lru | off (session KV reuse)
    cache_tokens: Optional[int] = None  # per-worker cache cap, tokens


@dataclasses.dataclass
class Disaggregated:
    """Two-tier topology: prefill pools hand KV to decode pools over a
    modeled interconnect (the ``simulate_disaggregated`` world)."""
    heartbeat: float = 0.05
    policy: str = "aladdin"            # decode placement: aladdin | jsq
    gamma: float = 0.5
    theta: float = 0.9
    kv_transfer_bw: float = 64e9
    kv_transfer_lat: float = 2e-3
    prefill_router: str = "packed"     # packed (legacy) | earliest
    decode_router: str = "packed"      # packed (legacy) | earliest


@dataclasses.dataclass
class FixedScale:
    """No autoscaling. ``n`` workers of the first pool type, or the fleet's
    explicit per-pool counts when ``n`` is None; a colocated fleet with
    neither runs *elastic* (open a worker whenever placement fails — the
    min-cost oracle)."""
    n: Optional[int] = None


@dataclasses.dataclass
class SideOverride:
    """Per-side parameter overrides for an autoscaled *disaggregated*
    scenario (``None`` inherits the scaling mode's value). The two sides
    genuinely want different settings: TTFT burns in the arrival->prefill
    hop, so the prefill side reacts on a short ``lead``; ATGT pressure
    builds through the handoff->decode pipeline, so the decode side wants a
    longer one (and its own headroom). ``window``/``metric`` tune the
    side's SLO-feedback controller (``FeedbackScale``)."""
    lead: Optional[float] = None
    headroom: Optional[float] = None
    interval: Optional[float] = None
    min_workers: Optional[int] = None
    window: Optional[float] = None            # feedback attainment window
    metric: Optional[str] = None              # feedback: ttft | atgt | both


@dataclasses.dataclass
class Reactive:
    """Eq. 7 scaling on the last observed rate, with a scale-down cooldown
    (``forecast.ReactivePolicy``)."""
    interval: float = 5.0
    provision_delay: float = 10.0
    cooldown: float = 60.0
    min_workers: int = 1
    max_workers: int = 512
    initial_workers: Optional[int] = None     # None: the fleet pool counts
    headroom: float = 1.0                     # SLO head-room on targets
    spot_mix: Optional[SpotMixConfig] = None
    prefill: Optional[SideOverride] = None    # disaggregated per-side knobs
    decode: Optional[SideOverride] = None


@dataclasses.dataclass
class Forecast:
    """Eq. 7 scaling on a seasonal-naive + EWMA-residual forecast
    ``provision_delay + interval`` ahead (``forecast.ForecastPolicy``).
    ``spot_mix`` overrides the economics derived from the market's spot
    spec (discount = spot price, hazard = its reclaim rate)."""
    interval: float = 5.0
    provision_delay: float = 10.0
    lead: Optional[float] = None
    period: float = 300.0
    bin_width: Optional[float] = None         # None: one bin per interval
    min_workers: int = 1
    max_workers: int = 512
    initial_workers: Optional[int] = None
    headroom: float = 1.0                     # SLO head-room on targets
    spot_mix: Optional[SpotMixConfig] = None
    prefill: Optional[SideOverride] = None    # disaggregated per-side knobs
    decode: Optional[SideOverride] = None


@dataclasses.dataclass
class FeedbackScale:
    """Closed-loop SLO-feedback scaling: ``base`` (an open-loop ``Forecast``
    or ``Reactive`` declaration) proposes each epoch's target and an
    attainment controller corrects it from the windowed SLO attainment the
    cluster actually delivered — a multiplicative gain boost while
    attainment sits below ``slo_target - deadband``, an additive release
    (down to ``min_gain``, below 1.0 shaving open-loop over-provisioning)
    while it saturates above ``slo_target + deadband``, hysteresis in
    between. On a disaggregated topology each side runs its own controller:
    prefill reacts on TTFT attainment, decode on ATGT attainment
    (``metric="auto"``), with the base's ``prefill``/``decode``
    ``SideOverride`` supplying per-side leads/windows. An infinite
    ``deadband`` reproduces the open-loop base bit-for-bit."""
    base: Union[Forecast, Reactive] = dataclasses.field(
        default_factory=Forecast)
    slo_target: float = 0.99
    deadband: float = 0.005
    boost: float = 1.3
    decay: float = 0.02
    max_gain: float = 3.0
    min_gain: float = 1.0
    window: float = 30.0
    min_samples: int = 8
    attack_cooldown: Optional[float] = None   # None: one boost per window
    metric: str = "auto"       # auto: both | ttft (prefill) | atgt (decode)


@dataclasses.dataclass
class PolicyScale:
    """Escape hatch wrapping a prebuilt policy instance + ScaleSimConfig —
    the legacy ``simulate_autoscaled`` calling convention. Colocated only
    (a disaggregated scenario needs one independent policy per side, which
    only the declarative forms can build)."""
    policy: object
    scfg: ScaleSimConfig


ScalingLike = Union[FixedScale, Reactive, Forecast, FeedbackScale,
                    PolicyScale]
TopologyLike = Union[Colocated, Disaggregated]


@dataclasses.dataclass
class Scenario:
    """A complete, declarative description of one serving experiment:
    what arrives (``workload``: a concrete trace or a zero-arg trace
    factory), what it runs on (``fleet``), how the tiers are arranged
    (``topology``), who owns the worker counts (``scaling``), whether a
    preemptible market exists (``market``), and the SLO it is judged by.

    Multi-tenant scenarios pass ``tenants=[TenantSpec(...), ...]`` in
    place of the scalar ``workload``/``slo`` pair: the merged trace tags
    every request with its tenant, the queue becomes priority-then-EDF,
    attainment is judged per tenant against its own SLO, and ``slo``
    defaults to the *planning* SLO (the strictest across tenants; an
    explicit ``slo`` overrides that planning value only). ``workload``
    may still be set alongside ``tenants`` when it is an already-merged,
    already-tagged trace (``optimize`` replays candidates this way)."""
    workload: object = None            # Sequence[Request] | () -> Sequence
    fleet: Optional[FleetSpec] = None
    slo: Optional[SLO] = None
    topology: TopologyLike = dataclasses.field(default_factory=Colocated)
    scaling: ScalingLike = dataclasses.field(default_factory=FixedScale)
    market: Optional[SpotMarket] = None
    predictor: Optional[LengthPredictor] = None
    observer: Optional[Callable] = None
    seed: int = 0
    # which simulation core executes the scenario:
    #   reference  — the per-object Python engine (every feature; the oracle)
    #   vectorized — the numpy struct-of-arrays core (serving.fastsim):
    #                bit-for-bit the reference on fixed colocated fleets,
    #                ValueError outside that envelope
    #   jax        — the jit/scan compiled core (serving.fastsim_jax):
    #                fixed colocated aladdin/jsq fleets with inert KV;
    #                optimize() evaluates candidate batches in one call
    engine: str = "reference"
    # multi-tenant form: a list of TenantSpec in place of workload/slo
    tenants: Optional[Sequence[TenantSpec]] = None

    def materialize(self) -> List:
        """The workload as a concrete request list (evaluating a trace
        factory once); use ``workload.clone_trace`` to replay it. A
        multi-tenant scenario without an explicit merged ``workload``
        materializes every tenant stream and merges them
        (:func:`repro.serving.tenants.materialize_tenants`)."""
        if self.workload is None:
            if self.tenants is None:
                raise ValueError("Scenario needs a workload (or tenants)")
            return materialize_tenants(self.tenants)
        w = self.workload
        return list(w() if callable(w) else w)


def resolve_scenario(sc: Scenario) -> Scenario:
    """The scalar view of a scenario: validate the workload/slo vs tenants
    contract and, for a multi-tenant scenario without an explicit ``slo``,
    fill in the planning SLO (strictest TTFT/ATGT across tenants) that
    parameterizes worker-level placement scoring. Idempotent; every engine
    entry point calls this first so direct engine calls see the same
    contract as ``run()``."""
    if sc.fleet is None:
        raise ValueError("Scenario needs a fleet")
    if sc.tenants is not None:
        if not isinstance(sc.topology, Colocated):
            raise ValueError("Scenario.tenants is a Colocated-topology "
                             "feature; a disaggregated multi-tenant fleet "
                             "is not modeled")
        if not sc.tenants:
            raise ValueError("Scenario.tenants must be non-empty when set")
        if sc.slo is None:
            sc = dataclasses.replace(sc, slo=planning_slo(sc.tenants))
    if sc.slo is None:
        raise ValueError("Scenario needs an slo (or tenants)")
    if sc.workload is None and sc.tenants is None:
        raise ValueError("Scenario needs a workload (or tenants)")
    return sc


# ---- the unified run record --------------------------------------------------


@dataclasses.dataclass
class RunReport:
    """The one versioned result record every ``run()`` returns — the union
    of the three legacy ``*Result.row()`` schemas. ``row()`` is the flat
    dict the benchmarks write; the ``to_*_result`` adapters feed the
    deprecation shims bit-for-bit."""
    schema: str = "runreport/2"
    topology: str = "colocated"        # colocated | disaggregated
    scaling: str = "fixed"             # fixed | elastic | policy name
    attainment: float = 0.0
    p99_ttft: float = float("nan")
    p99_atgt: float = float("nan")
    mean_atgt: float = float("nan")
    finished: int = 0
    total: int = 0
    peak_workers: int = 0
    gpu_cost: float = 0.0              # fleet cost (fixed) / billed (scaled)
    gpu_seconds: float = 0.0           # billed accelerator-seconds (scaled)
    spot_gpu_seconds: float = 0.0
    moves: int = 0
    n_prefill: int = 0
    n_decode: int = 0
    pool_mix: str = ""
    mean_transfer: float = 0.0
    kv_retransfers: int = 0
    preempted_workers: int = 0         # instant/deadline kills with loss
    drained_ok: int = 0                # reclaims that drained in the notice
    requeued: int = 0
    lora_swaps: int = 0                # adapter fault-ins (LoRA tenants)
    # multi-turn sessions: prefix-cache effectiveness. hit_rate is over
    # cacheable lookups (turns with a prior context; turn-0 requests have
    # nothing to reuse and are not counted); evictions count entries
    # dropped to capacity pressure, drain retirement or spot vaporization.
    cache_hit_rate: float = 0.0
    prefix_evictions: int = 0
    epochs: Dict[str, List[EpochStat]] = dataclasses.field(
        default_factory=dict)
    # per-tenant breakdown (multi-tenant scenarios): attainment vs the
    # tenant's own SLO, p99 TTFT/ATGT, queue delay, gpu-cost share. Like
    # ``epochs`` it is excluded from ``row()`` — benchmarks that want the
    # breakdown write it explicitly.
    tenant_rows: List[Dict] = dataclasses.field(default_factory=list)

    def row(self) -> Dict:
        d = dataclasses.asdict(self)
        d.pop("epochs")
        d.pop("tenant_rows")
        return d

    # ---- legacy adapters (deprecation shims) --------------------------------
    def to_sim_result(self) -> SimResult:
        return SimResult(n_workers_peak=self.peak_workers,
                         attainment=self.attainment, p99_atgt=self.p99_atgt,
                         p99_ttft=self.p99_ttft, mean_atgt=self.mean_atgt,
                         finished=self.finished, total=self.total,
                         moves=self.moves, gpu_cost=self.gpu_cost)

    def to_disagg_result(self) -> DisaggResult:
        return DisaggResult(n_prefill=self.n_prefill,
                            n_decode=self.n_decode, gpu_cost=self.gpu_cost,
                            attainment=self.attainment,
                            p99_ttft=self.p99_ttft, p99_atgt=self.p99_atgt,
                            mean_transfer=self.mean_transfer,
                            finished=self.finished, total=self.total,
                            pool_mix=self.pool_mix)

    def to_scale_result(self) -> ScaleSimResult:
        return ScaleSimResult(policy=self.scaling,
                              gpu_seconds=self.gpu_seconds,
                              attainment=self.attainment,
                              p99_ttft=self.p99_ttft,
                              p99_atgt=self.p99_atgt,
                              mean_atgt=self.mean_atgt,
                              finished=self.finished, total=self.total,
                              peak_workers=self.peak_workers,
                              spot_gpu_seconds=self.spot_gpu_seconds,
                              preempted_workers=self.preempted_workers,
                              requeued=self.requeued,
                              epochs=self.epochs.get("serve", []))


@dataclasses.dataclass
class Plan:
    """What ``optimize`` found: the winning concrete scenario (None when
    nothing within the search bounds attains the target), its report, and
    the search account. For a policy-space search over an autoscaled
    scenario, ``params`` records the winning axis assignment (axes left at
    the scenario's declared value are absent)."""
    objective: str
    scenario: Optional[Scenario]
    report: Optional[RunReport]
    n_workers: int = 0
    cost: float = float("nan")
    evals: int = 0
    params: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def feasible(self) -> bool:
        return self.report is not None

    @property
    def disagg_result(self) -> Optional[DisaggResult]:
        return self.report.to_disagg_result() if self.report else None


# ---- metric assembly ---------------------------------------------------------


def _percentiles(finished, total, slo) -> Dict:
    atgts = [r.atgt() for r in finished if r.atgt() is not None]
    ttfts = [r.ttft() for r in finished if r.ttft() is not None]
    return dict(
        attainment=slo_attainment(finished, total, slo),
        p99_atgt=float(np.percentile(atgts, 99)) if atgts else float("nan"),
        p99_ttft=float(np.percentile(ttfts, 99)) if ttfts else float("nan"),
        mean_atgt=float(np.mean(atgts)) if atgts else float("nan"),
        finished=len(finished), total=total)


# ---- scaling builders --------------------------------------------------------


def _open_loop(s: ScalingLike):
    """The open-loop declaration under a scaling mode: ``FeedbackScale``
    corrects its ``base``, everything else is its own open loop."""
    return s.base if isinstance(s, FeedbackScale) else s


def _side_override(s: ScalingLike, side: Optional[str]) -> SideOverride:
    ov = getattr(_open_loop(s), side, None) if side in ("prefill",
                                                        "decode") else None
    return ov if ov is not None else SideOverride()


def _scale_cfg(s: ScalingLike, initial: int,
               side: Optional[str] = None) -> ScaleSimConfig:
    base = _open_loop(s)
    ov = _side_override(s, side)
    return ScaleSimConfig(
        interval=ov.interval if ov.interval is not None else base.interval,
        provision_delay=base.provision_delay,
        cooldown=getattr(base, "cooldown", 60.0),
        lead=ov.lead if ov.lead is not None else getattr(base, "lead", None),
        min_workers=ov.min_workers if ov.min_workers is not None
        else base.min_workers,
        max_workers=base.max_workers,
        initial_workers=base.initial_workers
        if base.initial_workers is not None else max(initial, 1),
        headroom=ov.headroom if ov.headroom is not None else base.headroom)


_FEEDBACK_METRIC = {None: "both", "prefill": "ttft", "decode": "atgt"}


def _build_policy(s: ScalingLike, scfg: ScaleSimConfig,
                  spot_spec: Optional[WorkerSpec],
                  side: Optional[str] = None):
    base = _open_loop(s)
    mix = getattr(base, "spot_mix", None)
    if mix is None and spot_spec is not None and spot_spec.is_spot:
        mix = SpotMixConfig(discount=spot_spec.price,
                            hazard=spot_spec.preempt_hazard)
    if isinstance(base, Forecast):
        fc = SeasonalNaiveForecaster(ForecastConfig(
            period=base.period, bin_width=base.bin_width or base.interval))
        inner = ForecastPolicy(scfg, fc, spot_mix=mix)
    else:
        inner = ReactivePolicy(scfg, spot_mix=mix)
    if not isinstance(s, FeedbackScale):
        return inner
    ov = _side_override(s, side)
    metric = ov.metric or (s.metric if s.metric != "auto"
                           else _FEEDBACK_METRIC[side])
    fcfg = FeedbackConfig(
        slo_target=s.slo_target, deadband=s.deadband, boost=s.boost,
        decay=s.decay, max_gain=s.max_gain, min_gain=s.min_gain,
        window=ov.window if ov.window is not None else s.window,
        min_samples=s.min_samples, attack_cooldown=s.attack_cooldown)
    return FeedbackPolicy(inner, fcfg, metric=metric)


# ---- the engine: colocated ---------------------------------------------------


def _run_colocated(sc: Scenario, seed: int) -> RunReport:
    topo_cfg: Colocated = sc.topology
    cfg = SimConfig(heartbeat=topo_cfg.heartbeat, policy=topo_cfg.policy,
                    split_phase=topo_cfg.split_phase,
                    rebalance=topo_cfg.rebalance, gamma=topo_cfg.gamma,
                    theta=topo_cfg.theta, max_batch=topo_cfg.max_batch,
                    seed=seed, router=topo_cfg.router,
                    prefix_cache=topo_cfg.prefix_cache,
                    cache_tokens=topo_cfg.cache_tokens)
    rng = np.random.default_rng(seed)
    pools = sc.fleet.for_role("serve")
    if not pools:
        raise ValueError("colocated scenario needs at least one fleet pool "
                         "(role='serve')")
    tenants = list(sc.tenants) if sc.tenants is not None else None
    dedicated = any(p.tenants is not None for p in pools)
    if dedicated and tenants is None:
        raise ValueError("PoolSpec.tenants names tenants of a multi-tenant "
                         "scenario; set Scenario.tenants")
    lora = tenants is not None and any(t.lora is not None for t in tenants)
    # restricted fleets (dedicated pools / LoRA adapters) fence placement
    # per worker — only meaningful with explicit fixed pool counts
    restricted = dedicated or lora
    sims: Dict[int, SimWorker] = {}
    wid = [0]

    def new_worker(wspec: WorkerSpec):
        wid[0] += 1
        return make_worker_state(wid[0], wspec, cfg, sc.slo)

    market = sc.market
    if market is not None and (market.prefill_spec is not None
                               or len(market.prefill_events) > 0):
        raise ValueError("SpotMarket.prefill_spec/prefill_events describe "
                         "the prefill side of a Disaggregated topology; a "
                         "Colocated scenario would silently ignore them")
    notice = market.notice_s if market is not None else 0.0
    scaling = sc.scaling
    if restricted and not isinstance(scaling, FixedScale):
        raise ValueError("dedicated tenant pools / LoRA adapters need a "
                         "FixedScale fleet (autoscaling policies size one "
                         "undifferentiated pool)")
    if isinstance(scaling, FixedScale):
        if scaling.n is not None:
            src = [(pools[0], pools[0].spec)] * int(scaling.n)
        else:
            src = [(p, p.spec) for p in pools for _ in range(p.count)]
        name_idx = {t.name: i for i, t in enumerate(tenants)} \
            if tenants is not None else {}
        workers = []
        for p, s in src:
            w = new_worker(s)
            if p.tenants is not None:
                unknown = [nm for nm in p.tenants if nm not in name_idx]
                if unknown:
                    raise ValueError(f"PoolSpec.tenants names unknown "
                                     f"tenant(s) {unknown}")
                w.allowed_tenants = frozenset(name_idx[nm]
                                              for nm in p.tenants)
            workers.append(w)
            sims[w.id] = SimWorker(w, w.perf, 0.0, cfg.split_phase)
        factory = None
        if not workers:                # elastic: the min-cost oracle mode
            if restricted:
                raise ValueError("a restricted (dedicated/LoRA) fleet "
                                 "needs explicit pool counts; the elastic "
                                 "oracle opens undifferentiated workers")
            def factory():
                return new_worker(pools[0].spec)
        pool = FixedPool(workers, sims, rng, factory=factory,
                         notice_s=notice)
        scaling_label = "elastic" if factory is not None else "fixed"
    else:
        if isinstance(scaling, PolicyScale):
            policy, scfg = scaling.policy, scaling.scfg
        else:
            scfg = _scale_cfg(scaling, sum(p.count for p in pools))
            policy = _build_policy(
                scaling, scfg, market.spec if market is not None else None)

        def on_spawn(w, t):
            sims[w.id] = SimWorker(w, w.perf, t, cfg.split_phase)

        def on_kill(w):
            sim = sims.pop(w.id)
            if sim.cache is not None:
                sim.cache.vaporize()    # cached prefixes die with the worker
            lost = w.ongoing + w.new_batch + sim.preempted
            for r in lost:
                r.cached_len = 0        # granted reuse is void off-worker
            w.ongoing.clear()
            w.new_batch.clear()
            w.mark_dirty()
            return lost

        pool = ManagedPool(
            pools[0].spec, scfg, policy, cfg.heartbeat, rng,
            new_worker=new_worker, on_spawn=on_spawn, on_kill=on_kill,
            load=lambda w: w.batch_size,
            idle=lambda w: (not w.ongoing and not w.new_batch
                            and not sims[w.id].preempted),
            sims=sims, spot_spec=market.spec if market is not None else None,
            notice_s=notice, name="serve")
        scaling_label = getattr(policy, "name", type(policy).__name__)

    managed = isinstance(pool, ManagedPool)
    topo = ColocatedTopology(sc.slo, cfg, pool, rng, predictor=sc.predictor,
                             observer=sc.observer, tracking=not managed,
                             tenants=tenants)
    topo.restricted = restricted
    trace = sc.materialize()
    trace = run_heartbeat_loop(
        trace, cfg.heartbeat, topo.admit, topo.step, topo.drained,
        events=market.events if market is not None else None, fire=topo.fire)

    rep = RunReport(topology="colocated", scaling=scaling_label,
                    **_percentiles(topo.finished, len(trace), sc.slo))
    rep.moves = topo.moves
    if managed:
        rep.peak_workers = pool.peak
        rep.gpu_seconds = pool.gpu_s
        rep.gpu_cost = pool.gpu_s
        rep.spot_gpu_seconds = pool.spot_gpu_s
        rep.epochs = {"serve": pool.epochs}
    else:
        rep.peak_workers = topo.peak_workers
        # every worker that served counts, including market-reclaimed ones
        # the pool removed mid-run (matches the disagg fixed path, which
        # reports declared pool counts)
        rep.gpu_cost = sum(w.spec.n_accelerators for w in pool.workers) \
            + pool.retired_cost
    rep.preempted_workers = pool.killed
    rep.drained_ok = pool.drained_ok
    rep.requeued = pool.requeued
    rep.cache_hit_rate = topo.cache_stats.hit_rate()
    rep.prefix_evictions = topo.cache_stats.evictions
    if tenants is not None:
        # the multi-tenant headline judges every request against its OWN
        # tenant SLO (identical to the scalar number for one tenant, whose
        # budgets equal the planning SLO)
        rep.attainment = tenant_attainment(trace)
        rep.tenant_rows = tenant_rows(trace, tenants, rep.gpu_cost)
        rep.lora_swaps = topo.lora_swaps
    return rep


# ---- the engine: disaggregated -----------------------------------------------


@dataclasses.dataclass(frozen=True)
class _SideEvent:
    """A market reclaim event routed to one side of a disaggregated
    cluster (the heartbeat loop only needs the ``t`` attribute)."""
    t: float
    ev: object
    side: str


def _merge_side_events(market: Optional[SpotMarket]):
    if market is None:
        return None
    evs = [_SideEvent(e.t, e, "decode") for e in market.events] \
        + [_SideEvent(e.t, e, "prefill") for e in market.prefill_events]
    return evs or None


def _run_disagg(sc: Scenario, seed: int) -> RunReport:
    topo_cfg: Disaggregated = sc.topology
    cfg = DisaggConfig(heartbeat=topo_cfg.heartbeat, policy=topo_cfg.policy,
                       gamma=topo_cfg.gamma, theta=topo_cfg.theta,
                       kv_transfer_bw=topo_cfg.kv_transfer_bw,
                       kv_transfer_lat=topo_cfg.kv_transfer_lat, seed=seed,
                       prefill_router=topo_cfg.prefill_router,
                       decode_router=topo_cfg.decode_router)
    rng = np.random.default_rng(seed)
    p_pools = [(p.spec, p.count) for p in sc.fleet.for_role("prefill")]
    d_pools = [(p.spec, p.count) for p in sc.fleet.for_role("decode")]
    if not p_pools or not d_pools:
        raise ValueError("disaggregated scenario needs fleet pools with "
                         "role='prefill' and role='decode'")
    if isinstance(sc.scaling, FixedScale):
        # legacy _as_pools semantics: zero-count pool types do not exist
        # (they would pollute worker ids and the pool_mix label)
        p_pools = [(s, k) for s, k in p_pools if k > 0]
        d_pools = [(s, k) for s, k in d_pools if k > 0]
        if not p_pools or not d_pools:
            raise ValueError("fixed disaggregated scenario has an empty "
                             "prefill or decode pool (all counts are 0)")
    market = sc.market
    notice = market.notice_s if market is not None else 0.0
    scaling = sc.scaling

    if isinstance(scaling, FixedScale):
        if scaling.n is not None:
            raise ValueError("FixedScale.n is ambiguous for a disaggregated "
                             "fleet; set per-pool counts instead")
        # prefill groups: ids dense from 1; decode groups: ids from 1000
        pools_p: List[Tuple[WorkerSpec, List[PrefillSimWorker]]] = []
        wid = 0
        for spec, k in p_pools:
            group = []
            for _ in range(k):
                wid += 1
                group.append(PrefillSimWorker(wid, spec, sc.slo))
            pools_p.append((spec, group))
        dcfg = SimConfig(gamma=cfg.gamma, theta=cfg.theta, split_phase=True)
        pools_d: List[Tuple[WorkerSpec, List]] = []
        sims_d: Dict[int, SimWorker] = {}
        wid = 1000
        for spec, k in d_pools:
            group = []
            for _ in range(k):
                w = make_worker_state(wid, spec, dcfg, sc.slo)
                group.append(w)
                sims_d[w.id] = SimWorker(w, w.perf, 0.0, split_phase=True)
                wid += 1
            pools_d.append((spec, group))
        prefill = FixedPrefillSide(pools_p, rng=rng, notice_s=notice)
        decode = FixedDecodeSide(pools_d, sims_d, rng=rng, notice_s=notice)
        scaling_label = "fixed"
    else:
        if isinstance(scaling, PolicyScale):
            raise ValueError("PolicyScale wraps one policy instance; a "
                             "disaggregated scenario scales each side with "
                             "its own — use Reactive(...) or Forecast(...)")
        if len(p_pools) != 1 or len(d_pools) != 1:
            raise ValueError("autoscaled disaggregation supports one worker "
                             "type per side (plus its spot twin)")
        p_spec, p_n = p_pools[0]
        d_spec, d_n = d_pools[0]
        spot_d = market.spec if market is not None else None
        spot_p = market.prefill_spec if market is not None else None
        scfg_p = _scale_cfg(scaling, p_n, side="prefill")
        scfg_d = _scale_cfg(scaling, d_n, side="decode")
        pol_p = _build_policy(scaling, scfg_p, spot_p, side="prefill")
        pol_d = _build_policy(scaling, scfg_d, spot_d, side="decode")
        wid_p = [0]

        def new_prefill(wspec: WorkerSpec) -> PrefillSimWorker:
            wid_p[0] += 1
            return PrefillSimWorker(wid_p[0], wspec, sc.slo)

        def spawn_prefill(w, t):
            w.t = t

        def kill_prefill(w):
            lost = list(w.queue)
            w.queue.clear()
            w.pending_tokens = 0
            return lost

        pool_p = ManagedPool(
            p_spec, scfg_p, pol_p, cfg.heartbeat, rng,
            new_worker=new_prefill, on_spawn=spawn_prefill,
            on_kill=kill_prefill, load=lambda w: len(w.queue),
            idle=lambda w: not w.queue, mark=mark_requeue,
            spot_spec=spot_p, notice_s=notice, name="prefill")

        dcfg = SimConfig(gamma=cfg.gamma, theta=cfg.theta, split_phase=True)
        sims_d = {}
        wid_d = [100000]

        def new_decode(wspec: WorkerSpec):
            wid_d[0] += 1
            return make_worker_state(wid_d[0], wspec, dcfg, sc.slo)

        def spawn_decode(w, t):
            sims_d[w.id] = SimWorker(w, w.perf, t, split_phase=True)

        def kill_decode(w):
            sim = sims_d.pop(w.id)
            lost = w.ongoing + w.new_batch + sim.preempted
            w.ongoing.clear()
            w.new_batch.clear()
            w.mark_dirty()
            return lost

        pool_d = ManagedPool(
            d_spec, scfg_d, pol_d, cfg.heartbeat, rng,
            new_worker=new_decode, on_spawn=spawn_decode,
            on_kill=kill_decode, load=lambda w: w.batch_size,
            idle=lambda w: (not w.ongoing and not w.new_batch
                            and not sims_d[w.id].preempted),
            sims=sims_d, spot_spec=spot_d, notice_s=notice, name="decode")
        prefill = ManagedSide(pool_p, p_spec)
        decode = ManagedSide(pool_d, d_spec)
        scaling_label = getattr(pol_d, "name", type(pol_d).__name__)

    topo = DisaggTopology(sc.slo, cfg, prefill, decode, rng,
                          predictor=sc.predictor, observer=sc.observer)
    trace = sc.materialize()
    trace = run_heartbeat_loop(
        trace, cfg.heartbeat, topo.admit, topo.step, topo.drained,
        events=_merge_side_events(market), fire=topo.fire)

    rep = RunReport(topology="disaggregated", scaling=scaling_label,
                    **_percentiles(topo.finished, len(trace), sc.slo))
    rep.mean_transfer = float(np.mean(topo.transfers)) if topo.transfers \
        else 0.0
    rep.kv_retransfers = topo.kv_retransfers
    if isinstance(scaling, FixedScale):
        rep.n_prefill = sum(k for _, k in p_pools)
        rep.n_decode = sum(k for _, k in d_pools)
        rep.peak_workers = rep.n_prefill + rep.n_decode
        rep.gpu_cost = pool_cost(p_pools) + pool_cost(d_pools)
        p_label = ",".join(f"{s.name}x{k}" for s, k in p_pools)
        d_label = ",".join(f"{s.name}x{k}" for s, k in d_pools)
        rep.pool_mix = f"p:{p_label}|d:{d_label}"
    else:
        rep.n_prefill = prefill.pool.peak
        rep.n_decode = decode.pool.peak
        rep.peak_workers = rep.n_prefill + rep.n_decode
        rep.gpu_seconds = prefill.gpu_s + decode.gpu_s
        rep.gpu_cost = rep.gpu_seconds
        rep.spot_gpu_seconds = prefill.spot_gpu_s + decode.spot_gpu_s
        rep.pool_mix = (f"p:{p_pools[0][0].name}~auto|"
                        f"d:{d_pools[0][0].name}~auto")
        rep.epochs = {"prefill": prefill.epochs, "decode": decode.epochs}
    rep.preempted_workers = prefill.killed + decode.killed
    rep.drained_ok = prefill.drained_ok + decode.drained_ok
    rep.requeued = prefill.requeued + decode.requeued
    return rep


# ---- the two verbs -----------------------------------------------------------


def run(scenario: Scenario, seed: Optional[int] = None) -> RunReport:
    """Execute one scenario and return its :class:`RunReport`.

    ``seed`` overrides ``scenario.seed`` (placement tie-breaking and reclaim
    victim choice). A callable workload is materialized fresh per call; a
    concrete trace is simulated in place (its requests carry the outcome),
    exactly like the legacy entry points."""
    s = seed if seed is not None else scenario.seed
    scenario = resolve_scenario(scenario)
    if isinstance(scenario.topology, Colocated):
        if scenario.engine == "vectorized":
            from repro.serving import fastsim
            return fastsim.run_colocated_vectorized(scenario, s)
        if scenario.engine == "jax":
            from repro.serving import fastsim_jax
            return fastsim_jax.run_colocated_jax(scenario, s)
        if scenario.engine != "reference":
            raise ValueError(f"unknown engine {scenario.engine!r} (expected "
                             "'reference', 'vectorized' or 'jax')")
        return _run_colocated(scenario, s)
    if scenario.engine != "reference":
        raise ValueError("engine='vectorized'/'jax' accelerate Colocated "
                         "topologies only; a "
                         f"{type(scenario.topology).__name__} scenario "
                         "needs engine='reference'")
    if isinstance(scenario.topology, Disaggregated):
        return _run_disagg(scenario, s)
    raise TypeError(f"unknown topology {type(scenario.topology).__name__}")


def optimize(scenario: Scenario, objective: str = "cost", *,
             attain_target: float = 0.99, lo: int = 1, hi: int = 512,
             fleet_fn: Optional[Callable[[int], Sequence[WorkerSpec]]] = None,
             max_prefill: int = 8, hi_decode: int = 64,
             prefill_pool_fn: Optional[Callable] = None,
             decode_pool_fn: Optional[Callable] = None,
             prefill_mix: Optional[Sequence[WorkerSpec]] = None,
             decode_mix: Optional[Sequence[WorkerSpec]] = None,
             ratio_grid: Sequence[float] = (0.0, 0.25, 0.5, 0.75, 1.0),
             policy_space: Optional[Dict[str, Sequence]] = None,
             max_rounds: int = 3) -> Plan:
    """Search the cheapest scenario meeting ``attain_target``.

    For a **FixedScale** scenario this sizes the fleet — one verb subsuming
    the legacy ``min_workers_for_slo`` (binary search over the colocated
    worker count, with the plateau-infeasibility diagnosis) and
    ``min_cost_disagg`` (the joint (n_prefill, n_decode) frontier walk,
    including heterogeneous pool fns and the ratio search).

    For an **autoscaled** scenario (``Reactive``/``Forecast``/
    ``FeedbackScale``) the worker counts belong to the policy, so the
    search runs over the *policy parameters* instead: coordinate descent on
    ``policy_space`` — axis name -> candidate values, defaulting to
    headroom x theta x spot ``max_spot_frac`` x per-side leads (see
    ``default_policy_space``) — keeping the cheapest attaining assignment
    (or the highest-attaining one when nothing reaches the target). The
    returned ``Plan.scenario`` re-runs to exactly the searched report
    (``Plan.params`` names the winning assignment).

    Either way the workload is materialized ONCE — a trace factory is
    evaluated a single time and every candidate replays a clone of the same
    request list (``workload.clone_trace``), so the search compares
    candidates on the same arrivals instead of implicitly re-sampling.

    ``fleet_fn(n)`` (colocated) maps a worker count to a heterogeneous
    fleet; ``prefill_pool_fn``/``decode_pool_fn``/``prefill_mix``/
    ``decode_mix``/``ratio_grid`` (disaggregated) are the pool-mix hooks of
    the legacy frontier."""
    if objective != "cost":
        raise ValueError(f"unsupported objective {objective!r} (only 'cost')")
    if isinstance(scenario.scaling, PolicyScale):
        raise ValueError("optimize() cannot search a PolicyScale escape "
                         "hatch (the policy instance is prebuilt); declare "
                         "the scaling as Reactive/Forecast/FeedbackScale")
    scenario = resolve_scenario(scenario)
    template = scenario.materialize()
    if not isinstance(scenario.scaling, FixedScale):
        return _optimize_policy(scenario, template, attain_target,
                                policy_space, max_rounds)
    if policy_space is not None:
        raise ValueError("policy_space searches autoscaled scenarios; a "
                         "FixedScale scenario has no scaling policy to tune")
    if scenario.tenants is not None and len(scenario.tenants) > 1:
        if fleet_fn is not None:
            raise ValueError("fleet_fn and the multi-tenant pool-partition "
                             "search are mutually exclusive")
        return _optimize_tenants(scenario, template, attain_target, lo, hi)
    if isinstance(scenario.topology, Colocated):
        return _optimize_colocated(scenario, template, attain_target, lo, hi,
                                   fleet_fn)
    return _optimize_disagg(scenario, template, attain_target, max_prefill,
                            hi_decode, prefill_pool_fn, decode_pool_fn,
                            prefill_mix, decode_mix, ratio_grid)


# ---- the policy-space search (autoscaled scenarios) --------------------------


def default_policy_space(scenario: Scenario) -> Dict[str, Sequence]:
    """The default coordinate-descent axes for an autoscaled scenario:
    capacity headroom and placement strictness always; the spot capacity
    share when a market exists; per-side look-ahead leads when the
    topology is disaggregated (prefill wants a short lead — TTFT burns in
    the arrival hop — decode a longer one)."""
    space: Dict[str, Sequence] = {
        "headroom": (1.0, 1.1, 1.2, 1.35, 1.5),
        "theta": (0.7, 0.8, 0.9),
    }
    if scenario.market is not None:
        space["max_spot_frac"] = (0.0, 0.35, 0.7)
    if isinstance(scenario.topology, Disaggregated) \
            and isinstance(_open_loop(scenario.scaling), Forecast):
        # lead is a forecast look-ahead; ReactivePolicy never reads it, so
        # searching it under a reactive base would burn evals on a dead knob
        space["prefill_lead"] = (5.0, 10.0, 15.0)
        space["decode_lead"] = (15.0, 20.0, 30.0)
    return space


def _with_side_lead(s, side: str, value: float):
    ov = getattr(s, side, None) or SideOverride()
    return dataclasses.replace(s, **{side: dataclasses.replace(ov,
                                                               lead=value)})


def _scaling_with_axis(s: ScalingLike, name: str, value,
                       market: Optional[SpotMarket]) -> ScalingLike:
    """One open-loop scaling declaration with a policy axis applied.
    ``FeedbackScale`` axes route to its base — the feedback controller
    corrects whatever open loop the search proposes."""
    if isinstance(s, FeedbackScale):
        return dataclasses.replace(
            s, base=_scaling_with_axis(s.base, name, value, market))
    if name == "headroom":
        return dataclasses.replace(s, headroom=value)
    if name == "max_spot_frac":
        mix = s.spot_mix
        if mix is None:
            spec = market.spec if market is not None else None
            mix = SpotMixConfig(discount=spec.price,
                                hazard=spec.preempt_hazard) \
                if spec is not None and spec.is_spot else SpotMixConfig()
        return dataclasses.replace(
            s, spot_mix=dataclasses.replace(mix, max_spot_frac=value))
    if name == "prefill_lead":
        return _with_side_lead(s, "prefill", value)
    if name == "decode_lead":
        return _with_side_lead(s, "decode", value)
    raise ValueError(f"unknown policy axis {name!r}")


def _apply_assignment(scenario: Scenario,
                      assign: Dict[str, object]) -> Scenario:
    sc = scenario
    for name, value in assign.items():
        if name == "theta":
            sc = dataclasses.replace(
                sc, topology=dataclasses.replace(sc.topology, theta=value))
        else:
            sc = dataclasses.replace(
                sc, scaling=_scaling_with_axis(sc.scaling, name, value,
                                               sc.market))
    return sc


def _attains(rep: RunReport, attain_target: float,
             tenants: Optional[Sequence[TenantSpec]] = None) -> bool:
    """The optimize() feasibility test. Scalar scenarios: headline
    attainment >= target with nothing left unfinished. Multi-tenant
    scenarios: EVERY tenant's per-tenant attainment must reach its own
    target (``TenantSpec.attain_target`` overrides the argument). When an
    engine path yields no per-tenant rows (batched jax candidates do not
    write the trace back), the headline — judged against the strictest
    planning SLO — stands in, compared against the strictest target."""
    if rep.finished != rep.total:
        return False
    if tenants is not None:
        targets = [t.attain_target if t.attain_target is not None
                   else attain_target for t in tenants]
        if len(rep.tenant_rows) == len(tenants):
            return all(row["attainment"] >= tg
                       for row, tg in zip(rep.tenant_rows, targets))
        return rep.attainment >= max(targets)
    return rep.attainment >= attain_target


def _optimize_policy(scenario: Scenario, template, attain_target: float,
                     policy_space: Optional[Dict[str, Sequence]],
                     max_rounds: int) -> Plan:
    space = policy_space if policy_space is not None \
        else default_policy_space(scenario)
    if not space:
        raise ValueError("policy_space is empty: nothing to search")
    evals = [0]
    cache: Dict[Tuple, RunReport] = {}

    def key(assign: Dict) -> Tuple:
        # key on the *effective* configuration, not the assignment dict: an
        # axis value equal to the scenario's declared one (e.g. headroom=1.0
        # on a default scenario) must hit the baseline's cache entry instead
        # of replaying an identical simulation
        sc = _apply_assignment(scenario, assign)
        return repr(sc.scaling), repr(sc.topology)

    def evaluate(assign: Dict) -> RunReport:
        k = key(assign)
        rep = cache.get(k)
        if rep is None:
            sc = _apply_assignment(
                dataclasses.replace(scenario,
                                    workload=clone_trace(template)), assign)
            rep = run(sc)
            cache[k] = rep
            evals[0] += 1
        return rep

    def prefetch(assigns: Sequence[Dict]) -> None:
        """On the jax engine, fill the cache for one coordinate's whole
        candidate bracket with a single lockstep-batched compiled call
        (``run_policy_candidate_batch``) instead of one run per value."""
        if scenario.engine != "jax" \
                or not isinstance(scenario.topology, Colocated):
            return
        seen = set()
        uniq = []
        for a in assigns:
            k = key(a)
            if k not in cache and k not in seen:
                seen.add(k)
                uniq.append((k, a))
        if len(uniq) < 2:       # nothing to batch
            return
        from repro.serving import fastsim_jax
        scs = [_apply_assignment(
            dataclasses.replace(scenario, workload=clone_trace(template)),
            a) for _, a in uniq]
        for (k, _a), rep in zip(uniq,
                                fastsim_jax.run_policy_candidate_batch(scs)):
            cache[k] = rep
            evals[0] += 1

    def attains(rep: RunReport) -> bool:
        return _attains(rep, attain_target, scenario.tenants)

    def better(cand: RunReport, best: RunReport) -> bool:
        if attains(cand) != attains(best):
            return attains(cand)
        if attains(cand):                  # both attain: cheaper wins
            return cand.gpu_cost < best.gpu_cost
        if cand.attainment != best.attainment:
            return cand.attainment > best.attainment
        return cand.gpu_cost < best.gpu_cost

    current: Dict[str, object] = {}
    best = evaluate(current)
    for _ in range(max_rounds):
        improved = False
        for name, values in space.items():
            prefetch([dict(current, **{name: v}) for v in values
                      if current.get(name) != v])
            for v in values:
                if current.get(name) == v:
                    continue
                cand = dict(current)
                cand[name] = v
                rep = evaluate(cand)
                if better(rep, best):
                    best, current = rep, cand
                    improved = True
        if not improved:
            break
    win = _apply_assignment(
        dataclasses.replace(scenario,
                            workload=lambda: clone_trace(template)), current)
    return Plan(objective="cost", scenario=win, report=best,
                n_workers=best.peak_workers, cost=best.gpu_cost,
                evals=evals[0], params=dict(current))


def _optimize_colocated(scenario: Scenario, template, attain_target: float,
                        lo: int, hi: int, fleet_fn) -> Plan:
    pools = scenario.fleet.for_role("serve")
    if not pools:
        raise ValueError("optimize needs a fleet pool to size")
    base_spec = pools[0].spec
    reports: Dict[int, RunReport] = {}
    evals = [0]
    attain_hist: List[Tuple[int, float]] = []

    def scenario_for(n: int) -> Scenario:
        if fleet_fn is not None:
            fleet = FleetSpec([PoolSpec(s, 1) for s in fleet_fn(n)])
        else:
            fleet = FleetSpec([PoolSpec(base_spec, n)])
        return dataclasses.replace(scenario, workload=clone_trace(template),
                                   fleet=fleet, scaling=FixedScale())

    def evaluate(ns: Sequence[int]) -> None:
        """Evaluate candidate worker counts into ``reports``. On the jax
        engine a whole batch runs as ONE vmapped compiled call; the other
        engines sweep sequentially (the vectorized core still being far
        cheaper per candidate than the reference)."""
        ns = [n for n in ns if n not in reports]
        if not ns:
            return
        multi = scenario.tenants is not None and len(scenario.tenants) > 1
        if scenario.engine == "jax" and fleet_fn is None and not multi \
                and len(ns) > 1:
            from repro.serving import fastsim_jax
            batch = fastsim_jax.run_candidate_batch(
                [scenario_for(n) for n in ns])
            for n, rep in zip(ns, batch):
                reports[n] = rep
            evals[0] += len(ns)
        else:
            for n in ns:
                reports[n] = run(scenario_for(n))
                evals[0] += 1

    def ok(n: int) -> bool:
        if n not in reports:
            evaluate([n])
        rep = reports[n]
        attain_hist.append((n, rep.attainment))
        return _attains(rep, attain_target, scenario.tenants)

    escalations = 0
    while not ok(hi):
        # plateau detection: if doubling workers stops improving attainment,
        # the residual violations are scale-invariant (e.g. prediction-error
        # preemption tails) — the target is infeasible, not under-provisioned
        if len(attain_hist) >= 2 and \
                attain_hist[-1][1] <= attain_hist[-2][1] + 1e-3:
            raise RuntimeError(
                f"attainment plateaus at {attain_hist[-1][1]:.3f} < "
                f"{attain_target} (scale-invariant violations)")
        hi *= 2
        escalations += 1
        if hi > 8192 or escalations > 6:
            raise RuntimeError("workload cannot meet SLO at any scale")
    # multisection on the batch-capable engines: probe a whole bracket per
    # round (one compiled call on jax) instead of one midpoint at a time
    batch_k = 8 if scenario.engine in ("vectorized", "jax") else 1
    while lo < hi:
        if batch_k > 1 and hi - lo > 2:
            span = hi - lo
            cand = sorted({lo + (span * i) // (batch_k + 1)
                           for i in range(1, batch_k + 1)})
            cand = [c for c in cand if lo <= c < hi]
            evaluate(cand)
            for c in cand:              # monotone: walk the probe results
                if ok(c):
                    hi = c
                    break
                lo = c + 1
            continue
        mid = (lo + hi) // 2
        if ok(mid):
            hi = mid
        else:
            lo = mid + 1
    rep = reports.get(lo)
    if rep is None:                     # lo was proven by its neighbors only
        evaluate([lo])
        rep = reports[lo]
    return Plan(objective="cost", scenario=scenario_for(lo), report=rep,
                n_workers=lo, cost=rep.gpu_cost, evals=evals[0])


def _tenant_partitions(n: int, tenants) -> List[List[Tuple[int, ...]]]:
    """Candidate pool partitions of the tenant index set: for <= 4 tenants
    every set partition (Bell numbers stay tiny: B(4) = 15); beyond that,
    the three canonical assignments — fully shared, fully dedicated, and
    one pool per tier."""
    if n <= 4:
        parts: List[List[Tuple[int, ...]]] = []

        def rec(i: int, groups: List[List[int]]) -> None:
            if i == n:
                parts.append([tuple(g) for g in groups])
                return
            for g in groups:
                g.append(i)
                rec(i + 1, groups)
                g.pop()
            groups.append([i])
            rec(i + 1, groups)
            groups.pop()

        rec(0, [])
        return parts
    shared = [tuple(range(n))]
    dedicated = [(i,) for i in range(n)]
    tiers: Dict[str, List[int]] = {}
    for i, t in enumerate(tenants):
        tiers.setdefault(t.tier, []).append(i)
    by_tier = [tuple(v) for v in tiers.values()]
    cands = [shared, dedicated]
    if by_tier not in cands:
        cands.append(by_tier)
    return cands


def _optimize_tenants(scenario: Scenario, template, attain_target: float,
                      lo: int, hi: int) -> Plan:
    """The joint multi-tenant placement search: which tenants *share* a
    pool versus get a dedicated one, and how many workers each pool gets,
    subject to EVERY tenant reaching its attainment target.

    Dedicated pools do not interact — placement is fenced per pool and
    rebalance is disabled on restricted fleets — so each group of a
    candidate partition is sized independently with the scalar binary
    search on the group's merged sub-trace, groups are cached across
    partitions (the singleton {k} appears in many partitions), and the
    cheapest feasible partition wins. The winning plan's fleet records the
    pool->tenant assignment (``PoolSpec.tenants``; the fully-shared
    partition keeps one undifferentiated pool) and a final combined run —
    on the reference engine when the fleet is restricted — verifies the
    joint scenario and supplies the per-tenant report."""
    tenants = list(scenario.tenants)
    pools = scenario.fleet.for_role("serve")
    if not pools:
        raise ValueError("optimize needs a fleet pool to size")
    base_spec = pools[0].spec
    group_plans: Dict[Tuple[int, ...], Plan] = {}

    def size_group(group: Tuple[int, ...]) -> Plan:
        plan = group_plans.get(group)
        if plan is None:
            specs = [tenants[i] for i in group]
            remap = {g: i for i, g in enumerate(group)}
            sub = clone_trace([r for r in template if r.tenant in remap])
            for r in sub:
                r.tenant = remap[r.tenant]
            sub_sc = resolve_scenario(dataclasses.replace(
                scenario, workload=sub, slo=None, tenants=specs))
            if any(s.lora is not None for s in specs):
                # LoRA residency/swap modeling lives in the reference
                # engine; the compiled envelopes reject it
                sub_sc = dataclasses.replace(sub_sc, engine="reference")
            try:
                plan = _optimize_colocated(sub_sc, sub, attain_target,
                                           lo, hi, None)
            except RuntimeError:
                # this group cannot attain at any size (plateau / cap) —
                # the partition is infeasible, not the whole search
                plan = Plan(objective="cost", scenario=None, report=None)
            group_plans[group] = plan
        return plan

    best_part: Optional[List[Tuple[int, ...]]] = None
    best_cost = math.inf
    best_plans: Optional[List[Plan]] = None
    for part in _tenant_partitions(len(tenants), tenants):
        plans = [size_group(g) for g in part]
        if not all(p.feasible for p in plans):
            continue
        cost = sum(p.cost for p in plans)
        if cost < best_cost:
            best_part, best_cost, best_plans = part, cost, plans
    n_evals = sum(p.evals for p in group_plans.values())
    if best_part is None:
        return Plan(objective="cost", scenario=None, report=None,
                    evals=n_evals)
    fleet = FleetSpec([
        PoolSpec(base_spec, p.n_workers,
                 tenants=([tenants[i].name for i in g]
                          if len(best_part) > 1 else None))
        for g, p in zip(best_part, best_plans)])
    win = dataclasses.replace(scenario, workload=clone_trace(template),
                              fleet=fleet, scaling=FixedScale())
    if len(best_part) > 1 or any(t.lora is not None for t in tenants):
        # restricted fleets (dedicated pools / LoRA) run on the reference
        # engine only
        win = dataclasses.replace(win, engine="reference")
    rep = run(win)
    n_evals += 1
    win = dataclasses.replace(win, workload=lambda: clone_trace(template))
    return Plan(objective="cost", scenario=win, report=rep,
                n_workers=sum(p.n_workers for p in best_plans),
                cost=rep.gpu_cost, evals=n_evals,
                params={"pools": [tuple(tenants[i].name for i in g)
                                  for g in best_part]})


def _optimize_disagg(scenario: Scenario, template, attain_target: float,
                     max_prefill: int, hi_decode: int, prefill_pool_fn,
                     decode_pool_fn, prefill_mix, decode_mix,
                     ratio_grid) -> Plan:
    p_specs = scenario.fleet.for_role("prefill")
    d_specs = scenario.fleet.for_role("decode")
    prefill_spec = p_specs[0].spec if p_specs else None
    decode_spec = d_specs[0].spec if d_specs else None
    evals = [0]
    # id(report) -> (report, pools): the stored report reference keeps the
    # object alive, so the id key can never be recycled by a later eval
    winners: Dict[int, Tuple] = {}

    def run_pools(pp, dp) -> RunReport:
        fleet = FleetSpec([PoolSpec(s, k, role="prefill") for s, k in pp]
                          + [PoolSpec(s, k, role="decode") for s, k in dp])
        sc = dataclasses.replace(scenario, workload=clone_trace(template),
                                 fleet=fleet, scaling=FixedScale())
        evals[0] += 1
        rep = run(sc)
        winners[id(rep)] = (rep, pp, dp)
        return rep

    def attains(rep: RunReport) -> bool:
        return rep.attainment >= attain_target and rep.finished == rep.total

    def frontier(pf, df, best: Optional[RunReport]) -> Optional[RunReport]:
        min_decode_cost = pool_cost(df(1))
        for n_p in range(1, max_prefill + 1):
            if best is not None and \
                    pool_cost(pf(n_p)) + min_decode_cost >= best.gpu_cost:
                break                  # every remaining point costs more
            lo, hi = 1, hi_decode
            res_hi = run_pools(pf(n_p), df(hi))
            if not attains(res_hi):
                continue               # prefill pool too small at any scale
            best_np = res_hi
            while lo < hi:
                mid = (lo + hi) // 2
                res = run_pools(pf(n_p), df(mid))
                if attains(res):
                    best_np, hi = res, mid
                else:
                    lo = mid + 1
            if best is None or best_np.gpu_cost < best.gpu_cost:
                best = best_np
        return best

    best: Optional[RunReport] = None
    if prefill_mix is not None or decode_mix is not None:
        pmix = list(prefill_mix) if prefill_mix is not None \
            else [prefill_spec]
        dmix = list(decode_mix) if decode_mix is not None else [decode_spec]
        if any(s is None for s in pmix + dmix):
            raise ValueError("mix search needs specs on both sides "
                             "(a spec list or a fleet pool per role)")
        p_ratios = tuple(ratio_grid) if len(pmix) == 2 else (1.0,)
        d_ratios = tuple(ratio_grid) if len(dmix) == 2 else (1.0,)
        for rp in p_ratios:
            for rd in d_ratios:
                best = frontier(ratio_pool_fn(pmix, rp),
                                ratio_pool_fn(dmix, rd), best)
    else:
        if prefill_pool_fn is None and prefill_spec is None:
            raise ValueError("optimize needs prefill/decode fleet pools or "
                             "pool fns")
        pf = prefill_pool_fn or (lambda n: [(prefill_spec, n)])
        df = decode_pool_fn or (lambda n: [(decode_spec, n)])
        best = frontier(pf, df, None)

    if best is None:
        return Plan(objective="cost", scenario=None, report=None,
                    evals=evals[0])
    _, pp, dp = winners[id(best)]
    fleet = FleetSpec([PoolSpec(s, k, role="prefill") for s, k in pp]
                      + [PoolSpec(s, k, role="decode") for s, k in dp])
    win = dataclasses.replace(scenario, fleet=fleet, scaling=FixedScale())
    return Plan(objective="cost", scenario=win, report=best,
                n_workers=best.n_prefill + best.n_decode,
                cost=best.gpu_cost, evals=evals[0])


__all__ = [
    "Colocated", "Disaggregated", "FeedbackScale", "FixedScale", "FleetSpec",
    "Forecast", "Plan", "PolicyScale", "PoolSpec", "Reactive", "RunReport",
    "Scenario", "SideOverride", "SpotMarket", "TenantSpec", "optimize",
    "run",
]
