"""Cluster manager: Aladdin's control plane over real engine workers.

Runs the paper's full loop on live ``PagedEngine`` workers (tiny models on
CPU; TPU slices in production):

  submit -> predict l_out -> best-fit place (Alg. 1) -> engines run
  iteration-level batching -> traces refit the perf models -> re-balance
  (Alg. 2) -> autoscale (Eq. 7).

Fault tolerance: dead workers' in-flight requests are re-queued (prefill
restarts — the paper's no-migration rule means their KV is lost); stragglers
(decode-iteration EMA z-score) are drained and replaced. The scheduler state
(request table, error tracker, perf model) snapshots to a dict for
checkpoint/restart.

Split-phase mode keeps two scheduler pools (prefill / decode) with the decode
placement performed only once prompt processing finished — the Splitwise/
DistServe topology. On the CPU testbed both phases execute on the same
engine; on a real cluster the decode pool would receive the KV stream
(cf. DéjàVu) — the control-plane logic is identical.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.perf_model import analytic_perf_model
from repro.core.placement import (PlacementConfig, WorkerState,
                                  best_fit_place, jsq_place)
from repro.core.rebalance import ErrorTracker, rebalance
from repro.core.request import ReqState, Request
from repro.core.scaling import Autoscaler, AutoscalerConfig
from repro.core.slo import SLO
from repro.serving.engine import EngineConfig, PagedEngine
from repro.serving.length_predictor import LengthPredictor


@dataclasses.dataclass
class ClusterConfig:
    policy: str = "aladdin"            # aladdin | jsq
    heartbeat_iters: int = 4           # engine iterations per heartbeat
    enable_rebalance: bool = True
    straggler_z: float = 4.0
    autoscale: bool = False
    min_workers: int = 1
    max_workers: int = 8
    gamma: float = 0.5
    theta: float = 0.9
    # session-tagged requests: "sticky" prefers the worker that served the
    # session's previous turn (its KV pages may still hold the shared
    # prefix) whenever that worker passes every placement constraint;
    # "blind" routes every turn like a fresh request
    router: str = "blind"              # blind | sticky


class ClusterWorker:
    def __init__(self, wid: int, engine: PagedEngine, state: WorkerState):
        self.id = wid
        self.engine = engine
        self.state = state
        self.iter_ema: Optional[float] = None

    def observe_iter(self, dt: float) -> None:
        self.iter_ema = dt if self.iter_ema is None \
            else 0.9 * self.iter_ema + 0.1 * dt


class ServingCluster:
    def __init__(self, arch, params, slo: SLO,
                 engine_cfg: EngineConfig = EngineConfig(),
                 cfg: ClusterConfig = ClusterConfig(),
                 n_workers: int = 2,
                 time_fn: Callable[[], float] = time.perf_counter):
        self.arch = arch
        self.params = params
        self.slo = slo
        self.engine_cfg = engine_cfg
        self.cfg = cfg
        self.time_fn = time_fn
        self.perf = analytic_perf_model(arch)
        self.predictor = LengthPredictor()
        self.tracker = ErrorTracker()
        self.autoscaler = Autoscaler(AutoscalerConfig(
            min_workers=cfg.min_workers, max_workers=cfg.max_workers))
        self._wid = 0
        self.workers: Dict[int, ClusterWorker] = {}
        self.queued: List[Request] = []
        self.finished: List[Request] = []
        self.failed_events: List[int] = []
        self.session_home: Dict[int, int] = {}   # session -> last worker
        kv_cap = (engine_cfg.n_pages - 1) * engine_cfg.page_size \
            * arch.kv_bytes_per_token(dtype_bytes=4) / 2
        self.pcfg = PlacementConfig(gamma=cfg.gamma, theta=cfg.theta,
                                    kv_capacity=kv_cap,
                                    max_batch=engine_cfg.max_batch)
        for _ in range(n_workers):
            self._spawn_worker()

    # ---- worker lifecycle ----------------------------------------------------
    def _spawn_worker(self) -> ClusterWorker:
        self._wid += 1
        eng = PagedEngine(self.arch, self.params, self.engine_cfg,
                          time_fn=self.time_fn)
        st = WorkerState(self._wid, self.pcfg, self.perf, self.slo)
        w = ClusterWorker(self._wid, eng, st)
        self.workers[self._wid] = w
        return w

    def inject_failure(self, wid: int) -> int:
        """Kill a worker; re-queue its in-flight requests. Returns #requeued."""
        w = self.workers.pop(wid)
        w.state.alive = False
        requeued = 0
        for r in (w.state.ongoing + w.state.new_batch + w.engine.waiting
                  + w.engine.running):
            if r.state == ReqState.FINISHED or r in self.queued:
                continue
            r.state = ReqState.QUEUED
            r.worker = None
            r.l_out = 0
            r.t_decode_spent = 0.0
            r.cached_len = 0    # the dead worker's KV (and any shared
                                # session prefix on it) is gone
            if r.tokens is not None:
                r.tokens = r.tokens[:r.l_in]
            self.queued.append(r)
            requeued += 1
        # sessions homed on the dead worker re-route like fresh requests
        self.session_home = {s: h for s, h in self.session_home.items()
                             if h != wid}
        self.failed_events.append(wid)
        if len(self.workers) < self.cfg.min_workers:
            self._spawn_worker()
        return requeued

    def _detect_stragglers(self) -> List[int]:
        emas = [(w.id, w.iter_ema) for w in self.workers.values()
                if w.iter_ema is not None]
        if len(emas) < 3:
            return []
        vals = np.asarray([e for _, e in emas])
        med = np.median(vals)
        mad = np.median(np.abs(vals - med)) + 1e-9
        out = []
        for wid, e in emas:
            if (e - med) / (1.4826 * mad) > self.cfg.straggler_z:
                self.workers[wid].state.draining = True
                out.append(wid)
        return out

    # ---- request path ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.l_pred = self.predictor.predict(req.l_in)
        self.queued.append(req)

    def _try_home(self, r: Request):
        """Sticky session affinity: the home worker takes the turn only if
        it passes every placement constraint; otherwise fall through to
        the configured policy (never place on an infeasible home)."""
        home = self.workers.get(self.session_home.get(r.session_id))
        if home is None or not home.state.alive or home.state.draining:
            return None
        if home.state.feasible([r]):
            home.state.place(r)
            return home.state
        return None

    def _place_all(self) -> None:
        still = []
        states = [w.state for w in self.workers.values()]
        for r in self.queued:
            st = self._try_home(r) \
                if self.cfg.router == "sticky" and r.session_id >= 0 \
                else None
            if st is None:
                if self.cfg.policy == "aladdin":
                    st = best_fit_place(states, r, allow_new=False)
                else:
                    st = jsq_place(states, r, allow_new=False)
            if st is None and self.cfg.autoscale \
                    and len(self.workers) < self.cfg.max_workers:
                w = self._spawn_worker()
                st = w.state
                st.place(r)
            if st is None:
                still.append(r)
            else:
                r.state = ReqState.PLACED
                if self.cfg.router == "sticky" and r.session_id >= 0:
                    self.session_home[r.session_id] = st.id
        self.queued = still

    def heartbeat(self) -> List[Request]:
        """One control-plane cycle: place, re-balance, run engine iterations,
        refit models, straggler check. Returns newly finished requests."""
        self._place_all()
        if self.cfg.enable_rebalance and self.cfg.policy == "aladdin":
            rebalance([w.state for w in self.workers.values()], self.tracker)
            self.tracker.decay()
        # hand placed requests to engines
        for w in self.workers.values():
            for r in list(w.state.new_batch):
                w.engine.submit(r)
                w.state.new_batch.remove(r)
                w.state.ongoing.append(r)
        newly: List[Request] = []
        for w in list(self.workers.values()):
            for _ in range(self.cfg.heartbeat_iters):
                t0 = self.time_fn()
                done = w.engine.step()
                w.observe_iter(self.time_fn() - t0)
                for r in done:
                    w.state.ongoing.remove(r)
                    self.tracker.on_finish(r)
                    self.predictor.observe(r.l_in, r.l_real or r.l_out)
                    newly.append(r)
            # re-prediction for underruns
            for r in w.state.ongoing:
                if r.l_out > r.l_pred and not r.repredicted:
                    self.tracker.on_underrun(
                        r, self.predictor.repredict(r.l_in, r.l_out))
                    w.state.mark_dirty()
            # refit perf models from live traces (workflow step 3)
            self.perf.update_from_traces(w.engine.traces)
        self._detect_stragglers()
        # retire drained+empty workers
        for wid, w in list(self.workers.items()):
            if w.state.draining and not w.state.ongoing \
                    and not w.engine.waiting \
                    and len(self.workers) > self.cfg.min_workers:
                del self.workers[wid]
        self.finished.extend(newly)
        return newly

    def run_until_drained(self, max_beats: int = 500) -> None:
        for _ in range(max_beats):
            self.heartbeat()
            if not self.queued and all(
                    not w.state.ongoing and not w.engine.waiting
                    and not w.state.new_batch
                    for w in self.workers.values()):
                break

    # ---- checkpoint / restart ---------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "queued": [(r.id, r.l_in, r.l_pred, r.l_real, r.arrival)
                       for r in self.queued],
            "perf": dataclasses.asdict(self.perf.decode) | {
                "k1": self.perf.prefill.k1, "c1": self.perf.prefill.c1,
                "h": self.perf.kv.h, "j": self.perf.kv.j},
            "tracker_l": dict(self.tracker.l_e),
            "tracker_b": dict(self.tracker.b_e),
            "n_workers": len(self.workers),
        }

    def restore(self, snap: dict) -> None:
        from repro.core.perf_model import (DecodeModel, KVModel, PrefillModel)
        p = snap["perf"]
        self.perf.decode = DecodeModel(p["k2"], p["c2"], p["c3"])
        self.perf.prefill = PrefillModel(p["k1"], p["c1"])
        self.perf.kv = KVModel(p["h"], p["j"])
        self.tracker.l_e = dict(snap["tracker_l"])
        self.tracker.b_e = dict(snap["tracker_b"])
        for _, l_in, l_pred, l_real, arr in snap["queued"]:
            r = Request(l_in=l_in, l_pred=l_pred, l_real=l_real, arrival=arr)
            self.queued.append(r)
        while len(self.workers) < snap["n_workers"]:
            self._spawn_worker()

    # ---- metrics -----------------------------------------------------------------
    def attainment(self) -> float:
        if not self.finished:
            return 0.0
        return sum(r.slo_ok(self.slo) for r in self.finished) \
            / len(self.finished)
