"""Compiled colocated simulation core (the ``engine="jax"`` path).

The whole heartbeat loop — admission, FIFO placement, per-worker prefill /
decode-segment advancement — runs as ONE ``jax.jit``-compiled
``lax.while_loop`` over beats, with the per-worker advance ``vmap``-ped
across the fleet and (for ``optimize``) the entire simulation ``vmap``-ped
across a batch of candidate worker counts, so a whole bracket of the
binary search evaluates in a single compiled call
(:func:`run_candidate_batch`).

Scope: this is the throughput engine, not the oracle. It compiles the
semantics of :mod:`repro.serving.fastsim` (itself bit-for-bit against the
Python reference) for the **inert-KV** envelope — ``KVModel(h=0, j=0)``,
the regime of the calibrated benchmark specs, where KV occupancy never
binds so preemption/resume cannot occur — for fixed colocated
``aladdin``/``jsq`` fleets. Everything else raises ``ValueError``.

Performance contract: the beat body touches only O(W·B) lane-resident
state (request clocks live in per-worker row arrays, not in trace-sized
arrays), because on CPU XLA a bulk scatter into a trace-sized carry costs
~50 ns *per update element* per beat while single-element updates and
fused masked reductions are ~0.1 µs. Finished rows are drained into the
per-request output arrays one finisher at a time (a few per beat); the
still-running remainder is flushed with one bulk scatter after the loop.

Numerics: each request's clock arithmetic keeps the reference's
*sequential* add order (decode segments advance through an inner
``while_loop`` of dependent adds on lane-local rows). Worker aggregates
(context sums, batch counts) are reduced in slot order rather than
admission order — exact anyway, because they are sums of integers (and
integer multiples of ``gamma``) well below 2^52. XLA may still contract
multiply-add chains, so agreement with the oracle is to the last few ulps
rather than bit-for-bit — the equivalence tests pin the integer outputs
exactly and the float outputs at ``rtol=1e-12``.

``jax`` is an optional dependency: importing this module without it
raises ``ImportError`` (``api.run`` only imports it on ``engine="jax"``).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.request import ReqState
from repro.serving.fastsim import DEFAULT_TAIL, check_colocated_envelope

_BIG_I = 1 << 50


def check_jax_envelope(scenario) -> List:
    """The vectorized-engine envelope, further restricted to what the
    compiled core supports: inert KV, and aladdin/jsq placement (po2
    consumes the numpy Generator stream request-by-request, which a
    compiled batch cannot reproduce)."""
    specs = check_colocated_envelope(scenario)
    if scenario.topology.policy == "po2":
        raise ValueError("the jax engine supports aladdin/jsq placement "
                         "(po2 needs the sequential rng stream; use "
                         "engine='vectorized')")
    for s in specs:
        if s.perf.kv.h != 0.0 or s.perf.kv.j != 0.0:
            raise ValueError("the jax engine requires inert KV "
                             "(KVModel(h=0, j=0)); KV-bound scenarios need "
                             "engine='vectorized' or 'reference'")
        if s.kv_capacity <= 0:
            raise ValueError("kv_capacity must be positive")
    return specs


# ---- the compiled kernel -----------------------------------------------------


def _advance_lane(t0, active0, started0, li, lr, lo, tds, tf1, tfn,
                  k1, c1, k2, c2, c3, t_end):
    """One worker's ``advance_to(t_end)``: alternate prefill / decode
    segments until the local clock reaches the beat end. Membership is a
    pair of masks (``active`` rows hold a request; ``started`` ones have
    been prefilled) so finished slots become reusable holes without any
    compaction; all row state is lane-local, keeping every request's
    sequential add order. vmapped across the fleet."""

    def cond(st):
        return st[0] < t_end

    def body(st):
        t, active, started, lo, tds, tf1, tfn = st
        newm = active & ~started
        has_new = jnp.any(newm)
        n_on = jnp.sum(active & started)
        # --- prefill branch: joint new-batch prefill, decode stalls -------
        tot_in = jnp.sum(jnp.where(newm, li, 0))
        dur_p = k1 * tot_in + c1
        t_pre = t + dur_p
        tds_pre = tds + jnp.where(active & started, dur_p, 0.0)
        tf1_pre = jnp.where(newm, t_pre, tf1)
        lo_pre = jnp.where(newm, jnp.int64(1), lo)
        # --- decode branch: batch fixed until the next finish boundary ----
        do_dec = ~has_new & (n_on > 0)
        b = n_on
        C0 = jnp.sum(jnp.where(active, li + lo, 0))
        n_fin = jnp.min(jnp.where(active, jnp.maximum(lr - lo, 1), _BIG_I))
        n_fin = jnp.where(do_dec, n_fin, 0)
        cb = c2 * b

        def dcond(dst):
            k, td, _seg = dst
            return (k < n_fin) & (td < t_end)

        def dbody(dst):
            k, td, seg = dst
            dur = k2 * (C0 + k * b) + cb + c3
            return k + 1, td + dur, seg + dur

        k, t_dec, seg = lax.while_loop(
            dcond, dbody, (jnp.int64(0), t, jnp.float64(0.0)))
        lo_dec = lo + jnp.where(active, k, 0)
        tds_dec = tds + jnp.where(active, seg, 0.0)
        done = active & (lo_dec >= lr)
        tfn_dec = jnp.where(done, t_dec, tfn)
        # --- select: prefill > decode > idle ------------------------------
        t_new = jnp.where(has_new, t_pre, jnp.where(do_dec, t_dec, t_end))
        return (t_new,
                jnp.where(has_new, active, active & ~done),
                jnp.where(has_new, active, started),
                jnp.where(has_new, lo_pre,
                          jnp.where(do_dec, lo_dec, lo)),
                jnp.where(has_new, tds_pre,
                          jnp.where(do_dec, tds_dec, tds)),
                jnp.where(has_new, tf1_pre, tf1),
                jnp.where(do_dec, tfn_dec, tfn))

    return lax.while_loop(cond, body,
                          (t0, active0, started0, lo, tds, tf1, tfn))


def _make_simulate(n: int, W: int, B: int, hb: float, horizon: float,
                   theta: float, gamma: float, ttft: float, atgt: float,
                   policy: str,
                   coefs: Tuple[Tuple[float, ...], ...],
                   maxb: Tuple[int, ...],
                   maxb_norm: Tuple[float, ...],
                   cmax_norm: Tuple[float, ...]):
    """Close over the static configuration and return the whole-trace
    simulation ``fn(arrival, l_in, l_real, n_active)`` (jit/vmap-able)."""
    K1, C1, K2, C2, C3 = (jnp.asarray(c) for c in coefs)
    MAXB = jnp.asarray(maxb, dtype=jnp.int64)
    MAXBN = jnp.asarray(maxb_norm)
    CMAXN = jnp.asarray(cmax_norm)
    is_aladdin = policy == "aladdin"

    def simulate(arrival, l_in, l_real, n_active):
        alive = jnp.arange(W) < n_active

        def place_pass(qlen, q, mem, active, started, lane_li, lane_lr,
                       lane_lo, lane_tds, lane_tf1, lane_tfn):
            on = active & started
            if is_aladdin:
                # constraint (d) slack over *ongoing* members: fixed for
                # the whole pass (placement only adds new_batch entries)
                slack = jnp.min(jnp.where(
                    on, atgt * jnp.maximum(lane_lo - 1, 0) - lane_tds,
                    jnp.inf), axis=1)
                d_budget = theta * jnp.maximum(slack, 0.0)
            else:
                d_budget = jnp.zeros(W)
            # l_pred == l_real inside the envelope (no predictor); sums of
            # integers (x gamma), so slot order cannot perturb them
            wctx0 = jnp.sum(jnp.where(
                active, lane_li + gamma * lane_lr, 0.0), axis=1)
            newsum0 = jnp.sum(jnp.where(active & ~started, lane_li, 0),
                              axis=1)
            cnt0 = jnp.sum(active, axis=1)

            def pbody(st):
                (i, keep, q, mem, active, started, lane_li, lane_lr,
                 lane_lo, lane_tds, lane_tf1, lane_tfn, cnt, newsum,
                 wctx) = st
                rid = q[i]
                liv = l_in[rid]
                lrv = l_real[rid]
                v = liv + gamma * lrv
                bpost = cnt + 1
                if is_aladdin:
                    budget = jnp.where(
                        K2 > 0,
                        jnp.maximum(((atgt - C3) - C2 * bpost)
                                    / jnp.where(K2 > 0, K2, 1.0), 0.0),
                        jnp.inf)
                    pre_t = K1 * (newsum + liv) + C1
                    ok = ((bpost <= MAXB)
                          & (wctx + v <= theta * budget)
                          & (pre_t <= ttft) & (pre_t <= d_budget) & alive)
                    # best-fit: max capacity_norm, ties to the lowest index
                    # (argmax returns the first maximum, like stable sort)
                    norm = jnp.hypot(cnt / MAXBN, wctx / CMAXN)
                    w = jnp.argmax(jnp.where(ok, norm, -jnp.inf))
                else:
                    # jsq: min batch, ties to the lowest index; inert KV
                    # makes _admit_naive's occupancy test vacuous
                    ok = (bpost <= MAXB) & alive
                    w = jnp.argmin(jnp.where(ok, cnt, _BIG_I))
                placed = jnp.any(ok)
                # placed implies cnt[w] < max_batch <= B, so the row has a
                # hole; out-of-range updates drop, so B is a safe no-op
                wslot = jnp.where(placed, jnp.argmin(active[w]), B)
                mem = mem.at[w, wslot].set(rid, mode="drop")
                active = active.at[w, wslot].set(True, mode="drop")
                started = started.at[w, wslot].set(False, mode="drop")
                lane_li = lane_li.at[w, wslot].set(liv, mode="drop")
                lane_lr = lane_lr.at[w, wslot].set(lrv, mode="drop")
                lane_lo = lane_lo.at[w, wslot].set(0, mode="drop")
                lane_tds = lane_tds.at[w, wslot].set(0.0, mode="drop")
                lane_tf1 = lane_tf1.at[w, wslot].set(jnp.nan, mode="drop")
                lane_tfn = lane_tfn.at[w, wslot].set(jnp.nan, mode="drop")
                cnt = cnt.at[w].add(jnp.where(placed, 1, 0))
                newsum = newsum.at[w].add(jnp.where(placed, liv, 0))
                wctx = wctx.at[w].add(jnp.where(placed, v, 0.0))
                # unplaced requests stay queued, FIFO order preserved
                qslot = jnp.where(placed, jnp.int64(n), keep)
                q = q.at[qslot].set(rid, mode="drop")
                keep = keep + jnp.where(placed, 0, 1)
                return (i + 1, keep, q, mem, active, started, lane_li,
                        lane_lr, lane_lo, lane_tds, lane_tf1, lane_tfn,
                        cnt, newsum, wctx)

            st = lax.while_loop(
                lambda st: st[0] < qlen, pbody,
                (jnp.int64(0), jnp.int64(0), q, mem, active, started,
                 lane_li, lane_lr, lane_lo, lane_tds, lane_tf1, lane_tfn,
                 cnt0, newsum0, wctx0))
            return st[1:12]

        def beat_body(st):
            (t, idx, qlen, q, mem, active, started, t_w, lane_li, lane_lr,
             lane_lo, lane_tds, lane_tf1, lane_tfn, out_lo, out_tds,
             out_tf1, out_tfn, beats) = st

            # admit arrivals <= t (the trace is sorted by arrival)
            def adm_body(ast):
                i2, qlen2, q2 = ast
                return i2 + 1, qlen2 + 1, q2.at[qlen2].set(i2)

            idx, qlen, q = lax.while_loop(
                lambda ast: (ast[0] < n) & (arrival[ast[0]] <= t),
                adm_body, (idx, qlen, q))
            (qlen, q, mem, active, started, lane_li, lane_lr, lane_lo,
             lane_tds, lane_tf1, lane_tfn) = place_pass(
                qlen, q, mem, active, started, lane_li, lane_lr, lane_lo,
                lane_tds, lane_tf1, lane_tfn)
            # Event skip: with an empty queue, placement is a no-op at
            # every beat until the next arrival is admitted, and decode
            # segments continue across beat boundaries unchanged (lane
            # clocks persist and overshoot; segments end at finishes, not
            # beats).  So step the beat clock with the *same sequential
            # t += hb adds* as stepwise execution (bit-identical grid)
            # until the first beat whose admission check would fire, and
            # cover the whole gap with one advance call.  A backlogged
            # queue forces single-beat stepping, because placement must
            # retry every beat.
            can_skip = qlen == 0
            next_arr = jnp.where(idx < n,
                                 arrival[jnp.minimum(idx, n - 1)], jnp.inf)

            def jcond(jst):
                j, tt = jst
                return ((tt < horizon) & (tt < next_arr)
                        & ((j == 0) | can_skip))

            k_steps, t_next = lax.while_loop(
                jcond, lambda jst: (jst[0] + 1, jst[1] + hb),
                (jnp.int64(0), t))
            # advance every worker on its lane-resident rows
            pre_active = active
            t_w, active, started, lane_lo, lane_tds, lane_tf1, lane_tfn = \
                jax.vmap(_advance_lane,
                         in_axes=(0,) * 14 + (None,))(
                    t_w, active, started, lane_li, lane_lr, lane_lo,
                    lane_tds, lane_tf1, lane_tfn, K1, C1, K2, C2, C3,
                    t_next)
            # drain this step's finishers into the per-request outputs one
            # at a time (bulk scatters into trace-sized arrays are the
            # dominant cost on CPU XLA; single-element updates are free)
            fin = pre_active & ~active

            def ext_body(_j, es):
                fm, o_lo, o_tds, o_tf1, o_tfn, mf = es
                fl = jnp.argmax(fm.reshape(-1))
                w, s = fl // B, fl % B
                rid = mem[w, s]
                o_lo = o_lo.at[rid].set(lane_lo[w, s])
                o_tds = o_tds.at[rid].set(lane_tds[w, s])
                o_tf1 = o_tf1.at[rid].set(lane_tf1[w, s])
                o_tfn = o_tfn.at[rid].set(lane_tfn[w, s])
                mf = jnp.maximum(mf, lane_tfn[w, s])
                return fm.at[w, s].set(False), o_lo, o_tds, o_tf1, o_tfn, mf

            _fm, out_lo, out_tds, out_tf1, out_tfn, maxfin = lax.fori_loop(
                0, jnp.sum(fin), ext_body,
                (fin, out_lo, out_tds, out_tf1, out_tfn, -jnp.inf))
            # Stepwise execution exits once the last request finishes; the
            # final drain jump runs all the way to the horizon, so clamp
            # its beat count to the last finish (exact to within the final
            # decode segment's span -- nothing downstream consumes beats
            # beyond the benchmark rate).
            emptied = ~jnp.any(active)
            k_fin = jnp.ceil((maxfin - t) / hb).astype(jnp.int64)
            k_used = jnp.where((idx >= n) & emptied & (k_steps > 1),
                               jnp.clip(k_fin, 1, k_steps), k_steps)
            return (t_next, idx, qlen, q, mem, active, started, t_w,
                    lane_li, lane_lr, lane_lo, lane_tds, lane_tf1,
                    lane_tfn, out_lo, out_tds, out_tf1, out_tfn,
                    beats + k_used)

        def beat_cond(st):
            t, idx, qlen, active = st[0], st[1], st[2], st[5]
            drained = (idx >= n) & (qlen == 0) & ~jnp.any(active)
            return (t < horizon) & ~drained

        st0 = (jnp.float64(0.0), jnp.int64(0), jnp.int64(0),
               jnp.zeros((max(n, 1),), dtype=jnp.int64),
               jnp.full((W, B), -1, dtype=jnp.int64),
               jnp.zeros((W, B), dtype=bool),
               jnp.zeros((W, B), dtype=bool),
               jnp.zeros((W,)),
               jnp.zeros((W, B), dtype=jnp.int64),
               jnp.zeros((W, B), dtype=jnp.int64),
               jnp.zeros((W, B), dtype=jnp.int64),
               jnp.zeros((W, B)),
               jnp.full((W, B), jnp.nan), jnp.full((W, B), jnp.nan),
               jnp.zeros((n,), dtype=jnp.int64),
               jnp.zeros((n,)),
               jnp.full((n,), jnp.nan), jnp.full((n,), jnp.nan),
               jnp.int64(0))
        st = lax.while_loop(beat_cond, beat_body, st0)
        mem, active = st[4], st[5]
        lane_lo, lane_tds, lane_tf1, lane_tfn = st[10], st[11], st[12], \
            st[13]
        out_lo, out_tds, out_tf1, out_tfn, beats = st[14], st[15], st[16], \
            st[17], st[18]
        # flush still-running rows (partial clocks) once, after the loop
        sink = jnp.where(active, mem, n).reshape(-1)
        out_lo = out_lo.at[sink].set(lane_lo.reshape(-1), mode="drop")
        out_tds = out_tds.at[sink].set(lane_tds.reshape(-1), mode="drop")
        out_tf1 = out_tf1.at[sink].set(lane_tf1.reshape(-1), mode="drop")
        out_tfn = out_tfn.at[sink].set(lane_tfn.reshape(-1), mode="drop")
        return out_lo, out_tds, out_tf1, out_tfn, beats

    return simulate


# compiled kernels are cached per static configuration; the jit wrapper on
# top caches its traces too, so repeated runs/batches recompile nothing
_KERNELS: Dict[Tuple, object] = {}


def _kernel_for(scenario, specs, trace, batched: bool):
    from repro.serving import api

    topo = scenario.topology
    W = len(specs)
    B = max(max(int(s.max_batch) for s in specs), 1)
    arrival = np.array(sorted(r.arrival for r in trace))
    n = len(trace)
    horizon = (float(arrival[-1]) if n else 0.0) + DEFAULT_TAIL
    cmax_norm = []
    for s in specs:
        cmax = s.perf.decode.max_total_context(1, scenario.slo.atgt) or 1.0
        cmax_norm.append(max(cmax, 1.0))
    key = (n, W, B, float(topo.heartbeat), horizon, float(topo.theta),
           float(topo.gamma), float(scenario.slo.ttft),
           float(scenario.slo.atgt), topo.policy,
           tuple((float(s.perf.prefill.k1), float(s.perf.prefill.c1),
                  float(s.perf.decode.k2), float(s.perf.decode.c2),
                  float(s.perf.decode.c3), int(s.max_batch)) for s in specs),
           batched)
    fn = _KERNELS.get(key)
    if fn is None:
        coefs = tuple(tuple(getattr(s.perf.prefill, a) for s in specs)
                      for a in ("k1", "c1")) + \
            tuple(tuple(getattr(s.perf.decode, a) for s in specs)
                  for a in ("k2", "c2", "c3"))
        sim = _make_simulate(
            n, W, B, float(topo.heartbeat), horizon, float(topo.theta),
            float(topo.gamma), float(scenario.slo.ttft),
            float(scenario.slo.atgt), topo.policy, coefs,
            tuple(int(s.max_batch) for s in specs),
            tuple(max(int(s.max_batch), 1) for s in specs),
            tuple(cmax_norm))
        if batched:
            fn = jax.jit(jax.vmap(sim, in_axes=(None, None, None, 0)))
        else:
            fn = jax.jit(sim)
        _KERNELS[key] = fn
    return fn


def _trace_arrays(trace):
    order = sorted(range(len(trace)), key=lambda i: trace[i].arrival)
    ordered = [trace[i] for i in order]
    arrival = np.array([r.arrival for r in ordered])
    l_in = np.array([r.l_in for r in ordered], dtype=np.int64)
    l_real = np.array([r.l_real for r in ordered], dtype=np.int64)
    return ordered, arrival, l_in, l_real


def _report_from_arrays(scenario, specs, n_active, arrival, l_real, l_out,
                        tds, t_first, t_fin):
    """Replicate ``api._percentiles`` over the result arrays (requests in
    finish order, like the reference's finished list)."""
    from repro.serving import api

    slo = scenario.slo
    n = len(arrival)
    fin = ~np.isnan(t_fin)
    order = np.lexsort((np.arange(n)[fin], t_fin[fin]))
    idx = np.nonzero(fin)[0][order]
    ttfts = t_first[idx] - arrival[idx]
    has_atgt = l_real[idx] > 1
    atgts = tds[idx][has_atgt] / np.maximum(l_real[idx][has_atgt] - 1, 1)
    ok = (ttfts <= slo.ttft)
    ok_atgt = np.ones(len(idx), dtype=bool)
    ok_atgt[has_atgt] = atgts <= slo.atgt
    rep = api.RunReport(
        topology="colocated", scaling="fixed",
        attainment=float(np.sum(ok & ok_atgt)) / max(n, 1),
        p99_atgt=float(np.percentile(atgts, 99)) if len(atgts)
        else float("nan"),
        p99_ttft=float(np.percentile(ttfts, 99)) if len(ttfts)
        else float("nan"),
        mean_atgt=float(np.mean(atgts)) if len(atgts) else float("nan"),
        finished=int(len(idx)), total=n)
    rep.peak_workers = int(n_active)
    rep.gpu_cost = sum(s.n_accelerators for s in specs[:n_active])
    rep.moves = 0
    return rep


def run_colocated_jax(scenario, seed: Optional[int] = None):
    """Run a colocated ``Scenario`` on the compiled engine, mutate the
    trace's ``Request`` objects with the outcome (the same contract as the
    other engines) and return the ``RunReport``. Also returns the executed
    beat count via the report-side channel ``rep.beats`` attribute used by
    the benchmarks."""
    specs = check_jax_envelope(scenario)
    trace = scenario.materialize()
    ordered, arrival, l_in, l_real = _trace_arrays(trace)
    if len(ordered) == 0:
        # nothing to simulate: XLA rejects gathers into a size-0 trace
        # axis, and the reference drains immediately anyway
        empty = np.array([])
        rep = _report_from_arrays(scenario, specs, len(specs), empty,
                                  empty, empty, empty, empty, empty)
        rep.beats = 0
        return rep
    # x64 is scoped, not a process-global flag: the serving models run in
    # jax's default 32-bit mode and must not see this engine's precision
    with enable_x64():
        fn = _kernel_for(scenario, specs, trace, batched=False)
        l_out, tds, t_first, t_fin, beats = (
            np.asarray(x) for x in fn(arrival, l_in, l_real, len(specs)))
    for pos, r in enumerate(ordered):
        r.l_pred = int(l_real[pos])
        r.l_out = int(l_out[pos])
        r.t_decode_spent = float(tds[pos])
        tf = t_first[pos]
        r.t_first_token = None if math.isnan(tf) else float(tf)
        te = t_fin[pos]
        if not math.isnan(te):
            r.t_finish = float(te)
            r.state = ReqState.FINISHED
    rep = _report_from_arrays(scenario, specs, len(specs), arrival, l_real,
                              l_out, tds, t_first, t_fin)
    rep.beats = int(beats)      # benchmark side channel (not in row())
    return rep


def run_candidate_batch(scenarios) -> List:
    """Evaluate a batch of fleet-size candidates of the SAME workload /
    spec / policy in one vmapped compiled call — the whole bracket of
    ``optimize``'s search at once. Returns one ``RunReport`` per scenario
    (candidate traces are not mutated; the search only reads reports)."""
    if not scenarios:
        return []
    spec_lists = [check_jax_envelope(sc) for sc in scenarios]
    base = scenarios[0]
    base_spec = spec_lists[0][0]

    def coef_key(s):
        return (s.perf.prefill.k1, s.perf.prefill.c1, s.perf.decode.k2,
                s.perf.decode.c2, s.perf.decode.c3, s.max_batch,
                s.n_accelerators)

    for sl in spec_lists:
        if any(coef_key(s) != coef_key(base_spec) for s in sl):
            # vmap shares one coefficient set across the batch
            raise ValueError("run_candidate_batch needs homogeneous "
                             "candidates of one worker spec")
    W_max = max(len(sl) for sl in spec_lists)
    trace = base.materialize()
    _ordered, arrival, l_in, l_real = _trace_arrays(trace)
    padded = [base_spec] * W_max
    n_active = np.array([len(sl) for sl in spec_lists], dtype=np.int64)
    with enable_x64():
        fn = _kernel_for(base, padded, trace, batched=True)
        l_out, tds, t_first, t_fin, beats = (
            np.asarray(x) for x in fn(arrival, l_in, l_real, n_active))
    reps = []
    for i in range(len(scenarios)):
        rep = _report_from_arrays(base, padded, int(n_active[i]), arrival,
                                  l_real, l_out[i], tds[i], t_first[i],
                                  t_fin[i])
        rep.beats = int(beats[i])   # benchmark side channel
        reps.append(rep)
    return reps
