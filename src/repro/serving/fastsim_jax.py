"""Compiled colocated simulation core (the ``engine="jax"`` path).

The whole heartbeat loop — admission, FIFO placement, per-worker prefill /
decode-segment advancement — runs as ONE ``jax.jit``-compiled
``lax.while_loop`` over beats, with the per-worker advance ``vmap``-ped
across the fleet and (for ``optimize``) the entire simulation ``vmap``-ped
across a batch of candidate worker counts, so a whole bracket of the
binary search evaluates in a single compiled call
(:func:`run_candidate_batch`).

Scope: this is the throughput engine, not the oracle. It compiles the
semantics of :mod:`repro.serving.fastsim` (itself bit-for-bit against the
Python reference) for the whole colocated envelope: ``aladdin``/``jsq``/
``po2`` placement, live KV pressure (constraint-(e) peak admission,
overflow preemption, FIFO resume), and fixed or policy-scaled fleets with
or without a spot market. Two compiled cores share the lane layout:

* the *legacy* whole-trace kernel (``_make_simulate``) — inert-KV, fixed
  ``aladdin``/``jsq`` fleets, the original single-``while_loop`` path that
  ``run_candidate_batch`` vmaps across fleet sizes;
* the *chunked* kernel (``_make_chunk``) — everything else. The host
  splits the beat grid at fleet-mutation boundaries (scaling epochs, boot
  completions, market events, notice deadlines) and runs each
  fixed-fleet-configuration span as one compiled call; between chunks the
  REAL :class:`repro.serving.forecast.ManagedPool` /
  :class:`repro.serving.lifecycle.WorkerLifecycle` state machines make
  every boot/drain/kill decision on numpy mirrors of the lane state (so
  reclaim victim draws consume the same numpy Generator stream as the
  reference). Fleet membership enters the kernel as **lane activation
  masks**: per-lane ``mode`` (off / online / draining) plus serving-order
  ``rank`` arrays, rebuilt host-side per chunk. Lane rows stay resident
  across chunks — scaling never bulk-scatters per beat; a booted or
  recycled lane costs one O(B) row reset at the boundary.

Performance contract: the beat body touches only O(W·B) lane-resident
state (request clocks live in per-worker row arrays, not in trace-sized
arrays), because on CPU XLA a bulk scatter into a trace-sized carry costs
~50 ns *per update element* per beat while single-element updates and
fused masked reductions are ~0.1 µs. In the legacy kernel, finished rows
are drained into the per-request output arrays one finisher at a time (a
few per beat); the still-running remainder is flushed with one bulk
scatter after the loop. The chunked kernel goes further: its while-loop
carry holds NO trace-sized array at all. Finished rows park in their
slot as state 5 (finished, undrained) and the host fans them out from
the returned row arrays between chunks; the (n,)-sized re-entrant sinks
are read-only loop operands; the admission queue is host-presized per
chunk from the arrival trace. The lean carry is what makes the vmapped
candidate batch viable — under ``vmap``, the batched-``while_loop``
masking rule re-selects every carried byte on every iteration of every
nested loop, so each candidate pays the carry size each beat.
KV-preempted rows likewise park in the lane (slot-state 3) rather than
in any trace-sized structure, and the only trace-sized arrays the beat
body touches are single-element ``.at[rid]`` gathers against the sink
operands at placement and kill boundaries.

Numerics: each request's clock arithmetic keeps the reference's
*sequential* add order (decode segments advance through an inner
``while_loop`` of dependent adds on lane-local rows). Worker aggregates
(context sums, batch counts) are reduced in slot order rather than
admission order — exact anyway, because they are sums of integers (and
integer multiples of ``gamma``) well below 2^52. XLA may still contract
multiply-add chains, so agreement with the oracle is to the last few ulps
rather than bit-for-bit — the equivalence tests pin the integer outputs
exactly and the float outputs at ``rtol=1e-12``.

``jax`` is an optional dependency: importing this module without it
raises ``ImportError`` (``api.run`` only imports it on ``engine="jax"``).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.request import ReqState
from repro.serving.fastsim import (DEFAULT_TAIL, check_colocated_envelope,
                                   check_trace_session_free)

_BIG_I = 1 << 50


def check_jax_envelope(scenario) -> List:
    """The vectorized-engine envelope (the compiled cores now cover all of
    it: live KV, po2, policy-scaled fleets, spot markets). po2 placement
    draws from the jax PRNG instead of the reference's numpy Generator
    stream, so po2 cells are deterministic but only tolerance-comparable
    to the other engines; everything else tracks the reference within the
    pinned equivalence tolerances."""
    specs = check_colocated_envelope(scenario)
    for s in specs:
        if s.kv_capacity <= 0:
            raise ValueError("kv_capacity must be positive")
    market = scenario.market
    if market is not None and market.spec is not None \
            and market.spec.kv_capacity <= 0:
        raise ValueError("kv_capacity must be positive")
    return specs


def _legacy_ok(scenario, specs) -> bool:
    """True when the original whole-trace kernel applies (fixed fleet, no
    market, inert KV, aladdin/jsq) — the fast path ``run_candidate_batch``
    vmaps across fleet sizes."""
    from repro.serving import api

    return (isinstance(scenario.scaling, api.FixedScale)
            and scenario.market is None
            and scenario.topology.policy in ("aladdin", "jsq")
            and all(s.perf.kv.h == 0.0 and s.perf.kv.j == 0.0
                    for s in specs))


# ---- the compiled kernel -----------------------------------------------------


def _advance_lane(t0, active0, started0, li, lr, lo, tds, tf1, tfn,
                  k1, c1, k2, c2, c3, t_end):
    """One worker's ``advance_to(t_end)``: alternate prefill / decode
    segments until the local clock reaches the beat end. Membership is a
    pair of masks (``active`` rows hold a request; ``started`` ones have
    been prefilled) so finished slots become reusable holes without any
    compaction; all row state is lane-local, keeping every request's
    sequential add order. vmapped across the fleet."""

    def cond(st):
        return st[0] < t_end

    def body(st):
        t, active, started, lo, tds, tf1, tfn = st
        newm = active & ~started
        has_new = jnp.any(newm)
        n_on = jnp.sum(active & started)
        # --- prefill branch: joint new-batch prefill, decode stalls -------
        tot_in = jnp.sum(jnp.where(newm, li, 0))
        dur_p = k1 * tot_in + c1
        t_pre = t + dur_p
        tds_pre = tds + jnp.where(active & started, dur_p, 0.0)
        tf1_pre = jnp.where(newm, t_pre, tf1)
        lo_pre = jnp.where(newm, jnp.int64(1), lo)
        # --- decode branch: batch fixed until the next finish boundary ----
        do_dec = ~has_new & (n_on > 0)
        b = n_on
        C0 = jnp.sum(jnp.where(active, li + lo, 0))
        n_fin = jnp.min(jnp.where(active, jnp.maximum(lr - lo, 1), _BIG_I))
        n_fin = jnp.where(do_dec, n_fin, 0)
        cb = c2 * b

        def dcond(dst):
            k, td, _seg = dst
            return (k < n_fin) & (td < t_end)

        def dbody(dst):
            k, td, seg = dst
            dur = k2 * (C0 + k * b) + cb + c3
            return k + 1, td + dur, seg + dur

        k, t_dec, seg = lax.while_loop(
            dcond, dbody, (jnp.int64(0), t, jnp.float64(0.0)))
        lo_dec = lo + jnp.where(active, k, 0)
        tds_dec = tds + jnp.where(active, seg, 0.0)
        done = active & (lo_dec >= lr)
        tfn_dec = jnp.where(done, t_dec, tfn)
        # --- select: prefill > decode > idle ------------------------------
        t_new = jnp.where(has_new, t_pre, jnp.where(do_dec, t_dec, t_end))
        return (t_new,
                jnp.where(has_new, active, active & ~done),
                jnp.where(has_new, active, started),
                jnp.where(has_new, lo_pre,
                          jnp.where(do_dec, lo_dec, lo)),
                jnp.where(has_new, tds_pre,
                          jnp.where(do_dec, tds_dec, tds)),
                jnp.where(has_new, tf1_pre, tf1),
                jnp.where(do_dec, tfn_dec, tfn))

    return lax.while_loop(cond, body,
                          (t0, active0, started0, lo, tds, tf1, tfn))


def _make_simulate(n: int, W: int, B: int, hb: float, horizon: float,
                   theta: float, gamma: float, ttft: float, atgt: float,
                   policy: str,
                   coefs: Tuple[Tuple[float, ...], ...],
                   maxb: Tuple[int, ...],
                   maxb_norm: Tuple[float, ...],
                   cmax_norm: Tuple[float, ...],
                   edf: bool = False, tagged: bool = False):
    """Close over the static configuration and return the whole-trace
    simulation ``fn(arrival, l_in, l_real, n_active, rank_r, ttft_r,
    atgt_r)`` (jit/vmap-able). ``rank_r``/``ttft_r``/``atgt_r`` are
    read-only per-request operands for multi-tenant scenarios: ``rank_r``
    is the host-computed total queue order (priority desc, deadline asc,
    arrival index) that ``edf=True`` sorts the admission queue by each
    beat, and the raw per-request SLO budgets drive the tagged
    constraint-(b)/(c)/(d) math when ``tagged=True`` (``inf`` falls back
    to the planning SLO, like the reference). With both flags False the
    operands are ignored and the compiled graph is unchanged."""
    K1, C1, K2, C2, C3 = (jnp.asarray(c) for c in coefs)
    MAXB = jnp.asarray(maxb, dtype=jnp.int64)
    MAXBN = jnp.asarray(maxb_norm)
    CMAXN = jnp.asarray(cmax_norm)
    is_aladdin = policy == "aladdin"
    tag_a = tagged and is_aladdin

    def simulate(arrival, l_in, l_real, n_active, rank_r, ttft_r, atgt_r):
        alive = jnp.arange(W) < n_active

        def place_pass(qlen, q, mem, active, started, lane_li, lane_lr,
                       lane_lo, lane_tds, lane_tf1, lane_tfn):
            on = active & started
            if is_aladdin:
                # constraint (d) slack over *ongoing* members: fixed for
                # the whole pass (placement only adds new_batch entries)
                slack = jnp.min(jnp.where(
                    on, atgt * jnp.maximum(lane_lo - 1, 0) - lane_tds,
                    jnp.inf), axis=1)
                d_budget = theta * jnp.maximum(slack, 0.0)
                if tag_a:
                    # per-member budgets: each ongoing row's own tenant
                    # ATGT (inf -> planning SLO), selected per candidate
                    am = atgt_r[mem]
                    am = jnp.where(jnp.isinf(am), atgt, am)
                    slack_t = jnp.min(jnp.where(
                        on, am * jnp.maximum(lane_lo - 1, 0) - lane_tds,
                        jnp.inf), axis=1)
                    d_budget_t = theta * jnp.maximum(slack_t, 0.0)
            else:
                d_budget = jnp.zeros(W)
            if tag_a:
                # running raw-budget mins over members (b: ongoing + new
                # batch; c: new batch only), updated as placements land
                amin0 = jnp.min(jnp.where(active, atgt_r[mem], jnp.inf),
                                axis=1)
                tmin0 = jnp.min(jnp.where(active & ~started,
                                          ttft_r[mem], jnp.inf), axis=1)
            # l_pred == l_real inside the envelope (no predictor); sums of
            # integers (x gamma), so slot order cannot perturb them
            wctx0 = jnp.sum(jnp.where(
                active, lane_li + gamma * lane_lr, 0.0), axis=1)
            newsum0 = jnp.sum(jnp.where(active & ~started, lane_li, 0),
                              axis=1)
            cnt0 = jnp.sum(active, axis=1)

            def pbody(st):
                (i, keep, q, mem, active, started, lane_li, lane_lr,
                 lane_lo, lane_tds, lane_tf1, lane_tfn, cnt, newsum,
                 wctx) = st[:15]
                if tag_a:
                    amin, tmin = st[15], st[16]
                rid = q[i]
                liv = l_in[rid]
                lrv = l_real[rid]
                v = liv + gamma * lrv
                bpost = cnt + 1
                if is_aladdin:
                    if tag_a:
                        # an untagged candidate takes the scalar branch
                        # even among tagged members (reference _tagged)
                        ct = jnp.isfinite(atgt_r[rid])
                        a0 = jnp.minimum(amin, atgt_r[rid])
                        a_eff = jnp.where(
                            ct, jnp.where(jnp.isinf(a0), atgt, a0), atgt)
                        t0_ = jnp.minimum(tmin, ttft_r[rid])
                        t_eff = jnp.where(
                            ct, jnp.where(jnp.isinf(t0_), ttft, t0_),
                            ttft)
                        d_eff = jnp.where(ct, d_budget_t, d_budget)
                    else:
                        a_eff, t_eff, d_eff = atgt, ttft, d_budget
                    budget = jnp.where(
                        K2 > 0,
                        jnp.maximum(((a_eff - C3) - C2 * bpost)
                                    / jnp.where(K2 > 0, K2, 1.0), 0.0),
                        jnp.inf)
                    pre_t = K1 * (newsum + liv) + C1
                    ok = ((bpost <= MAXB)
                          & (wctx + v <= theta * budget)
                          & (pre_t <= t_eff) & (pre_t <= d_eff) & alive)
                    # best-fit: max capacity_norm, ties to the lowest index
                    # (argmax returns the first maximum, like stable sort)
                    norm = jnp.hypot(cnt / MAXBN, wctx / CMAXN)
                    w = jnp.argmax(jnp.where(ok, norm, -jnp.inf))
                else:
                    # jsq: min batch, ties to the lowest index; inert KV
                    # makes _admit_naive's occupancy test vacuous
                    ok = (bpost <= MAXB) & alive
                    w = jnp.argmin(jnp.where(ok, cnt, _BIG_I))
                placed = jnp.any(ok)
                # placed implies cnt[w] < max_batch <= B, so the row has a
                # hole; out-of-range updates drop, so B is a safe no-op
                wslot = jnp.where(placed, jnp.argmin(active[w]), B)
                mem = mem.at[w, wslot].set(rid, mode="drop")
                active = active.at[w, wslot].set(True, mode="drop")
                started = started.at[w, wslot].set(False, mode="drop")
                lane_li = lane_li.at[w, wslot].set(liv, mode="drop")
                lane_lr = lane_lr.at[w, wslot].set(lrv, mode="drop")
                lane_lo = lane_lo.at[w, wslot].set(0, mode="drop")
                lane_tds = lane_tds.at[w, wslot].set(0.0, mode="drop")
                lane_tf1 = lane_tf1.at[w, wslot].set(jnp.nan, mode="drop")
                lane_tfn = lane_tfn.at[w, wslot].set(jnp.nan, mode="drop")
                cnt = cnt.at[w].add(jnp.where(placed, 1, 0))
                newsum = newsum.at[w].add(jnp.where(placed, liv, 0))
                wctx = wctx.at[w].add(jnp.where(placed, v, 0.0))
                # unplaced requests stay queued, FIFO order preserved
                qslot = jnp.where(placed, jnp.int64(n), keep)
                q = q.at[qslot].set(rid, mode="drop")
                keep = keep + jnp.where(placed, 0, 1)
                out = (i + 1, keep, q, mem, active, started, lane_li,
                       lane_lr, lane_lo, lane_tds, lane_tf1, lane_tfn,
                       cnt, newsum, wctx)
                if tag_a:
                    amin = amin.at[w].min(
                        jnp.where(placed, atgt_r[rid], jnp.inf))
                    tmin = tmin.at[w].min(
                        jnp.where(placed, ttft_r[rid], jnp.inf))
                    out = out + (amin, tmin)
                return out

            st0p = (jnp.int64(0), jnp.int64(0), q, mem, active, started,
                    lane_li, lane_lr, lane_lo, lane_tds, lane_tf1,
                    lane_tfn, cnt0, newsum0, wctx0)
            if tag_a:
                st0p = st0p + (amin0, tmin0)
            st = lax.while_loop(lambda st: st[0] < qlen, pbody, st0p)
            return st[1:12]

        def beat_body(st):
            (t, idx, qlen, q, mem, active, started, t_w, lane_li, lane_lr,
             lane_lo, lane_tds, lane_tf1, lane_tfn, out_lo, out_tds,
             out_tf1, out_tfn, beats) = st

            # admit arrivals <= t (the trace is sorted by arrival)
            def adm_body(ast):
                i2, qlen2, q2 = ast
                return i2 + 1, qlen2 + 1, q2.at[qlen2].set(i2)

            idx, qlen, q = lax.while_loop(
                lambda ast: (ast[0] < n) & (arrival[ast[0]] <= t),
                adm_body, (idx, qlen, q))
            if edf:
                # priority-then-EDF admission order: sort the backlog by
                # the host-computed total rank (stable because ranks are
                # unique); entries past qlen sort to the tail
                ii = jnp.arange(q.shape[0])
                keys = jnp.where(ii < qlen, rank_r[q], _BIG_I)
                q = jnp.take(q, jnp.argsort(keys))
            (qlen, q, mem, active, started, lane_li, lane_lr, lane_lo,
             lane_tds, lane_tf1, lane_tfn) = place_pass(
                qlen, q, mem, active, started, lane_li, lane_lr, lane_lo,
                lane_tds, lane_tf1, lane_tfn)
            # Event skip: with an empty queue, placement is a no-op at
            # every beat until the next arrival is admitted, and decode
            # segments continue across beat boundaries unchanged (lane
            # clocks persist and overshoot; segments end at finishes, not
            # beats).  So step the beat clock with the *same sequential
            # t += hb adds* as stepwise execution (bit-identical grid)
            # until the first beat whose admission check would fire, and
            # cover the whole gap with one advance call.  A backlogged
            # queue forces single-beat stepping, because placement must
            # retry every beat.
            can_skip = qlen == 0
            next_arr = jnp.where(idx < n,
                                 arrival[jnp.minimum(idx, n - 1)], jnp.inf)

            def jcond(jst):
                j, tt = jst
                return ((tt < horizon) & (tt < next_arr)
                        & ((j == 0) | can_skip))

            k_steps, t_next = lax.while_loop(
                jcond, lambda jst: (jst[0] + 1, jst[1] + hb),
                (jnp.int64(0), t))
            # advance every worker on its lane-resident rows
            pre_active = active
            t_w, active, started, lane_lo, lane_tds, lane_tf1, lane_tfn = \
                jax.vmap(_advance_lane,
                         in_axes=(0,) * 14 + (None,))(
                    t_w, active, started, lane_li, lane_lr, lane_lo,
                    lane_tds, lane_tf1, lane_tfn, K1, C1, K2, C2, C3,
                    t_next)
            # drain this step's finishers into the per-request outputs one
            # at a time (bulk scatters into trace-sized arrays are the
            # dominant cost on CPU XLA; single-element updates are free)
            fin = pre_active & ~active

            def ext_body(_j, es):
                fm, o_lo, o_tds, o_tf1, o_tfn, mf = es
                fl = jnp.argmax(fm.reshape(-1))
                w, s = fl // B, fl % B
                rid = mem[w, s]
                o_lo = o_lo.at[rid].set(lane_lo[w, s])
                o_tds = o_tds.at[rid].set(lane_tds[w, s])
                o_tf1 = o_tf1.at[rid].set(lane_tf1[w, s])
                o_tfn = o_tfn.at[rid].set(lane_tfn[w, s])
                mf = jnp.maximum(mf, lane_tfn[w, s])
                return fm.at[w, s].set(False), o_lo, o_tds, o_tf1, o_tfn, mf

            _fm, out_lo, out_tds, out_tf1, out_tfn, maxfin = lax.fori_loop(
                0, jnp.sum(fin), ext_body,
                (fin, out_lo, out_tds, out_tf1, out_tfn, -jnp.inf))
            # Stepwise execution exits once the last request finishes; the
            # final drain jump runs all the way to the horizon, so clamp
            # its beat count to the last finish (exact to within the final
            # decode segment's span -- nothing downstream consumes beats
            # beyond the benchmark rate).
            emptied = ~jnp.any(active)
            k_fin = jnp.ceil((maxfin - t) / hb).astype(jnp.int64)
            k_used = jnp.where((idx >= n) & emptied & (k_steps > 1),
                               jnp.clip(k_fin, 1, k_steps), k_steps)
            return (t_next, idx, qlen, q, mem, active, started, t_w,
                    lane_li, lane_lr, lane_lo, lane_tds, lane_tf1,
                    lane_tfn, out_lo, out_tds, out_tf1, out_tfn,
                    beats + k_used)

        def beat_cond(st):
            t, idx, qlen, active = st[0], st[1], st[2], st[5]
            drained = (idx >= n) & (qlen == 0) & ~jnp.any(active)
            return (t < horizon) & ~drained

        st0 = (jnp.float64(0.0), jnp.int64(0), jnp.int64(0),
               jnp.zeros((max(n, 1),), dtype=jnp.int64),
               jnp.full((W, B), -1, dtype=jnp.int64),
               jnp.zeros((W, B), dtype=bool),
               jnp.zeros((W, B), dtype=bool),
               jnp.zeros((W,)),
               jnp.zeros((W, B), dtype=jnp.int64),
               jnp.zeros((W, B), dtype=jnp.int64),
               jnp.zeros((W, B), dtype=jnp.int64),
               jnp.zeros((W, B)),
               jnp.full((W, B), jnp.nan), jnp.full((W, B), jnp.nan),
               jnp.zeros((n,), dtype=jnp.int64),
               jnp.zeros((n,)),
               jnp.full((n,), jnp.nan), jnp.full((n,), jnp.nan),
               jnp.int64(0))
        st = lax.while_loop(beat_cond, beat_body, st0)
        mem, active = st[4], st[5]
        lane_lo, lane_tds, lane_tf1, lane_tfn = st[10], st[11], st[12], \
            st[13]
        out_lo, out_tds, out_tf1, out_tfn, beats = st[14], st[15], st[16], \
            st[17], st[18]
        # flush still-running rows (partial clocks) once, after the loop
        sink = jnp.where(active, mem, n).reshape(-1)
        out_lo = out_lo.at[sink].set(lane_lo.reshape(-1), mode="drop")
        out_tds = out_tds.at[sink].set(lane_tds.reshape(-1), mode="drop")
        out_tf1 = out_tf1.at[sink].set(lane_tf1.reshape(-1), mode="drop")
        out_tfn = out_tfn.at[sink].set(lane_tfn.reshape(-1), mode="drop")
        return out_lo, out_tds, out_tf1, out_tfn, beats

    return simulate


# ---- the chunked kernel (live KV / po2 / pooled fleets) ---------------------
#
# Slot states (``sst``): 0 empty, 1 placed awaiting prefill, 2 ongoing,
# 3 KV-preempted (parked in-lane), 4 popped for resume (transient within one
# advance iteration), 5 finished but not yet drained to the host output
# mirrors (slots are only recycled between chunks — every mask below is an
# equality test, so 5 behaves like empty for placement aggregates while
# still blocking the slot). Row ordering is carried by three per-slot
# counters:
# ``rnsq`` (global placement sequence — new-batch list order), ``rjsq``
# (lane join sequence — the reference's ongoing-list append order, which
# decides the KV-evict victim tie-break), ``rpsq`` (lane preemption
# sequence — FIFO resume order and kill extraction order).


def _advance_lane_kv(t0, t_start, t_end, sst0, rli, rlr, rnsq, rarr, lo0,
                     tds0, tf10, tpe0, tfn0, jsq0, psq0, jc0, pc0,
                     k1, c1, k2, c2, c3, h, jv, M):
    """One worker's ``advance_to(t_end)`` with the full KV semantics of
    ``fastsim._Engine._advance``: FIFO head-blocking resume against the
    pre-pop occupancy, joint prefill (new batch + resumed victims, stalls
    charged to everyone else), KV-overflow eviction of the youngest
    arrival, and decode segments that break on finish/overflow/beat end.
    All state is lane-resident; vmapped across the fleet."""
    resume_thr = 0.9 * M
    # a lane that sat booting/idle clamps to the beat start before any
    # pending work runs (the reference's advance_to t_start clamp)
    t_in = jnp.where(jnp.any((sst0 == 1) | (sst0 == 3)) & (t0 < t_start)
                     & (t0 < t_end), t_start, t0)

    def cond(st):
        return st[0] < t_end

    def body(st):
        t, sst, lo, tds, tf1, tpe, tfn, jsq, psq, jc, pc = st
        on0 = sst == 2
        n_on = jnp.sum(on0)
        base = h * jnp.sum(jnp.where(on0, rli + lo, 0)) + jv * n_on

        # --- FIFO head-blocking resume (admission tested against the
        # pre-pop occupancy for every pop, like the oracle) ---------------
        def rcond(rst):
            sst2 = rst
            pm = sst2 == 3
            head = jnp.argmin(jnp.where(pm, psq, _BIG_I))
            occ = base + h * (rli[head] + lo[head]) + jv
            return jnp.any(pm) & (occ <= resume_thr)

        def rbody(rst):
            sst2 = rst
            pm = sst2 == 3
            head = jnp.argmin(jnp.where(pm, psq, _BIG_I))
            return sst2.at[head].set(4)

        sst_r = lax.while_loop(rcond, rbody, sst)
        newm = sst_r == 1
        resm = sst_r == 4
        has_work = jnp.any(newm | resm)

        # --- prefill branch ----------------------------------------------
        tot_in = jnp.sum(jnp.where(newm | resm, rli + lo, 0))
        dur_p = k1 * tot_in + c1
        t_pre = t + dur_p
        stall = on0 | (sst_r == 3) | resm
        tds_p = tds + jnp.where(stall, dur_p, 0.0)
        fresh = newm & jnp.isnan(tf1)
        reent = newm & ~jnp.isnan(tf1) & ~jnp.isnan(tpe)
        tds_p = tds_p + jnp.where(
            reent, jnp.maximum(t_pre - tpe, 0.0), 0.0)
        tf1_p = jnp.where(fresh, t_pre, tf1)
        lo_p = jnp.where(fresh, jnp.int64(1), lo)
        tpe_p = jnp.where(newm, jnp.nan, tpe)
        # join order: new rows by placement sequence, then resumed rows by
        # preemption sequence — the ongoing-list append order
        nj = jnp.sum(newm)
        rank_new = jnp.sum(newm[None, :]
                           & (rnsq[None, :] < rnsq[:, None]), axis=1)
        rank_res = jnp.sum(resm[None, :]
                           & (psq[None, :] < psq[:, None]), axis=1)
        jsq_p = jnp.where(newm, jc + rank_new,
                          jnp.where(resm, jc + nj + rank_res, jsq))
        jc_p = jc + nj + jnp.sum(resm)
        sst_p = jnp.where(newm | resm, jnp.int64(2), sst_r)

        # --- KV overflow -> evict the youngest arrival (ties: earliest
        # joiner), then a decode segment ----------------------------------
        do_dec = ~has_work & (n_on > 0)

        def econd(est):
            sst2, _psq2, _pc2 = est
            on2 = sst2 == 2
            b2 = jnp.sum(on2)
            C2_ = jnp.sum(jnp.where(on2, rli + lo, 0))
            return do_dec & (h * C2_ + jv * b2 > M) & (b2 > 1)

        def ebody(est):
            sst2, psq2, pc2 = est
            on2 = sst2 == 2
            ma = jnp.max(jnp.where(on2, rarr, -jnp.inf))
            vic = jnp.argmin(jnp.where(on2 & (rarr == ma), jsq, _BIG_I))
            return (sst2.at[vic].set(3), psq2.at[vic].set(pc2), pc2 + 1)

        sst_e, psq_e, pc_e = lax.while_loop(econd, ebody, (sst_r, psq, pc))
        on_e = sst_e == 2
        b = jnp.sum(on_e)
        C0 = jnp.sum(jnp.where(on_e, rli + lo, 0))
        n_fin = jnp.min(jnp.where(on_e, jnp.maximum(rlr - lo, 1), _BIG_I))
        n_fin = jnp.where(do_dec, n_fin, 0)
        cb = c2 * b

        def dcond(dst):
            k, td, _seg = dst
            kv_break = (k > 0) & (h * (C0 + k * b) + jv * b > M) & (b > 1)
            return (k < n_fin) & (td < t_end) & ~kv_break

        def dbody(dst):
            k, td, seg = dst
            dur = k2 * (C0 + k * b) + cb + c3
            return k + 1, td + dur, seg + dur

        k, t_dec, seg = lax.while_loop(
            dcond, dbody, (jnp.int64(0), t, jnp.float64(0.0)))
        lo_d = lo + jnp.where(on_e, k, 0)
        # preempted rows' ATGT clocks stall through the segment too
        tds_d = tds + jnp.where(on_e | (sst_e == 3), seg, 0.0)
        done = on_e & (lo_d >= rlr)
        tfn_d = jnp.where(done, t_dec, tfn)
        # finished rows park as 5 (finished, undrained) so the beat loop
        # never touches (n,)-sized output arrays; the host fans them out
        # from the row state after the chunk returns
        sst_d = jnp.where(done, jnp.int64(5), sst_e)

        # --- select: prefill > decode > idle -----------------------------
        t_n = jnp.where(has_work, t_pre, jnp.where(do_dec, t_dec, t_end))
        sel_i = jnp.where(has_work, sst_p, jnp.where(do_dec, sst_d, sst_r))
        return (t_n, sel_i,
                jnp.where(has_work, lo_p, jnp.where(do_dec, lo_d, lo)),
                jnp.where(has_work, tds_p,
                          jnp.where(do_dec, tds_d, tds)),
                jnp.where(has_work, tf1_p, tf1),
                jnp.where(has_work, tpe_p, tpe),
                jnp.where(do_dec, tfn_d, tfn),
                jnp.where(has_work, jsq_p, jsq),
                jnp.where(do_dec, psq_e, psq),
                jnp.where(has_work, jc_p, jc),
                jnp.where(do_dec, pc_e, pc))

    return lax.while_loop(cond, body, (t_in, sst0, lo0, tds0, tf10, tpe0,
                                       tfn0, jsq0, psq0, jc0, pc0))


def _make_chunk(n: int, W: int, B: int, Q: int, hb: float,
                gamma: float, ttft: float, atgt: float, policy: str,
                edf: bool = False, tagged: bool = False):
    """Close over the static shape/config and return the chunk kernel
    ``fn(st, arrival, l_in, l_real, s_lo, s_tds, s_tf1, s_tpe, rank_r,
    ttft_r, atgt_r) -> st``
    advancing up to ``st['K']`` beats of a FIXED fleet configuration.
    The three trailing operands are the multi-tenant per-request arrays
    (see :func:`_tenant_arrays`): ``edf=True`` sorts the backlog by
    ``rank_r`` each beat, ``tagged=True`` swaps the aladdin constraint
    budgets for the per-request ones; both False ignores them and leaves
    the compiled graph unchanged.
    Fleet composition is traced state (activation masks + per-lane
    coefficient arrays), so boots, drains and reclaims never recompile;
    only lane-capacity growth does. ``st['theta']`` is traced too, which
    lets ``run_policy_candidate_batch`` vmap a whole theta bracket
    through one compiled call.

    The while-loop carry is kept deliberately lean — ``Q``-capped queue,
    finished rows parked in-slot as state 5 (the host drains them from
    the row arrays after the chunk) instead of (n,) output arrays, and
    the re-entrant sinks passed as loop-invariant
    operands — because under ``vmap`` every carried byte is re-selected
    each iteration of every loop (the batched while_loop masking rule),
    which is what the candidate-batch throughput lives or dies on."""
    is_aladdin = policy == "aladdin"
    is_jsq = policy == "jsq"
    tag_a = tagged and is_aladdin
    lane_ids = jnp.arange(W)

    def chunk(st, arrival, l_in, l_real, s_lo, s_tds, s_tf1, s_tpe,
              rank_r, ttft_r, atgt_r):

        def place_pass(st):
            theta = st["theta"]
            sst, rlo, rtds = st["sst"], st["rlo"], st["rtds"]
            rli, rlr = st["rli"], st["rlr"]
            online = st["mode"] == 2
            rank = st["rank"]
            on = sst == 2
            members = on | (sst == 1)
            # aggregates in the reference's cache roles: cnt=bsz (ongoing
            # + new batch), ctx0 over ongoing only, newctx over new batch
            # (re-entrants count their retained l_out — what kv_now sees)
            cnt0 = jnp.sum(members, axis=1)
            wctx0 = jnp.sum(jnp.where(members, rli + gamma * rlr, 0.0),
                            axis=1)
            newsum0 = jnp.sum(jnp.where(sst == 1, rli, 0), axis=1)
            newctx0 = jnp.sum(jnp.where(sst == 1, rli + rlo, 0), axis=1)
            ctx0 = jnp.sum(jnp.where(on, rli + rlo, 0), axis=1)
            if is_aladdin:
                slack = jnp.min(jnp.where(
                    on, atgt * jnp.maximum(rlo - 1, 0) - rtds,
                    jnp.inf), axis=1)
                d_budget = theta * jnp.maximum(slack, 0.0)
                if tag_a:
                    # per-member ATGT budgets (inf -> planning SLO),
                    # selected per candidate like the reference
                    am = atgt_r[st["rid"]]
                    am = jnp.where(jnp.isinf(am), atgt, am)
                    slack_t = jnp.min(jnp.where(
                        on, am * jnp.maximum(rlo - 1, 0) - rtds,
                        jnp.inf), axis=1)
                    d_budget_t = theta * jnp.maximum(slack_t, 0.0)
            else:
                d_budget = jnp.zeros(W)
            if tag_a:
                # running raw-budget mins over members (b: ongoing + new
                # batch; c: new batch only), updated as placements land
                amin0 = jnp.min(jnp.where(members, atgt_r[st["rid"]],
                                          jnp.inf), axis=1)
                tmin0 = jnp.min(jnp.where(sst == 1, ttft_r[st["rid"]],
                                          jnp.inf), axis=1)
            nserv = jnp.sum(online)

            def pbody(ps):
                (i, keep, q, sst, rid, rli, rlr, rlo, rtds, rtf1, rtpe,
                 rtfn, rarr, rnsq, rjsq, rpsq, cnt, newsum, newctx, wctx,
                 seqc, key, ovf) = ps[:23]
                if tag_a:
                    amin, tmin = ps[23], ps[24]
                r = q[i]
                liv = l_in[r]
                lrv = l_real[r]
                lov = s_lo[r]               # re-entrant retained l_out
                v = liv + gamma * lrv
                bpost = cnt + 1
                if is_aladdin:
                    K2a = st["K2"]
                    if tag_a:
                        # an untagged candidate takes the scalar branch
                        # even among tagged members (reference _tagged)
                        ct = jnp.isfinite(atgt_r[r])
                        a0 = jnp.minimum(amin, atgt_r[r])
                        a_eff = jnp.where(
                            ct, jnp.where(jnp.isinf(a0), atgt, a0), atgt)
                        t0_ = jnp.minimum(tmin, ttft_r[r])
                        t_eff = jnp.where(
                            ct, jnp.where(jnp.isinf(t0_), ttft, t0_),
                            ttft)
                        d_eff = jnp.where(ct, d_budget_t, d_budget)
                    else:
                        a_eff, t_eff, d_eff = atgt, ttft, d_budget
                    budget = jnp.where(
                        K2a > 0,
                        jnp.maximum(((a_eff - st["C3"]) - st["C2"] * bpost)
                                    / jnp.where(K2a > 0, K2a, 1.0), 0.0),
                        jnp.inf)
                    pre_t = st["K1"] * (newsum + liv) + st["C1"]
                    ok = ((bpost <= st["MAXB"])
                          & (wctx + v <= theta * budget)
                          & (pre_t <= t_eff) & (pre_t <= d_eff) & online)
                    norm = jnp.hypot(cnt / st["MAXBN"], wctx / st["CMAXN"])
                    # lazy best-fit: walk candidates by (norm desc, serving
                    # order), testing constraint (e)'s KV peak per lane
                    rem_c = jnp.maximum(lrv - lov, 0)
                    ctx_c = liv + lov

                    def kcond(ks):
                        m_, _w, found = ks
                        return jnp.any(m_) & ~found

                    def kbody(ks):
                        m_, _w, _f = ks
                        mn = jnp.max(jnp.where(m_, norm, -jnp.inf))
                        w_ = jnp.argmin(jnp.where(m_ & (norm == mn),
                                                  rank, _BIG_I))
                        memb = (sst[w_] == 1) | (sst[w_] == 2)
                        remv = jnp.concatenate([
                            jnp.where(memb,
                                      jnp.maximum(rlr[w_] - rlo[w_], 0), 0),
                            rem_c[None]])
                        ctxv = jnp.concatenate([
                            jnp.where(memb, rli[w_] + rlo[w_], 0),
                            ctx_c[None]])
                        mv = jnp.concatenate(
                            [memb, jnp.ones((1,), dtype=bool)])
                        hk, jk = st["H"][w_], st["J"][w_]
                        kiv = jnp.maximum(remv, 1)
                        aliveM = mv[None, :] & (remv[None, :]
                                                >= kiv[:, None])
                        cnt_a = jnp.sum(aliveM, axis=1)
                        sum_c = jnp.sum(
                            jnp.where(aliveM, ctxv[None, :], 0), axis=1)
                        tot = hk * (sum_c + cnt_a * kiv) + jk * cnt_a
                        valid = mv & (cnt_a > 0)
                        peak = jnp.maximum(
                            hk * jnp.sum(jnp.where(mv, ctxv, 0))
                            + jk * jnp.sum(mv),
                            jnp.max(jnp.where(valid, tot, -jnp.inf)))
                        return (m_.at[w_].set(False), w_,
                                peak <= theta * st["M"][w_])

                    _m, w, placed = lax.while_loop(
                        kcond, kbody, (ok, jnp.int64(0), jnp.bool_(False)))
                    key2 = key
                else:
                    # kv_now admission shared by jsq and po2 (_admit_naive)
                    kv_now = (st["H"] * (ctx0 + newctx) + st["J"] * cnt) \
                        + (st["H"] * liv + st["J"])
                    admit = (kv_now <= st["M"]) & (bpost <= st["MAXB"]) \
                        & online
                    if is_jsq:
                        # min batch, ties to serving-list order
                        w = jnp.argmin(jnp.where(
                            admit, cnt * (W + 1) + rank, _BIG_I))
                        placed = jnp.any(admit)
                        key2 = key
                    else:
                        # po2: two uniform draws (jax PRNG — deterministic
                        # but a different stream than the numpy oracle)
                        key2, ka, kb = jax.random.split(key, 3)
                        m = nserv
                        r1 = jax.random.randint(
                            ka, (), 0, jnp.maximum(m, 1))
                        r2 = jax.random.randint(
                            kb, (), 0, jnp.maximum(m - 1, 1))
                        jj = r2 + (r2 >= r1)
                        c1_ = st["p2l"][r1]
                        c2_ = st["p2l"][jj]
                        swap = wctx[c2_] < wctx[c1_]
                        c1_, c2_ = (jnp.where(swap, c2_, c1_),
                                    jnp.where(swap, c1_, c2_))
                        use1 = (m >= 1) & admit[c1_]
                        use2 = (m >= 2) & ~use1 & admit[c2_]
                        fb = admit & ~((lane_ids == c1_) & (m >= 1)) \
                            & ~((lane_ids == c2_) & (m >= 2))
                        mw = jnp.min(jnp.where(fb, wctx, jnp.inf))
                        wf = jnp.argmin(jnp.where(fb & (wctx == mw),
                                                  rank, _BIG_I))
                        w = jnp.where(use1, c1_,
                                      jnp.where(use2, c2_, wf))
                        placed = use1 | use2 | jnp.any(fb)
                slot = jnp.argmin(sst[w])
                has_free = sst[w, slot] == 0
                ovf = ovf | (placed & ~has_free)
                do = placed & has_free
                wslot = jnp.where(do, slot, B)   # B: out-of-range no-op
                sst = sst.at[w, wslot].set(1, mode="drop")
                rid = rid.at[w, wslot].set(r, mode="drop")
                rli = rli.at[w, wslot].set(liv, mode="drop")
                rlr = rlr.at[w, wslot].set(lrv, mode="drop")
                rlo = rlo.at[w, wslot].set(lov, mode="drop")
                rtds = rtds.at[w, wslot].set(s_tds[r], mode="drop")
                rtf1 = rtf1.at[w, wslot].set(s_tf1[r], mode="drop")
                rtpe = rtpe.at[w, wslot].set(s_tpe[r], mode="drop")
                rtfn = rtfn.at[w, wslot].set(jnp.nan, mode="drop")
                rarr = rarr.at[w, wslot].set(arrival[r], mode="drop")
                rnsq = rnsq.at[w, wslot].set(seqc, mode="drop")
                rjsq = rjsq.at[w, wslot].set(0, mode="drop")
                rpsq = rpsq.at[w, wslot].set(0, mode="drop")
                cnt = cnt.at[w].add(jnp.where(do, 1, 0))
                newsum = newsum.at[w].add(jnp.where(do, liv, 0))
                newctx = newctx.at[w].add(jnp.where(do, liv + lov, 0))
                wctx = wctx.at[w].add(jnp.where(do, v, 0.0))
                seqc = seqc + jnp.where(do, 1, 0)
                # unplaced requests stay queued, FIFO order preserved
                qslot = jnp.where(do, jnp.int64(Q), keep)
                q = q.at[qslot].set(r, mode="drop")
                keep = keep + jnp.where(do, 0, 1)
                out = (i + 1, keep, q, sst, rid, rli, rlr, rlo, rtds,
                       rtf1, rtpe, rtfn, rarr, rnsq, rjsq, rpsq, cnt,
                       newsum, newctx, wctx, seqc, key2, ovf)
                if tag_a:
                    amin = amin.at[w].min(
                        jnp.where(do, atgt_r[r], jnp.inf))
                    tmin = tmin.at[w].min(
                        jnp.where(do, ttft_r[r], jnp.inf))
                    out = out + (amin, tmin)
                return out

            ps0 = (jnp.int64(0), jnp.int64(0), st["q"], sst, st["rid"],
                   rli, rlr, rlo, rtds, st["rtf1"], st["rtpe"],
                   st["rtfn"], st["rarr"], st["rnsq"], st["rjsq"],
                   st["rpsq"], cnt0, newsum0, newctx0, wctx0, st["seqc"],
                   st["key"], st["ovf"])
            if tag_a:
                ps0 = ps0 + (amin0, tmin0)
            ps = lax.while_loop(lambda ps: ps[0] < st["qlen"], pbody, ps0)
            out = dict(st)
            (out["qlen"], out["q"], out["sst"], out["rid"], out["rli"],
             out["rlr"], out["rlo"], out["rtds"], out["rtf1"],
             out["rtpe"], out["rtfn"], out["rarr"], out["rnsq"],
             out["rjsq"], out["rpsq"]) = ps[1:16]
            out["seqc"], out["key"], out["ovf"] = ps[20], ps[21], ps[22]
            return out

        def beat_body(st):
            t = st["t"]

            # admit arrivals <= t (the trace is sorted by arrival): one
            # masked scatter append — the host pre-sizes Q so the whole
            # chunk's arrivals always fit (no in-kernel overflow path)
            hi = jnp.maximum(
                jnp.searchsorted(arrival, t, side="right"), st["idx"])
            na = hi - st["idx"]
            ii = jnp.arange(Q, dtype=jnp.int64)
            q = st["q"].at[jnp.where(ii < na, st["qlen"] + ii, Q)].set(
                st["idx"] + ii, mode="drop")
            st = dict(st)
            st["idx"], st["qlen"], st["q"] = hi, st["qlen"] + na, q
            if edf:
                # priority-then-EDF admission order: sort the backlog by
                # the host-computed total rank (unique per request);
                # entries past qlen sort to the tail
                keys = jnp.where(ii < st["qlen"], rank_r[st["q"]],
                                 _BIG_I)
                st["q"] = jnp.take(st["q"], jnp.argsort(keys))
            st = place_pass(st)
            t_next = t + hb
            adv = (st["mode"] == 2) | (st["mode"] == 3)
            t_end_w = jnp.where(adv, t_next, st["t_w"])
            sst_pp = st["sst"]
            ax = (0, None) + (0,) * 23
            (t_w, sst, rlo, rtds, rtf1, rtpe, rtfn, rjsq, rpsq, jc, pc) = \
                jax.vmap(_advance_lane_kv, in_axes=ax)(
                    st["t_w"], t, t_end_w, sst_pp, st["rli"], st["rlr"],
                    st["rnsq"], st["rarr"], st["rlo"], st["rtds"],
                    st["rtf1"], st["rtpe"], st["rtfn"], st["rjsq"],
                    st["rpsq"], st["jc"], st["pc"], st["K1"], st["C1"],
                    st["K2"], st["C2"], st["C3"], st["H"], st["J"],
                    st["M"])
            (st["t_w"], st["sst"], st["rlo"], st["rtds"], st["rtf1"],
             st["rtpe"], st["rtfn"], st["rjsq"], st["rpsq"], st["jc"],
             st["pc"]) = (t_w, sst, rlo, rtds, rtf1, rtpe, rtfn, rjsq,
                          rpsq, jc, pc)
            # busy/retirement stats for the host's billing replay: a lane
            # is busy with ongoing or new-batch rows (preempted rows are
            # not load); a draining lane that empties retires before its
            # beat is billed, so record the first-empty beat index.
            # Finished-undrained rows (5) are semantically gone: they
            # neither load a lane nor block its retirement
            loaded = jnp.any((sst == 1) | (sst == 2), axis=1)
            busy = jnp.sum((st["mode"] == 2) & loaded)
            st["busy_pk"] = jnp.maximum(st["busy_pk"], busy)
            st["busy_fin"] = busy
            occ_lane = jnp.any((sst > 0) & (sst < 5), axis=1)
            st["empty_at"] = jnp.where(
                (st["mode"] == 3) & ~occ_lane
                & (st["empty_at"] == _BIG_I),
                st["j"], st["empty_at"])
            st["j"] = st["j"] + 1
            st["t"] = t_next
            return st

        def beat_cond(st):
            drained = (st["idx"] >= n) & (st["qlen"] == 0) \
                & ~jnp.any((st["sst"] > 0) & (st["sst"] < 5))
            return (st["j"] < st["K"]) & ~drained

        return lax.while_loop(beat_cond, beat_body, st)

    return chunk


# compiled kernels are cached per static configuration; the jit wrapper on
# top caches its traces too, so repeated runs/batches recompile nothing
_KERNELS: Dict[Tuple, object] = {}


def _kernel_for(scenario, specs, trace, batched: bool,
                edf: bool = False, tagged: bool = False):
    from repro.serving import api

    topo = scenario.topology
    W = len(specs)
    B = max(max(int(s.max_batch) for s in specs), 1)
    arrival = np.array(sorted(r.arrival for r in trace))
    n = len(trace)
    horizon = (float(arrival[-1]) if n else 0.0) + DEFAULT_TAIL
    cmax_norm = []
    for s in specs:
        cmax = s.perf.decode.max_total_context(1, scenario.slo.atgt) or 1.0
        cmax_norm.append(max(cmax, 1.0))
    key = (n, W, B, float(topo.heartbeat), horizon, float(topo.theta),
           float(topo.gamma), float(scenario.slo.ttft),
           float(scenario.slo.atgt), topo.policy,
           tuple((float(s.perf.prefill.k1), float(s.perf.prefill.c1),
                  float(s.perf.decode.k2), float(s.perf.decode.c2),
                  float(s.perf.decode.c3), int(s.max_batch)) for s in specs),
           batched, edf, tagged)
    fn = _KERNELS.get(key)
    if fn is None:
        coefs = tuple(tuple(getattr(s.perf.prefill, a) for s in specs)
                      for a in ("k1", "c1")) + \
            tuple(tuple(getattr(s.perf.decode, a) for s in specs)
                  for a in ("k2", "c2", "c3"))
        sim = _make_simulate(
            n, W, B, float(topo.heartbeat), horizon, float(topo.theta),
            float(topo.gamma), float(scenario.slo.ttft),
            float(scenario.slo.atgt), topo.policy, coefs,
            tuple(int(s.max_batch) for s in specs),
            tuple(max(int(s.max_batch), 1) for s in specs),
            tuple(cmax_norm), edf, tagged)
        if batched:
            fn = jax.jit(jax.vmap(sim, in_axes=(None, None, None, 0,
                                                None, None, None)))
        else:
            fn = jax.jit(sim)
        _KERNELS[key] = fn
    return fn


def _trace_arrays(trace):
    order = sorted(range(len(trace)), key=lambda i: trace[i].arrival)
    ordered = [trace[i] for i in order]
    arrival = np.array([r.arrival for r in ordered])
    l_in = np.array([r.l_in for r in ordered], dtype=np.int64)
    l_real = np.array([r.l_real for r in ordered], dtype=np.int64)
    return ordered, arrival, l_in, l_real


def _tenant_arrays(ordered):
    """Per-request multi-tenant operands for the kernels: the total queue
    rank (priority desc, deadline asc, arrival index — the order a stable
    reference sort converges to; after a requeue an exact-key tie can
    differ, which the tolerance pins absorb) and the RAW per-request SLO
    budgets (``inf`` = untagged; the kernels resolve the fallback to the
    planning SLO in-branch, like the reference). ``tagged`` mirrors the
    reference's trace-level gate (any finite ATGT budget)."""
    n = len(ordered)
    prio = np.array([int(r.priority) for r in ordered], dtype=np.int64)
    dl = np.array([r.deadline for r in ordered])
    rank = np.empty(n, dtype=np.int64)
    rank[np.lexsort((dl, -prio))] = np.arange(n, dtype=np.int64)
    ttft_r = np.array([r.slo_ttft for r in ordered])
    atgt_r = np.array([r.slo_atgt for r in ordered])
    tagged = bool(np.isfinite(atgt_r).any()) if n else False
    return rank, ttft_r, atgt_r, tagged


def _report_from_arrays(scenario, specs, n_active, arrival, l_real, l_out,
                        tds, t_first, t_fin):
    """Replicate ``api._percentiles`` over the result arrays (requests in
    finish order, like the reference's finished list)."""
    from repro.serving import api

    slo = scenario.slo
    n = len(arrival)
    fin = ~np.isnan(t_fin)
    order = np.lexsort((np.arange(n)[fin], t_fin[fin]))
    idx = np.nonzero(fin)[0][order]
    ttfts = t_first[idx] - arrival[idx]
    has_atgt = l_real[idx] > 1
    atgts = tds[idx][has_atgt] / np.maximum(l_real[idx][has_atgt] - 1, 1)
    ok = (ttfts <= slo.ttft)
    ok_atgt = np.ones(len(idx), dtype=bool)
    ok_atgt[has_atgt] = atgts <= slo.atgt
    rep = api.RunReport(
        topology="colocated", scaling="fixed",
        attainment=float(np.sum(ok & ok_atgt)) / max(n, 1),
        p99_atgt=float(np.percentile(atgts, 99)) if len(atgts)
        else float("nan"),
        p99_ttft=float(np.percentile(ttfts, 99)) if len(ttfts)
        else float("nan"),
        mean_atgt=float(np.mean(atgts)) if len(atgts) else float("nan"),
        finished=int(len(idx)), total=n)
    rep.peak_workers = int(n_active)
    rep.gpu_cost = sum(s.n_accelerators for s in specs[:n_active])
    rep.moves = 0
    return rep


def _chunk_kernel(n: int, W: int, B: int, Q: int, hb: float,
                  gamma: float, ttft: float, atgt: float, policy: str,
                  batched: bool, edf: bool = False, tagged: bool = False):
    key = ("chunk", n, W, B, Q, hb, gamma, ttft, atgt, policy, batched,
           edf, tagged)
    fn = _KERNELS.get(key)
    if fn is None:
        sim = _make_chunk(n, W, B, Q, hb, gamma, ttft, atgt, policy,
                          edf, tagged)
        if batched:
            fn = jax.jit(jax.vmap(sim,
                                  in_axes=(0, None, None, None, 0, 0, 0, 0,
                                           None, None, None)))
        else:
            fn = jax.jit(sim)
        _KERNELS[key] = fn
    return fn


# mirror layout: per-lane coefficient/clock arrays and per-slot row arrays
# (grown by doubling; rows are recycled once a lane leaves every pool list)
_LANE_KEYS = ("t_w", "jc", "pc", "K1", "C1", "K2", "C2", "C3", "H", "J",
              "M", "MAXB", "MAXBN", "CMAXN")
_ROW_KEYS = ("sst", "rid", "rli", "rlr", "rlo", "rtds", "rtf1", "rtpe",
             "rtfn", "rarr", "rnsq", "rjsq", "rpsq")
_NAN_KEYS = ("rtf1", "rtpe", "rtfn")
_ONE_KEYS = ("MAXB", "MAXBN", "CMAXN")
# host-resident mirrors the kernel never carries: the (n,) request outputs
# (fed by the staged finisher ring) and the re-entrant sinks (loop-invariant
# kernel operands, written only between chunks by the lane adapters)
_HOST_KEYS = ("o_lo", "o_tds", "o_tf1", "o_tfn",
              "s_lo", "s_tds", "s_tf1", "s_tpe")


class _PooledSim:
    """Host half of the chunked compiled engine.

    The kernel advances beats inside a fixed fleet configuration; this
    class owns everything between chunks: numpy mirrors of the lane state,
    the REAL ``ManagedPool``/``_FixedLanes``/``WorkerLifecycle`` state
    machines (driven through the same adapter protocol the numpy engine
    uses, so every scaling/reclaim decision — including the victim rng
    draws — is made by reference code on the reference's numpy Generator),
    and the beat-grid bookkeeping that cuts chunks at fleet-mutation
    boundaries: scaling epochs, boot completions, market events, notice
    deadlines, and the horizon."""

    def __init__(self, scenario, seed: Optional[int] = None,
                 tail: float = DEFAULT_TAIL):
        from repro.serving import api
        from repro.serving.fastsim import (_FixedLanes, _managed_policy,
                                           _managed_scfg)
        from repro.serving.forecast import ManagedPool

        scenario = api.resolve_scenario(scenario)
        self.scenario = scenario
        self.specs0 = check_jax_envelope(scenario)
        topo = scenario.topology
        self.policy_name = topo.policy
        self.hb = float(topo.heartbeat)
        self.gamma = float(topo.gamma)
        self.theta = float(topo.theta)
        self.slo = scenario.slo
        s = seed if seed is not None else scenario.seed
        self.rng = np.random.default_rng(s)
        trace = scenario.materialize()
        check_trace_session_free(trace)
        self.trace, self.arrival, self.l_in, self.l_real = \
            _trace_arrays(trace)
        self.n = len(self.trace)
        self.rank_r, self.ttft_r, self.atgt_r, self.tagged = \
            _tenant_arrays(self.trace)
        self.edf = (scenario.tenants is not None
                    and len(scenario.tenants) > 1 and self.n > 0)
        horizon = (float(self.arrival[-1]) if self.n else 0.0) + tail
        grid = [0.0]
        while grid[-1] < horizon:    # the reference's sequential t += hb
            grid.append(grid[-1] + self.hb)
        self.G = np.array(grid)
        self.total_beats = len(grid) - 1
        market = scenario.market
        self.notice = float(market.notice_s) if market is not None else 0.0
        self.events = sorted(market.events, key=lambda e: e.t) \
            if market is not None and market.events else []
        self.managed = not isinstance(scenario.scaling, api.FixedScale)
        cand_specs = list(self.specs0)
        if market is not None and market.spec is not None:
            cand_specs.append(market.spec)
        maxb = max(max(int(sp.max_batch) for sp in cand_specs), 1)
        live_kv = any(sp.perf.kv.h != 0.0 or sp.perf.kv.j != 0.0
                      for sp in cand_specs)
        # live KV parks preempted rows in-lane, and finished rows park
        # in-slot as state 5 until the host drains them between chunks:
        # slots are transient scratch, not a capacity model.  Start
        # small — every while-loop carry in the kernel drags the (W, B)
        # row arrays, so an oversized B taxes every beat.  The kernel
        # flags slot exhaustion (ovf) and the drivers regrow B and
        # re-run the chunk; n rows is the absolute ceiling.
        self.Bmax = max(2 * maxb + 8 if live_kv else maxb, self.n, 1)
        self.B = max(min(2 * maxb + 8 if live_kv else maxb, 64), 1)
        # queue capacity is host-presized per chunk (arrivals are known)
        self.qcap = max(1, min(self.n, 64))
        self.W_cap = 8
        self.specs: List = []
        self._wid = 0
        n = self.n
        W, B = self.W_cap, self.B
        self.m = {
            "t_w": np.zeros(W), "jc": np.zeros(W, np.int64),
            "pc": np.zeros(W, np.int64),
            "K1": np.zeros(W), "C1": np.zeros(W), "K2": np.zeros(W),
            "C2": np.zeros(W), "C3": np.zeros(W), "H": np.zeros(W),
            "J": np.zeros(W), "M": np.zeros(W),
            "MAXB": np.ones(W, np.int64), "MAXBN": np.ones(W),
            "CMAXN": np.ones(W),
            "sst": np.zeros((W, B), np.int64),
            "rid": np.zeros((W, B), np.int64),
            "rli": np.zeros((W, B), np.int64),
            "rlr": np.zeros((W, B), np.int64),
            "rlo": np.zeros((W, B), np.int64),
            "rtds": np.zeros((W, B)),
            "rtf1": np.full((W, B), np.nan),
            "rtpe": np.full((W, B), np.nan),
            "rtfn": np.full((W, B), np.nan),
            "rarr": np.zeros((W, B)),
            "rnsq": np.zeros((W, B), np.int64),
            "rjsq": np.zeros((W, B), np.int64),
            "rpsq": np.zeros((W, B), np.int64),
            "o_lo": np.zeros(n, np.int64), "o_tds": np.zeros(n),
            "o_tf1": np.full(n, np.nan), "o_tfn": np.full(n, np.nan),
            "s_lo": np.zeros(n, np.int64), "s_tds": np.zeros(n),
            "s_tf1": np.full(n, np.nan), "s_tpe": np.full(n, np.nan),
        }
        self.h_pn = np.zeros(n, np.int64)   # preempt_count deltas
        self._queue: List[int] = []
        self.idx = 0
        self.eidx = 0
        self.beat = 0
        self.seqc = 0
        self.key = jax.random.PRNGKey(int(scenario.seed))
        self.done = False
        self.pool = None
        if self.managed:
            scfg = _managed_scfg(scenario)
            pol = _managed_policy(scenario, scfg)
            self.scaling_policy = pol
            self.pool = ManagedPool(
                scenario.fleet.for_role("serve")[0].spec, scfg, pol,
                self.hb, self.rng, new_worker=self._new_lane,
                on_spawn=self._spawn_lane, on_kill=self._kill_lane,
                load=self._lane_load, idle=self._lane_idle,
                mark=self._mark_rid,
                spot_spec=market.spec if market is not None else None,
                notice_s=self.notice, name="serve")
        else:
            lanes = [self._new_lane(sp) for sp in self.specs0]
            self.init_W = len(lanes)
            self.pool = _FixedLanes(self, lanes, self.rng, self.notice)

    # ---- lane allocation (grow-only mirrors, recycled rows) ----------------

    def _ensure_cap(self, need: int) -> None:
        if need <= self.W_cap:
            return
        cap = self.W_cap
        while cap < need:
            cap *= 2
        ext = cap - self.W_cap
        for k in _LANE_KEYS:
            fill = np.ones(ext, self.m[k].dtype) if k in _ONE_KEYS \
                else np.zeros(ext, self.m[k].dtype)
            self.m[k] = np.concatenate([self.m[k], fill])
        for k in _ROW_KEYS:
            fill = np.full((ext, self.B), np.nan) if k in _NAN_KEYS \
                else np.zeros((ext, self.B), self.m[k].dtype)
            self.m[k] = np.vstack([self.m[k], fill])
        self.W_cap = cap

    def _ensure_rows(self, need: int) -> None:
        """Grow the per-lane row dimension (slot exhaustion recovery)."""
        need = min(need, self.Bmax)
        if need <= self.B:
            return
        B = self.B
        while B < need:
            B = min(B * 2, self.Bmax)
        ext = B - self.B
        for k in _ROW_KEYS:
            fill = np.full((self.W_cap, ext), np.nan) if k in _NAN_KEYS \
                else np.zeros((self.W_cap, ext), self.m[k].dtype)
            self.m[k] = np.hstack([self.m[k], fill])
        self.B = B

    def _live_idx(self) -> set:
        if self.pool is None:       # pool ctor is mid-boot: nothing retired
            return set(range(len(self.specs)))
        live = {ln.idx for ln in self.pool.active()}
        if self.managed:
            live |= {b[1].idx for b in self.pool.booting}
        return live

    def _new_lane(self, spec):
        from repro.serving.fastsim import _Lane

        live = self._live_idx()
        free = [i for i in range(len(self.specs)) if i not in live]
        if free:
            idx = free[0]
            self.specs[idx] = spec
        else:
            idx = len(self.specs)
            self._ensure_cap(idx + 1)
            self.specs.append(spec)
        m = self.m
        m["t_w"][idx] = 0.0
        m["jc"][idx] = 0
        m["pc"][idx] = 0
        m["K1"][idx] = spec.perf.prefill.k1
        m["C1"][idx] = spec.perf.prefill.c1
        m["K2"][idx] = spec.perf.decode.k2
        m["C2"][idx] = spec.perf.decode.c2
        m["C3"][idx] = spec.perf.decode.c3
        m["H"][idx] = spec.perf.kv.h
        m["J"][idx] = spec.perf.kv.j
        m["M"][idx] = spec.kv_capacity
        m["MAXB"][idx] = int(spec.max_batch)
        m["MAXBN"][idx] = max(int(spec.max_batch), 1)
        cmax = spec.perf.decode.max_total_context(1, self.slo.atgt) or 1.0
        m["CMAXN"][idx] = max(cmax, 1.0)
        for k in _ROW_KEYS:
            m[k][idx] = np.nan if k in _NAN_KEYS else 0
        self._wid += 1
        return _Lane(self._wid, spec, idx)

    # ---- pool/lifecycle adapters (mirror-backed) ---------------------------

    def _spawn_lane(self, lane, t: float) -> None:
        self.m["t_w"][lane.idx] = t

    def _kill_lane(self, lane) -> List[int]:
        """Extraction in the reference's order: ongoing (join order), new
        batch (placement order), KV-preempted (preemption order). Row
        state is parked in the re-entrant sinks; the lifecycle's mark
        callback then stamps ``s_tpe``."""
        wi = lane.idx
        m = self.m
        sst = m["sst"][wi]
        parts = []
        for state, okey in ((2, "rjsq"), (1, "rnsq"), (3, "rpsq")):
            slots = np.nonzero(sst == state)[0]
            parts.append(slots[np.argsort(m[okey][wi][slots],
                                          kind="stable")])
        lost = []
        for slot in np.concatenate(parts):
            r = int(m["rid"][wi, slot])
            m["s_lo"][r] = m["rlo"][wi, slot]
            m["s_tds"][r] = m["rtds"][wi, slot]
            m["s_tf1"][r] = m["rtf1"][wi, slot]
            m["s_tpe"][r] = m["rtpe"][wi, slot]
            lost.append(r)
        m["sst"][wi] = 0
        return lost

    def _mark_rid(self, rid: int, t: float) -> None:
        self.m["s_tpe"][rid] = t
        self.h_pn[rid] += 1

    def _lane_load(self, lane) -> int:
        sst = self.m["sst"][lane.idx]
        return int(np.sum((sst == 1) | (sst == 2)))

    def _lane_idle(self, lane) -> bool:
        return not (self.m["sst"][lane.idx] > 0).any()

    # ---- the ColocatedTopology shim the pools call back into ---------------

    def requeue(self, rids, side: str = "serve") -> None:
        self._queue.extend(int(r) for r in rids)

    def backlog_len(self, side: str = "serve") -> int:
        return len(self._queue)

    def slo_window(self, side: str, t_now: float, window: float,
                   metric: str = "both") -> tuple:
        m = self.m
        t0 = t_now - window
        tfn = m["o_tfn"]
        inw = ~np.isnan(tfn) & (tfn >= t0)
        ids = np.nonzero(inw)[0]
        total = int(ids.size)
        ok = 0
        if total:
            ttft_ok = (m["o_tf1"][ids] - self.arrival[ids]) \
                <= self.slo.ttft
            has_dec = self.l_real[ids] > 1
            atgt_ok = np.ones(total, dtype=bool)
            d = ids[has_dec]
            atgt_ok[has_dec] = (m["o_tds"][d] / (self.l_real[d] - 1)) \
                <= self.slo.atgt
            if metric == "both":
                okm = ttft_ok & atgt_ok
            elif metric == "ttft":
                okm = ttft_ok
            elif metric == "atgt":
                okm = atgt_ok
            else:
                raise ValueError(f"unknown SLO metric {metric!r}")
            ok = int(okm.sum())
        if metric != "atgt":
            for rid in self._queue:
                if math.isnan(m["s_tf1"][rid]) \
                        and t_now - float(self.arrival[rid]) \
                        > self.slo.ttft:
                    total += 1
        return ok, total

    # ---- chunk orchestration -----------------------------------------------

    def _grid_beat(self, x: float) -> int:
        """First beat index b with G[b] >= x (the beat at which a
        time-armed transition fires under the reference's ``<= t`` test)."""
        return int(np.searchsorted(self.G, x, side="left"))

    def _boundary(self) -> None:
        """The host-side slice of one beat start: admit arrivals, fire
        market events, run ``begin_beat`` (boot onlining + reaps) — the
        reference's exact per-beat order. In-chunk beats run the admission
        step in-kernel; everything else is a no-op off-boundary by
        construction of the chunk cuts."""
        t = self.G[self.beat]
        while self.idx < self.n and self.arrival[self.idx] <= t:
            self._queue.append(self.idx)
            self.pool.note_arrival()
            self.idx += 1
        while self.eidx < len(self.events) \
                and self.events[self.eidx].t <= t:
            self.requeue(self.pool.on_reclaim(t, self.events[self.eidx]))
            self.eidx += 1
        self.pool.begin_beat(self, t)

    def _chunk_len(self) -> int:
        """Beats until the next fleet-mutation boundary (always >= 1: the
        boundary processing above already consumed everything due now)."""
        b = self.beat
        cands = [self.total_beats - b]
        if self.eidx < len(self.events):
            cands.append(self._grid_beat(self.events[self.eidx].t) - b)
        for dl in self.pool.life.condemned.values():
            cands.append(self._grid_beat(dl) - b)
        if self.managed:
            bpe = self.pool.beats_per_epoch
            cands.append(bpe - (self.pool.acc["beat"] % bpe))
            for bt in self.pool.booting:
                cands.append(self._grid_beat(bt[0]) - b)
        return max(min(cands), 1)

    def _pack(self, K: int) -> Dict:
        m = self.m
        W = self.W_cap
        mode = np.zeros(W, np.int64)
        rank = np.full(W, _BIG_I, np.int64)
        p2l = np.zeros(W, np.int64)
        serving = [ln for ln in self.pool.serving()
                   if ln.alive and not ln.draining]
        sset = {id(ln) for ln in serving}
        for p, ln in enumerate(serving):
            mode[ln.idx] = 2
            rank[ln.idx] = p
            p2l[p] = ln.idx
        for ln in self.pool.active():
            if id(ln) not in sset:
                mode[ln.idx] = 3
        q = np.zeros(self.qcap, np.int64)
        if self._queue:
            q[:len(self._queue)] = self._queue
        st = {k: v for k, v in m.items() if k not in _HOST_KEYS}
        st.update(
            mode=mode, rank=rank, p2l=p2l, q=q,
            t=np.float64(self.G[self.beat]), K=np.int64(K),
            idx=np.int64(self.idx), qlen=np.int64(len(self._queue)),
            seqc=np.int64(self.seqc), key=self.key, j=np.int64(0),
            busy_pk=np.int64(0), busy_fin=np.int64(0),
            empty_at=np.full(W, _BIG_I, np.int64), ovf=np.bool_(False),
            theta=np.float64(self.theta))
        return st

    def _pull(self, out) -> Tuple[int, int, int, np.ndarray]:
        for k in list(self.m):
            if k in _HOST_KEYS:
                continue
            # np.array(): device output buffers are read-only as views and
            # the mirrors are mutated by the lane adapters between chunks
            self.m[k] = np.array(out[k])
        # drain finished-undrained rows (state 5) from the row arrays to
        # the per-request output mirrors and recycle their slots; each
        # rid finishes exactly once, so the scatter is collision-free
        wf, sf = np.nonzero(self.m["sst"] == 5)
        if len(wf):
            r = self.m["rid"][wf, sf]
            self.m["o_lo"][r] = self.m["rlo"][wf, sf]
            self.m["o_tds"][r] = self.m["rtds"][wf, sf]
            self.m["o_tf1"][r] = self.m["rtf1"][wf, sf]
            self.m["o_tfn"][r] = self.m["rtfn"][wf, sf]
            self.m["sst"][wf, sf] = 0
        qlen = int(out["qlen"])
        q = np.asarray(out["q"])
        self._queue = [int(r) for r in q[:qlen]]
        self.idx = int(out["idx"])
        self.seqc = int(out["seqc"])
        self.key = out["key"]
        if bool(out["ovf"]):
            raise RuntimeError(
                "jax engine lane-slot overflow at the Bmax ceiling "
                "(KV-preempted backlog exceeded slot headroom); "
                "use engine='vectorized'")
        return (int(out["j"]), int(out["busy_pk"]), int(out["busy_fin"]),
                np.asarray(out["empty_at"]))

    def _settle(self, executed: int, busy_pk: int, busy_fin: int,
                empty_at: np.ndarray, arrivals: int) -> None:
        b0 = self.beat
        if self.managed:
            dts = [float(self.G[b0 + i + 1] - self.G[b0 + i])
                   for i in range(executed)]
            retiring: Dict[int, List] = {}
            for ln in list(self.pool.draining):
                ea = int(empty_at[ln.idx])
                if ea < executed:
                    retiring.setdefault(ea, []).append(ln)
            self.pool.absorb_chunk(self, self.G[b0 + executed], dts,
                                   retiring, busy_fin, busy_pk, arrivals,
                                   len(self._queue))
        self.beat = b0 + executed

    def _host_drained(self) -> bool:
        return (self.idx >= self.n and not self._queue
                and not (self.m["sst"] > 0).any())

    def _ensure_queue(self, K: int) -> None:
        """Pre-size the queue for every request that can be queued during
        the next K beats: the current backlog plus the chunk window's
        arrivals (the trace is known, so in-kernel overflow is impossible
        and the kernel needs no queue-growth path)."""
        hi = int(np.searchsorted(self.arrival,
                                 self.G[min(self.beat + K,
                                            self.total_beats)],
                                 side="right")) if self.n else 0
        need = len(self._queue) + max(hi - self.idx, 0)
        while self.qcap < need:
            self.qcap = min(self.qcap * 2, max(self.n, 1))

    def step_prepare(self):
        """One lockstep round's host half: process the boundary and return
        the packed state + chunk length (0 when this sim is finished)."""
        if self.done:
            return self._pack(0), 0
        self._boundary()
        K = self._chunk_len()
        self._ensure_queue(K)
        self._arr0 = self.idx
        return self._pack(K), K

    def step_absorb(self, out) -> None:
        if self.done:
            return
        executed, busy_pk, busy_fin, empty_at = self._pull(out)
        if executed == 0:
            raise RuntimeError("chunked kernel made no progress")
        self._settle(executed, busy_pk, busy_fin, empty_at,
                     self.idx - self._arr0)
        if self.beat >= self.total_beats or self._host_drained():
            self.done = True

    def run(self) -> None:
        def mk_kern():
            return _chunk_kernel(self.n, self.W_cap, self.B, self.qcap,
                                 self.hb, self.gamma,
                                 float(self.slo.ttft),
                                 float(self.slo.atgt), self.policy_name,
                                 batched=False, edf=self.edf,
                                 tagged=self.tagged)

        def call(kern, st):
            m = self.m
            return kern(st, self.arrival, self.l_in, self.l_real,
                        m["s_lo"], m["s_tds"], m["s_tf1"], m["s_tpe"],
                        self.rank_r, self.ttft_r, self.atgt_r)

        sig = None
        kern = None
        with enable_x64():
            while not self.done:
                st, K = self.step_prepare()
                cur = (self.W_cap, self.B, self.qcap)
                if cur != sig:    # shape growth: new compiled variant
                    kern, sig = mk_kern(), cur
                out = call(kern, st)
                # slot exhaustion: regrow and re-run the chunk — the
                # kernel is pure and mirrors are untouched until absorb,
                # so re-execution replays the identical decision stream
                while bool(out["ovf"]) and self.B < self.Bmax:
                    self._ensure_rows(self.B * 2)
                    kern = mk_kern()
                    sig = (self.W_cap, self.B, self.qcap)
                    st = self._pack(K)
                    out = call(kern, st)
                self.step_absorb(out)

    # ---- results -----------------------------------------------------------

    def finish(self):
        """Flush lane-resident and queued re-entrant rows into the
        per-request outputs; returns (l_out, tds, t_first, t_fin,
        t_preempted) arrays."""
        m = self.m
        t_pre = np.full(self.n, np.nan)
        for w, slot in zip(*np.nonzero(m["sst"] > 0)):
            r = int(m["rid"][w, slot])
            m["o_lo"][r] = m["rlo"][w, slot]
            m["o_tds"][r] = m["rtds"][w, slot]
            m["o_tf1"][r] = m["rtf1"][w, slot]
            t_pre[r] = m["rtpe"][w, slot]
        for r in self._queue:
            m["o_lo"][r] = m["s_lo"][r]
            m["o_tds"][r] = m["s_tds"][r]
            m["o_tf1"][r] = m["s_tf1"][r]
            t_pre[r] = m["s_tpe"][r]
        return m["o_lo"], m["o_tds"], m["o_tf1"], m["o_tfn"], t_pre


def _pooled_report(sim: _PooledSim, writeback: bool):
    o_lo, o_tds, o_tf1, o_tfn, t_pre = sim.finish()
    if writeback:
        for pos, r in enumerate(sim.trace):
            r.l_pred = int(sim.l_real[pos])
            r.l_out = int(o_lo[pos])
            r.t_decode_spent = float(o_tds[pos])
            tf = o_tf1[pos]
            r.t_first_token = None if math.isnan(tf) else float(tf)
            tp = t_pre[pos]
            r.t_preempted = None if math.isnan(tp) else float(tp)
            pn = int(sim.h_pn[pos])
            if pn:
                r.preempt_count += pn
            te = o_tfn[pos]
            if not math.isnan(te):
                r.t_finish = float(te)
                r.state = ReqState.FINISHED
    rep = _report_from_arrays(sim.scenario, sim.specs0, len(sim.specs0),
                              sim.arrival, sim.l_real, o_lo, o_tds, o_tf1,
                              o_tfn)
    pool = sim.pool
    if sim.managed:
        pol = sim.scaling_policy
        rep.scaling = getattr(pol, "name", type(pol).__name__)
        rep.peak_workers = pool.peak
        rep.gpu_seconds = pool.gpu_s
        rep.gpu_cost = pool.gpu_s
        rep.spot_gpu_seconds = pool.spot_gpu_s
        rep.epochs = {"serve": pool.epochs}
    else:
        rep.peak_workers = sim.init_W
        # every worker that served counts, including reclaimed ones
        rep.gpu_cost = sum(ln.spec.n_accelerators
                           for ln in pool.workers) + pool.retired_cost
    rep.preempted_workers = pool.killed
    rep.drained_ok = pool.drained_ok
    rep.requeued = pool.requeued
    rep.moves = 0
    rep.beats = sim.beat        # benchmark side channel (not in row())
    if writeback and sim.scenario.tenants is not None:
        from repro.serving.tenants import tenant_attainment, tenant_rows
        rep.attainment = tenant_attainment(sim.trace)
        rep.tenant_rows = tenant_rows(sim.trace,
                                      list(sim.scenario.tenants),
                                      rep.gpu_cost)
    return rep


def _run_pooled(scenario, seed: Optional[int] = None):
    sim = _PooledSim(scenario, seed)
    sim.run()
    return _pooled_report(sim, writeback=True)


def run_colocated_jax(scenario, seed: Optional[int] = None):
    """Run a colocated ``Scenario`` on the compiled engine, mutate the
    trace's ``Request`` objects with the outcome (the same contract as the
    other engines) and return the ``RunReport``. Also returns the executed
    beat count via the report-side channel ``rep.beats`` attribute used by
    the benchmarks."""
    from repro.serving import api

    scenario = api.resolve_scenario(scenario)
    specs = check_jax_envelope(scenario)
    trace = scenario.materialize()
    check_trace_session_free(trace)
    ordered, arrival, l_in, l_real = _trace_arrays(trace)
    multi = scenario.tenants is not None and len(scenario.tenants) > 1
    if len(ordered) == 0:
        if not _legacy_ok(scenario, specs):
            # pooled fleets still accrue billing/epochs on an empty trace;
            # the bit-for-bit numpy engine handles that without a kernel
            from repro.serving.fastsim import run_colocated_vectorized
            return run_colocated_vectorized(scenario, seed)
        # nothing to simulate: XLA rejects gathers into a size-0 trace
        # axis, and the reference drains immediately anyway
        empty = np.array([])
        rep = _report_from_arrays(scenario, specs, len(specs), empty,
                                  empty, empty, empty, empty, empty)
        rep.beats = 0
        return rep
    if not _legacy_ok(scenario, specs):
        # KV pressure / po2 / managed fleets / spot markets: the chunked
        # kernel with the host-side pool driver
        return _run_pooled(scenario, seed)
    rank_r, ttft_r, atgt_r, tagged = _tenant_arrays(ordered)
    # x64 is scoped, not a process-global flag: the serving models run in
    # jax's default 32-bit mode and must not see this engine's precision
    with enable_x64():
        fn = _kernel_for(scenario, specs, trace, batched=False,
                         edf=multi, tagged=tagged)
        l_out, tds, t_first, t_fin, beats = (
            np.asarray(x) for x in fn(arrival, l_in, l_real, len(specs),
                                      rank_r, ttft_r, atgt_r))
    for pos, r in enumerate(ordered):
        r.l_pred = int(l_real[pos])
        r.l_out = int(l_out[pos])
        r.t_decode_spent = float(tds[pos])
        tf = t_first[pos]
        r.t_first_token = None if math.isnan(tf) else float(tf)
        te = t_fin[pos]
        if not math.isnan(te):
            r.t_finish = float(te)
            r.state = ReqState.FINISHED
    rep = _report_from_arrays(scenario, specs, len(specs), arrival, l_real,
                              l_out, tds, t_first, t_fin)
    rep.beats = int(beats)      # benchmark side channel (not in row())
    if scenario.tenants is not None:
        from repro.serving.tenants import tenant_attainment, tenant_rows
        rep.attainment = tenant_attainment(ordered)
        rep.tenant_rows = tenant_rows(ordered, list(scenario.tenants),
                                      rep.gpu_cost)
    return rep


def run_candidate_batch(scenarios) -> List:
    """Evaluate a batch of fleet-size candidates of the SAME workload /
    spec / policy in one vmapped compiled call — the whole bracket of
    ``optimize``'s search at once. Returns one ``RunReport`` per scenario
    (candidate traces are not mutated; the search only reads reports —
    which is also why multi-tenant candidates keep the planning-SLO
    headline attainment and carry no per-tenant rows: ``optimize``
    evaluates multi-tenant scenarios sequentially instead)."""
    from repro.serving import api

    if not scenarios:
        return []
    scenarios = [api.resolve_scenario(sc) for sc in scenarios]
    spec_lists = [check_jax_envelope(sc) for sc in scenarios]
    if not all(_legacy_ok(sc, sl)
               for sc, sl in zip(scenarios, spec_lists)):
        # pooled candidates carry host-side fleet state machines that the
        # fleet-size vmap cannot batch; run them through the chunked
        # driver one at a time (each still amortizes its kernel)
        return [run_colocated_jax(sc) for sc in scenarios]
    base = scenarios[0]
    base_spec = spec_lists[0][0]

    def coef_key(s):
        return (s.perf.prefill.k1, s.perf.prefill.c1, s.perf.decode.k2,
                s.perf.decode.c2, s.perf.decode.c3, s.max_batch,
                s.n_accelerators)

    for sl in spec_lists:
        if any(coef_key(s) != coef_key(base_spec) for s in sl):
            # vmap shares one coefficient set across the batch
            raise ValueError("run_candidate_batch needs homogeneous "
                             "candidates of one worker spec")
    W_max = max(len(sl) for sl in spec_lists)
    trace = base.materialize()
    check_trace_session_free(trace)
    _ordered, arrival, l_in, l_real = _trace_arrays(trace)
    multi = base.tenants is not None and len(base.tenants) > 1
    rank_r, ttft_r, atgt_r, tagged = _tenant_arrays(_ordered)
    padded = [base_spec] * W_max
    n_active = np.array([len(sl) for sl in spec_lists], dtype=np.int64)
    with enable_x64():
        fn = _kernel_for(base, padded, trace, batched=True,
                         edf=multi, tagged=tagged)
        l_out, tds, t_first, t_fin, beats = (
            np.asarray(x) for x in fn(arrival, l_in, l_real, n_active,
                                      rank_r, ttft_r, atgt_r))
    reps = []
    for i in range(len(scenarios)):
        rep = _report_from_arrays(base, padded, int(n_active[i]), arrival,
                                  l_real, l_out[i], tds[i], t_first[i],
                                  t_fin[i])
        rep.beats = int(beats[i])   # benchmark side channel
        reps.append(rep)
    return reps


def run_policy_candidate_batch(scenarios) -> List:
    """Evaluate a batch of policy-knob candidates (same workload and spec
    family, differing theta / scaling parameters) in lockstep: each round
    advances every live candidate's next chunk through ONE vmapped
    compiled call, then settles each candidate's fleet boundary on the
    host. Finished candidates ride along with zero-length chunks until the
    batch drains. Candidate traces are never mutated; the policy search
    only reads the returned reports."""
    if not scenarios:
        return []
    if len(scenarios) == 1:
        sim = _PooledSim(scenarios[0])
        sim.run()
        return [_pooled_report(sim, writeback=False)]
    sims = [_PooledSim(sc) for sc in scenarios]
    s0 = sims[0]
    homog = all(
        s.n == s0.n and s.B == s0.B and s.Bmax == s0.Bmax
        and s.hb == s0.hb
        and s.gamma == s0.gamma and s.policy_name == s0.policy_name
        and float(s.slo.ttft) == float(s0.slo.ttft)
        and float(s.slo.atgt) == float(s0.slo.atgt)
        and s.edf == s0.edf and s.tagged == s0.tagged
        for s in sims[1:])
    if not homog:
        # heterogeneous statics cannot share one compiled kernel
        for s in sims:
            s.run()
        return [_pooled_report(s, writeback=False) for s in sims]
    with enable_x64():
        while not all(s.done for s in sims):
            lens = []
            for s in sims:
                if s.done:
                    lens.append(0)
                    continue
                s._boundary()
                lens.append(s._chunk_len())
                s._arr0 = s.idx
            cap = max(s.W_cap for s in sims)
            for s, k in zip(sims, lens):  # lockstep: one shared lane axis
                s._ensure_cap(cap)
                s._ensure_queue(k)
            qc = max(s.qcap for s in sims)
            for s in sims:                # ...and a shared queue axis
                s.qcap = qc

            def round_out():
                sts = [s._pack(k) for s, k in zip(sims, lens)]
                stb = {k: np.stack([np.asarray(st[k]) for st in sts])
                       for k in sts[0]}
                ops = {k: np.stack([s.m[k] for s in sims])
                       for k in ("s_lo", "s_tds", "s_tf1", "s_tpe")}
                kern = _chunk_kernel(s0.n, cap, s0.B, s0.qcap,
                                     s0.hb, s0.gamma,
                                     float(s0.slo.ttft),
                                     float(s0.slo.atgt),
                                     s0.policy_name, batched=True,
                                     edf=s0.edf, tagged=s0.tagged)
                out = kern(stb, s0.arrival, s0.l_in, s0.l_real,
                           ops["s_lo"], ops["s_tds"], ops["s_tf1"],
                           ops["s_tpe"], s0.rank_r, s0.ttft_r, s0.atgt_r)
                return {k: np.asarray(v) for k, v in out.items()}

            outs = round_out()
            # slot exhaustion in any candidate: regrow every sim to the
            # shared larger capacity and re-run the round
            while outs["ovf"].any() and s0.B < s0.Bmax:
                newB = min(s0.B * 2, s0.Bmax)
                for s in sims:
                    s._ensure_rows(newB)
                outs = round_out()
            for ci, s in enumerate(sims):
                s.step_absorb({k: v[ci] for k, v in outs.items()})
    return [_pooled_report(s, writeback=False) for s in sims]
