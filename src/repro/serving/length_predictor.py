"""Output-length prediction (paper §2.3): the deliberately-naive, *unbiased*
bucketed conditional mean over historical data, plus the conditional
re-prediction used by Algorithm 2 when a request overruns its estimate
(E[l_out | l_out > current, bucket])."""
from __future__ import annotations

import bisect
from typing import List, Sequence

import numpy as np


class LengthPredictor:
    def __init__(self, bucket_edges: Sequence[int] = (64, 128, 256, 512,
                                                      1024, 2048, 4096)):
        self.edges = list(bucket_edges)
        self.samples: List[List[int]] = [[] for _ in range(len(self.edges) + 1)]
        self.default = 128.0

    def _bucket(self, l_in: int) -> int:
        return bisect.bisect_right(self.edges, l_in)

    def observe(self, l_in: int, l_out: int) -> None:
        b = self.samples[self._bucket(l_in)]
        b.append(l_out)
        if len(b) > 20000:
            del b[:10000]

    def fit(self, l_ins: Sequence[int], l_outs: Sequence[int]) -> None:
        for i, o in zip(l_ins, l_outs):
            self.observe(int(i), int(o))

    def predict(self, l_in: int) -> int:
        s = self.samples[self._bucket(l_in)]
        if not s:
            pooled = [x for b in self.samples for x in b]
            return int(np.mean(pooled)) if pooled else int(self.default)
        return int(np.mean(s))

    def repredict(self, l_in: int, generated: int) -> int:
        """Conditional mean of the REMAINING tokens given l_out > generated."""
        s = [x for x in self.samples[self._bucket(l_in)] if x > generated]
        if not s:
            return max(generated // 2, 16)      # tail fallback: geometric-ish
        return max(int(np.mean(s)) - generated, 1)

    def bias(self) -> float:
        """Mean signed error on the training data (should be ~0: unbiased)."""
        errs = []
        for bi, s in enumerate(self.samples):
            if s:
                m = np.mean(s)
                errs.extend([m - x for x in s])
        return float(np.mean(errs)) if errs else 0.0
