"""Per-worker continuous-batching engine with a paged KV cache (vLLM-style).

Slot-based execution over a page pool: each running request owns a slot and a
list of pages (block table). Iteration-level scheduling (Orca-style): new
requests run a prefill iteration (preempting decode, as vLLM does — the
paper's constraint (d) budgets exactly this), otherwise all running slots
advance one decode step via paged attention. On TPU the paged Pallas kernel
is the attention path; on CPU the jnp oracle.

Supports dense/GQA transformer archs (the paper's Llama-2 family). Execution
is real JAX compute — iteration wall-times feed the TraceBuffer that fits the
paper's performance models (Eqs. 1-3)."""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, Family, PosEmb
from repro.core.perf_model import TraceBuffer
from repro.core.request import ReqState, Request
from repro.kernels.decode_attention import paged_decode_attention
from repro.models.common import gated_mlp, rms_norm, rope, sinusoidal_pos
from repro.models.model import LM, ExecConfig


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    page_size: int = 16
    n_pages: int = 512
    max_pages_per_seq: int = 64
    max_new_tokens: int = 2048
    use_pallas: bool = False        # pallas paged kernel (interpret on CPU)
    prefill_chunk: int = 0          # >0: Sarathi-style chunked prefill — at
                                    # most this many prompt tokens per
                                    # iteration, bounding decode preemption
                                    # stalls (shrinks constraint (d) pressure)


class PagedEngine:
    """One worker's execution engine."""

    def __init__(self, arch: ArchConfig, params, cfg: EngineConfig,
                 time_fn: Callable[[], float] = time.perf_counter):
        assert arch.family in (Family.DENSE, Family.AUDIO), \
            "engine path supports dense GQA archs (the paper's models)"
        self.arch = arch
        self.params = params
        self.cfg = cfg
        self.time_fn = time_fn
        self.traces = TraceBuffer()
        L = arch.n_layers
        hd = arch.resolved_head_dim
        self.kv_k = jnp.zeros((L, cfg.n_pages, cfg.page_size,
                               arch.n_kv_heads, hd), jnp.float32)
        self.kv_v = jnp.zeros_like(self.kv_k)
        self.block_tables = np.zeros((cfg.max_batch, cfg.max_pages_per_seq),
                                     np.int32)
        self.lengths = np.zeros((cfg.max_batch,), np.int32)
        self.free_pages = list(range(cfg.n_pages - 1, 0, -1))  # page 0 = null
        self.slots: List[Optional[Request]] = [None] * cfg.max_batch
        self.waiting: List[Request] = []
        self._prefill_jit = jax.jit(self._prefill_fn)
        self._decode_jit = jax.jit(self._decode_fn)
        self._chunk_jit = jax.jit(self._chunk_fn)
        self.kv_bytes_per_token = 2 * L * arch.n_kv_heads * hd * 4

    # ---- admission / state --------------------------------------------------
    def can_admit(self, n_tokens_total: int) -> bool:
        pages_needed = n_tokens_total // self.cfg.page_size + 2
        return (any(s is None for s in self.slots)
                and len(self.free_pages) >= pages_needed)

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    @property
    def running(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    def kv_used_bytes(self) -> float:
        return float(self.lengths.sum()) * self.kv_bytes_per_token / 2

    # ---- jitted model math --------------------------------------------------
    def _prefill_fn(self, params, tokens, logit_pos):
        """tokens: (1, S_bucket) -> (logits (V,), ks, vs (L, S, Hkv, hd)).
        S is a power-of-two bucket; real length = logit_pos + 1 (causal
        attention makes the tail padding inert)."""
        model = LM(self.arch, exec_cfg=ExecConfig(scan_layers=True))
        logits, cache = model.prefill(params, tokens=tokens,
                                      s_max=tokens.shape[1],
                                      logit_pos=logit_pos)
        c0 = cache[0]
        return (logits[0], c0["k_big"][:, 0].astype(jnp.float32),
                c0["v_big"][:, 0].astype(jnp.float32))

    def _decode_fn(self, params, kv_k, kv_v, block_tables, lengths, tokens,
                   active):
        """One decode iteration for every slot (inactive ones masked).
        Returns (logits, new kv_k, new kv_v)."""
        a = self.arch
        hd = a.resolved_head_dim
        x = params["embed"][tokens].astype(jnp.float32)
        if a.tie_embeddings:
            x = x * math.sqrt(a.d_model)
        if a.pos_emb == PosEmb.SINUSOIDAL:
            x = x + sinusoidal_pos(lengths, a.d_model).astype(x.dtype)
        page_ids = jnp.take_along_axis(
            block_tables, (lengths // self.cfg.page_size)[:, None],
            axis=1)[:, 0]
        offs = lengths % self.cfg.page_size
        msk = active[:, None, None]
        for i in range(a.n_layers):
            p = jax.tree.map(lambda t: t[i], params["seg0"])
            h = rms_norm(x, p["ln1"], a.norm_eps)
            q = (h @ p["wq"]).reshape(-1, a.n_heads, hd)
            k = (h @ p["wk"]).reshape(-1, a.n_kv_heads, hd)
            v = (h @ p["wv"]).reshape(-1, a.n_kv_heads, hd)
            if a.qkv_bias:
                q = q + p["bq"].reshape(a.n_heads, hd)
                k = k + p["bk"].reshape(a.n_kv_heads, hd)
                v = v + p["bv"].reshape(a.n_kv_heads, hd)
            if a.pos_emb == PosEmb.ROPE:
                q = rope(q[:, None], lengths[:, None], a.rope_theta)[:, 0]
                k = rope(k[:, None], lengths[:, None], a.rope_theta)[:, 0]
            kv_k = kv_k.at[i, page_ids, offs].set(
                jnp.where(msk, k, kv_k[i, page_ids, offs]))
            kv_v = kv_v.at[i, page_ids, offs].set(
                jnp.where(msk, v, kv_v[i, page_ids, offs]))
            att = paged_decode_attention(
                q, kv_k[i], kv_v[i], block_tables, lengths + 1,
                use_pallas=self.cfg.use_pallas, interpret=self.cfg.use_pallas)
            x = x + att.reshape(x.shape[0], -1) @ p["wo"]
            h = rms_norm(x, p["ln2"], a.norm_eps)
            x = x + gated_mlp(h, p["wg"], p["wu"], p["wd"], a.act)
        x = rms_norm(x, params["final_ln"], a.norm_eps)
        head = params["embed"].T if a.tie_embeddings else params["head"]
        return x @ head.astype(x.dtype), kv_k, kv_v

    # ---- page management ----------------------------------------------------
    def _alloc_slot(self, req: Request, n_tokens: int) -> int:
        slot = self.slots.index(None)
        pages = (n_tokens + self.cfg.page_size - 1) // self.cfg.page_size
        assert len(self.free_pages) >= pages
        tbl = np.zeros((self.cfg.max_pages_per_seq,), np.int32)
        for j in range(pages):
            tbl[j] = self.free_pages.pop()
        self.block_tables[slot] = tbl
        self.lengths[slot] = 0
        self.slots[slot] = req
        return slot

    def _ensure_page(self, slot: int) -> bool:
        pos = int(self.lengths[slot])
        pi = pos // self.cfg.page_size
        if pi >= self.cfg.max_pages_per_seq:
            return False
        if self.block_tables[slot, pi] == 0:
            if not self.free_pages:
                return False
            self.block_tables[slot, pi] = self.free_pages.pop()
        return True

    def _free_slot(self, slot: int) -> None:
        for pid in self.block_tables[slot]:
            if pid > 0:
                self.free_pages.append(int(pid))
        self.block_tables[slot] = 0
        self.lengths[slot] = 0
        self.slots[slot] = None

    # ---- iteration-level scheduling -----------------------------------------
    def step(self, now: Optional[float] = None) -> List[Request]:
        """Run ONE iteration (a prefill batch or a decode batch). Returns the
        requests that finished."""
        finished: List[Request] = []
        t0 = self.time_fn()
        if self.waiting and self.can_admit(self.waiting[0].l_in + 8):
            total_in, batch = 0, []
            while self.waiting and self.can_admit(self.waiting[0].l_in + 8):
                r = self.waiting.pop(0)
                batch.append(r)
                total_in += r.l_in
                self._run_prefill(r)
            t1 = self.time_fn()
            self.traces.record_prefill(total_in, t1 - t0)
            for r in batch:
                r.t_first_token = now if now is not None else t1
                r.state = ReqState.DECODING
            return finished
        active_slots = [i for i, r in enumerate(self.slots) if r is not None]
        if not active_slots:
            return finished
        for i in list(active_slots):
            if not self._ensure_page(i):
                r = self.slots[i]          # out of pages: preempt youngest
                self._free_slot(i)
                r.l_out = 0
                r.state = ReqState.QUEUED
                self.waiting.insert(0, r)
                active_slots.remove(i)
        if not active_slots:
            return finished
        tokens = np.zeros((self.cfg.max_batch,), np.int64)
        for i in active_slots:
            tokens[i] = self.slots[i].tokens[-1]
        active = np.zeros((self.cfg.max_batch,), bool)
        active[active_slots] = True
        logits, self.kv_k, self.kv_v = self._decode_jit(
            self.params, self.kv_k, self.kv_v,
            jnp.asarray(self.block_tables), jnp.asarray(self.lengths),
            jnp.asarray(tokens), jnp.asarray(active))
        nxt = np.asarray(jnp.argmax(logits, -1))
        t1 = self.time_fn()
        total_ctx = int(self.lengths[active_slots].sum()) + len(active_slots)
        self.traces.record_decode(len(active_slots), total_ctx, t1 - t0)
        for i in active_slots:
            r = self.slots[i]
            self.lengths[i] += 1
            r.l_out += 1
            r.t_decode_spent += (t1 - t0)
            r.tokens.append(int(nxt[i]))
            self.traces.record_kv(
                r.context, r.context * self.kv_bytes_per_token / 2)
            if r.l_out >= min(r.l_real or self.cfg.max_new_tokens,
                              self.cfg.max_new_tokens):
                r.state = ReqState.FINISHED
                r.t_finish = now if now is not None else t1
                finished.append(r)
                self._free_slot(i)
        return finished

    def _chunk_fn(self, params, chunk_toks, k_ctx, v_ctx, ctx_len,
                  logit_pos):
        """One chunked-prefill step: chunk tokens attend to the gathered
        context KV (q_offset = ctx) + causally within the chunk.
        Returns (logits at logit_pos, chunk ks, vs: (L, C, Hkv, hd))."""
        import math as _m
        from repro.kernels.flash_attention import flash_attention_ref
        from repro.models.common import gated_mlp, rms_norm, rope
        a = self.arch
        hd = a.resolved_head_dim
        x = params["embed"][chunk_toks].astype(jnp.float32)[None]  # (1,C,D)
        if a.tie_embeddings:
            x = x * _m.sqrt(a.d_model)
        c = x.shape[1]
        positions = ctx_len + jnp.arange(c)
        ks_out, vs_out = [], []
        for i in range(a.n_layers):
            p = jax.tree.map(lambda t: t[i], params["seg0"])
            h = rms_norm(x, p["ln1"], a.norm_eps)
            q = (h @ p["wq"]).reshape(1, c, a.n_heads, hd)
            k = (h @ p["wk"]).reshape(1, c, a.n_kv_heads, hd)
            v = (h @ p["wv"]).reshape(1, c, a.n_kv_heads, hd)
            if a.qkv_bias:
                q = q + p["bq"].reshape(a.n_heads, hd)
                k = k + p["bk"].reshape(a.n_kv_heads, hd)
                v = v + p["bv"].reshape(a.n_kv_heads, hd)
            if a.pos_emb == PosEmb.ROPE:
                q = rope(q, positions, a.rope_theta)
                k = rope(k, positions, a.rope_theta)
            ks_out.append(k[0])
            vs_out.append(v[0])
            k_all = jnp.concatenate([k_ctx[i][None], k], axis=1)
            v_all = jnp.concatenate([v_ctx[i][None], v], axis=1)
            kv_len = (ctx_len + c) * jnp.ones((1,), jnp.int32)
            att = flash_attention_ref(q, k_all, v_all, causal=True,
                                      q_offset=ctx_len, kv_len=kv_len)
            x = x + att.reshape(1, c, -1) @ p["wo"]
            h = rms_norm(x, p["ln2"], a.norm_eps)
            x = x + gated_mlp(h, p["wg"], p["wu"], p["wd"], a.act)
        x = rms_norm(x, params["final_ln"], a.norm_eps)
        head = params["embed"].T if a.tie_embeddings else params["head"]
        logits = x[0, logit_pos] @ head.astype(x.dtype)
        return logits, jnp.stack(ks_out), jnp.stack(vs_out)

    def _gather_ctx_kv(self, slot: int, ctx: int):
        """Contiguous (L, ctx_pad, Hkv, hd) views of this slot's pages."""
        n_pages = (ctx + self.cfg.page_size - 1) // self.cfg.page_size
        n_pages = max(n_pages, 1)
        pages = self.block_tables[slot][:n_pages]
        k = self.kv_k[:, pages].reshape(self.arch.n_layers,
                                        n_pages * self.cfg.page_size,
                                        self.arch.n_kv_heads, -1)
        v = self.kv_v[:, pages].reshape(self.arch.n_layers,
                                        n_pages * self.cfg.page_size,
                                        self.arch.n_kv_heads, -1)
        return k, v

    def _write_kv(self, slot: int, start: int, ks, vs) -> None:
        n = ks.shape[1]
        pos = np.arange(start, start + n)
        pages = self.block_tables[slot][pos // self.cfg.page_size]
        offs = pos % self.cfg.page_size
        self.kv_k = self.kv_k.at[:, pages, offs].set(
            ks.astype(self.kv_k.dtype))
        self.kv_v = self.kv_v.at[:, pages, offs].set(
            vs.astype(self.kv_v.dtype))

    def _run_prefill(self, req: Request) -> None:
        s = req.l_in
        slot = self._alloc_slot(req, s + 8)
        toks = list(req.tokens[:s]) if req.tokens else \
            list(np.random.default_rng(req.id).integers(
                2, self.arch.vocab, s))
        req.tokens = [int(t) for t in toks]
        cchunk = self.cfg.prefill_chunk
        if cchunk and s > cchunk:
            # Sarathi-style: process the prompt in fixed-size chunks, each
            # attending to the already-written context pages
            logits = None
            done = 0
            while done < s:
                n = min(cchunk, s - done)
                bucket = max(8, 1 << (n - 1).bit_length())
                chunk = toks[done:done + n] + [0] * (bucket - n)
                k_ctx, v_ctx = self._gather_ctx_kv(slot, max(done, 1))
                # slice to exactly the valid context so chunk positions in
                # the concatenated KV line up with their logical positions
                logits, ks, vs = self._chunk_jit(
                    self.params, jnp.asarray(chunk), k_ctx[:, :done],
                    v_ctx[:, :done], done, n - 1)
                self._write_kv(slot, done, ks[:, :n], vs[:, :n])
                done += n
        else:
            bucket = max(8, 1 << (s - 1).bit_length())  # pow-2 length buckets
            padded = toks + [0] * (bucket - s)
            logits, ks, vs = self._prefill_jit(
                self.params, jnp.asarray([padded]), s - 1)
            self._write_kv(slot, 0, ks[:, :s], vs[:, :s])
        self.lengths[slot] = s
        req.tokens.append(int(np.asarray(jnp.argmax(logits, -1))))
        req.l_out = 1      # the prefill emits the first token (TTFT)
