"""Multi-tenant serving: per-class SLOs over one shared fleet.

Production clusters serve many models and traffic classes on a shared
pool — interactive chat next to batch/eval traffic, LoRA fine-tunes
multiplexed on shared base workers. A :class:`TenantSpec` names one such
traffic class: its own workload, its own TTFT/ATGT SLO, an admission
priority, and optionally a LoRA adapter. ``Scenario(tenants=[...])``
accepts a list of them in place of the scalar ``workload``/``slo`` pair;
the merged trace tags every :class:`~repro.core.request.Request` with
its tenant, and the queue discipline becomes priority-then-EDF
(earliest deadline first by SLO slack) so batch-tier traffic soaks
trough capacity without breaking interactive TTFT.

Placement keeps the scalar engine's bit-for-bit-pinned kernels by
splitting SLO roles:

* the *planning SLO* (:func:`planning_slo` — the strictest TTFT/ATGT
  across tenants) parameterizes worker-level scoring (capacity_norm);
* the per-request constraint budgets (constraints (b)/(c)/(d) of
  §4.2) read each request's own tenant budgets, stamped on the request
  at merge time (``slo_ttft``/``slo_atgt``), with ``inf`` falling back
  to the planning SLO so untagged traces are arithmetically untouched;
* *attainment* is judged per tenant against each tenant's own SLO at
  reporting time (:func:`tenant_rows`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.request import Request
from repro.core.slo import SLO
from repro.serving.workload import mixture_trace

__all__ = ["TenantSpec", "planning_slo", "materialize_tenants",
           "tenant_attainment", "tenant_rows"]


@dataclasses.dataclass
class TenantSpec:
    """One traffic class sharing the fleet.

    ``workload`` is a materialized trace or a zero-arg factory (the same
    contract as ``Scenario.workload``); ``priority`` breaks admission
    ties (higher places first; EDF deadline ordering within a priority
    level); ``model`` is a descriptive label for reporting; ``lora``
    names an adapter multiplexed on shared base workers (reference
    engine only — workers need ``lora_slots``); ``tier`` is
    ``"interactive"`` or ``"batch"``; ``attain_target`` optionally
    overrides the fleet-wide attainment floor ``optimize()`` enforces
    for this tenant."""
    name: str
    workload: object
    slo: SLO
    priority: int = 0
    model: str = ""
    lora: Optional[str] = None
    tier: str = "interactive"
    attain_target: Optional[float] = None

    def materialize(self) -> List[Request]:
        w = self.workload
        return list(w() if callable(w) else w)


def planning_slo(tenants: Sequence[TenantSpec]) -> SLO:
    """The fleet-planning SLO: strictest TTFT and ATGT across tenants.

    Worker-level scoring (capacity_norm's context normalization) uses one
    SLO per worker; taking the strictest keeps the scalar placement
    kernels intact while per-request budgets relax constraints (b)-(d)
    for looser tenants. For a single tenant this is exactly its own SLO,
    which is what makes ``Scenario(tenants=[one])`` reproduce the scalar
    path bit-for-bit."""
    if not tenants:
        raise ValueError("tenants must be non-empty")
    return SLO(ttft=min(t.slo.ttft for t in tenants),
               atgt=min(t.slo.atgt for t in tenants))


def materialize_tenants(tenants: Sequence[TenantSpec]) -> List[Request]:
    """Materialize every tenant's workload, merge the streams with
    :func:`repro.serving.workload.mixture_trace` (stable arrival-order
    tie-break), and stamp each request with its tenant's priority and
    SLO budgets."""
    merged = mixture_trace([t.materialize() for t in tenants])
    for r in merged:
        spec = tenants[r.tenant]
        r.priority = int(spec.priority)
        r.slo_ttft = float(spec.slo.ttft)
        r.slo_atgt = float(spec.slo.atgt)
    return merged


def _request_ok(r: Request) -> bool:
    """SLO judgement against the request's own tenant budgets (unfinished
    requests count as misses, like ``slo_attainment``)."""
    if r.t_finish is None:
        return False
    t1 = r.ttft()
    if t1 is not None and not (t1 <= r.slo_ttft):
        return False
    t2 = r.atgt()
    if t2 is not None and not (t2 <= r.slo_atgt):
        return False
    return True


def tenant_attainment(trace: Sequence[Request]) -> float:
    """Fleet attainment with every request judged against its own
    tenant's SLO (the multi-tenant headline number)."""
    if not trace:
        return 1.0
    return sum(1 for r in trace if _request_ok(r)) / len(trace)


def tenant_rows(trace: Sequence[Request], tenants: Sequence[TenantSpec],
                gpu_cost: float) -> List[Dict]:
    """Per-tenant report rows: attainment vs the tenant's own SLO, p99
    TTFT/ATGT over its finished requests, mean queue delay (time from
    arrival to first token), and the tenant's gpu-cost share (total
    fleet cost split by processed-token share: ``l_in + l_out``)."""
    tokens = [0.0] * len(tenants)
    for r in trace:
        tokens[r.tenant] += r.l_in + r.l_out
    tok_total = sum(tokens) or 1.0
    rows: List[Dict] = []
    for k, spec in enumerate(tenants):
        reqs = [r for r in trace if r.tenant == k]
        fin = [r for r in reqs if r.t_finish is not None]
        ttfts = [r.ttft() for r in fin if r.t_first_token is not None]
        atgts = [a for a in (r.atgt() for r in fin) if a is not None]
        ok = sum(1 for r in reqs if _request_ok(r))
        share = tokens[k] / tok_total
        rows.append({
            "tenant": spec.name,
            "tier": spec.tier,
            "priority": int(spec.priority),
            "model": spec.model,
            "lora": spec.lora,
            "attainment": ok / max(len(reqs), 1),
            "p99_ttft": float(np.percentile(ttfts, 99)) if ttfts
            else math.nan,
            "p99_atgt": float(np.percentile(atgts, 99)) if atgts
            else math.nan,
            "mean_queue_delay": float(np.mean(ttfts)) if ttfts
            else math.nan,
            "finished": len(fin),
            "total": len(reqs),
            "gpu_cost_share": share,
            "gpu_cost": share * gpu_cost,
        })
    return rows
