"""Serving substrate: paged continuous-batching engine, cluster control
plane, discrete-event simulator, workload + length prediction."""
from repro.serving.cluster import ClusterConfig, ServingCluster      # noqa: F401
from repro.serving.disagg import (DisaggConfig, DisaggResult,        # noqa: F401
                                  min_cost_disagg, ratio_pool_fn,
                                  simulate_disaggregated)
from repro.serving.engine import EngineConfig, PagedEngine           # noqa: F401
from repro.serving.forecast import (EWMAForecaster, ForecastConfig,  # noqa: F401
                                    ForecastPolicy, ReactivePolicy,
                                    ScaleSimConfig, ScaleSimResult,
                                    SeasonalNaiveForecaster, SpotMarket,
                                    simulate_autoscaled)
from repro.serving.length_predictor import LengthPredictor           # noqa: F401
from repro.serving.simulator import (SimConfig, SimResult,           # noqa: F401
                                     min_workers_for_slo,
                                     run_heartbeat_loop, simulate)
from repro.serving.workload import (PreemptionEvent, WorkloadConfig,  # noqa: F401
                                    burst_trace, diurnal_rate_fn,
                                    diurnal_trace, generate_trace,
                                    nonhomogeneous_trace, preemption_trace,
                                    sample_lengths)
