"""Serving substrate: the declarative Scenario API (``api.run`` /
``api.optimize``) over one simulation engine, plus the paged
continuous-batching engine, cluster control plane, workload generators and
length prediction. ``__all__`` is the supported public surface — guarded by
tests/test_scenario_api.py against drifting from the documented names."""
from repro.serving.api import (Colocated, Disaggregated,             # noqa: F401
                               FeedbackScale, FixedScale, FleetSpec,
                               Forecast, Plan, PolicyScale, PoolSpec,
                               Reactive, RunReport, Scenario, SideOverride,
                               TenantSpec, optimize, run)
from repro.serving.cluster import ClusterConfig, ServingCluster      # noqa: F401
from repro.serving.disagg import (DisaggConfig, DisaggResult,        # noqa: F401
                                  min_cost_disagg, ratio_pool_fn,
                                  simulate_disaggregated)
from repro.serving.engine import EngineConfig, PagedEngine           # noqa: F401
from repro.serving.forecast import (EWMAForecaster, FeedbackPolicy,  # noqa: F401
                                    ForecastConfig, ForecastPolicy,
                                    ReactivePolicy, ScaleSimConfig,
                                    ScaleSimResult, SeasonalNaiveForecaster,
                                    SpotMarket, simulate_autoscaled)
from repro.serving.length_predictor import LengthPredictor           # noqa: F401
from repro.serving.simulator import (SimConfig, SimResult,           # noqa: F401
                                     min_workers_for_slo,
                                     run_heartbeat_loop, simulate)
from repro.serving.tenants import (planning_slo, tenant_rows)        # noqa: F401
from repro.serving.workload import (PreemptionEvent, WorkloadConfig,  # noqa: F401
                                    burst_trace, clone_trace,
                                    diurnal_rate_fn, diurnal_trace,
                                    drifting_diurnal_rate_fn,
                                    drifting_diurnal_trace, generate_trace,
                                    mixture_trace, nonhomogeneous_trace,
                                    preemption_trace, sample_lengths,
                                    session_trace)
from repro.serving.workload import SessionSpec                       # noqa: F401

# The documented public surface (README "Scenario API" + ROADMAP PR-4/5).
__all__ = [
    # declarative Scenario API (repro.serving.api)
    "Scenario", "FleetSpec", "PoolSpec", "Colocated", "Disaggregated",
    "FixedScale", "Reactive", "Forecast", "FeedbackScale", "SideOverride",
    "PolicyScale", "RunReport", "Plan", "run", "optimize",
    # multi-tenant serving (repro.serving.tenants)
    "TenantSpec", "planning_slo", "tenant_rows",
    # markets + scaling policies
    "SpotMarket", "ScaleSimConfig", "ScaleSimResult", "ReactivePolicy",
    "ForecastPolicy", "FeedbackPolicy", "SeasonalNaiveForecaster",
    "EWMAForecaster", "ForecastConfig",
    # legacy simulators (deprecation shims over run()/optimize())
    "SimConfig", "SimResult", "simulate", "min_workers_for_slo",
    "DisaggConfig", "DisaggResult", "simulate_disaggregated",
    "min_cost_disagg", "ratio_pool_fn", "simulate_autoscaled",
    "run_heartbeat_loop",
    # workload generation
    "WorkloadConfig", "generate_trace", "nonhomogeneous_trace",
    "burst_trace", "diurnal_trace", "diurnal_rate_fn",
    "drifting_diurnal_trace", "drifting_diurnal_rate_fn",
    "preemption_trace", "PreemptionEvent", "sample_lengths", "clone_trace",
    "mixture_trace", "SessionSpec", "session_trace",
    # engine + cluster + prediction
    "EngineConfig", "PagedEngine", "ClusterConfig", "ServingCluster",
    "LengthPredictor",
]
