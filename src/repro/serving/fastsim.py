"""Struct-of-arrays colocated simulation core (the ``engine="vectorized"``
path of :mod:`repro.serving.api`).

Per-beat state — the queue, per-worker batch membership, KV occupancy
``h*Σctx + j*b``, decode clocks and the per-request ``l_out`` /
``t_decode_spent`` arrays — lives in numpy arrays; placement scoring and the
decode-segment arithmetic run as array kernels (the scoring twins live in
:mod:`repro.core.placement`). The Python engine
(:func:`repro.serving.simulator.run_heartbeat_loop` over
``ColocatedTopology``) stays the oracle: this engine reproduces its
per-request ``(t_first_token, t_finish, l_out, t_decode_spent)``
**bit-for-bit** (pinned by tests/test_fastsim_equivalence.py), which demands
replicating the reference's floating-point operation order exactly:

* sequential left-associated accumulation (``np.cumsum`` /
  ``np.add.accumulate``) wherever the reference sums in a Python loop —
  never ``np.sum``, whose pairwise reduction rounds differently;
* the worker clock advances through ``np.add.accumulate([t, dur_0, ...])``,
  matching ``t += dur`` per iteration (``t + cumsum(durs)`` does not);
* multiply-add chains keep the scalar code's grouping
  (``k2*C + c2*b + c3`` as ``((k2*C) + (c2*b)) + c3``);
* ``capacity_norm`` keeps CPython's ``math.hypot`` (numpy's may differ in
  the last ulp, which could flip a best-fit ranking);
* integer-valued aggregates (context sums, KV peaks) are exact in float64
  and may be reduced in any order.

Supported envelope (everything else raises ``ValueError`` so ``api.run``
can fall back or the caller can switch engines explicitly): ``Colocated``
topology without ``split_phase``, no length predictor, no observer;
policies ``aladdin`` / ``jsq`` / ``po2``. Fleets may be fixed (explicit
worker count, heterogeneous allowed — every per-worker coefficient is an
array) or policy-scaled (``Reactive`` / ``Forecast`` / ``FeedbackScale`` /
``PolicyScale``): the engine keeps worker state in growable per-lane rows
and plugs them into the REAL :class:`repro.serving.forecast.ManagedPool` /
:class:`repro.serving.lifecycle.WorkerLifecycle` state machines through the
same adapter protocol the reference uses, so every scaling decision (epoch
targets, boots, drains, reclaim victim draws) is made by the reference code
itself on bit-identical inputs. A ``SpotMarket`` is supported on both fixed
and policy-scaled fleets (reclaims share the engine's Generator, which is
consumed in the reference's exact draw order). Elastic fixed fleets
(place-to-open) remain reference-only.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core.placement import (best_fit_order, decode_budget_arrays,
                                  jsq_order, kv_peak_arrays, slack_arrays)
from repro.core.request import ReqState, Request
from repro.serving.lifecycle import WorkerLifecycle

DEFAULT_TAIL = 240.0

# above this expected iteration count a decode segment is evaluated as one
# array kernel; below it a scalar loop is cheaper (numpy call overhead)
_SEG_VECTOR_MIN = 16


def check_colocated_envelope(scenario) -> List:
    """Validate that ``scenario`` fits the vectorized engine's envelope and
    return the expanded *initial* per-worker spec list (the t=0 fleet — a
    policy-scaled scenario boots and drains lanes from there). Raises
    ``ValueError`` with the first unsupported feature otherwise."""
    from repro.serving import api

    scenario = api.resolve_scenario(scenario)
    if not isinstance(scenario.topology, api.Colocated):
        raise ValueError("vectorized engine supports Colocated topologies "
                         f"only, not {type(scenario.topology).__name__}")
    topo = scenario.topology
    if topo.split_phase:
        raise ValueError("vectorized engine does not support split_phase "
                         "(decode-pool-only) simulation")
    if topo.policy not in ("aladdin", "jsq", "po2"):
        raise ValueError(f"unknown placement policy {topo.policy!r}")
    if topo.router != "blind":
        raise ValueError("session-affinity routing is reference-engine "
                         f"only (router={topo.router!r}; rerun with "
                         "engine='reference')")
    if topo.prefix_cache not in ("lru", "off"):
        raise ValueError(f"unknown prefix_cache mode {topo.prefix_cache!r}")
    if topo.cache_tokens is not None:
        raise ValueError("per-worker prefix-cache budgets (cache_tokens="
                         f"{topo.cache_tokens!r}) are reference-engine only")
    managed = not isinstance(scenario.scaling, api.FixedScale)
    if managed and not isinstance(
            scenario.scaling, (api.Reactive, api.Forecast, api.FeedbackScale,
                               api.PolicyScale)):
        raise ValueError("unknown scaling declaration "
                         f"{type(scenario.scaling).__name__}")
    market = scenario.market
    if market is not None and (market.prefill_spec is not None
                               or len(market.prefill_events) > 0):
        raise ValueError("SpotMarket.prefill_spec/prefill_events describe "
                         "the prefill side of a Disaggregated topology; a "
                         "Colocated scenario would silently ignore them")
    if scenario.predictor is not None:
        raise ValueError("vectorized engine does not support length "
                         "predictors (l_pred must equal l_real)")
    if scenario.observer is not None:
        raise ValueError("vectorized engine does not support observers "
                         "(there are no per-worker objects to observe)")
    pools = scenario.fleet.for_role("serve")
    if not pools:
        raise ValueError("colocated scenario needs at least one fleet pool")
    if managed:
        scfg = _managed_scfg(scenario)
        specs = [pools[0].spec] * max(scfg.initial_workers, scfg.min_workers)
    elif scenario.scaling.n is not None:
        specs = [pools[0].spec] * int(scenario.scaling.n)
    else:
        specs = [p.spec for p in pools for _ in range(p.count)]
    if not specs:
        raise ValueError("vectorized engine needs an explicit worker count "
                         "(elastic mode needs engine='reference')")
    tenants = scenario.tenants
    if tenants is not None:
        names = []
        for tn in tenants:
            names.append(tn.name)
            if tn.workload is None and scenario.workload is None:
                raise ValueError(f"tenant {tn.name!r} needs a workload")
            if tn.lora is not None:
                raise ValueError(
                    "LoRA adapter residency/swap modeling is reference-"
                    f"engine only (tenant {tn.name!r} sets "
                    f"lora={tn.lora!r})")
            if tn.tier not in ("interactive", "batch"):
                raise ValueError(f"tenant {tn.name!r}: tier must be "
                                 "'interactive' or 'batch', got "
                                 f"{tn.tier!r}")
            if tn.slo.ttft <= 0 or tn.slo.atgt <= 0:
                raise ValueError(f"tenant {tn.name!r}: SLO targets must "
                                 "be positive")
            if tn.attain_target is not None \
                    and not 0.0 < tn.attain_target <= 1.0:
                raise ValueError(f"tenant {tn.name!r}: attain_target "
                                 "must be in (0, 1]")
            if int(tn.priority) != tn.priority:
                raise ValueError(f"tenant {tn.name!r}: priority must be "
                                 "an integer")
            if not isinstance(tn.model, str):
                raise ValueError(f"tenant {tn.name!r}: model is a string "
                                 "label")
        if len(set(names)) != len(names):
            raise ValueError(f"tenant names must be unique (got {names})")
    if any(p.tenants is not None for p in pools):
        raise ValueError("dedicated tenant pools (PoolSpec.tenants) fence "
                         "placement per worker — reference engine only")
    if scenario.workload is None and tenants is None:
        raise ValueError("scenario needs a workload trace")
    if scenario.slo.ttft <= 0 or scenario.slo.atgt <= 0:
        raise ValueError("SLO targets must be positive "
                         f"(ttft={scenario.slo.ttft}, "
                         f"atgt={scenario.slo.atgt})")
    if not topo.heartbeat > 0:
        raise ValueError("heartbeat must be a positive interval "
                         f"(got {topo.heartbeat})")
    if not 0.0 < topo.theta <= 1.0:
        raise ValueError(f"theta must be in (0, 1] (got {topo.theta})")
    if not math.isfinite(topo.gamma):
        raise ValueError(f"gamma must be finite (got {topo.gamma})")
    if int(topo.max_batch) < 1:
        raise ValueError(f"max_batch must be >= 1 (got {topo.max_batch})")
    if not isinstance(topo.rebalance, bool):
        raise ValueError("rebalance must be a bool "
                         f"(got {topo.rebalance!r})")
    if int(scenario.seed) < 0:
        raise ValueError(f"seed must be non-negative (got {scenario.seed})")
    if scenario.engine not in ("reference", "vectorized", "jax"):
        raise ValueError(f"unknown engine {scenario.engine!r}")
    return specs


def check_trace_session_free(trace) -> None:
    """Reject session-tagged traces on the compiled engines.

    The compiled cores price every prefill at full context: a multi-turn
    trace from ``session_trace`` would silently lose its prefix-cache
    discount (and its sticky-routing semantics), so fail loudly instead."""
    for r in trace:
        if r.session_id >= 0:
            raise ValueError(
                "session-tagged traces (multi-turn workloads from "
                "session_trace) are reference-engine only — rerun with "
                f"engine='reference' (request {r.id} carries "
                f"session_id={r.session_id})")


def _managed_scfg(scenario):
    """The ``ScaleSimConfig`` a policy-scaled scenario resolves to (the same
    resolution path ``api._run_colocated`` uses)."""
    from repro.serving import api

    if isinstance(scenario.scaling, api.PolicyScale):
        return scenario.scaling.scfg
    pools = scenario.fleet.for_role("serve")
    return api._scale_cfg(scenario.scaling, sum(p.count for p in pools))


def _managed_policy(scenario, scfg):
    """Build the scaling policy instance exactly like the reference path."""
    from repro.serving import api

    if isinstance(scenario.scaling, api.PolicyScale):
        return scenario.scaling.policy
    spot = scenario.market.spec if scenario.market is not None else None
    return api._build_policy(scenario.scaling, scfg, spot)


class _Lane:
    """Worker adapter handed to the real ``ManagedPool``/``WorkerLifecycle``
    state machines: carries the identity (``id``/``spec``) the lifecycle
    code keys on, plus the engine's row index for this worker."""

    __slots__ = ("id", "spec", "idx", "draining", "alive")

    def __init__(self, wid: int, spec, idx: int):
        self.id = wid
        self.spec = spec
        self.idx = idx
        self.draining = False       # only the fixed+market path sets this
        self.alive = True


class _Engine:
    """One vectorized colocated simulation (struct-of-arrays state)."""

    def __init__(self, specs: Sequence, trace: Sequence[Request], topo, slo,
                 seed: int, tail: float = DEFAULT_TAIL):
        self.policy = topo.policy
        self.hb = float(topo.heartbeat)
        self.gamma = float(topo.gamma)
        self.theta = float(topo.theta)
        self.slo = slo
        self.tail = float(tail)
        self.rng = np.random.default_rng(seed)
        self.specs = list(specs)
        W = len(specs)
        self.W = W

        # ---- per-worker coefficient arrays (+ Python-float twins) ----------
        self.K1 = np.array([s.perf.prefill.k1 for s in specs])
        self.C1 = np.array([s.perf.prefill.c1 for s in specs])
        self.K2 = np.array([s.perf.decode.k2 for s in specs])
        self.C2 = np.array([s.perf.decode.c2 for s in specs])
        self.C3 = np.array([s.perf.decode.c3 for s in specs])
        self.H = np.array([s.perf.kv.h for s in specs])
        self.J = np.array([s.perf.kv.j for s in specs])
        self.M = np.array([s.kv_capacity for s in specs])
        self.MAXB = np.array([s.max_batch for s in specs], dtype=np.int64)
        # capacity_norm denominators: max(max_batch, 1) and
        # max(max_total_context(1, atgt) or 1.0, 1.0), fixed per worker
        self.maxb_norm = [max(int(s.max_batch), 1) for s in specs]
        self.cmax_norm = []
        for s in specs:
            cmax = s.perf.decode.max_total_context(1, slo.atgt) or 1.0
            self.cmax_norm.append(max(cmax, 1.0))
        self.coef = [(float(s.perf.prefill.k1), float(s.perf.prefill.c1),
                      float(s.perf.decode.k2), float(s.perf.decode.c2),
                      float(s.perf.decode.c3), float(s.perf.kv.h),
                      float(s.perf.kv.j), float(s.kv_capacity),
                      int(s.max_batch)) for s in specs]

        # ---- request struct-of-arrays (sorted by arrival, stable) ----------
        order = sorted(range(len(trace)), key=lambda i: trace[i].arrival)
        self.trace = [trace[i] for i in order]
        n = len(self.trace)
        self.n = n
        self.arrival = np.array([r.arrival for r in self.trace])
        self.l_in = np.array([r.l_in for r in self.trace], dtype=np.int64)
        self.l_real = np.array([r.l_real for r in self.trace],
                               dtype=np.int64)
        # no predictor in the envelope: admit() sets l_pred = l_real
        self.l_pred = self.l_real
        # multi-tenant tagging: raw per-request tenant budgets (inf =
        # untagged -> constraints fall back to the planning SLO), the EDF
        # ordering key (arrival + tenant TTFT budget) and the admission
        # priority. ``edf`` (>1 tenant; set by run_colocated_vectorized)
        # orders the queue priority-then-deadline before each placement
        # pass — a single tenant keeps the legacy FIFO walk bit-for-bit.
        self.prio = np.array([r.priority for r in self.trace],
                             dtype=np.int64)
        self.dl = np.array([r.deadline for r in self.trace])
        self.raw_ttft = np.array([r.slo_ttft for r in self.trace])
        self.raw_atgt = np.array([r.slo_atgt for r in self.trace])
        self.tagged = bool(np.isfinite(self.raw_atgt).any()) if n else False
        self.edf = False
        # running per-worker tenant-budget mins for constraints (b)/(c):
        # min tenant ATGT over ongoing+new_batch, min tenant TTFT over
        # new_batch — rebuilt each aladdin pass, updated per placement
        self._amin = np.full(W, np.inf)
        self._tmin = np.full(W, np.inf)
        self.l_out = np.zeros(n, dtype=np.int64)
        self.tds = np.zeros(n)                      # t_decode_spent
        self.t_first = np.full(n, np.nan)
        self.t_fin = np.full(n, np.nan)
        self.t_pre = np.full(n, np.nan)             # t_preempted (KV loss)
        self.preempt_n = np.zeros(n, dtype=np.int64)   # preempt_count delta

        # ---- mutable worker state ------------------------------------------
        Bcap = max(int(self.MAXB.max()), 1) if W else 1
        self.mem = np.full((W, Bcap), -1, dtype=np.int64)   # ongoing members
        self.cnt = np.zeros(W, dtype=np.int64)
        self.bsz = np.zeros(W, dtype=np.int64)      # cnt + len(newb)
        self.t_w = np.zeros(W)                      # local worker clocks
        self.ctx = np.zeros(W, dtype=np.int64)      # Σ context over ongoing
        self.wctx = np.zeros(W)                     # weighted-context cache
        self.dirty = np.ones(W, dtype=bool)
        self.norm = np.zeros(W)                     # capacity_norm cache
        self.newb: List[List[int]] = [[] for _ in range(W)]
        self.pre: List[List[int]] = [[] for _ in range(W)]
        self.newsum = np.zeros(W, dtype=np.int64)   # Σ l_in over newb
        # Σ context over newb (differs from newsum for KV-loss re-entrants,
        # whose retained l_out re-prefills too — what kv_now charges)
        self.newctx = np.zeros(W, dtype=np.int64)
        self.queued: List[int] = []
        self.fin_order: List[int] = []      # finish order (oracle's order)
        self.preemptions = 0
        self.beats = 0
        self.peak_lanes = W                 # topo.peak_workers twin
        self.pool = None                    # worker container, if pooled
        self._wid = 0                       # worker-id counter (pool lanes)

    # ---- dynamic lanes (policy-scaled fleets) ------------------------------

    def _alloc_lane(self, spec) -> int:
        """Append one worker row to every per-lane array; returns its index.
        Policy-scaled fleets boot lanes mid-run — lane rows are never
        recycled, so a retired lane's (empty) row just stops being visited."""
        idx = self.W
        self.W += 1
        self.specs.append(spec)
        for name, val in (("K1", spec.perf.prefill.k1),
                          ("C1", spec.perf.prefill.c1),
                          ("K2", spec.perf.decode.k2),
                          ("C2", spec.perf.decode.c2),
                          ("C3", spec.perf.decode.c3),
                          ("H", spec.perf.kv.h), ("J", spec.perf.kv.j),
                          ("M", spec.kv_capacity)):
            setattr(self, name, np.append(getattr(self, name), val))
        self.MAXB = np.append(self.MAXB, np.int64(spec.max_batch))
        self.maxb_norm.append(max(int(spec.max_batch), 1))
        cmax = spec.perf.decode.max_total_context(1, self.slo.atgt) or 1.0
        self.cmax_norm.append(max(cmax, 1.0))
        self.coef.append((float(spec.perf.prefill.k1),
                          float(spec.perf.prefill.c1),
                          float(spec.perf.decode.k2),
                          float(spec.perf.decode.c2),
                          float(spec.perf.decode.c3), float(spec.perf.kv.h),
                          float(spec.perf.kv.j), float(spec.kv_capacity),
                          int(spec.max_batch)))
        B = self.mem.shape[1] if idx else max(int(spec.max_batch), 1)
        self.mem = np.vstack([self.mem,
                              np.full((1, B), -1, dtype=np.int64)]) \
            if idx else np.full((1, B), -1, dtype=np.int64)
        for name in ("cnt", "bsz", "ctx", "newsum", "newctx"):
            setattr(self, name,
                    np.append(getattr(self, name), np.int64(0)))
        self.t_w = np.append(self.t_w, 0.0)
        self.wctx = np.append(self.wctx, 0.0)
        self.norm = np.append(self.norm, 0.0)
        self.dirty = np.append(self.dirty, True)
        self._amin = np.append(self._amin, np.inf)
        self._tmin = np.append(self._tmin, np.inf)
        self.newb.append([])
        self.pre.append([])
        return idx

    def _grow_mem(self) -> None:
        # resumes can push a batch past max_batch (placement bounds only
        # new admissions, like the scalar engine's unbounded ongoing list)
        W, B = self.mem.shape
        nm = np.full((W, 2 * B), -1, dtype=np.int64)
        nm[:, :B] = self.mem
        self.mem = nm

    # ---- weighted-context / capacity-norm caches ---------------------------

    def _recompute_wctx(self) -> None:
        """Ordered recompute of the weighted-context cache for dirty workers
        (sequential cumsum over ongoing-then-new_batch, like the scalar
        ``_wctx_now``)."""
        g = self.gamma
        for wi in np.nonzero(self.dirty)[0]:
            cnt = int(self.cnt[wi])
            nb = self.newb[wi]
            if cnt == 0 and not nb:
                self.wctx[wi] = 0.0
            else:
                m = self.mem[wi, :cnt]
                vals = self.l_in[m] + g * self.l_pred[m]
                if nb:
                    nba = np.asarray(nb, dtype=np.int64)
                    vals = np.concatenate(
                        [vals, self.l_in[nba] + g * self.l_pred[nba]])
                self.wctx[wi] = np.cumsum(vals)[-1]
            self.dirty[wi] = False

    def _refresh_norms(self, sel: Optional[np.ndarray] = None) -> None:
        for wi in (range(self.W) if sel is None else sel):
            wi = int(wi)
            self.norm[wi] = math.hypot(
                self.bsz[wi] / self.maxb_norm[wi],
                self.wctx[wi] / self.cmax_norm[wi])

    def _kv_peak_with(self, wi: int, ridx: int) -> float:
        cnt = int(self.cnt[wi])
        ids = self.mem[wi, :cnt]
        extra = self.newb[wi] + [ridx]
        ids = np.concatenate([ids, np.asarray(extra, dtype=np.int64)])
        rem = np.maximum(self.l_pred[ids] - self.l_out[ids], 0)
        ctx = self.l_in[ids] + self.l_out[ids]
        _, _, _, _, _, h, j, _, _ = self.coef[wi]
        return kv_peak_arrays(rem, ctx, h, j)

    # ---- placement ---------------------------------------------------------

    def _place(self, wi: int, ridx: int, v: float, li: int) -> None:
        self.newb[wi].append(ridx)
        self.newsum[wi] += li
        self.newctx[wi] += li + int(self.l_out[ridx])
        self.bsz[wi] += 1
        self.wctx[wi] += v
        self.norm[wi] = math.hypot(
            self.bsz[wi] / self.maxb_norm[wi],
            self.wctx[wi] / self.cmax_norm[wi])
        if self.tagged:
            # the new member's tenant budgets tighten the worker's running
            # constraint-(b)/(c) mins for the rest of the pass
            if self.raw_atgt[ridx] < self._amin[wi]:
                self._amin[wi] = self.raw_atgt[ridx]
            if self.raw_ttft[ridx] < self._tmin[wi]:
                self._tmin[wi] = self.raw_ttft[ridx]

    # Placement runs over the *serving* lanes in serving-list order: ``sel``
    # (None = every lane, the fixed-fleet fast path) maps serving position ->
    # lane row, so best-fit/JSQ tie-breaks keep the reference's list order
    # even when a pool boots, drains and reclaims lanes out of index order.

    def _place_all_aladdin(self, sel: Optional[np.ndarray] = None) -> None:
        theta = self.theta
        atgt = self.slo.atgt
        ttft = self.slo.ttft
        g = self.gamma
        self._recompute_wctx()
        self._refresh_norms(sel)
        if sel is None:
            def sub(a):
                return a
        else:
            def sub(a):
                return a[sel]
        # constraint (d) slack is over *ongoing* members only — fixed for
        # the whole placement pass
        B = self.mem.shape[1]
        mem_s = sub(self.mem)
        mask_slots = np.arange(B)[None, :] < sub(self.cnt)[:, None]
        slack = slack_arrays(self.l_out[mem_s], self.tds[mem_s],
                             mask_slots, atgt)
        d_budget = theta * np.maximum(slack, 0.0)
        tagged = self.tagged
        d_budget_tag = None
        if tagged:
            # per-member tenant ATGT budgets (inf -> planning SLO) for the
            # tagged-candidate variant of the (d) slack, plus the rebuilt
            # running (b)/(c) mins over ongoing + any pending new batch
            raw_am = self.raw_atgt[mem_s]
            atgt_mem = np.where(np.isinf(raw_am), atgt, raw_am)
            slack_t = slack_arrays(self.l_out[mem_s], self.tds[mem_s],
                                   mask_slots, atgt_mem)
            d_budget_tag = theta * np.maximum(slack_t, 0.0)
            live = np.arange(self.W) if sel is None else sel
            amin = np.where(mask_slots, raw_am, np.inf).min(axis=1)
            tmin = np.full(live.size, np.inf)
            for p, wi in enumerate(live):
                for rid in self.newb[int(wi)]:
                    if self.raw_atgt[rid] < amin[p]:
                        amin[p] = self.raw_atgt[rid]
                    if self.raw_ttft[rid] < tmin[p]:
                        tmin[p] = self.raw_ttft[rid]
            self._amin[live] = amin
            self._tmin[live] = tmin
        K1_s, C1_s = sub(self.K1), sub(self.C1)
        K2_s, C2_s, C3_s = sub(self.K2), sub(self.C2), sub(self.C3)
        MAXB_s = sub(self.MAXB)
        still: List[int] = []
        for ridx in self.queued:
            li = int(self.l_in[ridx])
            v = li + g * int(self.l_pred[ridx])
            bpost = sub(self.bsz) + 1
            if tagged and math.isfinite(self.raw_atgt[ridx]):
                # tagged candidate: constraints budget against the
                # strictest tenant among candidate + affected members,
                # mirroring WorkerState._constraint_{b,c,d}
                a_eff = np.minimum(sub(self._amin), self.raw_atgt[ridx])
                a_eff = np.where(np.isinf(a_eff), atgt, a_eff)
                t_eff = np.minimum(sub(self._tmin), self.raw_ttft[ridx])
                t_eff = np.where(np.isinf(t_eff), ttft, t_eff)
                d_eff = d_budget_tag
            else:
                a_eff, t_eff, d_eff = atgt, ttft, d_budget
            okb = (bpost <= MAXB_s) & (
                sub(self.wctx) + v <= theta * decode_budget_arrays(
                    bpost, a_eff, K2_s, C2_s, C3_s))
            pre_t = K1_s * (sub(self.newsum) + li) + C1_s
            mask = okb & (pre_t <= t_eff) & (pre_t <= d_eff)
            placed = False
            if mask.any():
                for p in best_fit_order(sub(self.norm)):
                    p = int(p)
                    if not mask[p]:
                        continue
                    wi = p if sel is None else int(sel[p])
                    if self._kv_peak_with(wi, ridx) \
                            <= theta * self.coef[wi][7]:
                        self._place(wi, ridx, v, li)
                        placed = True
                        break
            if not placed:
                still.append(ridx)
        self.queued[:] = still

    def _place_all_jsq(self, sel: Optional[np.ndarray] = None) -> None:
        if sel is None:
            def sub(a):
                return a
        else:
            def sub(a):
                return a[sel]
        H_s, J_s, M_s = sub(self.H), sub(self.J), sub(self.M)
        MAXB_s = sub(self.MAXB)
        still: List[int] = []
        for ridx in self.queued:
            li = int(self.l_in[ridx])
            # Σ context incl. new_batch (newctx: re-entrants count l_out too)
            csum = sub(self.ctx) + sub(self.newctx)
            bsz_s = sub(self.bsz)
            kv_now = (H_s * csum + J_s * bsz_s) + (H_s * li + J_s)
            mask = (kv_now <= M_s) & (bsz_s + 1 <= MAXB_s)
            order = jsq_order(bsz_s)
            hit = np.nonzero(mask[order])[0]
            if hit.size:
                p = int(order[hit[0]])
                wi = p if sel is None else int(sel[p])
                self._place(wi, ridx, li + self.gamma * int(
                    self.l_pred[ridx]), li)
            else:
                still.append(ridx)
        self.queued[:] = still

    def _admit_naive_scalar(self, wi: int, li: int) -> bool:
        _, _, _, _, _, h, j, M, maxb = self.coef[wi]
        csum = int(self.ctx[wi]) + int(self.newctx[wi])
        own = int(self.bsz[wi])
        kv_now = (h * csum + j * own) + (h * li + j)
        return kv_now <= M and own + 1 <= maxb

    def _place_all_po2(self, sel: Optional[np.ndarray] = None) -> None:
        self._recompute_wctx()
        g = self.gamma
        nlive = self.W if sel is None else int(sel.size)
        still: List[int] = []
        for ridx in self.queued:
            li = int(self.l_in[ridx])
            v = li + g * int(self.l_pred[ridx])
            wctx_live = self.wctx if sel is None else self.wctx[sel]
            if nlive >= 2:
                i, jj = self.rng.choice(nlive, size=2, replace=False)
                cands = sorted((int(i), int(jj)),
                               key=lambda p: wctx_live[p])
            else:
                cands = list(range(nlive))
            placed = False
            for p in cands:
                wi = p if sel is None else int(sel[p])
                if self._admit_naive_scalar(wi, li):
                    self._place(wi, ridx, v, li)
                    placed = True
                    break
            if not placed:
                for p in np.argsort(wctx_live, kind="stable"):
                    p = int(p)
                    if p in cands:
                        continue
                    wi = p if sel is None else int(sel[p])
                    if self._admit_naive_scalar(wi, li):
                        self._place(wi, ridx, v, li)
                        placed = True
                        break
            if not placed:
                still.append(ridx)
        self.queued[:] = still

    # ---- worker advance ----------------------------------------------------

    def _advance(self, wi: int, t_start: float, t_end: float) -> None:
        k1, c1, k2, c2, c3, h, j, M, _ = self.coef[wi]
        mem = self.mem
        l_in = self.l_in
        l_out = self.l_out
        l_real = self.l_real
        tds = self.tds
        t_first = self.t_first
        t_fin = self.t_fin
        t_pre = self.t_pre
        arrival = self.arrival
        t = float(self.t_w[wi])
        cnt = int(self.cnt[wi])
        ctx = int(self.ctx[wi])
        newb = self.newb[wi]
        pre = self.pre[wi]
        # a lane that sat booting/idle clamps to the beat start before any
        # pending work runs (the reference's advance_to t_start clamp)
        if (newb or pre) and t < t_start:
            t = t_start
        resume_thr = 0.9 * M
        while t < t_end:
            # resume preempted requests when KV frees up (recompute: prompt
            # AND generated tokens re-prefill). Like the scalar engine, the
            # admission test uses the pre-resume occupancy for every pop.
            resume: List[int] = []
            while pre:
                cand = pre[0]
                occ = (h * ctx + j * cnt) \
                    + h * (int(l_in[cand]) + int(l_out[cand])) + j
                if occ > resume_thr:
                    break
                resume.append(pre.pop(0))
            if newb or resume:
                total_in = sum(int(l_in[r]) + int(l_out[r]) for r in newb) \
                    + sum(int(l_in[r]) + int(l_out[r]) for r in resume)
                dur = k1 * total_in + c1
                t += dur
                # prefill preempts decode: ongoing + still-preempted +
                # resumed victims all stall through it
                if cnt:
                    tds[mem[wi, :cnt]] += dur
                for r in pre:
                    tds[r] += dur
                for r in resume:
                    tds[r] += dur
                for r in newb:
                    if math.isnan(t_first[r]):
                        t_first[r] = t
                        l_out[r] = 1
                    elif not math.isnan(t_pre[r]):
                        # KV-loss re-entrant: the stall since the reclaim
                        # instant lands on its ATGT clock
                        tds[r] += max(t - float(t_pre[r]), 0.0)
                    t_pre[r] = np.nan
                    if cnt == mem.shape[1]:
                        self._grow_mem()
                        mem = self.mem
                    mem[wi, cnt] = r
                    cnt += 1
                    ctx += int(l_in[r]) + int(l_out[r])
                for r in resume:
                    if cnt == mem.shape[1]:
                        self._grow_mem()
                        mem = self.mem
                    mem[wi, cnt] = r
                    cnt += 1
                    ctx += int(l_in[r]) + int(l_out[r])
                newb.clear()
                self.newsum[wi] = 0
                self.newctx[wi] = 0
                continue
            if cnt == 0:
                t = t_end
                break
            # KV overflow -> preempt the youngest (recompute semantics)
            while h * ctx + j * cnt > M and cnt > 1:
                row = mem[wi, :cnt]
                vpos = int(np.argmax(arrival[row]))
                victim = int(row[vpos])
                ctx -= int(l_in[victim]) + int(l_out[victim])
                mem[wi, vpos:cnt - 1] = mem[wi, vpos + 1:cnt]
                cnt -= 1
                pre.append(victim)
                self.preemptions += 1
            # decode segment: batch fixed until finish/overflow/heartbeat
            b = cnt
            row = mem[wi, :cnt]
            n_fin = int(np.min(np.maximum(l_real[row] - l_out[row], 1)))
            C = ctx
            k = 0
            seg = 0.0
            dur0 = k2 * C + c2 * b + c3
            est = (t_end - t) / dur0 if dur0 > 0 else float(n_fin)
            if n_fin <= _SEG_VECTOR_MIN or est <= _SEG_VECTOR_MIN \
                    or dur0 <= 0:
                while k < n_fin and t < t_end:
                    if k > 0 and h * C + j * b > M and b > 1:
                        break
                    dur = k2 * C + c2 * b + c3
                    t += dur
                    seg += dur
                    C += b
                    k += 1
            else:
                kmax = min(n_fin, int(est) + 2)
                ks = np.arange(kmax, dtype=np.int64)
                C_k = C + ks * b
                cb = c2 * b
                durs = k2 * C_k + cb + c3
                t_traj = np.add.accumulate(
                    np.concatenate(([t], durs)))
                k = int(np.searchsorted(t_traj[:kmax], t_end, side="left"))
                if b > 1:
                    viol = h * C_k + j * b > M
                    viol[0] = False
                    nz = np.nonzero(viol)[0]
                    if nz.size:
                        k = min(k, int(nz[0]))
                if k > 0:
                    seg = float(np.add.accumulate(durs[:k])[-1])
                    t = float(t_traj[k])
                    C += k * b
            ctx = C
            l_out[row] += k
            tds[row] += seg
            done = l_out[row] >= l_real[row]
            if done.any():
                fin_ids = row[done]
                t_fin[fin_ids] = t
                self.fin_order.extend(int(r) for r in fin_ids)
                ctx -= int((l_in[fin_ids] + l_out[fin_ids]).sum())
                kept = row[~done]
                cnt = kept.shape[0]
                mem[wi, :cnt] = kept
            # preempted requests' ATGT clocks also advance (stalled)
            for r in pre:
                tds[r] += seg
        self.t_w[wi] = t
        self.cnt[wi] = cnt
        self.ctx[wi] = ctx
        self.bsz[wi] = cnt + len(newb)
        self.dirty[wi] = True

    # ---- the heartbeat loop ------------------------------------------------

    def _edf_sort(self) -> None:
        """Priority-then-EDF queue ordering (>1 tenant only): stable sort
        by (-priority, deadline), so equal keys keep FIFO/requeue order —
        the same ``list.sort`` the reference topology runs."""
        prio, dl = self.prio, self.dl
        self.queued.sort(key=lambda i: (-prio[i], dl[i]))

    def _step(self, t: float, t_next: float) -> None:
        if self.queued:
            if self.edf:
                self._edf_sort()
            if self.policy == "aladdin":
                self._place_all_aladdin()
            elif self.policy == "jsq":
                self._place_all_jsq()
            else:
                self._place_all_po2()
        t_w = self.t_w
        cnt = self.cnt
        for wi in range(self.W):
            if cnt[wi] == 0 and not self.newb[wi] and not self.pre[wi]:
                # idle worker: the scalar loop just fast-forwards its clock
                if t_w[wi] < t_next:
                    t_w[wi] = t_next
                self.dirty[wi] = True
            else:
                self._advance(wi, t, t_next)

    def _drained(self) -> bool:
        return (not self.queued and int(self.cnt.sum()) == 0
                and all(not nb for nb in self.newb)
                and all(not p for p in self.pre))

    # ---- pool adapters (plugged into the REAL ManagedPool/WorkerLifecycle
    # state machines, which make every boot/drain/kill decision) -------------

    def _new_lane(self, spec) -> _Lane:
        self._wid += 1
        return _Lane(self._wid, spec, self._alloc_lane(spec))

    def _spawn_lane(self, lane: _Lane, t: float) -> None:
        # the reference arms a fresh SimWorker at the boot instant
        self.t_w[lane.idx] = t

    def _kill_lane(self, lane: _Lane) -> List[int]:
        """Strip and return the lane's in-flight requests (ongoing,
        new_batch, KV-preempted — the reference's extraction order)."""
        wi = lane.idx
        cnt = int(self.cnt[wi])
        lost = [int(r) for r in self.mem[wi, :cnt]] \
            + list(self.newb[wi]) + list(self.pre[wi])
        self.cnt[wi] = 0
        self.bsz[wi] = 0
        self.ctx[wi] = 0
        self.newsum[wi] = 0
        self.newctx[wi] = 0
        self.wctx[wi] = 0.0
        self.newb[wi] = []
        self.pre[wi] = []
        self.dirty[wi] = True
        return lost

    def _mark_rid(self, rid: int, t: float) -> None:
        # mark_kv_loss over array state: the stall clock arms at the
        # reclaim instant; settled when the re-prefill completes
        self.t_pre[rid] = t
        self.preempt_n[rid] += 1

    def _lane_load(self, lane: _Lane) -> int:
        return int(self.bsz[lane.idx])

    def _lane_idle(self, lane: _Lane) -> bool:
        wi = lane.idx
        return (int(self.cnt[wi]) == 0 and not self.newb[wi]
                and not self.pre[wi])

    # ---- the ColocatedTopology shim the pools call back into ---------------

    def requeue(self, rids: Sequence[int], side: str = "serve") -> None:
        self.queued.extend(rids)

    def backlog_len(self, side: str = "serve") -> int:
        return len(self.queued)

    def slo_window(self, side: str, t_now: float, window: float,
                   metric: str = "both") -> tuple:
        """``core.slo.windowed_attainment`` over array state: (ok, total)
        among requests finished in ``[t_now - window, t_now]``, plus
        assured-miss pending requests whose TTFT budget already expired."""
        t0 = t_now - window
        inw = ~np.isnan(self.t_fin) & (self.t_fin >= t0)
        ids = np.nonzero(inw)[0]
        total = int(ids.size)
        ok = 0
        if total:
            ttft_ok = (self.t_first[ids] - self.arrival[ids]) \
                <= self.slo.ttft
            has_dec = self.l_real[ids] > 1
            atgt_ok = np.ones(total, dtype=bool)
            d = ids[has_dec]
            atgt_ok[has_dec] = (self.tds[d] / (self.l_real[d] - 1)) \
                <= self.slo.atgt
            if metric == "both":
                okm = ttft_ok & atgt_ok
            elif metric == "ttft":
                okm = ttft_ok
            elif metric == "atgt":
                okm = atgt_ok
            else:
                raise ValueError(f"unknown SLO metric {metric!r}")
            ok = int(okm.sum())
        if metric != "atgt":
            for rid in self.queued:
                if math.isnan(self.t_first[rid]) \
                        and t_now - float(self.arrival[rid]) > self.slo.ttft:
                    total += 1
        return ok, total

    # ---- the pooled heartbeat loop (policy-scaled / fixed+market) ----------

    def _step_pooled(self, t: float, t_next: float) -> None:
        pool = self.pool
        pool.begin_beat(self, t)
        if self.queued:
            if self.edf:
                self._edf_sort()
            sel = np.asarray([ln.idx for ln in pool.serving()
                              if ln.alive and not ln.draining],
                             dtype=np.int64)
            if sel.size:
                if self.policy == "aladdin":
                    self._place_all_aladdin(sel)
                elif self.policy == "jsq":
                    self._place_all_jsq(sel)
                else:
                    self._place_all_po2(sel)
        t_w = self.t_w
        cnt = self.cnt
        for ln in pool.active():
            wi = ln.idx
            if cnt[wi] == 0 and not self.newb[wi] and not self.pre[wi]:
                if t_w[wi] < t_next:
                    t_w[wi] = t_next
                self.dirty[wi] = True
            else:
                self._advance(wi, t, t_next)
        pool.end_beat(self, t, t_next)

    def _drained_pooled(self) -> bool:
        if self.queued:
            return False
        for ln in self.pool.active():
            wi = ln.idx
            if int(self.cnt[wi]) or self.newb[wi]:
                return False
        return all(not p for p in self.pre)

    def run_pooled(self, events: Sequence) -> None:
        """Heartbeat loop with the engine playing ``ColocatedTopology``
        against ``self.pool`` (the real ManagedPool, or ``_FixedLanes`` for
        a market over a fixed fleet). Reclaim events consume ``self.rng``
        before placement draws, exactly like the reference's fire/step
        ordering."""
        pool = self.pool
        n = self.n
        horizon = (float(self.arrival[n - 1]) if n else 0.0) + self.tail
        hb = self.hb
        arr = self.arrival
        nev = len(events)
        t = 0.0
        idx = 0
        eidx = 0
        queued = self.queued
        while t < horizon:
            t_next = t + hb
            while idx < n and arr[idx] <= t:
                queued.append(idx)
                pool.note_arrival()
                idx += 1
            while eidx < nev and events[eidx].t <= t:
                self.requeue(pool.on_reclaim(t, events[eidx]))
                eidx += 1
            self._step_pooled(t, t_next)
            self.beats += 1
            t = t_next
            if idx >= n and self._drained_pooled():
                break

    def run(self) -> None:
        n = self.n
        horizon = (float(self.arrival[n - 1]) if n else 0.0) + self.tail
        hb = self.hb
        arr = self.arrival
        t = 0.0
        idx = 0
        queued = self.queued
        while t < horizon:
            t_next = t + hb
            while idx < n and arr[idx] <= t:
                queued.append(idx)
                idx += 1
            self._step(t, t_next)
            self.beats += 1
            t = t_next
            if idx >= n and self._drained():
                break

    # ---- results -----------------------------------------------------------

    def writeback(self) -> List[Request]:
        """Scatter the array state back onto the ``Request`` objects (the
        same mutation contract as the reference engine) and return the
        finished sublist in *finish order* — ``np.mean``/``np.percentile``
        are pairwise reductions, so matching the oracle's report to the
        last ulp needs the oracle's list order, not just its members."""
        for pos, r in enumerate(self.trace):
            r.l_pred = int(self.l_pred[pos])
            r.l_out = int(self.l_out[pos])
            r.t_decode_spent = float(self.tds[pos])
            tf = self.t_first[pos]
            r.t_first_token = None if math.isnan(tf) else float(tf)
            tp = self.t_pre[pos]
            r.t_preempted = None if math.isnan(tp) else float(tp)
            pn = int(self.preempt_n[pos])
            if pn:
                r.preempt_count += pn
            te = self.t_fin[pos]
            if not math.isnan(te):
                r.t_finish = float(te)
                r.state = ReqState.FINISHED
        return [self.trace[i] for i in self.fin_order]


class _FixedLanes:
    """``simulator.FixedPool`` twin over engine lanes: a static fleet a spot
    market may reclaim workers out of (they are not replaced). All condemn/
    kill/reap decisions run through the shared ``WorkerLifecycle``."""

    def __init__(self, eng: _Engine, lanes: List[_Lane], rng,
                 notice_s: float):
        self.workers = lanes
        self.retired_cost = 0.0
        self.life = WorkerLifecycle(
            rng, notice_s=notice_s, extract=eng._kill_lane,
            mark=eng._mark_rid, idle=eng._lane_idle, remove=self._remove,
            on_condemn=lambda ln: setattr(ln, "draining", True))

    def _remove(self, lane: _Lane) -> None:
        self.workers.remove(lane)
        self.retired_cost += lane.spec.n_accelerators

    @property
    def killed(self) -> int:
        return self.life.killed

    @property
    def drained_ok(self) -> int:
        return self.life.drained_ok

    @property
    def requeued(self) -> int:
        return self.life.requeued

    def note_arrival(self) -> None:
        pass

    def serving(self) -> List[_Lane]:
        return self.workers

    def active(self) -> List[_Lane]:
        return self.workers

    def begin_beat(self, topo, t: float) -> None:
        if self.life.condemned:
            topo.requeue(self.life.reap(t, self._lookup))

    def end_beat(self, topo, t: float, t_next: float) -> None:
        pass

    def _lookup(self, wid: int) -> Optional[_Lane]:
        return next((x for x in self.workers if x.id == wid), None)

    def on_reclaim(self, t: float, ev) -> List[int]:
        return self.life.reclaim(t, ev, self.life.eligible(self.workers))


def run_colocated_vectorized(scenario, seed: Optional[int] = None,
                             tail: float = DEFAULT_TAIL):
    """Run a colocated ``Scenario`` on the struct-of-arrays engine and
    return the same :class:`~repro.serving.api.RunReport` the reference
    engine would produce (bit-for-bit on the supported envelope)."""
    from repro.serving import api
    from repro.serving.forecast import ManagedPool

    scenario = api.resolve_scenario(scenario)
    specs = check_colocated_envelope(scenario)
    s = seed if seed is not None else scenario.seed
    edf = scenario.tenants is not None and len(scenario.tenants) > 1
    trace = scenario.materialize()
    check_trace_session_free(trace)
    market = scenario.market
    notice = market.notice_s if market is not None else 0.0
    events = sorted(market.events, key=lambda e: e.t) \
        if market is not None and market.events else []
    managed = not isinstance(scenario.scaling, api.FixedScale)
    if managed:
        # lanes are booted by the pool itself (the ctor spawns the t=0
        # fleet through the engine's new_worker adapter)
        eng = _Engine([], trace, scenario.topology, scenario.slo, s,
                      tail=tail)
        eng.edf = edf
        scfg = _managed_scfg(scenario)
        policy = _managed_policy(scenario, scfg)
        pool = ManagedPool(
            scenario.fleet.for_role("serve")[0].spec, scfg, policy,
            eng.hb, eng.rng, new_worker=eng._new_lane,
            on_spawn=eng._spawn_lane, on_kill=eng._kill_lane,
            load=eng._lane_load, idle=eng._lane_idle, mark=eng._mark_rid,
            spot_spec=market.spec if market is not None else None,
            notice_s=notice, name="serve")
        eng.pool = pool
        eng.run_pooled(events)
        finished = eng.writeback()
        rep = api.RunReport(
            topology="colocated",
            scaling=getattr(policy, "name", type(policy).__name__),
            **api._percentiles(finished, len(trace), scenario.slo))
        rep.peak_workers = pool.peak
        rep.gpu_seconds = pool.gpu_s
        rep.gpu_cost = pool.gpu_s
        rep.spot_gpu_seconds = pool.spot_gpu_s
        rep.epochs = {"serve": pool.epochs}
    elif market is not None:
        eng = _Engine(specs, trace, scenario.topology, scenario.slo, s,
                      tail=tail)
        eng.edf = edf
        lanes = []
        for wi, sp in enumerate(specs):
            eng._wid += 1
            lanes.append(_Lane(eng._wid, sp, wi))
        pool = _FixedLanes(eng, lanes, eng.rng, notice)
        eng.pool = pool
        eng.run_pooled(events)
        finished = eng.writeback()
        rep = api.RunReport(topology="colocated", scaling="fixed",
                            **api._percentiles(finished, len(trace),
                                               scenario.slo))
        rep.peak_workers = eng.peak_lanes
        # every worker that served counts, including reclaimed ones
        rep.gpu_cost = sum(ln.spec.n_accelerators
                           for ln in pool.workers) + pool.retired_cost
    else:
        eng = _Engine(specs, trace, scenario.topology, scenario.slo, s,
                      tail=tail)
        eng.edf = edf
        pool = None
        eng.run()
        finished = eng.writeback()
        rep = api.RunReport(topology="colocated", scaling="fixed",
                            **api._percentiles(finished, len(trace),
                                               scenario.slo))
        rep.peak_workers = eng.W
        rep.gpu_cost = sum(sp.n_accelerators for sp in specs)
    if pool is not None:
        rep.preempted_workers = pool.killed
        rep.drained_ok = pool.drained_ok
        rep.requeued = pool.requeued
    rep.moves = 0
    rep.beats = eng.beats       # benchmark side channel (not in row())
    if scenario.tenants is not None:
        from repro.serving.tenants import tenant_attainment, tenant_rows
        rep.attainment = tenant_attainment(trace)
        rep.tenant_rows = tenant_rows(trace, list(scenario.tenants),
                                      rep.gpu_cost)
    return rep
