"""Struct-of-arrays colocated simulation core (the ``engine="vectorized"``
path of :mod:`repro.serving.api`).

Per-beat state — the queue, per-worker batch membership, KV occupancy
``h*Σctx + j*b``, decode clocks and the per-request ``l_out`` /
``t_decode_spent`` arrays — lives in numpy arrays; placement scoring and the
decode-segment arithmetic run as array kernels (the scoring twins live in
:mod:`repro.core.placement`). The Python engine
(:func:`repro.serving.simulator.run_heartbeat_loop` over
``ColocatedTopology``) stays the oracle: this engine reproduces its
per-request ``(t_first_token, t_finish, l_out, t_decode_spent)``
**bit-for-bit** (pinned by tests/test_fastsim_equivalence.py), which demands
replicating the reference's floating-point operation order exactly:

* sequential left-associated accumulation (``np.cumsum`` /
  ``np.add.accumulate``) wherever the reference sums in a Python loop —
  never ``np.sum``, whose pairwise reduction rounds differently;
* the worker clock advances through ``np.add.accumulate([t, dur_0, ...])``,
  matching ``t += dur`` per iteration (``t + cumsum(durs)`` does not);
* multiply-add chains keep the scalar code's grouping
  (``k2*C + c2*b + c3`` as ``((k2*C) + (c2*b)) + c3``);
* ``capacity_norm`` keeps CPython's ``math.hypot`` (numpy's may differ in
  the last ulp, which could flip a best-fit ranking);
* integer-valued aggregates (context sums, KV peaks) are exact in float64
  and may be reduced in any order.

Supported envelope (everything else raises ``ValueError`` so ``api.run``
can fall back or the caller can switch engines explicitly): ``Colocated``
topology without ``split_phase``, ``FixedScale`` with an explicit worker
count (no elastic mode), no spot market, no length predictor, no observer;
policies ``aladdin`` / ``jsq`` / ``po2``. Heterogeneous fixed fleets are
supported — every per-worker coefficient is an array.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core.placement import (best_fit_order, decode_budget_arrays,
                                  jsq_order, kv_peak_arrays, slack_arrays)
from repro.core.request import ReqState, Request

DEFAULT_TAIL = 240.0

# above this expected iteration count a decode segment is evaluated as one
# array kernel; below it a scalar loop is cheaper (numpy call overhead)
_SEG_VECTOR_MIN = 16


def check_colocated_envelope(scenario) -> List:
    """Validate that ``scenario`` fits the vectorized engine's envelope and
    return the expanded per-worker spec list. Raises ``ValueError`` with the
    first unsupported feature otherwise."""
    from repro.serving import api

    if not isinstance(scenario.topology, api.Colocated):
        raise ValueError("vectorized engine supports Colocated topologies "
                         f"only, not {type(scenario.topology).__name__}")
    topo = scenario.topology
    if topo.split_phase:
        raise ValueError("vectorized engine does not support split_phase "
                         "(decode-pool-only) simulation")
    if topo.policy not in ("aladdin", "jsq", "po2"):
        raise ValueError(f"unknown placement policy {topo.policy!r}")
    if not isinstance(scenario.scaling, api.FixedScale):
        raise ValueError("vectorized engine supports FixedScale only; "
                         "autoscaled scenarios need engine='reference'")
    if scenario.market is not None:
        raise ValueError("vectorized engine does not support a spot market")
    if scenario.predictor is not None:
        raise ValueError("vectorized engine does not support length "
                         "predictors (l_pred must equal l_real)")
    if scenario.observer is not None:
        raise ValueError("vectorized engine does not support observers "
                         "(there are no per-worker objects to observe)")
    pools = scenario.fleet.for_role("serve")
    if not pools:
        raise ValueError("colocated scenario needs at least one fleet pool")
    if scenario.scaling.n is not None:
        specs = [pools[0].spec] * int(scenario.scaling.n)
    else:
        specs = [p.spec for p in pools for _ in range(p.count)]
    if not specs:
        raise ValueError("vectorized engine needs an explicit worker count "
                         "(elastic mode needs engine='reference')")
    if scenario.workload is None:
        raise ValueError("scenario needs a workload trace")
    if scenario.slo.ttft <= 0 or scenario.slo.atgt <= 0:
        raise ValueError("SLO targets must be positive "
                         f"(ttft={scenario.slo.ttft}, "
                         f"atgt={scenario.slo.atgt})")
    if not topo.heartbeat > 0:
        raise ValueError("heartbeat must be a positive interval "
                         f"(got {topo.heartbeat})")
    if not 0.0 < topo.theta <= 1.0:
        raise ValueError(f"theta must be in (0, 1] (got {topo.theta})")
    if not math.isfinite(topo.gamma):
        raise ValueError(f"gamma must be finite (got {topo.gamma})")
    if int(topo.max_batch) < 1:
        raise ValueError(f"max_batch must be >= 1 (got {topo.max_batch})")
    if not isinstance(topo.rebalance, bool):
        raise ValueError("rebalance must be a bool "
                         f"(got {topo.rebalance!r})")
    if int(scenario.seed) < 0:
        raise ValueError(f"seed must be non-negative (got {scenario.seed})")
    if scenario.engine not in ("reference", "vectorized", "jax"):
        raise ValueError(f"unknown engine {scenario.engine!r}")
    return specs


class _Engine:
    """One vectorized colocated simulation (struct-of-arrays state)."""

    def __init__(self, specs: Sequence, trace: Sequence[Request], topo, slo,
                 seed: int, tail: float = DEFAULT_TAIL):
        self.policy = topo.policy
        self.hb = float(topo.heartbeat)
        self.gamma = float(topo.gamma)
        self.theta = float(topo.theta)
        self.slo = slo
        self.tail = float(tail)
        self.rng = np.random.default_rng(seed)
        self.specs = list(specs)
        W = len(specs)
        self.W = W

        # ---- per-worker coefficient arrays (+ Python-float twins) ----------
        self.K1 = np.array([s.perf.prefill.k1 for s in specs])
        self.C1 = np.array([s.perf.prefill.c1 for s in specs])
        self.K2 = np.array([s.perf.decode.k2 for s in specs])
        self.C2 = np.array([s.perf.decode.c2 for s in specs])
        self.C3 = np.array([s.perf.decode.c3 for s in specs])
        self.H = np.array([s.perf.kv.h for s in specs])
        self.J = np.array([s.perf.kv.j for s in specs])
        self.M = np.array([s.kv_capacity for s in specs])
        self.MAXB = np.array([s.max_batch for s in specs], dtype=np.int64)
        # capacity_norm denominators: max(max_batch, 1) and
        # max(max_total_context(1, atgt) or 1.0, 1.0), fixed per worker
        self.maxb_norm = [max(int(s.max_batch), 1) for s in specs]
        self.cmax_norm = []
        for s in specs:
            cmax = s.perf.decode.max_total_context(1, slo.atgt) or 1.0
            self.cmax_norm.append(max(cmax, 1.0))
        self.coef = [(float(s.perf.prefill.k1), float(s.perf.prefill.c1),
                      float(s.perf.decode.k2), float(s.perf.decode.c2),
                      float(s.perf.decode.c3), float(s.perf.kv.h),
                      float(s.perf.kv.j), float(s.kv_capacity),
                      int(s.max_batch)) for s in specs]

        # ---- request struct-of-arrays (sorted by arrival, stable) ----------
        order = sorted(range(len(trace)), key=lambda i: trace[i].arrival)
        self.trace = [trace[i] for i in order]
        n = len(self.trace)
        self.n = n
        self.arrival = np.array([r.arrival for r in self.trace])
        self.l_in = np.array([r.l_in for r in self.trace], dtype=np.int64)
        self.l_real = np.array([r.l_real for r in self.trace],
                               dtype=np.int64)
        # no predictor in the envelope: admit() sets l_pred = l_real
        self.l_pred = self.l_real
        self.l_out = np.zeros(n, dtype=np.int64)
        self.tds = np.zeros(n)                      # t_decode_spent
        self.t_first = np.full(n, np.nan)
        self.t_fin = np.full(n, np.nan)

        # ---- mutable worker state ------------------------------------------
        Bcap = max(int(self.MAXB.max()), 1) if W else 1
        self.mem = np.full((W, Bcap), -1, dtype=np.int64)   # ongoing members
        self.cnt = np.zeros(W, dtype=np.int64)
        self.bsz = np.zeros(W, dtype=np.int64)      # cnt + len(newb)
        self.t_w = np.zeros(W)                      # local worker clocks
        self.ctx = np.zeros(W, dtype=np.int64)      # Σ context over ongoing
        self.wctx = np.zeros(W)                     # weighted-context cache
        self.dirty = np.ones(W, dtype=bool)
        self.norm = np.zeros(W)                     # capacity_norm cache
        self.newb: List[List[int]] = [[] for _ in range(W)]
        self.pre: List[List[int]] = [[] for _ in range(W)]
        self.newsum = np.zeros(W, dtype=np.int64)   # Σ l_in over newb
        self.queued: List[int] = []
        self.fin_order: List[int] = []      # finish order (oracle's order)
        self.preemptions = 0
        self.beats = 0

    def _grow_mem(self) -> None:
        # resumes can push a batch past max_batch (placement bounds only
        # new admissions, like the scalar engine's unbounded ongoing list)
        W, B = self.mem.shape
        nm = np.full((W, 2 * B), -1, dtype=np.int64)
        nm[:, :B] = self.mem
        self.mem = nm

    # ---- weighted-context / capacity-norm caches ---------------------------

    def _recompute_wctx(self) -> None:
        """Ordered recompute of the weighted-context cache for dirty workers
        (sequential cumsum over ongoing-then-new_batch, like the scalar
        ``_wctx_now``)."""
        g = self.gamma
        for wi in np.nonzero(self.dirty)[0]:
            cnt = int(self.cnt[wi])
            nb = self.newb[wi]
            if cnt == 0 and not nb:
                self.wctx[wi] = 0.0
            else:
                m = self.mem[wi, :cnt]
                vals = self.l_in[m] + g * self.l_pred[m]
                if nb:
                    nba = np.asarray(nb, dtype=np.int64)
                    vals = np.concatenate(
                        [vals, self.l_in[nba] + g * self.l_pred[nba]])
                self.wctx[wi] = np.cumsum(vals)[-1]
            self.dirty[wi] = False

    def _refresh_norms(self) -> None:
        for wi in range(self.W):
            self.norm[wi] = math.hypot(
                self.bsz[wi] / self.maxb_norm[wi],
                self.wctx[wi] / self.cmax_norm[wi])

    def _kv_peak_with(self, wi: int, ridx: int) -> float:
        cnt = int(self.cnt[wi])
        ids = self.mem[wi, :cnt]
        extra = self.newb[wi] + [ridx]
        ids = np.concatenate([ids, np.asarray(extra, dtype=np.int64)])
        rem = np.maximum(self.l_pred[ids] - self.l_out[ids], 0)
        ctx = self.l_in[ids] + self.l_out[ids]
        _, _, _, _, _, h, j, _, _ = self.coef[wi]
        return kv_peak_arrays(rem, ctx, h, j)

    # ---- placement ---------------------------------------------------------

    def _place(self, wi: int, ridx: int, v: float, li: int) -> None:
        self.newb[wi].append(ridx)
        self.newsum[wi] += li
        self.bsz[wi] += 1
        self.wctx[wi] += v
        self.norm[wi] = math.hypot(
            self.bsz[wi] / self.maxb_norm[wi],
            self.wctx[wi] / self.cmax_norm[wi])

    def _place_all_aladdin(self) -> None:
        theta = self.theta
        atgt = self.slo.atgt
        ttft = self.slo.ttft
        g = self.gamma
        self._recompute_wctx()
        self._refresh_norms()
        # constraint (d) slack is over *ongoing* members only — fixed for
        # the whole placement pass
        B = self.mem.shape[1]
        mask_slots = np.arange(B)[None, :] < self.cnt[:, None]
        slack = slack_arrays(self.l_out[self.mem], self.tds[self.mem],
                             mask_slots, atgt)
        d_budget = theta * np.maximum(slack, 0.0)
        still: List[int] = []
        for ridx in self.queued:
            li = int(self.l_in[ridx])
            v = li + g * int(self.l_pred[ridx])
            bpost = self.bsz + 1
            okb = (bpost <= self.MAXB) & (
                self.wctx + v <= theta * decode_budget_arrays(
                    bpost, atgt, self.K2, self.C2, self.C3))
            pre_t = self.K1 * (self.newsum + li) + self.C1
            mask = okb & (pre_t <= ttft) & (pre_t <= d_budget)
            placed = False
            if mask.any():
                for wi in best_fit_order(self.norm):
                    wi = int(wi)
                    if not mask[wi]:
                        continue
                    if self._kv_peak_with(wi, ridx) \
                            <= theta * self.coef[wi][7]:
                        self._place(wi, ridx, v, li)
                        placed = True
                        break
            if not placed:
                still.append(ridx)
        self.queued[:] = still

    def _place_all_jsq(self) -> None:
        still: List[int] = []
        for ridx in self.queued:
            li = int(self.l_in[ridx])
            csum = self.ctx + self.newsum       # Σ context incl. new_batch
            kv_now = (self.H * csum + self.J * self.bsz) \
                + (self.H * li + self.J)
            mask = (kv_now <= self.M) & (self.bsz + 1 <= self.MAXB)
            order = jsq_order(self.bsz)
            hit = np.nonzero(mask[order])[0]
            if hit.size:
                wi = int(order[hit[0]])
                self._place(wi, ridx, li + self.gamma * int(
                    self.l_pred[ridx]), li)
            else:
                still.append(ridx)
        self.queued[:] = still

    def _admit_naive_scalar(self, wi: int, li: int) -> bool:
        _, _, _, _, _, h, j, M, maxb = self.coef[wi]
        csum = int(self.ctx[wi]) + int(self.newsum[wi])
        own = int(self.bsz[wi])
        kv_now = (h * csum + j * own) + (h * li + j)
        return kv_now <= M and own + 1 <= maxb

    def _place_all_po2(self) -> None:
        self._recompute_wctx()
        W = self.W
        g = self.gamma
        still: List[int] = []
        for ridx in self.queued:
            li = int(self.l_in[ridx])
            v = li + g * int(self.l_pred[ridx])
            if W >= 2:
                i, jj = self.rng.choice(W, size=2, replace=False)
                cands = sorted((int(i), int(jj)),
                               key=lambda w: self.wctx[w])
            else:
                cands = list(range(W))
            placed = False
            for wi in cands:
                if self._admit_naive_scalar(wi, li):
                    self._place(wi, ridx, v, li)
                    placed = True
                    break
            if not placed:
                for wi in np.argsort(self.wctx, kind="stable"):
                    wi = int(wi)
                    if wi in cands:
                        continue
                    if self._admit_naive_scalar(wi, li):
                        self._place(wi, ridx, v, li)
                        placed = True
                        break
            if not placed:
                still.append(ridx)
        self.queued[:] = still

    # ---- worker advance ----------------------------------------------------

    def _advance(self, wi: int, t_start: float, t_end: float) -> None:
        k1, c1, k2, c2, c3, h, j, M, _ = self.coef[wi]
        mem = self.mem
        l_in = self.l_in
        l_out = self.l_out
        l_real = self.l_real
        tds = self.tds
        t_first = self.t_first
        t_fin = self.t_fin
        arrival = self.arrival
        t = float(self.t_w[wi])
        cnt = int(self.cnt[wi])
        ctx = int(self.ctx[wi])
        newb = self.newb[wi]
        pre = self.pre[wi]
        resume_thr = 0.9 * M
        while t < t_end:
            # resume preempted requests when KV frees up (recompute: prompt
            # AND generated tokens re-prefill). Like the scalar engine, the
            # admission test uses the pre-resume occupancy for every pop.
            resume: List[int] = []
            while pre:
                cand = pre[0]
                occ = (h * ctx + j * cnt) \
                    + h * (int(l_in[cand]) + int(l_out[cand])) + j
                if occ > resume_thr:
                    break
                resume.append(pre.pop(0))
            if newb or resume:
                total_in = sum(int(l_in[r]) + int(l_out[r]) for r in newb) \
                    + sum(int(l_in[r]) + int(l_out[r]) for r in resume)
                dur = k1 * total_in + c1
                t += dur
                # prefill preempts decode: ongoing + still-preempted +
                # resumed victims all stall through it
                if cnt:
                    tds[mem[wi, :cnt]] += dur
                for r in pre:
                    tds[r] += dur
                for r in resume:
                    tds[r] += dur
                for r in newb:
                    t_first[r] = t
                    l_out[r] = 1
                    if cnt == mem.shape[1]:
                        self._grow_mem()
                        mem = self.mem
                    mem[wi, cnt] = r
                    cnt += 1
                    ctx += int(l_in[r]) + 1
                for r in resume:
                    if cnt == mem.shape[1]:
                        self._grow_mem()
                        mem = self.mem
                    mem[wi, cnt] = r
                    cnt += 1
                    ctx += int(l_in[r]) + int(l_out[r])
                newb.clear()
                self.newsum[wi] = 0
                continue
            if cnt == 0:
                t = t_end
                break
            # KV overflow -> preempt the youngest (recompute semantics)
            while h * ctx + j * cnt > M and cnt > 1:
                row = mem[wi, :cnt]
                vpos = int(np.argmax(arrival[row]))
                victim = int(row[vpos])
                ctx -= int(l_in[victim]) + int(l_out[victim])
                mem[wi, vpos:cnt - 1] = mem[wi, vpos + 1:cnt]
                cnt -= 1
                pre.append(victim)
                self.preemptions += 1
            # decode segment: batch fixed until finish/overflow/heartbeat
            b = cnt
            row = mem[wi, :cnt]
            n_fin = int(np.min(np.maximum(l_real[row] - l_out[row], 1)))
            C = ctx
            k = 0
            seg = 0.0
            dur0 = k2 * C + c2 * b + c3
            est = (t_end - t) / dur0 if dur0 > 0 else float(n_fin)
            if n_fin <= _SEG_VECTOR_MIN or est <= _SEG_VECTOR_MIN \
                    or dur0 <= 0:
                while k < n_fin and t < t_end:
                    if k > 0 and h * C + j * b > M and b > 1:
                        break
                    dur = k2 * C + c2 * b + c3
                    t += dur
                    seg += dur
                    C += b
                    k += 1
            else:
                kmax = min(n_fin, int(est) + 2)
                ks = np.arange(kmax, dtype=np.int64)
                C_k = C + ks * b
                cb = c2 * b
                durs = k2 * C_k + cb + c3
                t_traj = np.add.accumulate(
                    np.concatenate(([t], durs)))
                k = int(np.searchsorted(t_traj[:kmax], t_end, side="left"))
                if b > 1:
                    viol = h * C_k + j * b > M
                    viol[0] = False
                    nz = np.nonzero(viol)[0]
                    if nz.size:
                        k = min(k, int(nz[0]))
                if k > 0:
                    seg = float(np.add.accumulate(durs[:k])[-1])
                    t = float(t_traj[k])
                    C += k * b
            ctx = C
            l_out[row] += k
            tds[row] += seg
            done = l_out[row] >= l_real[row]
            if done.any():
                fin_ids = row[done]
                t_fin[fin_ids] = t
                self.fin_order.extend(int(r) for r in fin_ids)
                ctx -= int((l_in[fin_ids] + l_out[fin_ids]).sum())
                kept = row[~done]
                cnt = kept.shape[0]
                mem[wi, :cnt] = kept
            # preempted requests' ATGT clocks also advance (stalled)
            for r in pre:
                tds[r] += seg
        self.t_w[wi] = t
        self.cnt[wi] = cnt
        self.ctx[wi] = ctx
        self.bsz[wi] = cnt + len(newb)
        self.dirty[wi] = True

    # ---- the heartbeat loop ------------------------------------------------

    def _step(self, t: float, t_next: float) -> None:
        if self.queued:
            if self.policy == "aladdin":
                self._place_all_aladdin()
            elif self.policy == "jsq":
                self._place_all_jsq()
            else:
                self._place_all_po2()
        t_w = self.t_w
        cnt = self.cnt
        for wi in range(self.W):
            if cnt[wi] == 0 and not self.newb[wi] and not self.pre[wi]:
                # idle worker: the scalar loop just fast-forwards its clock
                if t_w[wi] < t_next:
                    t_w[wi] = t_next
                self.dirty[wi] = True
            else:
                self._advance(wi, t, t_next)

    def _drained(self) -> bool:
        return (not self.queued and int(self.cnt.sum()) == 0
                and all(not nb for nb in self.newb)
                and all(not p for p in self.pre))

    def run(self) -> None:
        n = self.n
        horizon = (float(self.arrival[n - 1]) if n else 0.0) + self.tail
        hb = self.hb
        arr = self.arrival
        t = 0.0
        idx = 0
        queued = self.queued
        while t < horizon:
            t_next = t + hb
            while idx < n and arr[idx] <= t:
                queued.append(idx)
                idx += 1
            self._step(t, t_next)
            self.beats += 1
            t = t_next
            if idx >= n and self._drained():
                break

    # ---- results -----------------------------------------------------------

    def writeback(self) -> List[Request]:
        """Scatter the array state back onto the ``Request`` objects (the
        same mutation contract as the reference engine) and return the
        finished sublist in *finish order* — ``np.mean``/``np.percentile``
        are pairwise reductions, so matching the oracle's report to the
        last ulp needs the oracle's list order, not just its members."""
        for pos, r in enumerate(self.trace):
            r.l_pred = int(self.l_pred[pos])
            r.l_out = int(self.l_out[pos])
            r.t_decode_spent = float(self.tds[pos])
            tf = self.t_first[pos]
            r.t_first_token = None if math.isnan(tf) else float(tf)
            te = self.t_fin[pos]
            if not math.isnan(te):
                r.t_finish = float(te)
                r.state = ReqState.FINISHED
        return [self.trace[i] for i in self.fin_order]


def run_colocated_vectorized(scenario, seed: Optional[int] = None,
                             tail: float = DEFAULT_TAIL):
    """Run a colocated ``Scenario`` on the struct-of-arrays engine and
    return the same :class:`~repro.serving.api.RunReport` the reference
    engine would produce (bit-for-bit on the supported envelope)."""
    from repro.serving import api

    specs = check_colocated_envelope(scenario)
    s = seed if seed is not None else scenario.seed
    trace = scenario.materialize()
    eng = _Engine(specs, trace, scenario.topology, scenario.slo, s,
                  tail=tail)
    eng.run()
    finished = eng.writeback()
    rep = api.RunReport(topology="colocated", scaling="fixed",
                        **api._percentiles(finished, len(trace),
                                           scenario.slo))
    rep.peak_workers = eng.W
    rep.gpu_cost = sum(sp.n_accelerators for sp in specs)
    rep.moves = 0
    rep.beats = eng.beats       # benchmark side channel (not in row())
    return rep
