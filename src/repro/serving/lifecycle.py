"""The one condemn/kill/reap worker-lifecycle state machine.

Every worker container in the engine — the static colocated fleet
(``simulator.FixedPool``), both static disaggregated sides
(``disagg.FixedPrefillSide`` / ``disagg.FixedDecodeSide``) and the
policy-scaled ``forecast.ManagedPool`` — faces the same three questions when
a spot market reclaims capacity:

  * **condemn** — with a preemption notice, a victim stops taking new work
    and drains until ``t + notice_s``;
  * **kill** — without a notice (or at the notice deadline), the victim
    dies now: its in-flight requests are extracted, stamped with their
    recovery cost class, and handed back to the queue;
  * **reap** — each beat, condemned workers that drained empty retire
    cleanly (``drained_ok``), the rest are killed once their deadline
    passes.

Those transitions used to be four near-identical copies, each wired to its
container's innards. :class:`WorkerLifecycle` is that machine written once,
parameterized by what genuinely differs per container:

  ``extract(w)``   strip and return the worker's in-flight requests
  ``mark(r, t)``   stamp the recovery cost class on one lost request
                   (``mark_kv_loss`` for decode-capable workers whose KV
                   dies with them, ``mark_requeue`` for prefill queues)
  ``idle(w)``      is the worker empty (safe to retire)
  ``remove(w)``    physically take the worker out of its container
                   (including any retirement-cost accounting)
  ``on_condemn(w)`` flag the worker as draining so placement avoids it

The victim-selection RNG discipline (one ``rng.choice`` over the eligible
pool per event) and the counter semantics (``killed`` / ``drained_ok`` /
``requeued``) are part of the machine, so every container reports reclaim
accounting identically — tests/test_lifecycle_property.py fuzzes the same
interleavings through all four call sites.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.request import ReqState, Request


def mark_kv_loss(r: Request, t: float) -> None:
    """Default reclaim marking: the victim's KV is gone — the request
    requeues keeping ``l_out`` and pays a full context re-prefill plus the
    stall from the reclaim instant (settled by the simulator core)."""
    r.state = ReqState.QUEUED
    r.worker = None
    r.t_preempted = t
    r.preempt_count += 1


def mark_requeue(r: Request, t: float) -> None:
    """Prefill-side reclaim marking: no KV existed yet, so the only cost is
    the extra queue wait — which TTFT already measures (no ``t_preempted``
    stall is armed; the token stream has not started)."""
    r.state = ReqState.QUEUED
    r.worker = None
    r.preempt_count += 1


class WorkerLifecycle:
    """Condemn/kill/reap state machine shared by every worker container.

    Owns the condemned set (worker id -> notice deadline) and the reclaim
    counters; container-specific behavior enters only through the adapter
    callables described in the module docstring."""

    def __init__(self, rng, *, notice_s: float = 0.0,
                 extract: Callable[[object], List[Request]],
                 mark: Callable[[Request, float], None],
                 idle: Callable[[object], bool],
                 remove: Callable[[object], None],
                 on_condemn: Optional[Callable[[object], None]] = None):
        self.rng = rng
        self.notice_s = notice_s
        self._extract = extract
        self._mark = mark
        self._idle = idle
        self._remove = remove
        self._on_condemn = on_condemn or (lambda w: None)
        self.condemned: Dict[int, float] = {}     # wid -> kill deadline
        self.killed = 0
        self.drained_ok = 0
        self.requeued = 0

    # ---- victim selection ---------------------------------------------------
    def eligible(self, workers: Sequence) -> List:
        """The workers a market event may take: spot-priced and not already
        condemned by an earlier event (the provider is taking those back
        regardless — they are not fresh capacity)."""
        return [w for w in workers
                if w.spec.is_spot and w.id not in self.condemned]

    def reclaim(self, t: float, ev, candidates: Sequence,
                boots: Sequence = (),
                cancel_boot: Optional[Callable] = None) -> List[Request]:
        """One market reclaim event: take ``ceil(ev.frac * alive)`` victims
        (at least one) uniformly from ``candidates`` plus any ``boots``
        (still-booting workers, which die by cancellation — they never held
        requests). Without a notice window victims are killed on the spot;
        with one they are condemned to drain. Returns the requests knocked
        back into the queue."""
        alive = len(candidates) + len(boots)
        if alive == 0:
            return []
        n_kill = min(max(int(math.ceil(ev.frac * alive)), 1), alive)
        victims = self.rng.choice(alive, size=n_kill, replace=False)
        lost_all: List[Request] = []
        for vi in victims:
            if vi < len(candidates):
                w = candidates[vi]
                if self.notice_s > 0.0:
                    self.condemn(w, t)
                else:
                    lost_all += self.kill(w, t)
            else:
                cancel_boot(boots[vi - len(candidates)])
        return lost_all

    # ---- transitions --------------------------------------------------------
    def condemn(self, w, t: float) -> None:
        """Preemption notice: the worker drains (no new admissions) until
        ``t + notice_s``; whatever still runs at the deadline is killed."""
        self._on_condemn(w)
        self.condemned[w.id] = t + self.notice_s

    def kill(self, w, t: float) -> List[Request]:
        """The worker dies now: extract its in-flight requests, stamp each
        with the recovery cost class, and remove it from the container."""
        self.condemned.pop(w.id, None)
        lost = self._extract(w)
        self._remove(w)
        for r in lost:
            self._mark(r, t)
        self.killed += 1
        self.requeued += len(lost)
        return lost

    def retire_if_idle(self, w) -> bool:
        """Retire a draining worker that emptied out; counted ``drained_ok``
        only when it was inside a notice window (voluntary scale-down drains
        retire silently)."""
        if not self._idle(w):
            return False
        self._remove(w)
        if self.condemned.pop(w.id, None) is not None:
            self.drained_ok += 1
        return True

    def reap(self, t: float, lookup: Callable[[int], Optional[object]],
             retire_idle: bool = True) -> List[Request]:
        """Per-beat pass over the condemned set: workers that drained empty
        retire (when ``retire_idle``; containers with their own drain
        retirement — ManagedPool's end-of-beat — pass False), workers past
        their deadline are killed. ``lookup(wid)`` resolves a condemned id
        to the live worker, or None when it already retired."""
        lost_all: List[Request] = []
        for wid, deadline in list(self.condemned.items()):
            w = lookup(wid)
            if w is None:                 # already retired as drained_ok
                self.condemned.pop(wid, None)
                continue
            if retire_idle and self.retire_if_idle(w):
                continue
            if t >= deadline:
                lost_all += self.kill(w, t)
        return lost_all
