"""Discrete-event cluster simulator (paper §6.4's methodology).

Workers advance through prefill/decode iterations whose durations come from
the fitted performance models (Eqs. 2-3); the scheduler (Aladdin best-fit /
JSQ / power-of-two) places requests at heartbeat boundaries, re-balances
against prediction error (Algorithm 2), and the autoscaler (Eq. 7) tracks
demand. Used to measure the minimum worker count that attains the SLOs at a
given arrival rate — the paper's cost metric."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.perf_model import PerfModel
from repro.core.placement import (PlacementConfig, WorkerState,
                                  best_fit_place, jsq_place,
                                  power_of_two_place)
from repro.core.rebalance import ErrorTracker, rebalance
from repro.core.request import ReqState, Request
from repro.core.scaling import Autoscaler
from repro.core.slo import SLO
from repro.serving.length_predictor import LengthPredictor


@dataclasses.dataclass
class SimConfig:
    heartbeat: float = 0.25
    policy: str = "aladdin"          # aladdin | jsq | po2
    split_phase: bool = False        # decode-pool-only simulation (Fig. 12)
    rebalance: bool = True
    gamma: float = 0.5
    theta: float = 0.9
    max_batch: int = 128
    seed: int = 0


class SimWorker:
    """Execution model of one worker: runs iterations in virtual time."""

    def __init__(self, state: WorkerState, perf: PerfModel, now: float,
                 split_phase: bool):
        self.state = state
        self.perf = perf
        self.t = now                    # local clock
        self.split_phase = split_phase
        self.iters = 0
        self.preempted: List[Request] = []   # KV-overflow victims (vLLM
        self.preemptions = 0                 # recompute-preemption semantics)

    def _kv_now(self) -> float:
        kv = self.perf.kv
        return sum(float(kv(r.context)) for r in self.state.ongoing)

    def advance_to(self, t_end: float, finished: List[Request],
                   t_start: Optional[float] = None) -> None:
        w = self.state
        M = w.cfg.kv_capacity
        if t_start is not None and (w.new_batch or self.preempted):
            # work placed at the heartbeat boundary cannot start earlier
            self.t = max(self.t, t_start)
        while self.t < t_end:
            # resume preempted requests when KV frees up (recompute: the
            # prompt AND the already-generated tokens are re-prefilled)
            resume = []
            while self.preempted and self._kv_now() + float(
                    self.perf.kv(self.preempted[0].context)) <= 0.9 * M:
                resume.append(self.preempted.pop(0))
            # start any newly placed requests (prefill)
            if (w.new_batch or resume) and not self.split_phase:
                total_in = sum(r.l_in for r in w.new_batch) \
                    + sum(r.context for r in resume)
                dur = float(self.perf.prefill(total_in))
                self.t += dur
                # the prefill preempts decode: ongoing requests stall and
                # their ATGT clocks keep running (this is what constraint (d)
                # budgets and what naive placement ignores)
                for r in w.ongoing + self.preempted:
                    r.t_decode_spent += dur
                for r in w.new_batch:
                    r.t_first_token = self.t
                    r.l_out = 1
                    r.state = ReqState.DECODING
                    w.ongoing.append(r)
                for r in resume:
                    r.state = ReqState.DECODING
                    w.ongoing.append(r)
                w.new_batch.clear()
                self.iters += 1
                continue
            if w.new_batch and self.split_phase:
                # decode pool: requests arrive pre-filled
                for r in w.new_batch:
                    r.t_first_token = self.t
                    r.l_out = max(r.l_out, 1)
                    r.state = ReqState.DECODING
                    w.ongoing.append(r)
                w.new_batch.clear()
            if self.split_phase and resume:
                for r in resume:
                    r.state = ReqState.DECODING
                    w.ongoing.append(r)
            if not w.ongoing:
                self.t = t_end
                break
            # KV overflow -> preempt the youngest requests (recompute mode):
            # their decode clock keeps running against the ATGT SLO.
            while self._kv_now() > M and len(w.ongoing) > 1:
                victim = max(w.ongoing, key=lambda r: r.arrival)
                w.ongoing.remove(victim)
                victim.state = ReqState.QUEUED
                self.preempted.append(victim)
                self.preemptions += 1
            b = len(w.ongoing)
            total_ctx = sum(r.context for r in w.ongoing)
            dur = float(self.perf.decode(b, total_ctx))
            self.t += dur
            self.iters += 1
            for r in list(w.ongoing):
                r.l_out += 1
                r.t_decode_spent += dur
                if r.l_out >= r.l_real:
                    r.state = ReqState.FINISHED
                    r.t_finish = self.t
                    w.ongoing.remove(r)
                    finished.append(r)
            # preempted requests' ATGT clocks also advance (they are stalled)
            for r in self.preempted:
                r.t_decode_spent += dur


@dataclasses.dataclass
class SimResult:
    n_workers_peak: int
    attainment: float
    p99_atgt: float
    p99_ttft: float
    mean_atgt: float
    finished: int
    total: int
    moves: int = 0

    def row(self) -> Dict:
        return dataclasses.asdict(self)


def simulate(trace: Sequence[Request], perf: PerfModel, slo: SLO,
             kv_capacity: float, cfg: SimConfig,
             n_workers: Optional[int] = None,
             predictor: Optional[LengthPredictor] = None) -> SimResult:
    """Run the serving simulation. n_workers fixed (None = elastic: open a
    worker whenever placement fails, i.e. the min-cost oracle mode)."""
    rng = np.random.default_rng(cfg.seed)
    pcfg = PlacementConfig(gamma=cfg.gamma, theta=cfg.theta,
                           kv_capacity=kv_capacity, max_batch=cfg.max_batch,
                           split_phase=cfg.split_phase)
    tracker = ErrorTracker()
    wid_counter = [0]

    def factory() -> WorkerState:
        wid_counter[0] += 1
        return WorkerState(wid_counter[0], pcfg, perf, slo)

    workers: List[WorkerState] = []
    sims: Dict[int, SimWorker] = {}
    if n_workers:
        for _ in range(n_workers):
            w = factory()
            workers.append(w)
            sims[w.id] = SimWorker(w, perf, 0.0, cfg.split_phase)

    trace = sorted(trace, key=lambda r: r.arrival)
    horizon = max(r.arrival for r in trace) + 240.0
    finished: List[Request] = []
    queued: List[Request] = []
    idx = 0
    moves = 0
    t = 0.0
    peak_workers = len(workers)
    while t < horizon:
        t_next = t + cfg.heartbeat
        # arrivals in this heartbeat
        while idx < len(trace) and trace[idx].arrival < t_next:
            r = trace[idx]
            r.l_pred = predictor.predict(r.l_in) if predictor else r.l_real
            queued.append(r)
            idx += 1
        # re-prediction for underruns (Algorithm 2 inputs)
        for w in workers:
            for r in w.ongoing:
                if r.l_out > r.l_pred and not r.repredicted and predictor:
                    tracker.on_underrun(r, predictor.repredict(r.l_in,
                                                               r.l_out))
        # placement
        still: List[Request] = []
        for r in queued:
            fac = None if n_workers else factory
            if cfg.policy == "aladdin":
                w = best_fit_place(workers, r, allow_new=fac is not None,
                                   new_worker_factory=fac)
            elif cfg.policy == "jsq":
                w = jsq_place(workers, r, allow_new=fac is not None,
                              new_worker_factory=fac)
            else:
                w = power_of_two_place(workers, r, rng,
                                       allow_new=fac is not None,
                                       new_worker_factory=fac)
            if w is None:
                still.append(r)
            else:
                r.state = ReqState.PLACED
                if w.id not in sims:
                    sims[w.id] = SimWorker(w, perf, t, cfg.split_phase)
        queued = still
        if cfg.rebalance and cfg.policy == "aladdin":
            moves += rebalance(workers, tracker)
            tracker.decay()
        peak_workers = max(peak_workers, len(workers))
        # advance workers
        before = len(finished)
        for w in workers:
            sims[w.id].advance_to(t_next, finished, t_start=t)
        for r in finished[before:]:
            tracker.on_finish(r)
            if predictor:
                predictor.observe(r.l_in, r.l_real)
        t = t_next
        if idx >= len(trace) and not queued \
                and all(not w.ongoing and not w.new_batch for w in workers) \
                and all(not s.preempted for s in sims.values()):
            break

    atgts = [r.atgt() for r in finished if r.atgt() is not None]
    ttfts = [r.ttft() for r in finished if r.ttft() is not None]
    ok = [r for r in finished if r.slo_ok(slo)]
    total = len(trace)
    return SimResult(
        n_workers_peak=peak_workers,
        attainment=len(ok) / max(len(finished), 1) *
        (len(finished) / max(total, 1)),
        p99_atgt=float(np.percentile(atgts, 99)) if atgts else float("nan"),
        p99_ttft=float(np.percentile(ttfts, 99)) if ttfts else float("nan"),
        mean_atgt=float(np.mean(atgts)) if atgts else float("nan"),
        finished=len(finished), total=total, moves=moves)


def min_workers_for_slo(trace_fn, perf: PerfModel, slo: SLO,
                        kv_capacity: float, cfg: SimConfig,
                        attain_target: float = 0.99, lo: int = 1,
                        hi: int = 512,
                        predictor: Optional[LengthPredictor] = None) -> int:
    """Binary search the minimum fixed worker count attaining the SLO target
    (the paper's cost metric in Figs. 11/12)."""
    attain_hist = []

    def ok(n: int) -> bool:
        res = simulate(trace_fn(), perf, slo, kv_capacity, cfg, n_workers=n,
                       predictor=predictor)
        attain_hist.append((n, res.attainment))
        return res.attainment >= attain_target and res.finished == res.total

    escalations = 0
    while not ok(hi):
        # plateau detection: if doubling workers stops improving attainment,
        # the residual violations are scale-invariant (e.g. prediction-error
        # preemption tails) — the target is infeasible, not under-provisioned
        if len(attain_hist) >= 2 and \
                attain_hist[-1][1] <= attain_hist[-2][1] + 1e-3:
            raise RuntimeError(
                f"attainment plateaus at {attain_hist[-1][1]:.3f} < "
                f"{attain_target} (scale-invariant violations)")
        hi *= 2
        escalations += 1
        if hi > 8192 or escalations > 6:
            raise RuntimeError("workload cannot meet SLO at any scale")
    while lo < hi:
        mid = (lo + hi) // 2
        if ok(mid):
            hi = mid
        else:
            lo = mid + 1
    return lo
