"""Discrete-event cluster simulator (paper §6.4's methodology).

Workers advance through prefill/decode iterations whose durations come from
the fitted performance models (Eqs. 2-3); the scheduler (Aladdin best-fit /
JSQ / power-of-two) places requests at heartbeat boundaries, re-balances
against prediction error (Algorithm 2), and the autoscaler (Eq. 7) tracks
demand. Used to measure the minimum worker count that attains the SLOs at a
given arrival rate — the paper's cost metric.

Fleets may be heterogeneous: pass ``fleet`` (a list of ``WorkerSpec``) and
each simulated worker carries its own latency models, KV capacity, batch cap
and accelerator cost. The legacy (perf, kv_capacity) arguments describe a
homogeneous fleet and remain the default."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.perf_model import PerfModel
from repro.core.placement import (PlacementConfig, WorkerState,
                                  best_fit_place, jsq_place,
                                  power_of_two_place)
from repro.core.rebalance import ErrorTracker, rebalance
from repro.core.request import ReqState, Request
from repro.core.slo import SLO, windowed_attainment
from repro.core.worker_config import WorkerSpec
from repro.serving.length_predictor import LengthPredictor
from repro.serving.lifecycle import WorkerLifecycle, mark_kv_loss


def run_heartbeat_loop(trace: Sequence[Request], heartbeat: float,
                       admit: Callable[[Request], None],
                       step: Callable[[float, float, int], None],
                       drained: Callable[[], bool],
                       tail: float = 240.0,
                       events: Optional[Sequence] = None,
                       fire: Optional[Callable[[float, object], None]]
                       = None) -> List[Request]:
    """Causal-time heartbeat event core shared by every cluster simulator
    (colocated, disaggregated, autoscaled).

    Arrivals are admitted at the first heartbeat boundary ``t >= r.arrival``
    and never before it, so no simulator can see — let alone prefill — a
    request ahead of its arrival timestamp.  ``admit(r)`` is called once per
    request in timestamp order, ``step(t, t_next, arrived)`` runs one
    heartbeat over [t, t_next), and the loop ends when the trace is exhausted
    and ``drained()`` reports every queue empty (or at the horizon = last
    arrival + ``tail``).  Returns the time-sorted trace.

    ``events`` is an optional stream of external cluster events — objects
    with a ``t`` timestamp (e.g. ``workload.PreemptionEvent`` spot reclaims)
    — delivered via ``fire(t, event)`` under the same causal rule as
    arrivals: at the first boundary at-or-after the event time, before the
    heartbeat's ``step``, so a worker death is visible to placement in the
    beat it lands on and never earlier. Events past the drain point of an
    exhausted trace are dropped (there is nothing left for them to kill)."""
    trace = sorted(trace, key=lambda r: r.arrival)
    horizon = (trace[-1].arrival if trace else 0.0) + tail
    evs = sorted(events, key=lambda e: e.t) if events else []
    if evs and fire is None:
        raise ValueError("run_heartbeat_loop: events supplied without a "
                         "fire callback to deliver them")
    n = len(trace)
    idx = 0
    eidx = 0
    t = 0.0
    while t < horizon:
        t_next = t + heartbeat
        while idx < n and trace[idx].arrival <= t:
            admit(trace[idx])
            idx += 1
        while eidx < len(evs) and evs[eidx].t <= t:
            fire(t, evs[eidx])
            eidx += 1
        step(t, t_next, idx)
        t = t_next
        if idx >= n and drained():
            break
    return trace


@dataclasses.dataclass
class SimConfig:
    heartbeat: float = 0.25
    policy: str = "aladdin"          # aladdin | jsq | po2
    split_phase: bool = False        # decode-pool-only simulation (Fig. 12)
    rebalance: bool = True
    gamma: float = 0.5
    theta: float = 0.9
    max_batch: int = 128
    seed: int = 0
    # multi-turn sessions: how session-tagged requests are routed (sticky =
    # prefer the session's previous worker while feasible) and whether
    # workers keep an LRU prefix cache over finished session contexts
    # (cache_tokens caps its footprint; None = spare-KV pressure only).
    # Single-shot traces are arithmetically untouched by either knob.
    router: str = "blind"            # blind | sticky
    prefix_cache: str = "lru"        # lru | off
    cache_tokens: Optional[int] = None


class CacheStats:
    """Shared prefix-cache tally. One instance per topology: per-worker
    caches die with their workers (reclaims, drain retirement), so the
    hit/miss/eviction counts the run report surfaces must outlive them."""

    __slots__ = ("hits", "misses", "hit_tokens", "evictions")

    def __init__(self):
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0          # total prefill tokens skipped
        self.evictions = 0           # entries dropped (pressure + vaporize)

    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class PrefixCache:
    """Per-worker LRU over finished session prefixes (insertion-ordered
    dict; re-store moves an entry to the back, grant consumes it).

    The cache is a best-effort *renter* of the worker's spare KV: resident
    prefixes occupy ``kv.h`` bytes per token, but they never block
    placement, admission or live decode — the placement constraints and the
    KV-overflow preemption see live KV only, and live growth sheds cache
    entries LRU-first instead (``shed`` runs at every beat boundary, so
    ``h * resident <= capacity - live KV`` holds whenever an observer
    looks). A cache hit prices the next turn's prefill at
    ``context - cached_len``; a miss or eviction repays the full
    re-prefill."""

    def __init__(self, stats: CacheStats, cap_tokens: Optional[int] = None):
        self.stats = stats
        self.cap = cap_tokens
        self.entries: Dict[int, int] = {}   # session_id -> cached tokens
        self.resident = 0                   # Σ entries, tokens

    def peek(self, sid: int, prefix_len: int) -> int:
        """The reuse a grant would return, without consuming the entry
        (sticky routing checks home-worker feasibility with the discount
        the hit would buy, before committing the placement)."""
        ent = self.entries.get(sid)
        if ent is None or prefix_len <= 0:
            return 0
        return min(ent, prefix_len)

    def grant(self, sid: int, prefix_len: int) -> int:
        """Consume the session's entry at placement: the cached blocks
        convert into the request's live KV (full context is charged to the
        worker on admit, so the entry must leave the cache ledger)."""
        if prefix_len <= 0:
            return 0
        ent = self.entries.pop(sid, None)
        if ent is None:
            self.stats.misses += 1
            return 0
        self.resident -= ent
        got = min(ent, prefix_len)
        self.stats.hits += 1
        self.stats.hit_tokens += got
        return got

    def store(self, sid: int, tokens: int) -> None:
        old = self.entries.pop(sid, None)
        if old is not None:
            self.resident -= old
        self.entries[sid] = int(tokens)
        self.resident += int(tokens)
        if self.cap is not None:
            self.shed(self.cap)

    def shed(self, max_tokens: float) -> int:
        """Evict LRU-first until ``resident <= max_tokens``."""
        n = 0
        while self.entries and self.resident > max_tokens:
            sid = next(iter(self.entries))
            self.resident -= self.entries.pop(sid)
            n += 1
        self.stats.evictions += n
        return n

    def vaporize(self) -> int:
        """The worker died (spot reclaim) or retired (drain): every cached
        prefix is gone; returning turns repay their full prefill."""
        n = len(self.entries)
        self.entries.clear()
        self.resident = 0
        self.stats.evictions += n
        return n


class SimWorker:
    """Execution model of one worker: runs iterations in virtual time.

    The decode loop is event-batched: between finish/preemption/heartbeat
    events the batch composition is fixed, so each iteration costs O(1)
    (context sum and KV usage are tracked incrementally; the linear KV model
    makes current usage h·Σcontext + j·b) and per-request bookkeeping is
    applied once per segment instead of once per iteration."""

    def __init__(self, state: WorkerState, perf: PerfModel, now: float,
                 split_phase: bool):
        self.state = state
        self.perf = perf
        self.t = now                    # local clock
        self.split_phase = split_phase
        self.iters = 0
        self.preempted: List[Request] = []   # KV-overflow victims (vLLM
        self.preemptions = 0                 # recompute-preemption semantics)
        self._ctx = 0                        # Σ context over state.ongoing
        self.cache: Optional[PrefixCache] = None   # session prefix cache
                                             # (installed by the topology)

    def _kv_now(self) -> float:
        kv = self.perf.kv
        return kv.h * self._ctx + kv.j * len(self.state.ongoing)

    def _admit(self, r: Request) -> None:
        self.state.ongoing.append(r)
        self._ctx += r.context

    def advance_to(self, t_end: float, finished: List[Request],
                   t_start: Optional[float] = None) -> None:
        w = self.state
        M = w.cfg.kv_capacity
        kv = self.perf.kv
        dec = self.perf.decode
        if t_start is not None and (w.new_batch or self.preempted):
            # work placed at the heartbeat boundary cannot start earlier
            self.t = max(self.t, t_start)
        while self.t < t_end:
            # resume preempted requests when KV frees up (recompute: the
            # prompt AND the already-generated tokens are re-prefilled)
            resume = []
            while self.preempted and self._kv_now() + \
                    kv.h * self.preempted[0].context + kv.j <= 0.9 * M:
                resume.append(self.preempted.pop(0))
            # start any newly placed requests (prefill). A spot-preemption
            # re-entrant (l_out > 0: its worker was reclaimed mid-decode and
            # its KV lost) re-prefills prompt AND generated tokens — context,
            # not l_in — which is the recovery cost the spot mix planner must
            # out-save; for fresh requests context == l_in.
            if (w.new_batch or resume) and not self.split_phase:
                # a prefix-cache hit (cached_len > 0, granted at placement)
                # prices the prefill at the *new* tokens only; resumed
                # KV-overflow victims recompute in full (their cached_len
                # was consumed by their first prefill). Single-shot and
                # cache-off traces carry cached_len == 0: the integer sums
                # below are then bit-for-bit the undiscounted legacy image.
                total_in = sum(r.context - r.cached_len for r in w.new_batch) \
                    + sum(r.context for r in resume)
                dur = float(self.perf.prefill(total_in))
                self.t += dur
                # the prefill preempts decode: ongoing requests stall and
                # their ATGT clocks keep running (this is what constraint (d)
                # budgets and what naive placement ignores). Resumed victims
                # stall through their own re-prefill too — recompute
                # semantics: their decode clock never stopped.
                for r in w.ongoing + self.preempted + resume:
                    r.t_decode_spent += dur
                for r in w.new_batch:
                    if r.t_first_token is None:
                        r.t_first_token = self.t
                        r.l_out = 1
                    elif r.t_preempted is not None:
                        # token stream stalled from the reclaim instant until
                        # this re-prefill finished: queue wait + re-prefill
                        # both burn the ATGT budget (no token was generated)
                        r.t_decode_spent += max(self.t - r.t_preempted, 0.0)
                    r.t_preempted = None
                    r.state = ReqState.DECODING
                    self._admit(r)
                    r.cached_len = 0     # grant consumed by this prefill
                for r in resume:
                    r.state = ReqState.DECODING
                    self._admit(r)
                w.new_batch.clear()
                self.iters += 1
                continue
            if w.new_batch and self.split_phase:
                # decode pool: requests arrive pre-filled (first token — and
                # TTFT — may already be stamped by a disaggregated prefill
                # pool; only stamp it here for decode-pool-only traces)
                for r in w.new_batch:
                    if r.t_first_token is None:
                        r.t_first_token = self.t
                    elif r.t_preempted is not None:
                        # spot-preemption re-entrant: only the stall since
                        # the reclaim burns budget (decode time before it is
                        # already on the clock)
                        r.t_decode_spent += max(self.t - r.t_preempted, 0.0)
                    else:
                        # disaggregated handoff: KV transfer + decode-queue
                        # wait stalls the token stream after the first token,
                        # so it burns ATGT budget like a prefill stall does
                        r.t_decode_spent += max(self.t - r.t_first_token, 0.0)
                    r.t_preempted = None
                    r.l_out = max(r.l_out, 1)
                    r.state = ReqState.DECODING
                    self._admit(r)
                w.new_batch.clear()
            if self.split_phase and resume:
                for r in resume:
                    r.state = ReqState.DECODING
                    self._admit(r)
            if not w.ongoing:
                self.t = t_end
                break
            # KV overflow -> preempt the youngest requests (recompute mode):
            # their decode clock keeps running against the ATGT SLO.
            while self._kv_now() > M and len(w.ongoing) > 1:
                victim = max(w.ongoing, key=lambda r: r.arrival)
                w.ongoing.remove(victim)
                self._ctx -= victim.context
                victim.state = ReqState.QUEUED
                self.preempted.append(victim)
                self.preemptions += 1
            # decode segment: batch is fixed until the next finish /
            # KV-overflow / heartbeat event
            b = len(w.ongoing)
            n_fin = min(max(r.l_real - r.l_out, 1) for r in w.ongoing)
            C = self._ctx
            k = 0
            seg = 0.0
            while k < n_fin and self.t < t_end:
                if k > 0 and kv.h * C + kv.j * b > M and b > 1:
                    break               # preemption due before next iteration
                dur = dec.k2 * C + dec.c2 * b + dec.c3
                self.t += dur
                seg += dur
                C += b
                k += 1
            self._ctx = C
            self.iters += k
            for r in w.ongoing:
                r.l_out += k
                r.t_decode_spent += seg
            for r in list(w.ongoing):
                if r.l_out >= r.l_real:
                    r.state = ReqState.FINISHED
                    r.t_finish = self.t
                    w.ongoing.remove(r)
                    self._ctx -= r.context
                    finished.append(r)
                    if self.cache is not None and r.session_id >= 0:
                        # the finished turn's KV becomes the session's
                        # cacheable prefix for its next turn
                        self.cache.store(r.session_id, r.context)
            # preempted requests' ATGT clocks also advance (they are stalled)
            for r in self.preempted:
                r.t_decode_spent += seg
        if self.cache is not None:
            # beat-boundary pressure: cached prefixes only rent KV the live
            # batch is not using (h > 0 on any real spec; a degenerate h = 0
            # KV model prices blocks at zero, so nothing needs shedding)
            h = self.perf.kv.h
            if h > 0:
                self.cache.shed((M - self._kv_now()) / h)
        # this call mutated w.ongoing in ways the length-keyed aggregate
        # cache cannot see (a finish + a resume can swap membership at equal
        # length) — force one recompute before the next placement pass
        w.mark_dirty()


@dataclasses.dataclass
class SimResult:
    n_workers_peak: int
    attainment: float
    p99_atgt: float
    p99_ttft: float
    mean_atgt: float
    finished: int
    total: int
    moves: int = 0
    gpu_cost: float = 0.0            # Σ accelerators over the fleet

    def row(self) -> Dict:
        return dataclasses.asdict(self)


def make_worker_state(wid: int, spec: WorkerSpec, cfg: SimConfig,
                      slo: SLO) -> WorkerState:
    """Scheduler-side worker for ``spec`` under the simulation's placement
    knobs — the one construction path every topology and pool kind shares."""
    pcfg = PlacementConfig(gamma=cfg.gamma, theta=cfg.theta,
                           kv_capacity=spec.kv_capacity,
                           max_batch=spec.max_batch,
                           split_phase=cfg.split_phase)
    w = WorkerState(wid, pcfg, spec.perf, slo)
    w.spec = spec
    return w


class FixedPool:
    """Static worker container: the fleet of the classic ``simulate`` path.

    ``factory`` (elastic mode) lets placement open a worker whenever nothing
    fits — the min-cost oracle. A spot market may still reclaim workers out
    of a fixed fleet (they are simply not replaced): with a notice window the
    victim drains (``WorkerState.draining`` keeps placement away) and is
    killed at the deadline if work remains — all driven by the shared
    :class:`~repro.serving.lifecycle.WorkerLifecycle` machine."""

    def __init__(self, workers: List[WorkerState], sims: Dict[int, SimWorker],
                 rng, factory: Optional[Callable[[], WorkerState]] = None,
                 notice_s: float = 0.0):
        self.workers = workers
        self.sims = sims
        self.factory = factory
        self.retired_cost = 0.0     # accelerators of reclaimed/drained
        self.gpu_s = 0.0            # workers; fixed fleets bill no seconds
        self.spot_gpu_s = 0.0
        self.epochs: List = []
        self.life = WorkerLifecycle(
            rng, notice_s=notice_s, extract=self._extract,
            mark=mark_kv_loss, idle=self._is_idle, remove=self._remove,
            on_condemn=lambda w: setattr(w, "draining", True))

    # ---- WorkerLifecycle adapters -------------------------------------------
    def _extract(self, w: WorkerState) -> List[Request]:
        sim = self.sims.get(w.id)
        lost = w.ongoing + w.new_batch + (sim.preempted if sim else [])
        for r in lost:
            r.cached_len = 0    # the granted blocks die with the worker
        w.ongoing.clear()
        w.new_batch.clear()
        w.mark_dirty()
        return lost

    def _is_idle(self, w: WorkerState) -> bool:
        sim = self.sims.get(w.id)
        return not w.ongoing and not w.new_batch \
            and not (sim and sim.preempted)

    def _remove(self, w: WorkerState) -> None:
        self.workers.remove(w)
        self.retired_cost += w.spec.n_accelerators
        sim = self.sims.pop(w.id, None)
        if sim is not None and sim.cache is not None:
            sim.cache.vaporize()    # cached prefixes die with the worker

    @property
    def killed(self) -> int:
        return self.life.killed

    @property
    def drained_ok(self) -> int:
        return self.life.drained_ok

    @property
    def requeued(self) -> int:
        return self.life.requeued

    # ---- lifecycle hooks (static fleet: only the notice reaper) -------------
    def note_arrival(self) -> None:
        pass

    def serving(self) -> List[WorkerState]:
        return self.workers

    def active(self) -> List[WorkerState]:
        return self.workers

    def begin_beat(self, topo, t: float) -> None:
        if self.life.condemned:
            topo.requeue(self.life.reap(t, self._lookup))

    def end_beat(self, topo, t: float, t_next: float) -> None:
        pass

    def _lookup(self, wid: int) -> Optional[WorkerState]:
        return next((x for x in self.workers if x.id == wid), None)

    # ---- market reclaims ----------------------------------------------------
    def on_reclaim(self, t: float, ev) -> List[Request]:
        return self.life.reclaim(t, ev, self.life.eligible(self.workers))


class ColocatedTopology:
    """One colocated serving tier: queue -> placement (Algorithm 1 or a
    baseline) -> event-batched worker advance, over a pluggable worker
    container — ``FixedPool`` (fixed / elastic fleets) or
    ``forecast.ManagedPool`` (policy-driven boot/drain/bill lifecycle).
    The pluggable pool is what makes topology x scaling x market composable
    while every combination runs the same placement core and the same
    causal heartbeat loop."""

    def __init__(self, slo: SLO, cfg: SimConfig, pool, rng,
                 predictor: Optional[LengthPredictor] = None,
                 observer: Optional[Callable] = None, tracking: bool = True,
                 tenants: Optional[Sequence] = None):
        self.slo = slo
        self.cfg = cfg
        self.pool = pool
        self.rng = rng
        self.predictor = predictor
        self.observer = observer
        self.tracking = tracking       # Algorithm 2 repredict + rebalance
        self.tracker = ErrorTracker()
        self.queued: List[Request] = []
        self.finished: List[Request] = []
        self.moves = 0
        self.peak_workers = len(pool.serving())
        # multi-tenant serving: with >1 tenant the queue is ordered
        # priority-then-EDF before every placement pass; a single tenant
        # resolves to the legacy FIFO walk (bit-for-bit the scalar path).
        # ``restricted`` marks fleets where not every worker may serve
        # every request (dedicated pools / LoRA-capable workers) — it
        # filters placement candidates and disables cross-worker
        # rebalance moves (which do not re-check eligibility).
        self.tenants = list(tenants) if tenants is not None else None
        self.edf = self.tenants is not None and len(self.tenants) > 1
        self.restricted = False
        self.lora_swaps = 0
        self._lora: Dict[int, List[str]] = {}   # wid -> resident adapters
        # multi-turn sessions: the sticky session -> home-worker affinity
        # map and the shared cache tally (per-worker PrefixCaches are
        # installed lazily on each SimWorker; they die with their worker,
        # the tally must not). split_phase fleets never prefill, so a
        # prefill cache is meaningless there.
        if cfg.router not in ("blind", "sticky"):
            raise ValueError(f"unknown session router {cfg.router!r} "
                             "(expected 'blind' or 'sticky')")
        if cfg.prefix_cache not in ("lru", "off"):
            raise ValueError(f"unknown prefix_cache {cfg.prefix_cache!r} "
                             "(expected 'lru' or 'off')")
        self.cache_stats = CacheStats()
        self.session_home: Dict[int, int] = {}
        self._sticky = cfg.router == "sticky"
        self._caching = cfg.prefix_cache != "off" and not cfg.split_phase

    def admit(self, r: Request) -> None:
        r.l_pred = self.predictor.predict(r.l_in) if self.predictor \
            else r.l_real
        self.queued.append(r)
        self.pool.note_arrival()

    def requeue(self, reqs: List[Request], side: str = "serve") -> None:
        for r in reqs:
            r.cached_len = 0    # any granted prefix reuse is void off-worker
        self.queued.extend(reqs)

    def backlog_len(self, side: str = "serve") -> int:
        return len(self.queued)

    def slo_window(self, side: str, t_now: float, window: float,
                   metric: str = "both") -> tuple:
        """Windowed observed attainment for the SLO-feedback policies
        (``core.slo.windowed_attainment``); queued requests whose TTFT
        budget expired while waiting count as assured misses."""
        return windowed_attainment(self.finished, self.slo, t_now, window,
                                   metric, ttft_pending=self.queued)

    def fire(self, t: float, ev) -> None:
        self.requeue(self.pool.on_reclaim(t, ev))

    def _eligible(self, w: WorkerState, r: Request) -> bool:
        """Dedicated-pool / LoRA placement fence: a worker tagged with
        ``allowed_tenants`` only serves those tenants, and LoRA-tenant
        traffic needs a worker with adapter slots."""
        allowed = getattr(w, "allowed_tenants", None)
        if allowed is not None and r.tenant not in allowed:
            return False
        if self.tenants is not None \
                and self.tenants[r.tenant].lora is not None \
                and w.spec.lora_slots <= 0:
            return False
        return True

    def _lora_admit(self, w: WorkerState, r: Request, t: float) -> None:
        """Adapter residency accounting after a LoRA-tenant placement:
        fault the adapter in (LRU-evicting at ``lora_slots``), charge the
        worker's KV budget ``lora_overhead`` per resident adapter, and
        stall the worker ``lora_swap_s`` for the weight fetch (ongoing
        requests' ATGT clocks burn through the stall, like a prefill)."""
        adapter = self.tenants[r.tenant].lora if self.tenants else None
        if adapter is None:
            return
        res = self._lora.setdefault(w.id, [])
        if adapter in res:
            res.remove(adapter)
            res.append(adapter)         # LRU touch
            return
        spec = w.spec
        if len(res) >= spec.lora_slots:
            res.pop(0)
            w.cfg.kv_capacity += spec.lora_overhead
        res.append(adapter)
        w.cfg.kv_capacity -= spec.lora_overhead
        self.lora_swaps += 1
        if spec.lora_swap_s > 0.0:
            sim = self.pool.sims.get(w.id)
            if sim is not None:
                sim.t = max(sim.t, t) + spec.lora_swap_s
            for m in w.ongoing:
                m.t_decode_spent += spec.lora_swap_s

    def _cache(self, sim: SimWorker) -> Optional[PrefixCache]:
        if not self._caching:
            return None
        if sim.cache is None:
            sim.cache = PrefixCache(self.cache_stats,
                                    cap_tokens=self.cfg.cache_tokens)
        return sim.cache

    def _try_home(self, r: Request) -> Optional[WorkerState]:
        """Sticky routing: place the turn on its session's home worker —
        but only if the home is alive, not draining, eligible and passes
        every placement constraint *with the prefill discount its cache
        hit would buy*. An infeasible (or dead) home falls through to the
        configured placement policy like any other request."""
        wid = self.session_home.get(r.session_id)
        if wid is None:
            return None
        w = next((x for x in self.pool.serving() if x.id == wid), None)
        if w is None or not w.alive or w.draining:
            return None
        if self.restricted and not self._eligible(w, r):
            return None
        sim = self.pool.sims.get(wid)
        if sim is not None and sim.cache is not None:
            r.cached_len = sim.cache.peek(r.session_id, r.prefix_len)
        if w.feasible([r]):
            w.place(r)
            return w
        r.cached_len = 0        # discount only applies on the home worker
        return None

    def _place_one(self, r: Request) -> Optional[WorkerState]:
        workers = self.pool.serving()
        fac = self.pool.factory
        if self.restricted:
            # pass a filtered copy: restricted fleets are fixed-size, so
            # the factory append path is never taken on the copy
            workers = [w for w in workers if self._eligible(w, r)]
            fac = None
        if self.cfg.policy == "aladdin":
            return best_fit_place(workers, r, allow_new=fac is not None,
                                  new_worker_factory=fac)
        if self.cfg.policy == "jsq":
            return jsq_place(workers, r, allow_new=fac is not None,
                             new_worker_factory=fac)
        return power_of_two_place(workers, r, self.rng,
                                  allow_new=fac is not None,
                                  new_worker_factory=fac)

    def step(self, t: float, t_next: float, arrived: int) -> None:
        pool = self.pool
        pool.begin_beat(self, t)
        # re-prediction for underruns (Algorithm 2 inputs)
        if self.tracking and self.predictor:
            for w in pool.serving():
                for r in w.ongoing:
                    if r.l_out > r.l_pred and not r.repredicted:
                        self.tracker.on_underrun(
                            r, self.predictor.repredict(r.l_in, r.l_out))
                        w.mark_dirty()
        # placement — multi-tenant queues order priority-then-EDF first
        # (stable sort: equal keys keep FIFO/requeue order), so interactive
        # traffic places ahead of batch tier every beat while unplaced
        # requests simply stay queued (no starvation under bounded load:
        # every queued request is retried every beat)
        if self.edf:
            self.queued.sort(key=lambda r: (-r.priority, r.deadline))
        still: List[Request] = []
        for r in self.queued:
            w = self._try_home(r) if self._sticky and r.session_id >= 0 \
                else None
            if w is None:
                w = self._place_one(r)
            if w is None:
                still.append(r)
            else:
                r.state = ReqState.PLACED
                if w.id not in pool.sims:
                    pool.sims[w.id] = SimWorker(w, w.perf, t,
                                                self.cfg.split_phase)
                if r.session_id >= 0:
                    cache = self._cache(pool.sims[w.id])
                    # consume the session's entry on the chosen worker —
                    # a blind-router placement that happens to land on the
                    # cached worker gets the same discount sticky would
                    r.cached_len = cache.grant(r.session_id, r.prefix_len) \
                        if cache is not None else 0
                    if self._sticky:
                        self.session_home[r.session_id] = w.id
                if self.restricted:
                    self._lora_admit(w, r, t)
        self.queued = still
        if self.tracking and self.cfg.rebalance and not self.restricted \
                and self.cfg.policy == "aladdin":
            self.moves += rebalance(pool.serving(), self.tracker)
            self.tracker.decay()
        self.peak_workers = max(self.peak_workers, len(pool.serving()))
        # advance workers
        before = len(self.finished)
        for w in pool.active():
            pool.sims[w.id].advance_to(t_next, self.finished, t_start=t)
        if self.tracking:
            for r in self.finished[before:]:
                self.tracker.on_finish(r)
                if self.predictor:
                    self.predictor.observe(r.l_in, r.l_real)
        pool.end_beat(self, t, t_next)
        if self.observer is not None:
            self.observer(t=t_next, workers=pool.serving(), sims=pool.sims,
                          queued=self.queued, finished=self.finished,
                          arrived=arrived)

    def drained(self) -> bool:
        return (not self.queued
                and all(not w.ongoing and not w.new_batch
                        for w in self.pool.active())
                and all(not s.preempted for s in self.pool.sims.values()))


def simulate(trace: Sequence[Request], perf: PerfModel, slo: SLO,
             kv_capacity: float, cfg: SimConfig,
             n_workers: Optional[int] = None,
             predictor: Optional[LengthPredictor] = None,
             fleet: Optional[Sequence[WorkerSpec]] = None,
             observer: Optional[Callable] = None) -> SimResult:
    """Run the serving simulation.

    .. deprecated:: delegate to :func:`repro.serving.api.run` — this shim
       builds the equivalent declarative ``Scenario`` and reproduces the
       pre-Scenario metrics bit-for-bit (pinned by tests/test_shim_goldens).

    n_workers fixed (None = elastic: open a worker whenever placement fails,
    i.e. the min-cost oracle mode). ``fleet`` overrides the homogeneous
    (perf, kv_capacity) description with exactly one WorkerSpec per worker —
    a fixed (possibly heterogeneous) fleet; elastic mode requires fleet=None
    (sweep fleet sizes via min_workers_for_slo's fleet_fn instead).
    ``observer(t, workers, sims, queued, finished, arrived)`` is called at
    the end of every heartbeat (invariant checks in tests)."""
    from repro.serving import api

    default_spec = WorkerSpec(perf=perf, kv_capacity=kv_capacity,
                              max_batch=cfg.max_batch)
    if fleet is not None:
        pools = [api.PoolSpec(spec, 1) for spec in fleet]
    else:
        pools = [api.PoolSpec(default_spec, int(n_workers or 0))]
    scenario = api.Scenario(
        workload=trace, fleet=api.FleetSpec(pools), slo=slo,
        topology=api.Colocated(heartbeat=cfg.heartbeat, policy=cfg.policy,
                               split_phase=cfg.split_phase,
                               rebalance=cfg.rebalance, gamma=cfg.gamma,
                               theta=cfg.theta, max_batch=cfg.max_batch),
        scaling=api.FixedScale(),
        predictor=predictor, observer=observer, seed=cfg.seed)
    return api.run(scenario).to_sim_result()


def min_workers_for_slo(trace_fn, perf: PerfModel, slo: SLO,
                        kv_capacity: float, cfg: SimConfig,
                        attain_target: float = 0.99, lo: int = 1,
                        hi: int = 512,
                        predictor: Optional[LengthPredictor] = None,
                        fleet_fn: Optional[Callable[[int],
                                                    Sequence[WorkerSpec]]]
                        = None) -> int:
    """Binary search the minimum fixed worker count attaining the SLO target
    (the paper's cost metric in Figs. 11/12). ``fleet_fn(n)`` maps a worker
    count to a (possibly heterogeneous) fleet — e.g. an A100/V100 mix at a
    fixed ratio; the default is n homogeneous (perf, kv_capacity) workers.

    .. deprecated:: delegate to :func:`repro.serving.api.optimize`, which
       subsumes this search (objective="cost" on a colocated scenario)."""
    from repro.serving import api

    default_spec = WorkerSpec(perf=perf, kv_capacity=kv_capacity,
                              max_batch=cfg.max_batch)
    scenario = api.Scenario(
        workload=trace_fn,
        fleet=api.FleetSpec([api.PoolSpec(default_spec, 0)]), slo=slo,
        topology=api.Colocated(heartbeat=cfg.heartbeat, policy=cfg.policy,
                               split_phase=cfg.split_phase,
                               rebalance=cfg.rebalance, gamma=cfg.gamma,
                               theta=cfg.theta, max_batch=cfg.max_batch),
        scaling=api.FixedScale(), predictor=predictor, seed=cfg.seed)
    plan = api.optimize(scenario, objective="cost",
                        attain_target=attain_target, lo=lo, hi=hi,
                        fleet_fn=fleet_fn)
    return plan.n_workers
