"""End-to-end prefill/decode disaggregated cluster simulation.

The Splitwise/DistServe topology as a discrete-event model: *prefill pools*
admit arrivals under constraint (c) only (TTFT is the prefill pool's whole
job), finished prefills hand their KV cache to *decode pools* over an
interconnect with modeled bandwidth/latency, and the decode pools run the
split-phase variant of Algorithm 1 (constraints (b)/(e); no prefill ever
interferes with decode, which is the point of disaggregation).

Pools may be heterogeneous: ``simulate_disaggregated`` takes lists of
``(WorkerSpec, count)`` pool types on both sides (e.g. A100-TP4 next to
V100-TP8 prefill pools) and an SLO-aware router picks the pool per request.
The router score is prompt-length-affine (UELLM-style): the accelerator-cost
-weighted prefill latency ``gpu_cost * (k1*l_in + c1)`` — short prompts flow
to cheap pools, long prompts to pools whose fast prefill is worth the cost —
and a pool is only eligible when constraint (c) holds on some worker in it.
The legacy single ``(prefill_spec, decode_spec)`` arguments still work and
describe one pool type per side.

Both simulators share the causal-time heartbeat core
(``run_heartbeat_loop``): a request is admitted at the first heartbeat
boundary at-or-after its arrival, never before it, so colocated and
disaggregated TTFTs are measured under identical admission semantics.

``min_cost_disagg`` walks the joint (n_prefill, n_decode) frontier and
returns the cheapest configuration meeting the SLO target, directly
comparable with the colocated ``min_workers_for_slo`` cost on the same
trace; ``prefill_pool_fn`` / ``decode_pool_fn`` map a worker count to a
heterogeneous pool mix at a fixed ratio, while ``prefill_mix`` /
``decode_mix`` + ``ratio_grid`` make the pool-type ratio itself a search
dimension (the cheapest (ratio_p, ratio_d, n_p, n_d) point wins).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.placement import (PlacementConfig, WorkerState,
                                  best_fit_place, jsq_place)
from repro.core.request import ReqState, Request
from repro.core.slo import SLO, slo_attainment
from repro.core.worker_config import WorkerSpec
from repro.serving.simulator import SimWorker, run_heartbeat_loop

# One pool type: (worker spec, number of workers of that type).
Pool = Tuple[WorkerSpec, int]


@dataclasses.dataclass
class DisaggConfig:
    # Finer than the colocated 0.25 s default: the disaggregated pipeline
    # has TWO scheduler-quantized hops (arrival->prefill, handoff->decode),
    # and the handoff wait is charged against the tight ATGT budget. Real
    # systems admit handoffs at decode-iteration granularity (~tens of ms);
    # a coarse beat would bill scheduling quantization as SLO loss (the
    # seed hid it by starting decode before the KV had arrived).
    heartbeat: float = 0.05
    policy: str = "aladdin"            # decode-pool placement: aladdin | jsq
    gamma: float = 0.5
    theta: float = 0.9
    kv_transfer_bw: float = 64e9       # bytes/s prefill->decode interconnect
    kv_transfer_lat: float = 2e-3      # fixed per-handoff latency, s
    seed: int = 0


def prefill_affinity(spec: WorkerSpec, l_in: int) -> float:
    """UELLM-style prompt-length-affine routing score (lower = preferred):
    accelerator-cost-weighted prefill latency a + b*l_in of this prompt on
    the pool type."""
    p = spec.perf.prefill
    return spec.gpu_cost * (p.k1 * l_in + p.c1)


def decode_affinity(spec: WorkerSpec, r: Request, gamma: float) -> float:
    """Decode-side analogue, affine in the predicted context: cost-weighted
    marginal decode time of carrying (l_in + gamma*l_pred) KV tokens."""
    d = spec.perf.decode
    return spec.gpu_cost * (d.k2 * (r.l_in + gamma * r.l_pred) + d.c2)


class PrefillSimWorker:
    """One prefill-pool worker: a clock and a queue of admitted prompts.

    Admission is constraint (c) alone — the pending prompt tokens plus the
    candidate must prefill within the TTFT budget (Eq. 2). Queued prompts are
    batched once per heartbeat, exactly like the colocated simulator's
    prefill iterations."""

    def __init__(self, wid: int, spec: WorkerSpec, slo: SLO):
        self.id = wid
        self.spec = spec
        self.perf = spec.perf
        self.slo = slo
        self.t = 0.0
        self.queue: List[Request] = []
        self.pending_tokens = 0
        self.iters = 0

    def feasible(self, r: Request) -> bool:
        return float(self.perf.prefill(self.pending_tokens + r.l_in)) \
            <= self.slo.ttft

    def place(self, r: Request) -> None:
        r.worker = self.id
        r.state = ReqState.PLACED
        self.queue.append(r)
        self.pending_tokens += r.l_in

    def advance_to(self, t_end: float, t_start: float,
                   done: List[Request]) -> None:
        if self.queue:
            self.t = max(self.t, t_start)
        while self.queue and self.t < t_end:
            batch, self.queue = self.queue, []
            dur = float(self.perf.prefill(sum(r.l_in for r in batch)))
            self.t += dur
            self.iters += 1
            for r in batch:
                self.pending_tokens -= r.l_in
                r.t_first_token = self.t     # first token comes from prefill
                r.l_out = 1
                done.append(r)
        if not self.queue:
            self.t = max(self.t, t_end)


@dataclasses.dataclass
class DisaggResult:
    n_prefill: int
    n_decode: int
    gpu_cost: float
    attainment: float
    p99_ttft: float
    p99_atgt: float
    mean_transfer: float               # mean KV-handoff time, s
    finished: int
    total: int
    pool_mix: str = ""                 # e.g. "p:a100-tp4x2|d:a100-tp4x4"

    def row(self) -> Dict:
        return dataclasses.asdict(self)


def _as_pools(spec: Optional[WorkerSpec], n: int,
              pools: Optional[Sequence[Pool]]) -> List[Pool]:
    if pools is not None:
        out = [(s, int(k)) for s, k in pools if k > 0]
        if not out:
            raise ValueError("pool list contains no workers")
        return out
    if spec is None:
        raise ValueError("pass either a spec+count or a pool list")
    if n <= 0:
        raise ValueError(f"worker count must be positive, got {n}")
    return [(spec, int(n))]


def pool_cost(pools: Sequence[Pool]) -> float:
    return sum(k * s.gpu_cost for s, k in pools)


def _mix_label(prefill_pools: Sequence[Pool],
               decode_pools: Sequence[Pool]) -> str:
    p = ",".join(f"{s.name}x{k}" for s, k in prefill_pools)
    d = ",".join(f"{s.name}x{k}" for s, k in decode_pools)
    return f"p:{p}|d:{d}"


def simulate_disaggregated(trace: Sequence[Request], slo: SLO,
                           cfg: DisaggConfig,
                           prefill_spec: Optional[WorkerSpec] = None,
                           decode_spec: Optional[WorkerSpec] = None,
                           n_prefill: int = 0, n_decode: int = 0,
                           predictor=None,
                           observer: Optional[Callable] = None,
                           prefill_pools: Optional[Sequence[Pool]] = None,
                           decode_pools: Optional[Sequence[Pool]] = None
                           ) -> DisaggResult:
    """Simulate ``trace`` on a disaggregated cluster.

    Homogeneous form: ``(prefill_spec, decode_spec, n_prefill, n_decode)``.
    Heterogeneous form: ``prefill_pools`` / ``decode_pools`` as lists of
    ``(WorkerSpec, count)``; the affine router picks the pool per request,
    falling through to the next-ranked pool when no worker is feasible."""
    p_pools = _as_pools(prefill_spec, n_prefill, prefill_pools)
    d_pools = _as_pools(decode_spec, n_decode, decode_pools)

    # prefill pools: one worker group per type, ids dense from 1
    pools_p: List[Tuple[WorkerSpec, List[PrefillSimWorker]]] = []
    wid = 0
    for spec, k in p_pools:
        group = []
        for _ in range(k):
            wid += 1
            group.append(PrefillSimWorker(wid, spec, slo))
        pools_p.append((spec, group))
    pool_p = [w for _, group in pools_p for w in group]

    # decode pools: split-phase WorkerStates per type, ids from 1000
    pools_d: List[Tuple[WorkerSpec, List[WorkerState]]] = []
    sims_d: Dict[int, SimWorker] = {}
    wid = 1000
    for spec, k in d_pools:
        dcfg = PlacementConfig(gamma=cfg.gamma, theta=cfg.theta,
                               kv_capacity=spec.kv_capacity,
                               max_batch=spec.max_batch, split_phase=True)
        group = []
        for _ in range(k):
            w = WorkerState(wid, dcfg, spec.perf, slo)
            w.spec = spec
            group.append(w)
            sims_d[w.id] = SimWorker(w, w.perf, 0.0, split_phase=True)
            wid += 1
        pools_d.append((spec, group))
    states_d = [w for _, group in pools_d for w in group]

    queued_p: List[Request] = []       # waiting for prefill-pool admission
    in_transfer: List[Tuple[float, Request]] = []   # (ready time, request)
    queued_d: List[Request] = []       # KV arrived, waiting for decode slot
    finished: List[Request] = []
    transfers: List[float] = []

    def admit(r: Request) -> None:
        r.l_pred = predictor.predict(r.l_in) if predictor else r.l_real
        queued_p.append(r)

    def place_prefill(r: Request) -> Optional[PrefillSimWorker]:
        # rank pool types by the affine routing score, then best-fit within
        # the pool (fullest feasible worker first, Algorithm 1's bin order);
        # fall through to the next pool when nothing in this one is feasible
        for spec, group in sorted(pools_p,
                                  key=lambda p: prefill_affinity(p[0],
                                                                 r.l_in)):
            ranked = sorted(group, key=lambda w: w.pending_tokens,
                            reverse=True)
            for w in ranked:
                if w.feasible(r):
                    w.place(r)
                    return w
        return None

    def place_decode(r: Request) -> Optional[WorkerState]:
        for spec, group in sorted(pools_d,
                                  key=lambda p: decode_affinity(p[0], r,
                                                                cfg.gamma)):
            if cfg.policy == "aladdin":
                w = best_fit_place(group, r, allow_new=False)
            else:
                w = jsq_place(group, r, allow_new=False)
            if w is not None:
                return w
        return None

    def step(t: float, t_next: float, arrived: int) -> None:
        nonlocal queued_p, queued_d
        # prefill placement: constraint (c) only, router picks the pool
        still: List[Request] = []
        for r in queued_p:
            if place_prefill(r) is None:
                still.append(r)
        queued_p = still
        # advance the prefill pools; completed prefills enter KV transfer
        for spec, group in pools_p:
            done: List[Request] = []
            for w in group:
                w.advance_to(t_next, t, done)
            for r in done:
                dt = cfg.kv_transfer_lat \
                    + r.l_in * spec.kv_bytes_per_token \
                    / max(cfg.kv_transfer_bw, 1.0)
                transfers.append(dt)
                in_transfer.append((max(r.t_first_token, t) + dt, r))
        # KV handoffs completed by this boundary join the decode queue —
        # causally: a handoff ready inside (t, t_next) must wait for the
        # next boundary, else its decode would start before the KV arrived
        in_transfer.sort(key=lambda e: e[0])
        while in_transfer and in_transfer[0][0] <= t:
            queued_d.append(in_transfer.pop(0)[1])
        # decode placement: split-phase constraints (b)/(e), router-ordered
        still = []
        for r in queued_d:
            w = place_decode(r)
            if w is None:
                still.append(r)
            else:
                r.state = ReqState.PLACED
        queued_d = still
        for w in states_d:
            sims_d[w.id].advance_to(t_next, finished, t_start=t)
        if observer is not None:
            observer(t=t_next, pool_p=pool_p, states_d=states_d,
                     queued_p=queued_p, in_transfer=in_transfer,
                     queued_d=queued_d, finished=finished, arrived=arrived)

    def drained() -> bool:
        return (not queued_p and not queued_d and not in_transfer
                and all(not w.queue for w in pool_p)
                and all(not w.ongoing and not w.new_batch for w in states_d)
                and all(not s.preempted for s in sims_d.values()))

    trace = run_heartbeat_loop(trace, cfg.heartbeat, admit, step, drained)

    atgts = [r.atgt() for r in finished if r.atgt() is not None]
    ttfts = [r.ttft() for r in finished if r.ttft() is not None]
    total = len(trace)
    return DisaggResult(
        n_prefill=sum(k for _, k in p_pools),
        n_decode=sum(k for _, k in d_pools),
        gpu_cost=pool_cost(p_pools) + pool_cost(d_pools),
        attainment=slo_attainment(finished, total, slo),
        p99_ttft=float(np.percentile(ttfts, 99)) if ttfts else float("nan"),
        p99_atgt=float(np.percentile(atgts, 99)) if atgts else float("nan"),
        mean_transfer=float(np.mean(transfers)) if transfers else 0.0,
        finished=len(finished), total=total,
        pool_mix=_mix_label(p_pools, d_pools))


def ratio_pool_fn(specs: Sequence[WorkerSpec],
                  ratio: float) -> Callable[[int], List[Pool]]:
    """Map a worker count n to a two-type (spec, count) mix at a fixed
    ratio: ``round(n * ratio)`` workers of ``specs[0]``, the rest of
    ``specs[1]`` (a single spec ignores the ratio). Rounding keeps both
    per-type counts — hence the pool cost — monotone in n, which the
    ``min_cost_disagg`` frontier prune requires."""
    if len(specs) == 1:
        return lambda n: [(specs[0], n)]
    if len(specs) != 2:
        raise ValueError("ratio mixes support exactly 1 or 2 worker types")
    a, b = specs
    r = min(max(ratio, 0.0), 1.0)

    def fn(n: int) -> List[Pool]:
        na = int(round(n * r))
        return [(s, k) for s, k in ((a, na), (b, n - na)) if k > 0]

    return fn


def min_cost_disagg(trace_fn, slo: SLO, cfg: DisaggConfig,
                    prefill_spec: Optional[WorkerSpec] = None,
                    decode_spec: Optional[WorkerSpec] = None,
                    attain_target: float = 0.99,
                    max_prefill: int = 8, hi_decode: int = 64,
                    predictor=None,
                    prefill_pool_fn: Optional[Callable[[int],
                                                       Sequence[Pool]]]
                    = None,
                    decode_pool_fn: Optional[Callable[[int],
                                                      Sequence[Pool]]]
                    = None,
                    prefill_mix: Optional[Sequence[WorkerSpec]] = None,
                    decode_mix: Optional[Sequence[WorkerSpec]] = None,
                    ratio_grid: Sequence[float] = (0.0, 0.25, 0.5,
                                                   0.75, 1.0)
                    ) -> Optional[DisaggResult]:
    """Walk the joint (n_prefill, n_decode) frontier: for each prefill-pool
    size, binary-search the minimum decode pool meeting the target, and keep
    the cheapest feasible point. Returns None if nothing within the bounds
    attains the target.

    ``prefill_pool_fn(n)`` / ``decode_pool_fn(n)`` map a worker count to a
    heterogeneous (spec, count) mix at a ratio the caller fixed; they must
    be monotone (cost non-decreasing in n) for the frontier prune to stay
    exact. The default is n homogeneous workers of the given spec.

    ``prefill_mix`` / ``decode_mix`` (each one or two ``WorkerSpec``) search
    the pool-type *ratio* jointly instead of fixing it: every ratio in
    ``ratio_grid`` (share of the first spec) is frontier-walked on both
    sides, sharing one best-so-far cost bound so expensive ratios are pruned
    before their first simulation where possible."""
    best: Optional[DisaggResult] = None

    def attains(res: DisaggResult) -> bool:
        return res.attainment >= attain_target and res.finished == res.total

    def frontier(pf: Callable[[int], Sequence[Pool]],
                 df: Callable[[int], Sequence[Pool]],
                 best: Optional[DisaggResult]) -> Optional[DisaggResult]:
        min_decode_cost = pool_cost(df(1))

        def run(n_p: int, n_d: int) -> DisaggResult:
            return simulate_disaggregated(trace_fn(), slo, cfg,
                                          predictor=predictor,
                                          prefill_pools=pf(n_p),
                                          decode_pools=df(n_d))

        for n_p in range(1, max_prefill + 1):
            if best is not None and \
                    pool_cost(pf(n_p)) + min_decode_cost >= best.gpu_cost:
                break                  # every remaining point costs more
            lo, hi = 1, hi_decode
            res_hi = run(n_p, hi)
            if not attains(res_hi):
                continue               # prefill pool too small at any scale
            best_np = res_hi
            while lo < hi:
                mid = (lo + hi) // 2
                res = run(n_p, mid)
                if attains(res):
                    best_np, hi = res, mid
                else:
                    lo = mid + 1
            if best is None or best_np.gpu_cost < best.gpu_cost:
                best = best_np
        return best

    if prefill_mix is not None or decode_mix is not None:
        pmix = list(prefill_mix) if prefill_mix is not None \
            else [prefill_spec]
        dmix = list(decode_mix) if decode_mix is not None else [decode_spec]
        if any(s is None for s in pmix + dmix):
            raise ValueError("mix search needs specs on both sides "
                             "(a spec list or the legacy spec argument)")
        p_ratios = tuple(ratio_grid) if len(pmix) == 2 else (1.0,)
        d_ratios = tuple(ratio_grid) if len(dmix) == 2 else (1.0,)
        for rp in p_ratios:
            for rd in d_ratios:
                best = frontier(ratio_pool_fn(pmix, rp),
                                ratio_pool_fn(dmix, rd), best)
        return best

    pf = prefill_pool_fn or (lambda n: [(prefill_spec, n)])
    df = decode_pool_fn or (lambda n: [(decode_spec, n)])
    return frontier(pf, df, None)
