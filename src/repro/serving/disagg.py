"""End-to-end prefill/decode disaggregated cluster simulation.

The Splitwise/DistServe topology as a discrete-event model: a *prefill pool*
admits arrivals under constraint (c) only (TTFT is the prefill pool's whole
job), finished prefills hand their KV cache to a *decode pool* over an
interconnect with modeled bandwidth/latency, and the decode pool runs the
split-phase variant of Algorithm 1 (constraints (b)/(e); no prefill ever
interferes with decode, which is the point of disaggregation).

This replaces the decode-pool-only ``split_phase`` approximation for cost
studies: ``min_cost_disagg`` walks the joint (n_prefill, n_decode) frontier
and returns the cheapest configuration meeting the SLO target, directly
comparable with the colocated ``min_workers_for_slo`` cost on the same trace.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.perf_model import PerfModel
from repro.core.placement import (PlacementConfig, WorkerState,
                                  best_fit_place, jsq_place)
from repro.core.request import ReqState, Request
from repro.core.slo import SLO
from repro.core.worker_config import WorkerSpec
from repro.serving.length_predictor import LengthPredictor
from repro.serving.simulator import SimWorker


@dataclasses.dataclass
class DisaggConfig:
    heartbeat: float = 0.25
    policy: str = "aladdin"            # decode-pool placement: aladdin | jsq
    gamma: float = 0.5
    theta: float = 0.9
    kv_transfer_bw: float = 64e9       # bytes/s prefill->decode interconnect
    kv_transfer_lat: float = 2e-3      # fixed per-handoff latency, s
    seed: int = 0


class PrefillSimWorker:
    """One prefill-pool worker: a clock and a queue of admitted prompts.

    Admission is constraint (c) alone — the pending prompt tokens plus the
    candidate must prefill within the TTFT budget (Eq. 2). Queued prompts are
    batched once per heartbeat, exactly like the colocated simulator's
    prefill iterations."""

    def __init__(self, wid: int, perf: PerfModel, slo: SLO):
        self.id = wid
        self.perf = perf
        self.slo = slo
        self.t = 0.0
        self.queue: List[Request] = []
        self.pending_tokens = 0
        self.iters = 0

    def feasible(self, r: Request) -> bool:
        return float(self.perf.prefill(self.pending_tokens + r.l_in)) \
            <= self.slo.ttft

    def place(self, r: Request) -> None:
        r.worker = self.id
        r.state = ReqState.PLACED
        self.queue.append(r)
        self.pending_tokens += r.l_in

    def advance_to(self, t_end: float, t_start: float,
                   done: List[Request]) -> None:
        if self.queue:
            self.t = max(self.t, t_start)
        while self.queue and self.t < t_end:
            batch, self.queue = self.queue, []
            dur = float(self.perf.prefill(sum(r.l_in for r in batch)))
            self.t += dur
            self.iters += 1
            for r in batch:
                self.pending_tokens -= r.l_in
                r.t_first_token = self.t     # first token comes from prefill
                r.l_out = 1
                done.append(r)
        if not self.queue:
            self.t = max(self.t, t_end)


@dataclasses.dataclass
class DisaggResult:
    n_prefill: int
    n_decode: int
    gpu_cost: float
    attainment: float
    p99_ttft: float
    p99_atgt: float
    mean_transfer: float               # mean KV-handoff time, s
    finished: int
    total: int

    def row(self) -> Dict:
        return dataclasses.asdict(self)


def simulate_disaggregated(trace: Sequence[Request], slo: SLO,
                           cfg: DisaggConfig,
                           prefill_spec: WorkerSpec,
                           decode_spec: WorkerSpec,
                           n_prefill: int, n_decode: int,
                           predictor: Optional[LengthPredictor] = None,
                           observer: Optional[Callable] = None
                           ) -> DisaggResult:
    """Simulate ``trace`` on a (n_prefill, n_decode) disaggregated cluster."""
    kv_tok = prefill_spec.kv_bytes_per_token
    pool_p = [PrefillSimWorker(i + 1, prefill_spec.perf, slo)
              for i in range(n_prefill)]
    dcfg = PlacementConfig(gamma=cfg.gamma, theta=cfg.theta,
                           kv_capacity=decode_spec.kv_capacity,
                           max_batch=decode_spec.max_batch, split_phase=True)
    states_d: List[WorkerState] = []
    sims_d: Dict[int, SimWorker] = {}
    for i in range(n_decode):
        w = WorkerState(1000 + i, dcfg, decode_spec.perf, slo)
        w.spec = decode_spec
        states_d.append(w)
        sims_d[w.id] = SimWorker(w, w.perf, 0.0, split_phase=True)

    trace = sorted(trace, key=lambda r: r.arrival)
    horizon = max(r.arrival for r in trace) + 240.0
    queued_p: List[Request] = []       # waiting for prefill-pool admission
    in_transfer: List[Tuple[float, Request]] = []   # (ready time, request)
    queued_d: List[Request] = []       # KV arrived, waiting for decode slot
    finished: List[Request] = []
    transfers: List[float] = []
    idx = 0
    t = 0.0
    while t < horizon:
        t_next = t + cfg.heartbeat
        # only admit requests that have actually arrived by this boundary
        # (the colocated simulator's intra-beat admission can stamp a first
        # token before the arrival; the disaggregated path keeps causal time)
        while idx < len(trace) and trace[idx].arrival <= t:
            r = trace[idx]
            r.l_pred = predictor.predict(r.l_in) if predictor else r.l_real
            queued_p.append(r)
            idx += 1
        # prefill placement: constraint (c) only, best-fit (fullest feasible
        # worker first, mirroring Algorithm 1's bin-packing order)
        still: List[Request] = []
        for r in queued_p:
            ranked = sorted(pool_p, key=lambda w: w.pending_tokens,
                            reverse=True)
            for w in ranked:
                if w.feasible(r):
                    w.place(r)
                    break
            else:
                still.append(r)
        queued_p = still
        # advance the prefill pool; completed prefills enter KV transfer
        prefilled: List[Request] = []
        for w in pool_p:
            w.advance_to(t_next, t, prefilled)
        for r in prefilled:
            dt = cfg.kv_transfer_lat \
                + r.l_in * kv_tok / max(cfg.kv_transfer_bw, 1.0)
            transfers.append(dt)
            in_transfer.append((max(r.t_first_token, t) + dt, r))
        # KV handoffs that completed by this heartbeat join the decode queue
        in_transfer.sort(key=lambda e: e[0])
        while in_transfer and in_transfer[0][0] <= t_next:
            queued_d.append(in_transfer.pop(0)[1])
        # decode placement: split-phase constraints (b)/(e)
        still = []
        for r in queued_d:
            if cfg.policy == "aladdin":
                w = best_fit_place(states_d, r, allow_new=False)
            else:
                w = jsq_place(states_d, r, allow_new=False)
            if w is None:
                still.append(r)
            else:
                r.state = ReqState.PLACED
        queued_d = still
        for w in states_d:
            sims_d[w.id].advance_to(t_next, finished, t_start=t)
        t = t_next
        if observer is not None:
            observer(t=t, pool_p=pool_p, states_d=states_d,
                     queued_p=queued_p, in_transfer=in_transfer,
                     queued_d=queued_d, finished=finished, arrived=idx)
        if idx >= len(trace) and not queued_p and not queued_d \
                and not in_transfer \
                and all(not w.queue for w in pool_p) \
                and all(not w.ongoing and not w.new_batch for w in states_d) \
                and all(not s.preempted for s in sims_d.values()):
            break

    atgts = [r.atgt() for r in finished if r.atgt() is not None]
    ttfts = [r.ttft() for r in finished if r.ttft() is not None]
    ok = [r for r in finished if r.slo_ok(slo)]
    total = len(trace)
    return DisaggResult(
        n_prefill=n_prefill, n_decode=n_decode,
        gpu_cost=n_prefill * prefill_spec.gpu_cost
        + n_decode * decode_spec.gpu_cost,
        attainment=len(ok) / max(total, 1),
        p99_ttft=float(np.percentile(ttfts, 99)) if ttfts else float("nan"),
        p99_atgt=float(np.percentile(atgts, 99)) if atgts else float("nan"),
        mean_transfer=float(np.mean(transfers)) if transfers else 0.0,
        finished=len(finished), total=total)


def min_cost_disagg(trace_fn, slo: SLO, cfg: DisaggConfig,
                    prefill_spec: WorkerSpec, decode_spec: WorkerSpec,
                    attain_target: float = 0.99,
                    max_prefill: int = 8, hi_decode: int = 64,
                    predictor: Optional[LengthPredictor] = None
                    ) -> Optional[DisaggResult]:
    """Walk the joint (n_prefill, n_decode) frontier: for each prefill-pool
    size, binary-search the minimum decode pool meeting the target, and keep
    the cheapest feasible point. Returns None if nothing within the bounds
    attains the target."""
    best: Optional[DisaggResult] = None

    def attains(res: DisaggResult) -> bool:
        return res.attainment >= attain_target and res.finished == res.total

    for n_p in range(1, max_prefill + 1):
        if best is not None and \
                n_p * prefill_spec.gpu_cost + decode_spec.gpu_cost \
                >= best.gpu_cost:
            break                      # every remaining point costs more
        lo, hi = 1, hi_decode
        res_hi = simulate_disaggregated(trace_fn(), slo, cfg, prefill_spec,
                                        decode_spec, n_p, hi,
                                        predictor=predictor)
        if not attains(res_hi):
            continue                   # prefill pool too small at any scale
        best_np = res_hi
        while lo < hi:
            mid = (lo + hi) // 2
            res = simulate_disaggregated(trace_fn(), slo, cfg, prefill_spec,
                                         decode_spec, n_p, mid,
                                         predictor=predictor)
            if attains(res):
                best_np, hi = res, mid
            else:
                lo = mid + 1
        if best is None or best_np.gpu_cost < best.gpu_cost:
            best = best_np
    return best
