"""End-to-end prefill/decode disaggregated cluster simulation.

The Splitwise/DistServe topology as a discrete-event model: *prefill pools*
admit arrivals under constraint (c) only (TTFT is the prefill pool's whole
job), finished prefills hand their KV cache to *decode pools* over an
interconnect with modeled bandwidth/latency, and the decode pools run the
split-phase variant of Algorithm 1 (constraints (b)/(e); no prefill ever
interferes with decode, which is the point of disaggregation).

Pools may be heterogeneous: ``simulate_disaggregated`` takes lists of
``(WorkerSpec, count)`` pool types on both sides (e.g. A100-TP4 next to
V100-TP8 prefill pools) and an SLO-aware router picks the pool per request.
The router score is prompt-length-affine (UELLM-style): the accelerator-cost
-weighted prefill latency ``gpu_cost * (k1*l_in + c1)`` — short prompts flow
to cheap pools, long prompts to pools whose fast prefill is worth the cost —
and a pool is only eligible when constraint (c) holds on some worker in it.
The legacy single ``(prefill_spec, decode_spec)`` arguments still work and
describe one pool type per side.

Both simulators share the causal-time heartbeat core
(``run_heartbeat_loop``): a request is admitted at the first heartbeat
boundary at-or-after its arrival, never before it, so colocated and
disaggregated TTFTs are measured under identical admission semantics.

``min_cost_disagg`` walks the joint (n_prefill, n_decode) frontier and
returns the cheapest configuration meeting the SLO target, directly
comparable with the colocated ``min_workers_for_slo`` cost on the same
trace; ``prefill_pool_fn`` / ``decode_pool_fn`` map a worker count to a
heterogeneous pool mix at a fixed ratio, while ``prefill_mix`` /
``decode_mix`` + ``ratio_grid`` make the pool-type ratio itself a search
dimension (the cheapest (ratio_p, ratio_d, n_p, n_d) point wins).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.placement import WorkerState, best_fit_place, jsq_place
from repro.core.request import ReqState, Request
from repro.core.slo import SLO, windowed_attainment
from repro.core.worker_config import WorkerSpec
from repro.serving.lifecycle import (WorkerLifecycle, mark_kv_loss,
                                     mark_requeue)

# One pool type: (worker spec, number of workers of that type).
Pool = Tuple[WorkerSpec, int]


@dataclasses.dataclass
class DisaggConfig:
    # Finer than the colocated 0.25 s default: the disaggregated pipeline
    # has TWO scheduler-quantized hops (arrival->prefill, handoff->decode),
    # and the handoff wait is charged against the tight ATGT budget. Real
    # systems admit handoffs at decode-iteration granularity (~tens of ms);
    # a coarse beat would bill scheduling quantization as SLO loss (the
    # seed hid it by starting decode before the KV had arrived).
    heartbeat: float = 0.05
    policy: str = "aladdin"            # decode-pool placement: aladdin | jsq
    gamma: float = 0.5
    theta: float = 0.9
    kv_transfer_bw: float = 64e9       # bytes/s prefill->decode interconnect
    kv_transfer_lat: float = 2e-3      # fixed per-handoff latency, s
    seed: int = 0
    # Prefill-pool routing. "packed" is the legacy Algorithm-1 bin order
    # (fullest feasible worker first) — it ignores the worker's *clock*, so
    # at high rates every tie routes to the first worker whose just-run
    # batch left pending_tokens == 0 while its clock sits a whole batch
    # ahead, a scale-invariant TTFT tail the deprecation shims must keep
    # reproducing. "earliest" ranks by estimated completion (clock backlog
    # + queued + candidate prefill) and admits only when that estimate
    # meets the TTFT budget — what the autoscaled disaggregated scenarios
    # use, since it makes added capacity actually absorb the tail.
    prefill_router: str = "packed"     # packed | earliest
    # Decode-pool placement order. "packed" is Algorithm 1's bin order
    # (fullest feasible worker first) — like the packed prefill router it
    # is blind to the worker's *clock*, so a worker whose just-run batch
    # left it top-ranked keeps absorbing ties while its event-batched
    # clock sits a whole decode segment past the beat; every request
    # placed there stalls that long before its next token, an ATGT tail
    # that does not shrink with pool size. "earliest" ranks feasible
    # workers by clock backlog first (then the affine routing score, then
    # Algorithm 1's packing), mirroring the wait-aware prefill router.
    decode_router: str = "packed"      # packed | earliest


def prefill_affinity(spec: WorkerSpec, l_in: int) -> float:
    """UELLM-style prompt-length-affine routing score (lower = preferred):
    accelerator-cost-weighted prefill latency a + b*l_in of this prompt on
    the pool type."""
    p = spec.perf.prefill
    return spec.gpu_cost * (p.k1 * l_in + p.c1)


def decode_affinity(spec: WorkerSpec, r: Request, gamma: float) -> float:
    """Decode-side analogue, affine in the predicted context: cost-weighted
    marginal decode time of carrying (l_in + gamma*l_pred) KV tokens."""
    d = spec.perf.decode
    return spec.gpu_cost * (d.k2 * (r.l_in + gamma * r.l_pred) + d.c2)


class PrefillSimWorker:
    """One prefill-pool worker: a clock and a queue of admitted prompts.

    Admission is constraint (c) alone — the pending prompt tokens plus the
    candidate must prefill within the TTFT budget (Eq. 2). Queued prompts are
    batched once per heartbeat, exactly like the colocated simulator's
    prefill iterations.

    All token accounting is in ``r.context`` (= l_in + l_out) rather than
    ``l_in``: identical for a fresh request (l_out == 0 until prefill stamps
    its first token), but a spot-reclaim re-entrant from a dead decode
    worker re-prefills its prompt AND the tokens generated so far — the
    KV-loss recovery cost the asymmetric-hazard scenarios measure."""

    def __init__(self, wid: int, spec: WorkerSpec, slo: SLO):
        self.id = wid
        self.spec = spec
        self.perf = spec.perf
        self.slo = slo
        self.t = 0.0
        self.queue: List[Request] = []
        self.pending_tokens = 0
        self.iters = 0
        self.draining = False          # notice window / scale-down drain

    def feasible(self, r: Request) -> bool:
        return float(self.perf.prefill(self.pending_tokens + r.context)) \
            <= self.slo.ttft

    def place(self, r: Request) -> None:
        r.worker = self.id
        r.state = ReqState.PLACED
        self.queue.append(r)
        self.pending_tokens += r.context

    def advance_to(self, t_end: float, t_start: float,
                   done: List[Tuple[Request, float]]) -> None:
        """Run whole-queue prefill batches until the clock passes ``t_end``;
        ``done`` collects ``(request, completion_time)`` pairs. The explicit
        completion time matters for decode-reclaim re-entrants: their
        ``t_first_token`` is the *original* pre-reclaim stamp, so the KV
        re-transfer must be anchored to when this re-prefill actually
        finished (for fresh requests the two are the same instant)."""
        if self.queue:
            self.t = max(self.t, t_start)
        while self.queue and self.t < t_end:
            batch, self.queue = self.queue, []
            dur = float(self.perf.prefill(sum(r.context for r in batch)))
            self.t += dur
            self.iters += 1
            for r in batch:
                self.pending_tokens -= r.context
                if r.t_first_token is None:
                    r.t_first_token = self.t   # first token is prefill's
                    r.l_out = 1
                done.append((r, self.t))
        if not self.queue:
            self.t = max(self.t, t_end)


@dataclasses.dataclass
class DisaggResult:
    n_prefill: int
    n_decode: int
    gpu_cost: float
    attainment: float
    p99_ttft: float
    p99_atgt: float
    mean_transfer: float               # mean KV-handoff time, s
    finished: int
    total: int
    pool_mix: str = ""                 # e.g. "p:a100-tp4x2|d:a100-tp4x4"

    def row(self) -> Dict:
        return dataclasses.asdict(self)


def _as_pools(spec: Optional[WorkerSpec], n: int,
              pools: Optional[Sequence[Pool]]) -> List[Pool]:
    if pools is not None:
        out = [(s, int(k)) for s, k in pools if k > 0]
        if not out:
            raise ValueError("pool list contains no workers")
        return out
    if spec is None:
        raise ValueError("pass either a spec+count or a pool list")
    if n <= 0:
        raise ValueError(f"worker count must be positive, got {n}")
    return [(spec, int(n))]


def pool_cost(pools: Sequence[Pool]) -> float:
    return sum(k * s.gpu_cost for s, k in pools)


def _mix_label(prefill_pools: Sequence[Pool],
               decode_pools: Sequence[Pool]) -> str:
    p = ",".join(f"{s.name}x{k}" for s, k in prefill_pools)
    d = ",".join(f"{s.name}x{k}" for s, k in decode_pools)
    return f"p:{p}|d:{d}"


# ---- topology sides ----------------------------------------------------------
# A "side" is one half of the disaggregated pipeline: its worker groups (for
# the affine router), a lifecycle (static or ManagedPool-scaled), and the
# market-reclaim handler. The topology below drives either kind through the
# same step sequence.

class _FixedSide:
    """Shared shell of the two static disaggregated sides: routed worker
    groups plus the one :class:`WorkerLifecycle` reclaim machine. Subclasses
    supply only the lost-request extraction, the idle test and the recovery
    marking — the whole condemn/kill/reap flow is the shared helper's."""

    side = "prefill"

    def __init__(self, pools: List[Tuple[WorkerSpec, List]],
                 rng=None, notice_s: float = 0.0):
        self.pools = pools
        self.gpu_s = 0.0
        self.spot_gpu_s = 0.0
        self.epochs: List = []
        self.life = WorkerLifecycle(
            rng, notice_s=notice_s, extract=self._extract, mark=self._mark,
            idle=self._is_idle, remove=self._remove,
            on_condemn=lambda w: setattr(w, "draining", True))

    def groups(self):
        return self.pools

    def active(self) -> List:
        return [w for _, g in self.pools for w in g]

    def note_arrival(self) -> None:
        pass

    def begin_beat(self, topo, t: float) -> None:
        if self.life.condemned:
            topo.requeue(self.life.reap(t, self._lookup), side=self.side)

    def end_beat(self, topo, t: float, t_next: float) -> None:
        pass

    def on_reclaim(self, t: float, ev) -> List[Request]:
        return self.life.reclaim(t, ev, self.life.eligible(self.active()))

    @property
    def killed(self) -> int:
        return self.life.killed

    @property
    def drained_ok(self) -> int:
        return self.life.drained_ok

    @property
    def requeued(self) -> int:
        return self.life.requeued

    # ---- WorkerLifecycle adapters -------------------------------------------
    def _lookup(self, wid: int):
        return next((x for x in self.active() if x.id == wid), None)

    def _remove(self, w) -> None:
        for _, g in self.pools:
            if w in g:
                g.remove(w)
                break


class FixedPrefillSide(_FixedSide):
    """Static prefill pool groups. A spot market may reclaim spot workers
    out of the fixed pool (not replaced): instant kill requeues the queued
    prompts (nearly free — no KV existed), a notice window drains first."""

    side = "prefill"
    _mark = staticmethod(mark_requeue)

    def _extract(self, w: PrefillSimWorker) -> List[Request]:
        lost = list(w.queue)
        w.queue.clear()
        w.pending_tokens = 0
        return lost

    def _is_idle(self, w: PrefillSimWorker) -> bool:
        return not w.queue


class FixedDecodeSide(_FixedSide):
    """Static decode pool groups (split-phase WorkerStates + SimWorkers).
    Market reclaims lose the victims' KV: requests requeue to the *prefill*
    queue and pay a full context re-prefill plus the KV re-transfer."""

    side = "decode"
    _mark = staticmethod(mark_kv_loss)

    def __init__(self, pools: List[Tuple[WorkerSpec, List]],
                 sims: Dict, rng=None, notice_s: float = 0.0):
        super().__init__(pools, rng=rng, notice_s=notice_s)
        self.sims = sims

    def _extract(self, w) -> List[Request]:
        sim = self.sims.get(w.id)
        lost = w.ongoing + w.new_batch + (sim.preempted if sim else [])
        w.ongoing.clear()
        w.new_batch.clear()
        w.mark_dirty()
        return lost

    def _is_idle(self, w) -> bool:
        sim = self.sims.get(w.id)
        return not w.ongoing and not w.new_batch \
            and not (sim and sim.preempted)

    def _remove(self, w) -> None:
        super()._remove(w)
        self.sims.pop(w.id, None)


class ManagedSide:
    """Adapter presenting a ``forecast.ManagedPool`` as one routed pool
    group of a disaggregated side — the autoscaled half of the disagg x
    scaling x spot matrix."""

    def __init__(self, pool, spec: WorkerSpec):
        self.pool = pool
        self.spec = spec
        self.sims = pool.sims

    def groups(self):
        return [(self.spec, self.pool.online)]

    def active(self) -> List:
        return self.pool.active()

    def note_arrival(self) -> None:
        self.pool.note_arrival()

    def begin_beat(self, topo, t: float) -> None:
        self.pool.begin_beat(topo, t)

    def end_beat(self, topo, t: float, t_next: float) -> None:
        self.pool.end_beat(topo, t, t_next)

    def on_reclaim(self, t: float, ev) -> List[Request]:
        return self.pool.on_reclaim(t, ev)

    @property
    def killed(self):
        return self.pool.killed

    @property
    def drained_ok(self):
        return self.pool.drained_ok

    @property
    def requeued(self):
        return self.pool.requeued

    @property
    def gpu_s(self):
        return self.pool.gpu_s

    @property
    def spot_gpu_s(self):
        return self.pool.spot_gpu_s

    @property
    def epochs(self):
        return self.pool.epochs


class DisaggTopology:
    """Prefill pools -> modeled KV transfer -> decode pools, over pluggable
    sides (static groups or ManagedPool-scaled), driven beat-by-beat by the
    shared causal heartbeat loop."""

    def __init__(self, slo: SLO, cfg: DisaggConfig, prefill, decode, rng,
                 predictor=None, observer: Optional[Callable] = None):
        self.slo = slo
        self.cfg = cfg
        self.prefill = prefill
        self.decode = decode
        self.rng = rng
        self.predictor = predictor
        self.observer = observer
        self.queued_p: List[Request] = []    # waiting for prefill admission
        self.in_transfer: List[Tuple[float, Request]] = []
        self.queued_d: List[Request] = []    # KV arrived, awaiting decode
        self.finished: List[Request] = []
        self.transfers: List[float] = []
        self.kv_retransfers = 0              # re-entrant KV re-transfers
        self._now = 0.0                      # beat start (earliest router)

    def admit(self, r: Request) -> None:
        r.l_pred = self.predictor.predict(r.l_in) if self.predictor \
            else r.l_real
        self.queued_p.append(r)
        self.prefill.note_arrival()
        self.decode.note_arrival()

    def requeue(self, reqs: List[Request], side: str = "prefill") -> None:
        # both sides' reclaim victims re-enter at the prefill queue: a
        # decode victim lost its KV (full re-prefill + re-transfer), a
        # prefill victim simply waits for another slot
        self.queued_p.extend(reqs)

    def backlog_len(self, side: str) -> int:
        return len(self.queued_p) if side == "prefill" \
            else len(self.queued_d)

    def slo_window(self, side: str, t_now: float, window: float,
                   metric: str = "both") -> Tuple[int, int]:
        """Windowed observed attainment for the SLO-feedback policies
        (``core.slo.windowed_attainment``: ``ttft`` for the prefill side,
        ``atgt`` for decode; TTFT-expired waiting prompts are assured
        misses), plus the decode queue's own assured misses — a handed-off
        request whose decode-queue stall alone already burned the whole
        per-token budget of its predicted stream."""
        ok, total = windowed_attainment(self.finished, self.slo, t_now,
                                        window, metric,
                                        ttft_pending=self.queued_p)
        if metric != "ttft":
            for r in self.queued_d:
                if r.t_first_token is not None \
                        and t_now - r.t_first_token \
                        > self.slo.atgt * max(r.l_pred - 1, 1):
                    total += 1
        return ok, total

    def fire(self, t: float, ev) -> None:
        side = self.decode if getattr(ev, "side", "decode") == "decode" \
            else self.prefill
        self.requeue(side.on_reclaim(t, getattr(ev, "ev", ev)))

    def place_prefill(self, r: Request) -> Optional[PrefillSimWorker]:
        if self.cfg.prefill_router == "earliest":
            w = self._place_prefill_earliest(r)
        else:
            w = self._place_prefill_packed(r)
        if w is None and r.l_out > 0:
            # decode-reclaim re-entrant: its TTFT is already history, so the
            # fresh-arrival admission budget cannot apply — a grown context
            # that no longer prefills inside slo.ttft would otherwise be
            # stranded in queued_p until the horizon. Recovery is
            # best-effort: take the least-loaded worker and bill the stall
            # against ATGT like every other recovery cost.
            w = self._place_prefill_fallback(r)
        return w

    def _place_prefill_packed(self, r: Request) -> \
            Optional[PrefillSimWorker]:
        # rank pool types by the affine routing score, then best-fit within
        # the pool (fullest feasible worker first, Algorithm 1's bin order);
        # fall through to the next pool when nothing in this one is feasible
        for spec, group in sorted(self.prefill.groups(),
                                  key=lambda p: prefill_affinity(p[0],
                                                                 r.l_in)):
            ranked = sorted((w for w in group if not w.draining),
                            key=lambda w: w.pending_tokens, reverse=True)
            for w in ranked:
                if w.feasible(r):
                    w.place(r)
                    return w
        return None

    def _place_prefill_fallback(self, r: Request) -> \
            Optional[PrefillSimWorker]:
        best, _ = self._earliest_scan(r)
        if best is not None:
            best.place(r)
        return best

    def _earliest_scan(self, r: Request) -> \
            Tuple[Optional[PrefillSimWorker], float]:
        """The worker with the earliest estimated completion for this
        prompt — clock backlog past 'now' plus the prefill of
        (pending + candidate) tokens — and that estimate."""
        now = self._now
        best = None
        best_done = float("inf")
        for spec, group in self.prefill.groups():
            for w in group:
                if w.draining:
                    continue
                backlog = max(w.t - now, 0.0)
                done = backlog + float(w.perf.prefill(w.pending_tokens
                                                      + r.context))
                if done < best_done:
                    best, best_done = w, done
        return best, best_done

    def _place_prefill_earliest(self, r: Request) -> \
            Optional[PrefillSimWorker]:
        """Wait-aware prefill routing: admit on the earliest-completion
        worker if its estimate still meets the TTFT budget. Unlike the
        legacy packed order this sees a worker whose just-run batch left
        it 'empty' but whose clock overshot the beat, so ties spread
        instead of piling onto one bin."""
        best, best_done = self._earliest_scan(r)
        if best is not None and best_done <= self.slo.ttft:
            best.place(r)
            return best
        return None

    def place_decode(self, r: Request) -> Optional[WorkerState]:
        if self.cfg.decode_router == "earliest":
            return self._place_decode_earliest(r)
        for spec, group in sorted(self.decode.groups(),
                                  key=lambda p: decode_affinity(
                                      p[0], r, self.cfg.gamma)):
            if self.cfg.policy == "aladdin":
                w = best_fit_place(group, r, allow_new=False)
            else:
                w = jsq_place(group, r, allow_new=False)
            if w is not None:
                return w
        return None

    def _place_decode_earliest(self, r: Request) -> Optional[WorkerState]:
        """Wait-aware decode placement mirroring the 'earliest' prefill
        router: rank candidates by how far the worker's event-batched clock
        overshot this beat (the stall every new placement inherits before
        its next token), then by the affine pool score, then by Algorithm
        1's packing order — and take the first constraint-feasible one.
        Unlike the packed order this never keeps piling ties onto a bin
        whose clock sits a whole decode segment ahead, so the packed
        router's scale-invariant ATGT tie-pile tail disappears
        (tests/test_decode_router.py pins it)."""
        now = self._now
        sims = self.decode.sims
        ranked = []
        for spec, group in self.decode.groups():
            aff = decode_affinity(spec, r, self.cfg.gamma)
            for w in group:
                if not w.alive or w.draining:
                    continue
                sim = sims.get(w.id)
                backlog = max(sim.t - now, 0.0) if sim is not None else 0.0
                ranked.append((backlog, aff, -w.capacity_norm(), w.id, w))
        ranked.sort(key=lambda e: e[:4])
        for _, _, _, _, w in ranked:
            ok = w.feasible([r]) if self.cfg.policy == "aladdin" \
                else w._admit_naive([r])
            if ok:
                w.place(r)
                return w
        return None

    def step(self, t: float, t_next: float, arrived: int) -> None:
        cfg = self.cfg
        self._now = t
        self.prefill.begin_beat(self, t)
        self.decode.begin_beat(self, t)
        # prefill placement: constraint (c) only, router picks the pool
        still: List[Request] = []
        for r in self.queued_p:
            if self.place_prefill(r) is None:
                still.append(r)
        self.queued_p = still
        # advance the prefill pools; completed prefills enter KV transfer.
        # A re-entrant (t_preempted armed: its decode worker was reclaimed)
        # moves its whole context — prompt plus generated tokens — through
        # the interconnect again; that is the KV re-transfer the asymmetric
        # spot hazards price in.
        for w in self.prefill.active():
            done: List[Tuple[Request, float]] = []
            w.advance_to(t_next, t, done)
            for r, t_done in done:
                retransfer = r.t_preempted is not None
                tok = r.l_in + r.l_out if retransfer else r.l_in
                dt = cfg.kv_transfer_lat \
                    + tok * w.spec.kv_bytes_per_token \
                    / max(cfg.kv_transfer_bw, 1.0)
                self.transfers.append(dt)
                if retransfer:
                    self.kv_retransfers += 1
                # anchor the transfer to the actual prefill completion: for
                # a fresh request t_done == t_first_token (bit-for-bit with
                # the legacy max(t_first_token, t)), for a re-entrant the
                # stale first-token stamp would let the re-transfer start a
                # whole re-prefill early
                self.in_transfer.append((max(t_done, t) + dt, r))
        # KV handoffs completed by this boundary join the decode queue —
        # causally: a handoff ready inside (t, t_next) must wait for the
        # next boundary, else its decode would start before the KV arrived
        self.in_transfer.sort(key=lambda e: e[0])
        while self.in_transfer and self.in_transfer[0][0] <= t:
            self.queued_d.append(self.in_transfer.pop(0)[1])
        # decode placement: split-phase constraints (b)/(e), router-ordered
        still = []
        for r in self.queued_d:
            w = self.place_decode(r)
            if w is None:
                still.append(r)
            else:
                r.state = ReqState.PLACED
        self.queued_d = still
        for w in self.decode.active():
            self.decode.sims[w.id].advance_to(t_next, self.finished,
                                              t_start=t)
        self.prefill.end_beat(self, t, t_next)
        self.decode.end_beat(self, t, t_next)
        if self.observer is not None:
            self.observer(t=t_next, pool_p=self.prefill.active(),
                          states_d=self.decode.active(),
                          queued_p=self.queued_p,
                          in_transfer=self.in_transfer,
                          queued_d=self.queued_d, finished=self.finished,
                          arrived=arrived)

    def drained(self) -> bool:
        return (not self.queued_p and not self.queued_d
                and not self.in_transfer
                and all(not w.queue for w in self.prefill.active())
                and all(not w.ongoing and not w.new_batch
                        for w in self.decode.active())
                and all(not s.preempted
                        for s in self.decode.sims.values()))


def simulate_disaggregated(trace: Sequence[Request], slo: SLO,
                           cfg: DisaggConfig,
                           prefill_spec: Optional[WorkerSpec] = None,
                           decode_spec: Optional[WorkerSpec] = None,
                           n_prefill: int = 0, n_decode: int = 0,
                           predictor=None,
                           observer: Optional[Callable] = None,
                           prefill_pools: Optional[Sequence[Pool]] = None,
                           decode_pools: Optional[Sequence[Pool]] = None
                           ) -> DisaggResult:
    """Simulate ``trace`` on a disaggregated cluster.

    Homogeneous form: ``(prefill_spec, decode_spec, n_prefill, n_decode)``.
    Heterogeneous form: ``prefill_pools`` / ``decode_pools`` as lists of
    ``(WorkerSpec, count)``; the affine router picks the pool per request,
    falling through to the next-ranked pool when no worker is feasible.

    .. deprecated:: delegate to :func:`repro.serving.api.run` — this shim
       builds the equivalent declarative ``Scenario`` and reproduces the
       pre-Scenario metrics bit-for-bit (pinned by tests/test_shim_goldens).
    """
    from repro.serving import api

    p_pools = _as_pools(prefill_spec, n_prefill, prefill_pools)
    d_pools = _as_pools(decode_spec, n_decode, decode_pools)
    pools = [api.PoolSpec(s, k, role="prefill") for s, k in p_pools] \
        + [api.PoolSpec(s, k, role="decode") for s, k in d_pools]
    scenario = api.Scenario(
        workload=trace, fleet=api.FleetSpec(pools), slo=slo,
        topology=api.Disaggregated(heartbeat=cfg.heartbeat, policy=cfg.policy,
                                   gamma=cfg.gamma, theta=cfg.theta,
                                   kv_transfer_bw=cfg.kv_transfer_bw,
                                   kv_transfer_lat=cfg.kv_transfer_lat,
                                   prefill_router=cfg.prefill_router,
                                   decode_router=cfg.decode_router),
        scaling=api.FixedScale(), predictor=predictor, observer=observer,
        seed=cfg.seed)
    return api.run(scenario).to_disagg_result()


def ratio_pool_fn(specs: Sequence[WorkerSpec],
                  ratio: float) -> Callable[[int], List[Pool]]:
    """Map a worker count n to a two-type (spec, count) mix at a fixed
    ratio: ``round(n * ratio)`` workers of ``specs[0]``, the rest of
    ``specs[1]`` (a single spec ignores the ratio). Rounding keeps both
    per-type counts — hence the pool cost — monotone in n, which the
    ``min_cost_disagg`` frontier prune requires."""
    if len(specs) == 1:
        return lambda n: [(specs[0], n)]
    if len(specs) != 2:
        raise ValueError("ratio mixes support exactly 1 or 2 worker types")
    a, b = specs
    r = min(max(ratio, 0.0), 1.0)

    def fn(n: int) -> List[Pool]:
        na = int(round(n * r))
        return [(s, k) for s, k in ((a, na), (b, n - na)) if k > 0]

    return fn


def min_cost_disagg(trace_fn, slo: SLO, cfg: DisaggConfig,
                    prefill_spec: Optional[WorkerSpec] = None,
                    decode_spec: Optional[WorkerSpec] = None,
                    attain_target: float = 0.99,
                    max_prefill: int = 8, hi_decode: int = 64,
                    predictor=None,
                    prefill_pool_fn: Optional[Callable[[int],
                                                       Sequence[Pool]]]
                    = None,
                    decode_pool_fn: Optional[Callable[[int],
                                                      Sequence[Pool]]]
                    = None,
                    prefill_mix: Optional[Sequence[WorkerSpec]] = None,
                    decode_mix: Optional[Sequence[WorkerSpec]] = None,
                    ratio_grid: Sequence[float] = (0.0, 0.25, 0.5,
                                                   0.75, 1.0)
                    ) -> Optional[DisaggResult]:
    """Walk the joint (n_prefill, n_decode) frontier: for each prefill-pool
    size, binary-search the minimum decode pool meeting the target, and keep
    the cheapest feasible point. Returns None if nothing within the bounds
    attains the target.

    ``prefill_pool_fn(n)`` / ``decode_pool_fn(n)`` map a worker count to a
    heterogeneous (spec, count) mix at a ratio the caller fixed; they must
    be monotone (cost non-decreasing in n) for the frontier prune to stay
    exact. The default is n homogeneous workers of the given spec.

    ``prefill_mix`` / ``decode_mix`` (each one or two ``WorkerSpec``) search
    the pool-type *ratio* jointly instead of fixing it: every ratio in
    ``ratio_grid`` (share of the first spec) is frontier-walked on both
    sides, sharing one best-so-far cost bound so expensive ratios are pruned
    before their first simulation where possible.

    .. deprecated:: delegate to :func:`repro.serving.api.optimize`, which
       subsumes this frontier walk (objective="cost" on a disaggregated
       scenario)."""
    from repro.serving import api

    scenario = api.Scenario(
        workload=trace_fn,
        fleet=api.FleetSpec(
            [api.PoolSpec(prefill_spec, 0, role="prefill"),
             api.PoolSpec(decode_spec, 0, role="decode")]
            if prefill_spec is not None and decode_spec is not None else []),
        slo=slo,
        topology=api.Disaggregated(heartbeat=cfg.heartbeat, policy=cfg.policy,
                                   gamma=cfg.gamma, theta=cfg.theta,
                                   kv_transfer_bw=cfg.kv_transfer_bw,
                                   kv_transfer_lat=cfg.kv_transfer_lat,
                                   prefill_router=cfg.prefill_router,
                                   decode_router=cfg.decode_router),
        scaling=api.FixedScale(), predictor=predictor, seed=cfg.seed)
    plan = api.optimize(scenario, objective="cost",
                        attain_target=attain_target,
                        max_prefill=max_prefill, hi_decode=hi_decode,
                        prefill_pool_fn=prefill_pool_fn,
                        decode_pool_fn=decode_pool_fn,
                        prefill_mix=prefill_mix, decode_mix=decode_mix,
                        ratio_grid=ratio_grid)
    return plan.disagg_result
