"""Workload generation: ShareGPT-like length distributions + Poisson arrivals.

The real ShareGPT dump is not redistributable inside this container; we use a
lognormal fit matching its published summary statistics (median prompt ~50
tokens with a heavy tail clipped at the 4k context, outputs ~200 median,
weakly correlated with prompt length — cf. the paper's Fig. 2, where output
CDFs shift only slightly across prompt-length bins)."""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.core.request import Request


@dataclasses.dataclass
class WorkloadConfig:
    mean_rate: float = 2.0              # requests / second (Poisson)
    duration: float = 60.0              # seconds
    max_context: int = 4096
    in_mu: float = 4.2                  # ln-space prompt mean (~66 median)
    in_sigma: float = 1.3
    out_mu: float = 5.1                 # ~164 median
    out_sigma: float = 0.9
    out_in_corr: float = 0.15           # mild coupling of ln-lengths
    tail_frac: float = 0.0              # fraction of Pareto-tail outputs
    tail_alpha: float = 1.5             # Pareto shape (lower = heavier)
    seed: int = 0


def sample_lengths(cfg: WorkloadConfig, n: int, rng=None):
    rng = rng or np.random.default_rng(cfg.seed)
    z1 = rng.standard_normal(n)
    z2 = cfg.out_in_corr * z1 + np.sqrt(1 - cfg.out_in_corr ** 2) \
        * rng.standard_normal(n)
    l_in = np.exp(cfg.in_mu + cfg.in_sigma * z1).astype(np.int64)
    l_out = np.exp(cfg.out_mu + cfg.out_sigma * z2).astype(np.int64)
    if cfg.tail_frac > 0:
        # heavy-tail mixture: a Pareto(α) share of outputs models the long
        # agentic/code generations the lognormal body under-represents
        tail = rng.random(n) < cfg.tail_frac
        pareto = (np.exp(cfg.out_mu)
                  * (1.0 + rng.pareto(cfg.tail_alpha, n))).astype(np.int64)
        l_out = np.where(tail, pareto, l_out)
    l_in = np.clip(l_in, 4, cfg.max_context // 2)
    l_out = np.clip(l_out, 4, cfg.max_context // 2)
    return l_in, l_out


def clone_trace(trace) -> List[Request]:
    """Replay a materialized workload: fresh ``Request`` objects carrying
    the same immutable draw (l_in, l_real, arrival) and none of the per-run
    mutable state. This is how ``api.optimize`` evaluates every candidate
    fleet against the *same* arrivals — the workload is sampled once and
    cloned per simulation, instead of implicitly re-sampled via a trace
    factory."""
    return [Request(l_in=r.l_in, l_pred=0, l_real=r.l_real,
                    arrival=r.arrival, tenant=r.tenant,
                    priority=r.priority, slo_ttft=r.slo_ttft,
                    slo_atgt=r.slo_atgt, session_id=r.session_id,
                    turn=r.turn, prefix_len=r.prefix_len) for r in trace]


def mixture_trace(tenant_traces) -> List[Request]:
    """Merge per-tenant arrival streams into one trace, tagging every
    request with its tenant index.

    ``tenant_traces`` is a sequence of per-tenant request lists (already
    materialized). Each request's ``tenant`` field is set to its stream's
    position in ``tenant_traces``; the merged trace is ordered by arrival
    time with a stable, documented tie-break: at equal arrival times, the
    lower tenant index comes first, and within one tenant the original
    stream order is preserved. The merge is a pure reorder of the input
    objects — deterministic for a given input, so a merged trace replays
    identically across all three engines and across reseeds of the
    underlying per-tenant generators."""
    merged: List[Request] = []
    for k, trace in enumerate(tenant_traces):
        for r in trace:
            r.tenant = k
            merged.append(r)
    # sorted() is stable, so equal arrivals keep concatenation order:
    # the effective key is (arrival, tenant index, within-tenant position)
    merged.sort(key=lambda r: r.arrival)
    return merged


def generate_trace(cfg: WorkloadConfig,
                   rate: Optional[float] = None) -> List[Request]:
    """Poisson arrival stream with sampled (l_in, l_real) per request."""
    rng = np.random.default_rng(cfg.seed)
    rate = rate if rate is not None else cfg.mean_rate
    scale = 1.0 / max(rate, 1e-9)
    n = max(int(rate * cfg.duration * 1.5), 16)
    gaps = rng.exponential(scale, n)
    # keep drawing until the stream covers the whole horizon — a fixed
    # draw silently truncates the trace tail on unlucky seeds (same bug
    # class nonhomogeneous_trace guards against)
    while gaps.sum() < cfg.duration:
        gaps = np.concatenate([gaps, rng.exponential(scale, n)])
    arrivals = np.cumsum(gaps)
    arrivals = arrivals[arrivals < cfg.duration]
    l_in, l_out = sample_lengths(cfg, len(arrivals), rng)
    return [Request(l_in=int(a), l_pred=0, l_real=int(b), arrival=float(t))
            for a, b, t in zip(l_in, l_out, arrivals)]


def nonhomogeneous_trace(cfg: WorkloadConfig,
                         rate_fn: Callable[[float], float],
                         rate_max: float) -> List[Request]:
    """Non-homogeneous Poisson arrivals via Lewis-Shedler thinning: draw a
    homogeneous stream at rate_max, keep each point with probability
    rate_fn(t) / rate_max."""
    rng = np.random.default_rng(cfg.seed)
    scale = 1.0 / max(rate_max, 1e-9)
    chunk = max(int(rate_max * cfg.duration * 1.5), 16)
    gaps = rng.exponential(scale, chunk)
    # keep drawing until the candidate stream covers the whole horizon —
    # a fixed draw silently truncates the trace tail on unlucky seeds
    while gaps.sum() < cfg.duration:
        gaps = np.concatenate([gaps, rng.exponential(scale, chunk)])
    cand = np.cumsum(gaps)
    cand = cand[cand < cfg.duration]
    keep = rng.random(len(cand)) < np.array(
        [rate_fn(float(t)) for t in cand]) / rate_max
    arrivals = cand[keep]
    l_in, l_out = sample_lengths(cfg, len(arrivals), rng)
    return [Request(l_in=int(a), l_pred=0, l_real=int(b), arrival=float(t))
            for a, b, t in zip(l_in, l_out, arrivals)]


def burst_trace(cfg: WorkloadConfig, burst_rate: float,
                burst_start: float, burst_duration: float) -> List[Request]:
    """Base-rate stream with a rectangular rate spike (flash crowd): the
    demand change the Eq. 7 autoscaler's change-point detector must catch."""
    base = cfg.mean_rate

    def rate_fn(t: float) -> float:
        return burst_rate if burst_start <= t < burst_start + burst_duration \
            else base

    return nonhomogeneous_trace(cfg, rate_fn, max(base, burst_rate))


def diurnal_rate_fn(cfg: WorkloadConfig, amplitude: float = 0.5,
                    period: Optional[float] = None,
                    phase: float = 0.0) -> Callable[[float], float]:
    """Ground-truth diurnal rate curve rate(t) = mean·(1 + A·sin(2πt/period)),
    exposed separately so forecast evaluations can compare predictions
    against the true intensity (period defaults to the trace duration)."""
    period = period or cfg.duration
    a = min(max(amplitude, 0.0), 1.0)

    def rate_fn(t: float) -> float:
        return cfg.mean_rate * (1.0 + a * np.sin(2 * np.pi * t / period
                                                 + phase))

    return rate_fn


def diurnal_trace(cfg: WorkloadConfig, amplitude: float = 0.5,
                  period: Optional[float] = None,
                  phase: float = 0.0) -> List[Request]:
    """Sinusoidal day/night demand: rate(t) = mean·(1 + A·sin(2πt/period)).
    period defaults to the trace duration (one full cycle)."""
    a = min(max(amplitude, 0.0), 1.0)
    rate_fn = diurnal_rate_fn(cfg, amplitude, period, phase)
    return nonhomogeneous_trace(cfg, rate_fn, cfg.mean_rate * (1.0 + a))


def drifting_diurnal_rate_fn(cfg: WorkloadConfig, amplitude: float = 0.5,
                             period: Optional[float] = None,
                             drift: float = 0.5,
                             phase: float = 0.0) -> Callable[[float], float]:
    """Diurnal rate curve whose seasonality *drifts*: the instantaneous
    period stretches linearly from ``period`` at t=0 to
    ``period * (1 + drift)`` at t=duration, so the accumulated phase is
    ``2π ∫ dt'/P(t')`` rather than ``2π t/period``. A seasonal-naive
    forecaster keyed to the nominal period accumulates phase error cycle
    after cycle — by mid-trace it provisions for yesterday's peak at
    today's trough — which is exactly the open-loop miscalibration regime
    SLO-feedback scaling exists for."""
    period = period or cfg.duration
    a = min(max(amplitude, 0.0), 1.0)
    d = max(drift, 0.0)

    def cycles(t: float) -> float:
        if d <= 1e-12:
            return t / period
        # ∫0^t dt' / (period * (1 + d*t'/duration))
        return cfg.duration / (period * d) * np.log1p(d * t / cfg.duration)

    def rate_fn(t: float) -> float:
        return cfg.mean_rate * (1.0 + a * np.sin(2 * np.pi * cycles(t)
                                                 + phase))

    return rate_fn


def drifting_diurnal_trace(cfg: WorkloadConfig, amplitude: float = 0.5,
                           period: Optional[float] = None,
                           drift: float = 0.5,
                           phase: float = 0.0) -> List[Request]:
    """Drifted-seasonality demand (see :func:`drifting_diurnal_rate_fn`):
    the trace a forecast policy trained on the nominal ``period``
    mis-serves — the benchmark workload for ``FeedbackScale``."""
    a = min(max(amplitude, 0.0), 1.0)
    rate_fn = drifting_diurnal_rate_fn(cfg, amplitude, period, drift, phase)
    return nonhomogeneous_trace(cfg, rate_fn, cfg.mean_rate * (1.0 + a))


# ---- multi-turn sessions -----------------------------------------------------

@dataclasses.dataclass
class SessionSpec:
    """Multi-turn chat workload: sessions arrive Poisson at ``mean_rate``;
    each runs a geometric number of turns (mean ``mean_turns``, capped at
    ``max_turns``). Turn 0 draws a fresh lognormal prompt; every later turn
    re-submits the previous turn's full context (prompt + reply — the
    cacheable prefix, tagged on the request as ``prefix_len``) plus a
    lognormal ``growth`` of new user tokens. Turn k+1 arrives at
    ``arrival_k + service_proxy * (l_in_k + l_out_k) + think_k`` — a
    finish-independent causal bound (the proxy stands in for service time,
    so a turn can arrive while its predecessor is still queued on a slow
    cluster, but never before the user could plausibly have read the
    previous reply). Deterministic per ``seed``."""
    mean_rate: float = 0.5              # session starts / second (Poisson)
    duration: float = 60.0              # session-start horizon, seconds
    mean_turns: float = 4.0             # geometric mean turn count (>= 1)
    max_turns: int = 12
    in_mu: float = 4.2                  # ln-space first-turn prompt
    in_sigma: float = 1.0
    growth_mu: float = 3.4              # ln-space per-turn new user tokens
    growth_sigma: float = 0.8
    out_mu: float = 5.1                 # ln-space per-turn reply length
    out_sigma: float = 0.9
    think_mu: float = 1.8               # ln-space think time, seconds
    think_sigma: float = 0.8
    service_proxy: float = 0.02         # causal-bound proxy, seconds/token
    max_context: int = 4096
    seed: int = 0


def check_session_envelope(spec: SessionSpec) -> SessionSpec:
    """Validate every ``SessionSpec`` knob (the generator's envelope fence;
    simlint SIM006 requires each field to be validator-inspected)."""
    if not spec.mean_rate > 0:
        raise ValueError(f"mean_rate must be > 0 (got {spec.mean_rate})")
    if not spec.duration > 0:
        raise ValueError(f"duration must be > 0 (got {spec.duration})")
    if not spec.mean_turns >= 1.0:
        raise ValueError(f"mean_turns must be >= 1 (got {spec.mean_turns})")
    if int(spec.max_turns) < 1:
        raise ValueError(f"max_turns must be >= 1 (got {spec.max_turns})")
    dists = {"in_mu": spec.in_mu, "in_sigma": spec.in_sigma,
             "growth_mu": spec.growth_mu, "growth_sigma": spec.growth_sigma,
             "out_mu": spec.out_mu, "out_sigma": spec.out_sigma,
             "think_mu": spec.think_mu, "think_sigma": spec.think_sigma}
    for name, v in dists.items():
        if not np.isfinite(v):
            raise ValueError(f"{name} must be finite (got {v})")
        if name.endswith("sigma") and v < 0:
            raise ValueError(f"{name} must be >= 0 (got {v})")
    if not spec.service_proxy >= 0:
        raise ValueError("service_proxy must be >= 0 "
                         f"(got {spec.service_proxy})")
    if int(spec.max_context) < 8:
        raise ValueError(f"max_context must be >= 8 (got {spec.max_context})")
    if int(spec.seed) < 0:
        raise ValueError(f"seed must be >= 0 (got {spec.seed})")
    return spec


def session_trace(spec: SessionSpec) -> List[Request]:
    """Materialize a :class:`SessionSpec` into an arrival-ordered request
    list. Per-turn invariants (property-tested): ``prefix_len`` is monotone
    non-decreasing within a session and equals the previous turn's full
    context (clipped at the context budget); arrivals within a session are
    strictly causal under the think-time bound; ``l_in >= prefix_len`` and
    ``l_in + l_real <= max_context``."""
    check_session_envelope(spec)
    rng = np.random.default_rng(spec.seed)
    cap_in = spec.max_context // 2      # same per-side budget sample_lengths
    cap_out = spec.max_context // 2     # enforces for single-shot traces
    reqs: List[Request] = []
    sid = 0
    t0 = float(rng.exponential(1.0 / spec.mean_rate))
    while t0 < spec.duration:
        n_turns = min(int(rng.geometric(1.0 / spec.mean_turns)),
                      int(spec.max_turns))
        t = t0
        prefix = 0
        l_in = int(np.clip(int(np.exp(spec.in_mu + spec.in_sigma
                                      * rng.standard_normal())), 4, cap_in))
        for k in range(n_turns):
            l_out = int(np.clip(int(np.exp(spec.out_mu + spec.out_sigma
                                           * rng.standard_normal())),
                                4, cap_out))
            reqs.append(Request(l_in=l_in, l_pred=0, l_real=l_out,
                                arrival=float(t), session_id=sid, turn=k,
                                prefix_len=prefix))
            think = float(np.exp(spec.think_mu + spec.think_sigma
                                 * rng.standard_normal()))
            t = t + spec.service_proxy * (l_in + l_out) + think
            # next turn re-submits the whole conversation so far plus new
            # user tokens; the clip keeps l_in within the context budget
            # (prefix stays monotone: min is over a non-decreasing pair)
            prefix = min(l_in + l_out, cap_in)
            growth = int(np.clip(int(np.exp(
                spec.growth_mu + spec.growth_sigma
                * rng.standard_normal())), 1, cap_in))
            l_in = min(prefix + growth, cap_in)
        sid += 1
        t0 += float(rng.exponential(1.0 / spec.mean_rate))
    reqs.sort(key=lambda r: r.arrival)
    return reqs


# ---- spot-market preemption events -------------------------------------------

@dataclasses.dataclass(frozen=True)
class PreemptionEvent:
    """One market-level spot reclaim: at time ``t`` the provider takes back
    ``frac`` of the *current* spot pool (at least one worker if any are up).
    A fractional reclaim models the correlated nature of real spot markets —
    a capacity crunch reclaims a slice of the pool at once, not independent
    single instances."""
    t: float
    frac: float = 0.25


def preemption_trace(duration: float, event_rate: float,
                     frac: float = 0.25, frac_jitter: float = 0.0,
                     seed: int = 0) -> List[PreemptionEvent]:
    """Poisson stream of market reclaim events over ``[0, duration)``.

    Events arrive at ``event_rate`` per second; each reclaims ``frac`` of the
    spot pool alive at that instant (± uniform ``frac_jitter``, clipped to
    (0, 1]). The effective per-worker hazard — what
    ``core.scaling.SpotMixConfig`` should be fed — is approximately
    ``event_rate * frac``. A pre-generated trace (rather than per-worker
    lifetime draws inside the simulator) keeps preemptions replayable and
    independent of how many workers the policy happens to buy."""
    rng = np.random.default_rng(seed)
    events: List[PreemptionEvent] = []
    t = float(rng.exponential(1.0 / max(event_rate, 1e-12)))
    while t < duration:
        f = frac
        if frac_jitter > 0:
            f += float(rng.uniform(-frac_jitter, frac_jitter))
        f = float(np.clip(f, 1e-6, 1.0))
        events.append(PreemptionEvent(t=t, frac=f))
        t += float(rng.exponential(1.0 / max(event_rate, 1e-12)))
    return events
