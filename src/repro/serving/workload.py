"""Workload generation: ShareGPT-like length distributions + Poisson arrivals.

The real ShareGPT dump is not redistributable inside this container; we use a
lognormal fit matching its published summary statistics (median prompt ~50
tokens with a heavy tail clipped at the 4k context, outputs ~200 median,
weakly correlated with prompt length — cf. the paper's Fig. 2, where output
CDFs shift only slightly across prompt-length bins)."""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.core.request import Request


@dataclasses.dataclass
class WorkloadConfig:
    mean_rate: float = 2.0              # requests / second (Poisson)
    duration: float = 60.0              # seconds
    max_context: int = 4096
    in_mu: float = 4.2                  # ln-space prompt mean (~66 median)
    in_sigma: float = 1.3
    out_mu: float = 5.1                 # ~164 median
    out_sigma: float = 0.9
    out_in_corr: float = 0.15           # mild coupling of ln-lengths
    seed: int = 0


def sample_lengths(cfg: WorkloadConfig, n: int, rng=None):
    rng = rng or np.random.default_rng(cfg.seed)
    z1 = rng.standard_normal(n)
    z2 = cfg.out_in_corr * z1 + np.sqrt(1 - cfg.out_in_corr ** 2) \
        * rng.standard_normal(n)
    l_in = np.exp(cfg.in_mu + cfg.in_sigma * z1).astype(np.int64)
    l_out = np.exp(cfg.out_mu + cfg.out_sigma * z2).astype(np.int64)
    l_in = np.clip(l_in, 4, cfg.max_context // 2)
    l_out = np.clip(l_out, 4, cfg.max_context // 2)
    return l_in, l_out


def generate_trace(cfg: WorkloadConfig,
                   rate: Optional[float] = None) -> List[Request]:
    """Poisson arrival stream with sampled (l_in, l_real) per request."""
    rng = np.random.default_rng(cfg.seed)
    rate = rate if rate is not None else cfg.mean_rate
    n = max(int(rate * cfg.duration * 1.5), 16)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), n)
    arrivals = np.cumsum(gaps)
    arrivals = arrivals[arrivals < cfg.duration]
    l_in, l_out = sample_lengths(cfg, len(arrivals), rng)
    return [Request(l_in=int(a), l_pred=0, l_real=int(b), arrival=float(t))
            for a, b, t in zip(l_in, l_out, arrivals)]
