"""Forecast-aware worker-count scaling (SageServe-style predictive scaling).

The reactive Eq. 7 scaler only reacts: it observes the arrival rate of the
*last* epoch, so with a non-zero provisioning delay every diurnal ramp is
served late (SLO misses on the ascent) and every decline is served long
(GPU-seconds wasted on the descent, where a reactive policy must hold a
scale-down cooldown because it cannot know demand is really falling).

This module adds the look-ahead half of the paper's §5.2 story:

  * ``SeasonalNaiveForecaster`` — demand forecast = the rate observed at the
    same phase one period ago (seasonal-naive) plus an EWMA of the recent
    residuals (level correction for traffic growth/decay). Any object with
    ``observe(t, rate)`` / ``forecast(t, lead)`` plugs in; ``EWMAForecaster``
    is the trivial non-seasonal baseline.
  * ``ReactivePolicy`` / ``ForecastPolicy`` — epoch scaling policies. Both
    feed the Eq. 7 fit; the forecast policy asks the forecaster for the rate
    ``provision_delay + epoch`` ahead, so workers are booted *before* the
    ramp needs them, and it keeps a per-phase floor of the worker count each
    phase bin has historically needed (never provision fewer workers at a
    ramp peak than the same phase needed one period earlier).
  * ``simulate_autoscaled`` — the colocated simulator with a worker
    lifecycle (boot delay, draining, retirement) driven by a policy, built
    on the same causal-time heartbeat core; reports GPU-seconds actually
    billed, which is what the cost comparison in the benchmarks uses.
  * ``SpotMarket`` — a preemptible capacity pool next to the on-demand one:
    a spot ``WorkerSpec`` (discounted price, reclaim hazard) plus a
    ``workload.preemption_trace`` of market reclaim events. The simulator
    kills spot workers when an event lands — their in-flight requests lose
    KV and re-enter the queue, paying a full re-prefill (prompt + generated
    tokens) plus the stall, both charged against TTFT/ATGT — and bills every
    worker at its own price class. ``ForecastPolicy`` (given a
    ``core.scaling.SpotMixConfig``) splits each epoch's capacity target into
    an (on-demand, spot) mix: the diurnal trough is served from reliable
    capacity, the swing from discounted-but-mortal spot, inflated by the
    hazard so expected surviving capacity still covers the target.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.request import Request
from repro.core.scaling import (AttainmentController, Autoscaler,
                                AutoscalerConfig, FeedbackConfig,
                                SpotMixConfig, split_spot_mix)
from repro.core.slo import SLO
from repro.core.worker_config import WorkerSpec
from repro.serving.lifecycle import (WorkerLifecycle,          # noqa: F401
                                     mark_kv_loss, mark_requeue)
from repro.serving.simulator import SimConfig
from repro.serving.workload import PreemptionEvent


# ---- forecasters -------------------------------------------------------------

@dataclasses.dataclass
class ForecastConfig:
    period: float = 300.0       # seasonal period, s (one diurnal cycle)
    bin_width: float = 5.0      # phase-bin resolution, s
    ewma_alpha: float = 0.3     # residual / level smoothing


class SeasonalNaiveForecaster:
    """Seasonal-naive + EWMA-residual demand forecaster.

    ``forecast(t, lead)`` returns the rate last observed at phase
    ``(t + lead) mod period`` plus the EWMA of recent (observed - seasonal)
    residuals; before a phase has been seen once, it falls back to the EWMA
    level of the rate itself (cold start = the reactive estimate)."""

    def __init__(self, cfg: ForecastConfig = ForecastConfig()):
        self.cfg = cfg
        self.n_bins = max(int(round(cfg.period / cfg.bin_width)), 1)
        self.seasonal: List[float] = [float("nan")] * self.n_bins
        self.resid = 0.0
        self.level: Optional[float] = None

    def _bin(self, t: float) -> int:
        return int(t / self.cfg.bin_width) % self.n_bins

    def observe(self, t: float, rate: float) -> None:
        a = self.cfg.ewma_alpha
        b = self._bin(t)
        prev = self.seasonal[b]
        if not math.isnan(prev):
            self.resid = a * (rate - prev) + (1 - a) * self.resid
        self.level = rate if self.level is None \
            else a * rate + (1 - a) * self.level
        self.seasonal[b] = rate

    def forecast(self, t: float, lead: float = 0.0) -> float:
        s = self.seasonal[self._bin(t + lead)]
        if math.isnan(s):
            return self.level if self.level is not None else 0.0
        return max(s + self.resid, 0.0)


class EWMAForecaster:
    """Non-seasonal baseline: the forecast at any lead is the EWMA level."""

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self.level: Optional[float] = None

    def observe(self, t: float, rate: float) -> None:
        self.level = rate if self.level is None \
            else self.alpha * rate + (1 - self.alpha) * self.level

    def forecast(self, t: float, lead: float = 0.0) -> float:
        return self.level if self.level is not None else 0.0


# ---- scaling policies --------------------------------------------------------

@dataclasses.dataclass
class ScaleSimConfig:
    interval: float = 5.0            # scaling-epoch length, s
    provision_delay: float = 10.0    # boot time before a new worker serves
    cooldown: float = 60.0           # reactive scale-down stabilization, s
    lead: Optional[float] = None     # forecast look-ahead; None = delay+epoch
    min_workers: int = 1
    max_workers: int = 512
    initial_workers: int = 1
    # SLO head-room on every epoch target (kube-HPA-style utilization < 1):
    # a disaggregated pipeline needs it because per-side queue pressure
    # under-measures SLO pressure — TTFT burns in the arrival->prefill hop
    # and ATGT in the handoff->decode hop before any placement fails.
    headroom: float = 1.0


class ReactivePolicy:
    """Eq. 7 on the last observed rate + change-point boost + a scale-down
    cooldown (the kube-HPA-style stabilization window a reactive scaler
    needs to avoid flapping, and the GPU-seconds it pays on every descent)."""

    name = "reactive"

    def __init__(self, scfg: ScaleSimConfig,
                 autoscaler: Optional[Autoscaler] = None,
                 spot_mix: Optional[SpotMixConfig] = None):
        self.scfg = scfg
        self.autoscaler = autoscaler or Autoscaler(AutoscalerConfig(
            heartbeat=scfg.interval, min_workers=scfg.min_workers,
            max_workers=scfg.max_workers))
        self._recent: List[tuple] = []      # (t, raw target) inside cooldown
        # same exposure-horizon derivation as ForecastPolicy (policy-local
        # copy; the caller's config object is never mutated)
        self.spot_mix = None if spot_mix is None else dataclasses.replace(
            spot_mix, horizon=scfg.provision_delay + scfg.interval)

    def target(self, t: float, rate: float, needed: int,
               queued: int) -> int:
        sc = self.autoscaler
        sc.observe(rate, needed)
        tgt = sc.predict_workers(rate, last_needed=needed)
        if sc.change_point():
            tgt = max(tgt, needed)
        self._recent.append((t, tgt))
        self._recent = [x for x in self._recent
                        if x[0] >= t - self.scfg.cooldown]
        return max(tg for _, tg in self._recent)

    def split(self, t: float, target: int) -> Tuple[int, int]:
        """Price-class split: pure ``split_spot_mix`` economics (a reactive
        policy has no seasonal trough to pin on-demand). Without a mix the
        split is all-on-demand, which keeps the pre-spot behavior."""
        if self.spot_mix is None:
            return target, 0
        return split_spot_mix(target, self.spot_mix)


class ForecastPolicy:
    """Eq. 7 on the *forecast* rate ``lead`` seconds ahead, plus a per-phase
    floor of the worker count that phase has historically needed.  No
    cooldown: the forecaster itself says when demand is really falling, so
    the policy sheds workers on the descent instead of holding them.

    With a ``SpotMixConfig`` the policy also owns the price-class decision:
    ``split(t, target)`` carves each epoch's capacity target into an
    (on-demand, spot) pair — the historical diurnal *trough* (capacity some
    phase always needs) stays on reliable on-demand workers, the
    forecast-driven swing above it rides discounted spot, inflated by the
    reclaim hazard so the expected surviving capacity still covers the
    target (``core.scaling.split_spot_mix``)."""

    name = "forecast"

    def __init__(self, scfg: ScaleSimConfig, forecaster,
                 autoscaler: Optional[Autoscaler] = None,
                 spot_mix: Optional[SpotMixConfig] = None):
        self.scfg = scfg
        self.forecaster = forecaster
        self.autoscaler = autoscaler or Autoscaler(AutoscalerConfig(
            heartbeat=scfg.interval, min_workers=scfg.min_workers,
            max_workers=scfg.max_workers))
        # exposure horizon = how long a loss stays unreplaced: one epoch to
        # notice it plus the boot delay of the replacement (a policy-local
        # copy — the caller's config object is never mutated)
        self.spot_mix = None if spot_mix is None else dataclasses.replace(
            spot_mix, horizon=scfg.provision_delay + scfg.interval)
        # phase bin -> max workers that phase has needed (seasonal floor);
        # a forecaster without phase bins degrades to one global bin
        self._bin: Callable[[float], int] = getattr(forecaster, "_bin",
                                                    lambda t: 0)
        self._season_needed: Dict[int, int] = {}

    @property
    def lead(self) -> float:
        return self.scfg.lead if self.scfg.lead is not None \
            else self.scfg.provision_delay + self.scfg.interval

    def _leads(self) -> List[float]:
        # sample the whole look-ahead window at epoch resolution so no
        # phase bin inside [t, t + lead] can be skipped over
        step = max(min(self.scfg.interval, self.lead), 1e-9)
        leads = [k * step for k in range(int(self.lead / step) + 1)]
        if leads[-1] < self.lead:
            leads.append(self.lead)
        return leads

    def target(self, t: float, rate: float, needed: int,
               queued: int) -> int:
        sc, fc = self.autoscaler, self.forecaster
        sc.observe(rate, needed)
        fc.observe(t, rate)
        b_now = self._bin(t)
        self._season_needed[b_now] = max(self._season_needed.get(b_now, 0),
                                         needed)
        leads = self._leads()
        r_ahead = max(fc.forecast(t, dl) for dl in leads)
        tgt = sc.predict_workers(max(rate, r_ahead), last_needed=needed)
        floor = max(self._season_needed.get(self._bin(t + dl), 0)
                    for dl in leads)
        return max(tgt, floor)

    def split(self, t: float, target: int) -> Tuple[int, int]:
        """Carve ``target`` into (n_on_demand, n_spot) for this epoch.

        The always-on base — the smallest worker count any observed phase
        has needed (the diurnal trough) — is pinned to on-demand capacity;
        only the swing above it is eligible for spot. Within that bound the
        economics of ``split_spot_mix`` decide, so a hazard spike or a thin
        discount degrades gracefully to all-on-demand."""
        mix = self.spot_mix
        if mix is None:
            return target, 0
        n_od, n_spot = split_spot_mix(target, mix)
        if mix.spot_frac is None and self._season_needed:
            trough = min(self._season_needed.values())
            base = min(trough, target)
            if base > n_od and n_spot > 0:
                n_od = base
                n_spot = int(math.ceil(max(target - base, 0)
                                       / max(mix.survival(), 1e-9)))
        return n_od, n_spot


class FeedbackPolicy:
    """Closed-loop SLO-feedback scaling: an open-loop policy (reactive or
    forecast) proposes each epoch's worker target, and an
    :class:`~repro.core.scaling.AttainmentController` corrects it from the
    *observed* windowed SLO attainment the cluster delivered.

    The wrapper composes rather than replaces: the inner policy keeps its
    whole demand model (Eq. 7 fit, forecaster, seasonal floor, spot split),
    so the feedback term only has to absorb what the demand model got wrong
    — a drifted seasonality boosts the gain at the mispredicted ramps and
    releases it in the over-provisioned troughs. ``metric`` selects which
    SLO dimension the controller watches (``both`` for a colocated tier,
    ``ttft`` for a prefill side, ``atgt`` for a decode side); the pool feeds
    ``observe_slo`` once per scaling epoch from the topology's windowed
    attainment. With an infinite deadband the controller never moves off
    gain 1.0 and the closed loop reproduces the open-loop policy
    bit-for-bit (pinned by tests/test_feedback.py)."""

    name = "feedback"

    def __init__(self, inner, fcfg: Optional[FeedbackConfig] = None,
                 metric: str = "both"):
        self.inner = inner
        self.fcfg = fcfg or FeedbackConfig()
        self.metric = metric
        self.controller = AttainmentController(self.fcfg)

    @property
    def scfg(self) -> ScaleSimConfig:
        return self.inner.scfg

    @property
    def spot_mix(self):
        return getattr(self.inner, "spot_mix", None)

    @property
    def gain(self) -> float:
        return self.controller.gain

    @property
    def window(self) -> float:
        return self.fcfg.window

    def observe_slo(self, t: float, ok: int, total: int) -> None:
        self.controller.observe(t, ok, total)

    def target(self, t: float, rate: float, needed: int,
               queued: int) -> int:
        return self.controller.apply(
            self.inner.target(t, rate, needed, queued))

    def split(self, t: float, target: int) -> Tuple[int, int]:
        return self.inner.split(t, target)


# ---- autoscaled simulation ---------------------------------------------------

@dataclasses.dataclass
class SpotMarket:
    """A preemptible capacity pool the engine may buy from: the spot worker
    type (same hardware as the on-demand spec, discounted ``price``, non-zero
    ``preempt_hazard``) plus the market's reclaim-event trace
    (``workload.preemption_trace``). Each event kills a slice of the spot
    workers alive at that instant — on-demand workers are never touched.

    ``notice_s`` models the preemption notice real clouds give (30-120 s):
    a reclaimed worker drains — no new admissions, in-flight decode may
    finish until the deadline — instead of dying instantly; whatever is
    still running at the deadline is killed and requeued with the usual
    KV-loss recovery cost. ``RunReport`` records ``drained_ok`` vs
    ``killed``. ``notice_s=0`` (default) is the instant-kill behavior.

    On a disaggregated topology ``spec``/``events`` drive the *decode* side
    (a decode reclaim loses KV and pays a full re-prefill plus KV
    re-transfer); ``prefill_spec``/``prefill_events`` describe the
    prefill-side market, whose reclaims are nearly free (queued prompts just
    re-queue) — which is why asymmetric hazards/discounts between the two
    sides are worth modeling at all."""
    spec: WorkerSpec
    events: Sequence[PreemptionEvent] = dataclasses.field(
        default_factory=list)
    notice_s: float = 0.0
    prefill_spec: Optional[WorkerSpec] = None
    prefill_events: Sequence[PreemptionEvent] = dataclasses.field(
        default_factory=list)


@dataclasses.dataclass
class EpochStat:
    t: float                 # epoch start time
    rate: float              # observed arrivals / interval
    needed: int              # peak busy workers (+1 if a backlog remained)
    target: int              # policy decision for the next epoch (total)
    online: int              # workers online after applying the decision
    target_spot: int = 0     # spot share of the target
    online_spot: int = 0     # spot workers online after the decision


@dataclasses.dataclass
class ScaleSimResult:
    policy: str
    gpu_seconds: float       # Σ billed cost (gpu_cost * dt over the fleet,
    attainment: float        # in on-demand accelerator-second equivalents)
    p99_ttft: float
    p99_atgt: float
    mean_atgt: float
    finished: int
    total: int
    peak_workers: int
    spot_gpu_seconds: float = 0.0    # billed share from the spot pool
    preempted_workers: int = 0       # spot workers reclaimed mid-flight
    requeued: int = 0                # requests that lost KV and re-entered
    epochs: List[EpochStat] = dataclasses.field(default_factory=list)

    def row(self) -> Dict:
        d = dataclasses.asdict(self)
        d.pop("epochs")
        return d


class ManagedPool:
    """Policy-driven worker lifecycle extracted from the pre-Scenario
    ``simulate_autoscaled``: boot delay (billed while booting), voluntary
    draining on scale-down, retirement, price-class-aware booting, per-price
    billing, market reclaims and the preemption-notice drain window.

    Generic over the worker kind via adapter callables — ``new_worker(spec)``
    builds one, ``on_spawn(w, t)`` arms its execution model, ``on_kill(w)``
    strips and returns its in-flight requests, ``load(w)``/``idle(w)`` rank
    drain victims and detect retirement, ``mark(r, t)`` stamps the recovery
    cost class on reclaimed work — so the colocated tier and either side of
    a disaggregated cluster scale through one state machine."""

    def __init__(self, spec: WorkerSpec, scfg: ScaleSimConfig, policy,
                 heartbeat: float, rng, *, new_worker, on_spawn, on_kill,
                 load, idle, mark=mark_kv_loss, sims=None,
                 spot_spec: Optional[WorkerSpec] = None,
                 notice_s: float = 0.0, name: str = "serve"):
        self.spec = spec
        self.scfg = scfg
        self.policy = policy
        self.rng = rng
        self.spot_spec = spot_spec
        self.name = name
        self._new_worker = new_worker
        self._on_spawn = on_spawn
        self._load = load
        self._idle = idle
        self.sims = sims if sims is not None else {}
        self.factory = None                # managed pools never place-to-open
        self.beats_per_epoch = max(int(round(scfg.interval / heartbeat)), 1)
        self.online: List = []
        self.draining: List = []
        self.booting: List[List] = []      # [online_at, worker]
        self.life = WorkerLifecycle(
            rng, notice_s=notice_s, extract=on_kill, mark=mark, idle=idle,
            remove=self._remove, on_condemn=self._condemn)
        self.epochs: List[EpochStat] = []
        self.acc = {"gpu_s": 0.0, "spot_gpu_s": 0.0, "beat": 0,
                    "arrivals": 0, "busy_peak": 0, "peak": 0}
        for _ in range(max(scfg.initial_workers, scfg.min_workers)):
            w = self._new_worker(self.spec)
            self.online.append(w)
            self._on_spawn(w, 0.0)

    # ---- WorkerLifecycle adapters -------------------------------------------
    def _remove(self, w) -> None:
        (self.online if w in self.online else self.draining).remove(w)
        # flush the worker's execution-model state: a voluntarily drained
        # retirement never goes through on_kill (nothing to extract), so
        # without this pop the sims entry — and any prefix cache it holds —
        # would outlive the worker and leak stale session prefixes into
        # ``drained()`` checks and the cache ledger
        sim = self.sims.pop(w.id, None)
        if sim is not None and getattr(sim, "cache", None) is not None:
            sim.cache.vaporize()

    def _condemn(self, w) -> None:
        # the provider is taking it back: drain immediately (no admissions)
        if w in self.online:
            self.online.remove(w)
            self.draining.append(w)

    # ---- accessors the topologies use ---------------------------------------
    @property
    def gpu_s(self) -> float:
        return self.acc["gpu_s"]

    @property
    def spot_gpu_s(self) -> float:
        return self.acc["spot_gpu_s"]

    @property
    def killed(self) -> int:
        return self.life.killed

    @property
    def drained_ok(self) -> int:
        return self.life.drained_ok

    @property
    def requeued(self) -> int:
        return self.life.requeued

    @property
    def peak(self) -> int:
        return self.acc["peak"]

    def note_arrival(self) -> None:
        self.acc["arrivals"] += 1

    def serving(self) -> List:
        return self.online

    def active(self) -> List:
        return self.online + self.draining

    # ---- per-beat lifecycle --------------------------------------------------
    def begin_beat(self, topo, t: float) -> None:
        # workers whose boot completed join the serving set
        ready = [b for b in self.booting if b[0] <= t]
        for b in ready:
            self.booting.remove(b)
            w = b[1]
            self.online.append(w)
            self._on_spawn(w, t)
        if self.life.condemned:
            topo.requeue(self.reap_condemned(t), side=self.name)

    def end_beat(self, topo, t: float, t_next: float) -> None:
        # retire drained workers (billing stops with this heartbeat); a
        # condemned worker that got here finished inside its notice window
        for w in list(self.draining):
            self.life.retire_if_idle(w)
        busy = sum(1 for w in self.online if self._load(w) > 0)
        self.acc["busy_peak"] = max(self.acc["busy_peak"], busy)
        self.acc["peak"] = max(self.acc["peak"], len(self.online))
        dt = t_next - t
        billed = [w.spec for w in self.online] \
            + [w.spec for w in self.draining] \
            + [b[1].spec for b in self.booting]
        self.acc["gpu_s"] += sum(s.gpu_cost for s in billed) * dt
        self.acc["spot_gpu_s"] += sum(s.gpu_cost for s in billed
                                      if s.is_spot) * dt
        self.acc["beat"] += 1
        if self.acc["beat"] % self.beats_per_epoch == 0:
            n_queued = topo.backlog_len(self.name)
            self._scale_epoch(topo, t_next, busy, n_queued)

    def absorb_chunk(self, topo, t_next: float, dts: Sequence[float],
                     retiring: dict, busy_final: int, busy_peak: int,
                     arrivals: int, n_queued: int) -> None:
        """Replay ``len(dts)`` beats of ``end_beat`` bookkeeping at once —
        the settlement half of the compiled (jax) engine's chunked
        execution. The kernel advanced the lanes; this replays the exact
        per-beat billing order (retire drained workers *before* billing the
        beat, each beat's ``dt`` accumulated left-to-right) so
        ``gpu_seconds`` matches stepwise execution bit-for-bit.

        ``retiring`` maps chunk-local beat index -> draining workers that
        first emptied on that beat (in draining-list order); ``busy_peak``
        / ``busy_final`` are the kernel's loaded-online-lane stats;
        ``arrivals`` counts kernel-admitted requests. Chunks are cut at
        epoch boundaries, so at most the final beat fires ``_scale_epoch``
        — with exactly the state stepwise execution would have seen."""
        self.acc["arrivals"] += arrivals
        for j, dt in enumerate(dts):
            for w in retiring.get(j, ()):
                self.life.retire_if_idle(w)
            billed = [w.spec for w in self.online] \
                + [w.spec for w in self.draining] \
                + [b[1].spec for b in self.booting]
            self.acc["gpu_s"] += sum(s.gpu_cost for s in billed) * dt
            self.acc["spot_gpu_s"] += sum(s.gpu_cost for s in billed
                                          if s.is_spot) * dt
        self.acc["busy_peak"] = max(self.acc["busy_peak"], busy_peak)
        self.acc["peak"] = max(self.acc["peak"], len(self.online))
        self.acc["beat"] += len(dts)
        if self.acc["beat"] % self.beats_per_epoch == 0:
            self._scale_epoch(topo, t_next, busy_final, n_queued)

    def _scale_epoch(self, topo, t_next: float, busy: int,
                     n_queued: int) -> None:
        scfg = self.scfg
        rate = self.acc["arrivals"] / scfg.interval
        # feedback policies close the loop on what the cluster actually
        # delivered: feed them the topology's windowed observed attainment
        # (a pure read — open-loop policies skip this entirely)
        observe = getattr(self.policy, "observe_slo", None)
        if observe is not None:
            ok, total = topo.slo_window(
                self.name, t_next, getattr(self.policy, "window",
                                           scfg.interval),
                getattr(self.policy, "metric", "both"))
            observe(t_next, ok, total)
        # workers needed = peak busy set, plus enough extra workers to
        # absorb any placement backlog at the typical per-worker batch
        if n_queued:
            per_w = sum(self._load(w) for w in self.online) / max(busy, 1)
            backlog = max(int(math.ceil(n_queued / max(per_w, 1.0))), 1)
        else:
            backlog = 0
        needed = self.acc["busy_peak"] + backlog
        t_epoch = t_next - scfg.interval
        tgt = self.policy.target(t_epoch, rate, needed, n_queued)
        if scfg.headroom != 1.0:
            tgt = int(math.ceil(tgt * scfg.headroom))
        tgt = max(tgt, busy, scfg.min_workers)
        tgt = min(tgt, scfg.max_workers)
        # price-class split: policies without one (or no spot market to buy
        # from) run all-on-demand
        split = getattr(self.policy, "split", None)
        if self.spot_spec is not None and split is not None:
            tgt_od, tgt_spot = split(t_epoch, tgt)
            tgt_spot = min(tgt_spot, scfg.max_workers - tgt_od)
        else:
            tgt_od, tgt_spot = tgt, 0
        self.apply_target(t_next, tgt_od, tgt_spot, bool(n_queued))
        self.epochs.append(EpochStat(
            t=t_epoch, rate=rate, needed=needed, target=tgt_od + tgt_spot,
            online=len(self.online), target_spot=tgt_spot,
            online_spot=sum(1 for w in self.online if w.spec.is_spot)))
        self.acc["arrivals"] = 0
        self.acc["busy_peak"] = 0

    def apply_target(self, t: float, tgt_od: int, tgt_spot: int,
                     has_backlog: bool) -> None:
        target = tgt_od + tgt_spot
        cur = len(self.online) + len(self.booting)
        if target > cur:
            want = target - cur
            # reclaim draining workers first: they are warm, boot is free —
            # but never one inside a preemption notice (the provider is
            # taking it back regardless)
            while want > 0 and self.draining:
                cand = [w for w in self.draining
                        if w.id not in self.life.condemned]
                if not cand:
                    break
                w = cand[-1]
                self.draining.remove(w)
                self.online.append(w)
                want -= 1
            # boot composition: fill the spot deficit first (it is the
            # cheaper capacity), the remainder on-demand
            n_spot_cur = sum(1 for w in self.online if w.spec.is_spot) \
                + sum(1 for b in self.booting if b[1].spec.is_spot)
            want_spot = min(max(tgt_spot - n_spot_cur, 0), max(want, 0))
            for i in range(want):
                wspec = self.spot_spec \
                    if self.spot_spec is not None and i < want_spot \
                    else self.spec
                self.booting.append([t + self.scfg.provision_delay,
                                     self._new_worker(wspec)])
        elif target < cur:
            excess = cur - target
            # cancel pending boots first (nothing running on them yet)
            while excess > 0 and self.booting:
                self.booting.pop()
                excess -= 1
            # then drain the emptiest online workers; never below the busy
            # set — draining a loaded worker strands its queue time
            victims = sorted(self.online, key=self._load)
            for w in victims:
                if excess <= 0 or len(self.online) <= self.scfg.min_workers:
                    break
                if self._load(w) > 0 and has_backlog:
                    break             # backlog: keep every loaded worker
                self.online.remove(w)
                self.draining.append(w)
                excess -= 1

    # ---- market reclaims -----------------------------------------------------
    def on_reclaim(self, t: float, ev: PreemptionEvent) -> List[Request]:
        """A market reclaim: take ceil(frac * spot pool) spot workers —
        online, draining or still booting. The shared
        :class:`WorkerLifecycle` machine decides instant-kill vs condemn;
        a cancelled boot never held requests (it was billed, which
        gpu_seconds already reflects). Returns the requests knocked back
        into the queue."""
        pool = self.life.eligible(self.online) \
            + self.life.eligible(self.draining)
        boots = [b for b in self.booting if b[1].spec.is_spot]
        return self.life.reclaim(t, ev, pool, boots=boots,
                                 cancel_boot=self.booting.remove)

    def reap_condemned(self, t: float) -> List[Request]:
        """Kill condemned workers whose notice deadline has passed; workers
        that drained empty first are retired (and counted ``drained_ok``)
        by the regular end-of-beat retirement."""
        return self.life.reap(
            t, lambda wid: next((x for x in self.draining if x.id == wid),
                                None),
            retire_idle=False)


def simulate_autoscaled(trace: Sequence[Request], spec: WorkerSpec, slo: SLO,
                        cfg: SimConfig, scfg: ScaleSimConfig, policy,
                        predictor=None,
                        spot: Optional[SpotMarket] = None) -> ScaleSimResult:
    """Colocated serving with a policy-driven worker lifecycle.

    Same causal-time heartbeat core and placement as ``simulate``, but the
    worker count is owned by ``policy.target(t, rate, needed, queued)``
    evaluated once per scaling epoch: new workers take ``provision_delay``
    seconds to boot (billed while booting), surplus workers drain (no new
    placements; billed until their last request finishes) and a scale-up
    reclaims draining workers before booting cold ones.  ``gpu_seconds`` is
    the billed accelerator time, each worker at its own price class — the
    cost metric the reactive-vs-forecast(-vs-spot) benchmarks compare.

    With a ``SpotMarket``, the policy's ``split(t, target)`` (all-on-demand
    for policies without one) decides each epoch's price-class mix; booted
    workers fill the spot deficit first (it is the cheaper capacity). When a
    market reclaim event lands — delivered by the heartbeat core under the
    same causal rule as arrivals — a slice of the live spot workers dies:
    every in-flight request on them loses its KV, re-enters the queue (its
    generated-token count is retained), and pays a full context re-prefill
    plus the stall, charged against its TTFT/ATGT clocks by the simulator
    core. Scale-down stays price-class-blind (drain the emptiest worker
    wherever it is); the boot composition re-converges the realized mix to
    the split at the next epoch, so a zero-hazard, undiscounted spot pool
    reproduces the on-demand simulation exactly."""
    from repro.serving import api

    scenario = api.Scenario(
        workload=trace,
        fleet=api.FleetSpec([api.PoolSpec(spec, scfg.initial_workers)]),
        slo=slo,
        topology=api.Colocated(heartbeat=cfg.heartbeat, policy=cfg.policy,
                               split_phase=cfg.split_phase,
                               rebalance=cfg.rebalance, gamma=cfg.gamma,
                               theta=cfg.theta, max_batch=cfg.max_batch),
        scaling=api.PolicyScale(policy=policy, scfg=scfg),
        market=spot, predictor=predictor, seed=cfg.seed)
    return api.run(scenario).to_scale_result()
