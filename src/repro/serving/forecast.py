"""Forecast-aware worker-count scaling (SageServe-style predictive scaling).

The reactive Eq. 7 scaler only reacts: it observes the arrival rate of the
*last* epoch, so with a non-zero provisioning delay every diurnal ramp is
served late (SLO misses on the ascent) and every decline is served long
(GPU-seconds wasted on the descent, where a reactive policy must hold a
scale-down cooldown because it cannot know demand is really falling).

This module adds the look-ahead half of the paper's §5.2 story:

  * ``SeasonalNaiveForecaster`` — demand forecast = the rate observed at the
    same phase one period ago (seasonal-naive) plus an EWMA of the recent
    residuals (level correction for traffic growth/decay). Any object with
    ``observe(t, rate)`` / ``forecast(t, lead)`` plugs in; ``EWMAForecaster``
    is the trivial non-seasonal baseline.
  * ``ReactivePolicy`` / ``ForecastPolicy`` — epoch scaling policies. Both
    feed the Eq. 7 fit; the forecast policy asks the forecaster for the rate
    ``provision_delay + epoch`` ahead, so workers are booted *before* the
    ramp needs them, and it keeps a per-phase floor of the worker count each
    phase bin has historically needed (never provision fewer workers at a
    ramp peak than the same phase needed one period earlier).
  * ``simulate_autoscaled`` — the colocated simulator with a worker
    lifecycle (boot delay, draining, retirement) driven by a policy, built
    on the same causal-time heartbeat core; reports GPU-seconds actually
    billed, which is what the cost comparison in the benchmarks uses.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.placement import (PlacementConfig, WorkerState,
                                  best_fit_place, jsq_place,
                                  power_of_two_place)
from repro.core.request import ReqState, Request
from repro.core.scaling import Autoscaler, AutoscalerConfig
from repro.core.slo import SLO, slo_attainment
from repro.core.worker_config import WorkerSpec
from repro.serving.simulator import SimConfig, SimWorker, run_heartbeat_loop


# ---- forecasters -------------------------------------------------------------

@dataclasses.dataclass
class ForecastConfig:
    period: float = 300.0       # seasonal period, s (one diurnal cycle)
    bin_width: float = 5.0      # phase-bin resolution, s
    ewma_alpha: float = 0.3     # residual / level smoothing


class SeasonalNaiveForecaster:
    """Seasonal-naive + EWMA-residual demand forecaster.

    ``forecast(t, lead)`` returns the rate last observed at phase
    ``(t + lead) mod period`` plus the EWMA of recent (observed - seasonal)
    residuals; before a phase has been seen once, it falls back to the EWMA
    level of the rate itself (cold start = the reactive estimate)."""

    def __init__(self, cfg: ForecastConfig = ForecastConfig()):
        self.cfg = cfg
        self.n_bins = max(int(round(cfg.period / cfg.bin_width)), 1)
        self.seasonal: List[float] = [float("nan")] * self.n_bins
        self.resid = 0.0
        self.level: Optional[float] = None

    def _bin(self, t: float) -> int:
        return int(t / self.cfg.bin_width) % self.n_bins

    def observe(self, t: float, rate: float) -> None:
        a = self.cfg.ewma_alpha
        b = self._bin(t)
        prev = self.seasonal[b]
        if not math.isnan(prev):
            self.resid = a * (rate - prev) + (1 - a) * self.resid
        self.level = rate if self.level is None \
            else a * rate + (1 - a) * self.level
        self.seasonal[b] = rate

    def forecast(self, t: float, lead: float = 0.0) -> float:
        s = self.seasonal[self._bin(t + lead)]
        if math.isnan(s):
            return self.level if self.level is not None else 0.0
        return max(s + self.resid, 0.0)


class EWMAForecaster:
    """Non-seasonal baseline: the forecast at any lead is the EWMA level."""

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self.level: Optional[float] = None

    def observe(self, t: float, rate: float) -> None:
        self.level = rate if self.level is None \
            else self.alpha * rate + (1 - self.alpha) * self.level

    def forecast(self, t: float, lead: float = 0.0) -> float:
        return self.level if self.level is not None else 0.0


# ---- scaling policies --------------------------------------------------------

@dataclasses.dataclass
class ScaleSimConfig:
    interval: float = 5.0            # scaling-epoch length, s
    provision_delay: float = 10.0    # boot time before a new worker serves
    cooldown: float = 60.0           # reactive scale-down stabilization, s
    lead: Optional[float] = None     # forecast look-ahead; None = delay+epoch
    min_workers: int = 1
    max_workers: int = 512
    initial_workers: int = 1


class ReactivePolicy:
    """Eq. 7 on the last observed rate + change-point boost + a scale-down
    cooldown (the kube-HPA-style stabilization window a reactive scaler
    needs to avoid flapping, and the GPU-seconds it pays on every descent)."""

    name = "reactive"

    def __init__(self, scfg: ScaleSimConfig,
                 autoscaler: Optional[Autoscaler] = None):
        self.scfg = scfg
        self.autoscaler = autoscaler or Autoscaler(AutoscalerConfig(
            heartbeat=scfg.interval, min_workers=scfg.min_workers,
            max_workers=scfg.max_workers))
        self._recent: List[tuple] = []      # (t, raw target) inside cooldown

    def target(self, t: float, rate: float, needed: int,
               queued: int) -> int:
        sc = self.autoscaler
        sc.observe(rate, needed)
        tgt = sc.predict_workers(rate, last_needed=needed)
        if sc.change_point():
            tgt = max(tgt, needed)
        self._recent.append((t, tgt))
        self._recent = [x for x in self._recent
                        if x[0] >= t - self.scfg.cooldown]
        return max(tg for _, tg in self._recent)


class ForecastPolicy:
    """Eq. 7 on the *forecast* rate ``lead`` seconds ahead, plus a per-phase
    floor of the worker count that phase has historically needed.  No
    cooldown: the forecaster itself says when demand is really falling, so
    the policy sheds workers on the descent instead of holding them."""

    name = "forecast"

    def __init__(self, scfg: ScaleSimConfig, forecaster,
                 autoscaler: Optional[Autoscaler] = None):
        self.scfg = scfg
        self.forecaster = forecaster
        self.autoscaler = autoscaler or Autoscaler(AutoscalerConfig(
            heartbeat=scfg.interval, min_workers=scfg.min_workers,
            max_workers=scfg.max_workers))
        # phase bin -> max workers that phase has needed (seasonal floor);
        # a forecaster without phase bins degrades to one global bin
        self._bin: Callable[[float], int] = getattr(forecaster, "_bin",
                                                    lambda t: 0)
        self._season_needed: Dict[int, int] = {}

    @property
    def lead(self) -> float:
        return self.scfg.lead if self.scfg.lead is not None \
            else self.scfg.provision_delay + self.scfg.interval

    def _leads(self) -> List[float]:
        # sample the whole look-ahead window at epoch resolution so no
        # phase bin inside [t, t + lead] can be skipped over
        step = max(min(self.scfg.interval, self.lead), 1e-9)
        leads = [k * step for k in range(int(self.lead / step) + 1)]
        if leads[-1] < self.lead:
            leads.append(self.lead)
        return leads

    def target(self, t: float, rate: float, needed: int,
               queued: int) -> int:
        sc, fc = self.autoscaler, self.forecaster
        sc.observe(rate, needed)
        fc.observe(t, rate)
        b_now = self._bin(t)
        self._season_needed[b_now] = max(self._season_needed.get(b_now, 0),
                                         needed)
        leads = self._leads()
        r_ahead = max(fc.forecast(t, dl) for dl in leads)
        tgt = sc.predict_workers(max(rate, r_ahead), last_needed=needed)
        floor = max(self._season_needed.get(self._bin(t + dl), 0)
                    for dl in leads)
        return max(tgt, floor)


# ---- autoscaled simulation ---------------------------------------------------

@dataclasses.dataclass
class EpochStat:
    t: float                 # epoch start time
    rate: float              # observed arrivals / interval
    needed: int              # peak busy workers (+1 if a backlog remained)
    target: int              # policy decision for the next epoch
    online: int              # workers online after applying the decision


@dataclasses.dataclass
class ScaleSimResult:
    policy: str
    gpu_seconds: float       # Σ accelerators billed (online+boot+drain) * dt
    attainment: float
    p99_ttft: float
    p99_atgt: float
    mean_atgt: float
    finished: int
    total: int
    peak_workers: int
    epochs: List[EpochStat] = dataclasses.field(default_factory=list)

    def row(self) -> Dict:
        d = dataclasses.asdict(self)
        d.pop("epochs")
        return d


def simulate_autoscaled(trace: Sequence[Request], spec: WorkerSpec, slo: SLO,
                        cfg: SimConfig, scfg: ScaleSimConfig, policy,
                        predictor=None) -> ScaleSimResult:
    """Colocated serving with a policy-driven worker lifecycle.

    Same causal-time heartbeat core and placement as ``simulate``, but the
    worker count is owned by ``policy.target(t, rate, needed, queued)``
    evaluated once per scaling epoch: new workers take ``provision_delay``
    seconds to boot (billed while booting), surplus workers drain (no new
    placements; billed until their last request finishes) and a scale-up
    reclaims draining workers before booting cold ones.  ``gpu_seconds`` is
    the billed accelerator time — the cost metric the reactive-vs-forecast
    benchmark compares."""
    rng = np.random.default_rng(cfg.seed)
    beats_per_epoch = max(int(round(scfg.interval / cfg.heartbeat)), 1)

    online: List[WorkerState] = []
    draining: List[WorkerState] = []
    booting: List[List] = []           # [online_at, WorkerState]
    sims: Dict[int, SimWorker] = {}
    finished: List[Request] = []
    queued: List[Request] = []
    epochs: List[EpochStat] = []
    wid = [0]
    acc = {"gpu_s": 0.0, "beat": 0, "arrivals": 0, "busy_peak": 0,
           "peak": 0}

    def new_worker() -> WorkerState:
        wid[0] += 1
        pcfg = PlacementConfig(gamma=cfg.gamma, theta=cfg.theta,
                               kv_capacity=spec.kv_capacity,
                               max_batch=spec.max_batch,
                               split_phase=cfg.split_phase)
        w = WorkerState(wid[0], pcfg, spec.perf, slo)
        w.spec = spec
        return w

    for _ in range(max(scfg.initial_workers, scfg.min_workers)):
        w = new_worker()
        online.append(w)
        sims[w.id] = SimWorker(w, w.perf, 0.0, cfg.split_phase)

    def admit(r: Request) -> None:
        r.l_pred = predictor.predict(r.l_in) if predictor else r.l_real
        queued.append(r)
        acc["arrivals"] += 1

    def place(r: Request, t: float) -> bool:
        if cfg.policy == "aladdin":
            w = best_fit_place(online, r, allow_new=False)
        elif cfg.policy == "jsq":
            w = jsq_place(online, r, allow_new=False)
        else:
            w = power_of_two_place(online, r, rng, allow_new=False)
        if w is None:
            return False
        r.state = ReqState.PLACED
        if w.id not in sims:
            sims[w.id] = SimWorker(w, w.perf, t, cfg.split_phase)
        return True

    def apply_target(t: float, target: int) -> None:
        cur = len(online) + len(booting)
        if target > cur:
            want = target - cur
            # reclaim draining workers first: they are warm, boot is free
            while want > 0 and draining:
                w = draining.pop()
                online.append(w)
                want -= 1
            for _ in range(want):
                booting.append([t + scfg.provision_delay, new_worker()])
        elif target < cur:
            excess = cur - target
            # cancel pending boots first (nothing running on them yet)
            while excess > 0 and booting:
                booting.pop()
                excess -= 1
            # then drain the emptiest online workers; never below the busy
            # set — draining a loaded worker strands its queue time
            victims = sorted(online, key=lambda w: w.batch_size)
            for w in victims:
                if excess <= 0 or len(online) <= scfg.min_workers:
                    break
                if w.batch_size > 0 and queued:
                    break             # backlog: keep every loaded worker
                online.remove(w)
                draining.append(w)
                excess -= 1

    def step(t: float, t_next: float, arrived: int) -> None:
        nonlocal queued
        # workers whose boot completed join the serving set
        ready = [b for b in booting if b[0] <= t]
        for b in ready:
            booting.remove(b)
            w = b[1]
            online.append(w)
            sims[w.id] = SimWorker(w, w.perf, t, cfg.split_phase)
        queued = [r for r in queued if not place(r, t)]
        for w in online + draining:
            sims[w.id].advance_to(t_next, finished, t_start=t)
        # retire drained workers (billing stops with this heartbeat)
        for w in list(draining):
            if not w.ongoing and not w.new_batch \
                    and not sims[w.id].preempted:
                draining.remove(w)
        busy = sum(1 for w in online if w.batch_size > 0)
        acc["busy_peak"] = max(acc["busy_peak"], busy)
        acc["peak"] = max(acc["peak"], len(online))
        acc["gpu_s"] += (len(online) + len(draining) + len(booting)) \
            * spec.n_accelerators * (t_next - t)
        acc["beat"] += 1
        if acc["beat"] % beats_per_epoch == 0:
            rate = acc["arrivals"] / scfg.interval
            # workers needed = peak busy set, plus enough extra workers to
            # absorb any placement backlog at the typical per-worker batch
            if queued:
                per_w = sum(w.batch_size for w in online) / max(busy, 1)
                backlog = max(int(math.ceil(len(queued) / max(per_w, 1.0))),
                              1)
            else:
                backlog = 0
            needed = acc["busy_peak"] + backlog
            t_epoch = t_next - scfg.interval
            tgt = policy.target(t_epoch, rate, needed, len(queued))
            tgt = max(tgt, busy, scfg.min_workers)
            tgt = min(tgt, scfg.max_workers)
            apply_target(t_next, tgt)
            epochs.append(EpochStat(t=t_epoch, rate=rate, needed=needed,
                                    target=tgt, online=len(online)))
            acc["arrivals"] = 0
            acc["busy_peak"] = 0

    def drained() -> bool:
        return (not queued
                and all(not w.ongoing and not w.new_batch
                        for w in online + draining)
                and all(not s.preempted for s in sims.values()))

    trace = run_heartbeat_loop(trace, cfg.heartbeat, admit, step, drained)

    atgts = [r.atgt() for r in finished if r.atgt() is not None]
    ttfts = [r.ttft() for r in finished if r.ttft() is not None]
    total = len(trace)
    return ScaleSimResult(
        policy=getattr(policy, "name", type(policy).__name__),
        gpu_seconds=acc["gpu_s"],
        attainment=slo_attainment(finished, total, slo),
        p99_ttft=float(np.percentile(ttfts, 99)) if ttfts else float("nan"),
        p99_atgt=float(np.percentile(atgts, 99)) if atgts else float("nan"),
        mean_atgt=float(np.mean(atgts)) if atgts else float("nan"),
        finished=len(finished), total=total,
        peak_workers=acc["peak"], epochs=epochs)
