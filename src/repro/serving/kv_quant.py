"""int8 KV-cache quantization (KIVI/KVQuant-style, per-token-per-head scales).

Serving-side lever on the paper's Eq. 5-6: halving KV bytes doubles each
worker's capacity M, which moves the KV-bound branch of T_max and therefore
the optimal worker configuration — ``optimal_worker_config`` accepts
``kv_dtype_bytes`` to reflect it. The engine stores quantized pages and
dequantizes inside the attention read.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., D) -> (int8 values, fp32 scales (..., 1)); symmetric
    per-vector (token x head) scaling — the D axis shares one scale."""
    m = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = m / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray,
                  dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def kv_quant_error(x: jnp.ndarray) -> float:
    """Max relative reconstruction error (diagnostics)."""
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s)
    denom = jnp.maximum(jnp.max(jnp.abs(x)), 1e-9)
    return float(jnp.max(jnp.abs(back - x.astype(jnp.float32))) / denom)
