"""End-to-end serving driver (deliverable b): a Poisson stream of batched
requests against a small model with the full Aladdin control plane —
autoscaling up under load, worker failure mid-run, straggler drain, and a
scheduler checkpoint/restore. This is the serving analogue of a multi-hundred
-step training driver.

  PYTHONPATH=src python examples/serve_e2e.py
"""
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.request import Request
from repro.core.slo import SLO
from repro.models.model import LM
from repro.serving.cluster import ClusterConfig, ServingCluster
from repro.serving.engine import EngineConfig


def main() -> None:
    arch = reduced(get_arch("llama2-13b"), n_layers=2, d_model=64, vocab=256)
    model = LM(arch)
    params = model.init(jax.random.key(1))
    cluster = ServingCluster(
        arch, params, SLO(ttft=10.0, atgt=2.0),
        engine_cfg=EngineConfig(max_batch=4, page_size=8, n_pages=128,
                                max_pages_per_seq=16),
        cfg=ClusterConfig(policy="aladdin", autoscale=True, min_workers=1,
                          max_workers=4),
        n_workers=1)

    rng = np.random.default_rng(7)
    submitted = 0
    t0 = time.perf_counter()
    print("phase 1: ramping load (autoscale up)...")
    for beat in range(30):
        for _ in range(2 if beat > 8 else 1):
            r = Request(l_in=int(rng.integers(8, 32)), l_pred=0,
                        l_real=int(rng.integers(4, 10)),
                        arrival=time.perf_counter())
            r.tokens = [int(x) for x in rng.integers(2, arch.vocab, r.l_in)]
            cluster.submit(r)
            submitted += 1
        cluster.heartbeat()
        if beat == 12:
            wid = next(iter(cluster.workers))
            n = cluster.inject_failure(wid)
            print(f"  !! injected failure on worker {wid}: "
                  f"{n} requests re-queued, "
                  f"{len(cluster.workers)} workers remain")
        if beat == 18:
            snap = cluster.snapshot()
            print(f"  checkpointed scheduler state "
                  f"({len(snap['queued'])} queued, perf k2="
                  f"{snap['perf']['k2']:.2e})")
    print(f"  workers now: {len(cluster.workers)} (autoscaled)")
    print("phase 2: draining...")
    cluster.run_until_drained(max_beats=400)
    dt = time.perf_counter() - t0
    print(f"served {len(cluster.finished)}/{submitted} requests in {dt:.1f}s"
          f" | attainment {cluster.attainment():.2f} | "
          f"failures handled: {len(cluster.failed_events)}")
    assert len(cluster.finished) == submitted, "requests lost!"


if __name__ == "__main__":
    main()
