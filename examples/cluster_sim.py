"""Cluster-scale simulation (paper §6.4): minimum GPU count vs arrival rate
for Aladdin vs JSQ vs power-of-two vs vanilla-vLLM worker config, the Eq. 7
autoscaler tracking a diurnal demand curve, a heterogeneous A100/V100 fleet,
and an end-to-end prefill/decode disaggregated cluster.

  PYTHONPATH=src:. python examples/cluster_sim.py
"""
import dataclasses

import numpy as np

from benchmarks.bench_cluster_sim import (_kv_cap_tokens, _perf_for,
                                          _predictor, _trace_fn, MODEL)
from repro.configs import get_arch
from repro.core.scaling import Autoscaler, SpotMixConfig
from repro.core.slo import PAPER_SLOS
from repro.core.worker_config import (A100_80G, V100_32G, make_worker_spec,
                                      optimal_worker_config, spot_variant)
from repro.serving.api import (Disaggregated, FeedbackScale, FleetSpec,
                               Forecast, PoolSpec, Scenario, TenantSpec,
                               optimize, run)
from repro.serving.disagg import DisaggConfig, min_cost_disagg
from repro.serving.forecast import (ForecastConfig, ForecastPolicy,
                                    ReactivePolicy, ScaleSimConfig,
                                    SeasonalNaiveForecaster, SpotMarket,
                                    simulate_autoscaled)
from repro.serving.simulator import SimConfig, min_workers_for_slo, simulate
from repro.serving.workload import (WorkloadConfig, diurnal_trace,
                                    drifting_diurnal_trace,
                                    preemption_trace)


def main() -> None:
    arch = get_arch(MODEL)
    slo = PAPER_SLOS[MODEL]
    opt = optimal_worker_config(arch, A100_80G, slo, mean_context=450.0)
    print(f"optimal worker config ({MODEL}): {opt.n_accelerators} GPUs "
          f"({opt.bound}-bound, {opt.per_gpu_throughput:.0f} tok/s/GPU)")

    perf = _perf_for(arch, opt.n_accelerators)
    kv = _kv_cap_tokens(arch, opt.n_accelerators)
    print("\nGPUs needed for 98% SLO attainment:")
    print("rate  aladdin  jsq  po2")
    for rate in (2.0, 5.0):
        row = [rate]
        for pol in ("aladdin", "jsq", "po2"):
            try:
                n = min_workers_for_slo(_trace_fn(rate, duration=20.0), perf,
                                        slo, kv, SimConfig(policy=pol), 0.98,
                                        hi=32, predictor=_predictor())
                row.append(n * opt.n_accelerators)
            except RuntimeError as e:
                row.append(f"plateau({e})")
        print("  ".join(str(x) for x in row))

    # Eq. 7 autoscaler tracking a diurnal curve
    print("\nEq. 7 autoscaler on a diurnal demand curve:")
    sc = Autoscaler()
    for hour in range(24):
        rate = 6.0 + 4.0 * np.sin(hour / 24 * 2 * np.pi)
        res = simulate(_trace_fn(rate, duration=10.0)(), perf, slo, kv,
                       SimConfig(policy="aladdin"), n_workers=None,
                       predictor=_predictor())
        sc.observe(rate, res.n_workers_peak)
        pred = sc.predict_workers(rate, res.n_workers_peak)
        if hour % 4 == 0:
            print(f"  h{hour:02d} rate={rate:4.1f} needed="
                  f"{res.n_workers_peak:2d} Eq7->{pred:2d} "
                  f"change_point={sc.change_point()}")
    print(f"fitted Eq.7: N_w = ceil({sc.k5:.2f} * r + {sc.c5:.2f})")

    # heterogeneous fleet: alternate optimal A100 workers with V100 TP=8
    print("\nheterogeneous A100/V100 fleet (50/50 mix):")
    a100 = make_worker_spec(arch, A100_80G, slo, mean_context=450.0)
    v100 = make_worker_spec(arch, V100_32G, slo, n_g=8, mean_context=450.0)
    for rate in (2.0, 5.0):
        n = min_workers_for_slo(
            _trace_fn(rate, duration=15.0), a100.perf, slo, a100.kv_capacity,
            SimConfig(), 0.95, hi=32, predictor=_predictor(),
            fleet_fn=lambda n: [(a100 if i % 2 == 0 else v100)
                                for i in range(n)])
        fleet = [(a100 if i % 2 == 0 else v100) for i in range(n)]
        print(f"  rate={rate:g}: {n} workers "
              f"({sum(s.n_accelerators for s in fleet)} GPUs: "
              f"{sum(1 for s in fleet if s is a100)}x{a100.name} + "
              f"{sum(1 for s in fleet if s is v100)}x{v100.name})")

    # disaggregated prefill/decode cluster vs the colocated minimum
    print("\ndisaggregated prefill/decode frontier (rate=2.0):")
    best = min_cost_disagg(_trace_fn(2.0, duration=15.0), slo, DisaggConfig(),
                           a100, a100, 0.95, max_prefill=4, hi_decode=32,
                           predictor=_predictor())
    if best is None:
        print("  infeasible within bounds")
    else:
        print(f"  cheapest: {best.n_prefill} prefill + {best.n_decode} "
              f"decode workers = {best.gpu_cost:g} GPUs "
              f"(attain={best.attainment:.3f}, "
              f"kv transfer {best.mean_transfer*1e3:.1f} ms/req)")

    # heterogeneous 2-pool frontier: the affine router may split traffic
    # between A100 and V100 pools when the mix prices out cheaper
    def mix(n):
        na = (n + 1) // 2
        return [(a100, na), (v100, n - na)]

    het = min_cost_disagg(_trace_fn(2.0, duration=15.0), slo, DisaggConfig(),
                          attain_target=0.95, max_prefill=4, hi_decode=32,
                          predictor=_predictor(),
                          prefill_pool_fn=mix, decode_pool_fn=mix)
    if het is not None:
        print(f"  2-pool hetero: {het.gpu_cost:g} GPUs ({het.pool_mix}, "
              f"attain={het.attainment:.3f})")

    # pool-*ratio* search: instead of a fixed 50/50 mix, let min_cost_disagg
    # sweep the A100 share on both sides and keep the cheapest ratio
    rat = min_cost_disagg(_trace_fn(2.0, duration=15.0), slo, DisaggConfig(),
                          attain_target=0.95, max_prefill=4, hi_decode=32,
                          predictor=_predictor(),
                          prefill_mix=[a100, v100], decode_mix=[a100, v100],
                          ratio_grid=(0.0, 0.5, 1.0))
    if rat is not None:
        print(f"  ratio search:  {rat.gpu_cost:g} GPUs ({rat.pool_mix}, "
              f"attain={rat.attainment:.3f})")

    # forecast-aware vs reactive scaling on a diurnal day (provision delay
    # 10s): the forecaster provisions before the ramp and sheds on descent
    print("\nforecast-aware vs reactive scaling (diurnal, 2 periods):")
    period, dur = 150.0, 300.0
    fcfg = WorkloadConfig(mean_rate=4.0, duration=dur, seed=21, in_mu=5.0,
                          in_sigma=1.1, out_mu=5.3, out_sigma=0.9)
    scfg = ScaleSimConfig(interval=5.0, provision_delay=10.0, cooldown=60.0,
                          initial_workers=3)
    fc = SeasonalNaiveForecaster(ForecastConfig(period=period, bin_width=5.0))
    for pol in (ReactivePolicy(scfg), ForecastPolicy(scfg, fc)):
        r = simulate_autoscaled(diurnal_trace(fcfg, amplitude=0.6,
                                              period=period),
                                a100, slo, SimConfig(), scfg, pol)
        print(f"  {r.policy:9s} gpu_seconds={r.gpu_seconds:8.0f} "
              f"attain={r.attainment:.3f} peak={r.peak_workers}")

    # spot-aware mix: the diurnal trough stays on-demand, the swing rides
    # spot capacity billed at a discount but reclaimable by the market —
    # reclaimed workers requeue their work with a full KV re-prefill
    print("\nspot-aware mix vs all-on-demand (same trace):")
    hazard = 1.0 / 300.0
    sspec = spot_variant(a100, price=0.35, preempt_hazard=hazard)
    market = SpotMarket(sspec, preemption_trace(dur, event_rate=hazard / 0.25,
                                                frac=0.25, seed=13))
    fc2 = SeasonalNaiveForecaster(ForecastConfig(period=period,
                                                 bin_width=5.0))
    pol = ForecastPolicy(scfg, fc2,
                         spot_mix=SpotMixConfig(discount=0.35, hazard=hazard))
    r = simulate_autoscaled(diurnal_trace(fcfg, amplitude=0.6, period=period),
                            a100, slo, SimConfig(), scfg, pol, spot=market)
    print(f"  spot mix  gpu_seconds={r.gpu_seconds:8.0f} "
          f"(spot share {r.spot_gpu_seconds:.0f}) "
          f"attain={r.attainment:.3f} reclaimed={r.preempted_workers} "
          f"requeued={r.requeued}")

    # the Scenario API's genuinely new cell: autoscaled disaggregated pools
    # under asymmetric spot hazards — decode reclaims pay a full context
    # re-prefill + KV re-transfer, prefill reclaims only re-queue prompts.
    # One declarative Scenario, one run(), one RunReport.
    print("\nautoscaled disaggregated pools + asymmetric spot (Scenario "
          "API):")
    dspec = dataclasses.replace(a100, max_batch=24)
    dmarket = SpotMarket(
        spot_variant(dspec, price=0.35, preempt_hazard=hazard),
        preemption_trace(dur, event_rate=hazard / 0.25, frac=0.25, seed=13),
        prefill_spec=spot_variant(a100, price=0.35,
                                  preempt_hazard=hazard / 4),
        prefill_events=preemption_trace(dur, event_rate=hazard / 4 / 0.25,
                                        frac=0.25, seed=14))
    rep = run(Scenario(
        workload=lambda: diurnal_trace(fcfg, amplitude=0.6, period=period),
        fleet=FleetSpec([PoolSpec(a100, 2, role="prefill"),
                         PoolSpec(dspec, 5, role="decode")]),
        slo=slo,
        topology=Disaggregated(heartbeat=0.02, theta=0.7,
                               prefill_router="earliest"),
        scaling=Forecast(period=period, min_workers=2, headroom=1.2),
        market=dmarket))
    print(f"  gpu_seconds={rep.gpu_seconds:8.0f} (spot share "
          f"{rep.spot_gpu_seconds:.0f}) attain={rep.attainment:.3f} "
          f"killed={rep.preempted_workers} requeued={rep.requeued} "
          f"kv_retransfers={rep.kv_retransfers} "
          f"peak=p{rep.n_prefill}/d{rep.n_decode}")

    # closed-loop SLO feedback on a drifted-seasonality trace: the nominal
    # period stretches 2x across the run, so the open-loop forecast's
    # per-phase floor goes stale and over-provisions; FeedbackScale shaves
    # it (gain down to min_gain) while observed attainment saturates, and
    # optimize() searches the policy space itself — the Plan re-runs to the
    # searched report exactly
    print("\nclosed-loop SLO feedback on drifted seasonality "
          "(+ policy-space optimize):")
    dcfg = WorkloadConfig(mean_rate=4.0, duration=dur, seed=33, in_mu=5.0,
                          in_sigma=1.1, out_mu=5.3, out_sigma=0.9)

    def drift_fn():
        return drifting_diurnal_trace(dcfg, amplitude=0.6, period=period,
                                      drift=1.0)

    def fb_scenario(scaling):
        return Scenario(workload=drift_fn,
                        fleet=FleetSpec([PoolSpec(a100, 4)]), slo=slo,
                        scaling=scaling)

    open_loop = Forecast(period=period, min_workers=2)
    for label, scaling in (
            ("open-loop", open_loop),
            ("feedback",
             FeedbackScale(base=open_loop, min_gain=0.85, max_gain=1.3,
                           boost=1.2, window=45.0))):
        rep = run(fb_scenario(scaling))
        print(f"  {label:9s} gpu_seconds={rep.gpu_seconds:8.0f} "
              f"attain={rep.attainment:.3f} peak={rep.peak_workers}")
    plan = optimize(fb_scenario(FeedbackScale(base=open_loop, min_gain=0.85,
                                              max_gain=1.3, boost=1.2,
                                              window=45.0)),
                    attain_target=0.99,
                    policy_space={"headroom": (0.9, 1.0, 1.1)})
    match = run(plan.scenario).row() == plan.report.row()
    print(f"  optimize  gpu_seconds={plan.cost:8.0f} "
          f"attain={plan.report.attainment:.3f} params={plan.params} "
          f"evals={plan.evals} replay_exact={match}")

    # diurnal trace through the elastic simulator
    wcfg = WorkloadConfig(mean_rate=4.0, duration=30.0, seed=17, in_mu=5.0,
                          in_sigma=1.1, out_mu=5.3, out_sigma=0.9)
    res = simulate(diurnal_trace(wcfg, amplitude=0.8), a100.perf, slo,
                   a100.kv_capacity, SimConfig(), n_workers=None,
                   predictor=_predictor())
    print(f"\ndiurnal trace (elastic): peak={res.n_workers_peak} workers, "
          f"attainment={res.attainment:.3f}")

    # multi-tenant serving: an interactive LoRA chat tenant and a loose
    # batch eval tier share one fleet. run() judges each request against
    # its OWN tenant's SLO and reports per-class rows; optimize()
    # searches shared-vs-dedicated pool assignment subject to every
    # class's attainment target.
    print("\nmulti-tenant fleet (priority/EDF admission, shared LoRA "
          "workers):")
    lspec = dataclasses.replace(
        make_worker_spec(arch, A100_80G, slo, mean_context=450.0),
        lora_slots=8, lora_overhead=64.0, lora_swap_s=0.02)
    tenants = [
        TenantSpec(name="chat",
                   workload=lambda: diurnal_trace(wcfg, amplitude=0.5),
                   slo=slo, priority=1, lora="chat-v2"),
        TenantSpec(name="eval",
                   workload=lambda: diurnal_trace(
                       dataclasses.replace(wcfg, mean_rate=2.0, seed=31),
                       amplitude=0.5),
                   slo=dataclasses.replace(slo, ttft=4 * slo.ttft),
                   tier="batch"),
    ]
    rep = run(Scenario(fleet=FleetSpec([PoolSpec(lspec, 5)]),
                       tenants=tenants))
    for row in rep.tenant_rows:
        print(f"  {row['tenant']:<5} tier={row['tier']:<11} "
              f"attain={row['attainment']:.3f} "
              f"p99_ttft={row['p99_ttft']:.2f}s "
              f"queue_delay={row['mean_queue_delay']:.2f}s "
              f"cost_share={row['gpu_cost_share']:.2f}")
    tplan = optimize(Scenario(fleet=FleetSpec([PoolSpec(lspec, 1)]),
                              tenants=tenants), attain_target=0.98)
    print(f"  joint plan: {tplan.n_workers} workers "
          f"cost={tplan.cost:.0f} pools={tplan.params['pools']} "
          f"lora_swaps={tplan.report.lora_swaps}")


if __name__ == "__main__":
    main()
