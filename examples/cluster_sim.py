"""Cluster-scale simulation (paper §6.4): minimum GPU count vs arrival rate
for Aladdin vs JSQ vs power-of-two vs vanilla-vLLM worker config, plus the
Eq. 7 autoscaler tracking a diurnal demand curve.

  PYTHONPATH=src:. python examples/cluster_sim.py
"""
import numpy as np

from benchmarks.bench_cluster_sim import (_kv_cap_tokens, _perf_for,
                                          _predictor, _trace_fn, MODEL)
from repro.configs import get_arch
from repro.core.scaling import Autoscaler
from repro.core.slo import PAPER_SLOS
from repro.core.worker_config import A100_80G, optimal_worker_config
from repro.serving.simulator import SimConfig, min_workers_for_slo, simulate


def main() -> None:
    arch = get_arch(MODEL)
    slo = PAPER_SLOS[MODEL]
    opt = optimal_worker_config(arch, A100_80G, slo, mean_context=450.0)
    print(f"optimal worker config ({MODEL}): {opt.n_accelerators} GPUs "
          f"({opt.bound}-bound, {opt.per_gpu_throughput:.0f} tok/s/GPU)")

    perf = _perf_for(arch, opt.n_accelerators)
    kv = _kv_cap_tokens(arch, opt.n_accelerators)
    print("\nGPUs needed for 98% SLO attainment:")
    print("rate  aladdin  jsq  po2")
    for rate in (2.0, 5.0):
        row = [rate]
        for pol in ("aladdin", "jsq", "po2"):
            try:
                n = min_workers_for_slo(_trace_fn(rate, duration=20.0), perf,
                                        slo, kv, SimConfig(policy=pol), 0.98,
                                        hi=32, predictor=_predictor())
                row.append(n * opt.n_accelerators)
            except RuntimeError as e:
                row.append(f"plateau({e})")
        print("  ".join(str(x) for x in row))

    # Eq. 7 autoscaler tracking a diurnal curve
    print("\nEq. 7 autoscaler on a diurnal demand curve:")
    sc = Autoscaler()
    for hour in range(24):
        rate = 6.0 + 4.0 * np.sin(hour / 24 * 2 * np.pi)
        res = simulate(_trace_fn(rate, duration=10.0)(), perf, slo, kv,
                       SimConfig(policy="aladdin"), n_workers=None,
                       predictor=_predictor())
        sc.observe(rate, res.n_workers_peak)
        pred = sc.predict_workers(rate, res.n_workers_peak)
        if hour % 4 == 0:
            print(f"  h{hour:02d} rate={rate:4.1f} needed="
                  f"{res.n_workers_peak:2d} Eq7->{pred:2d} "
                  f"change_point={sc.change_point()}")
    print(f"fitted Eq.7: N_w = ceil({sc.k5:.2f} * r + {sc.c5:.2f})")


if __name__ == "__main__":
    main()
