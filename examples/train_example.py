"""Train a ~10M-param dense LM for a few hundred steps on CPU with the full
training substrate: AdamW + cosine schedule, microbatch accumulation, int8
gradient compression, periodic checkpointing, and a mid-run restart that
reproduces the direct run exactly.

  PYTHONPATH=src python examples/train_example.py [--steps 200]
"""
import argparse
import time

import jax

from repro.configs import get_arch, reduced
from repro.models.model import LM, ExecConfig
from repro.training import (AdamWConfig, DataConfig, TrainConfig,
                            batch_at_step, init_train_state, latest_step,
                            load, make_train_step, save)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    arch = reduced(get_arch("granite-3-8b"), n_layers=4, d_model=128,
                   vocab=512, n_heads=8, n_kv_heads=4, d_ff=512)
    model = LM(arch, exec_cfg=ExecConfig(loss_chunk=32))
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: model.init(jax.random.key(0)))))
    print(f"model: {arch.name} ({n_params/1e6:.1f}M params)")

    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=20,
                                         total_steps=args.steps),
                       microbatches=2, grad_compression=True)
    dcfg = DataConfig(vocab=arch.vocab, seq_len=64, global_batch=8)
    step_fn = jax.jit(make_train_step(model, tcfg))

    start = latest_step(args.ckpt) or 0
    if start:
        params, opt = init_train_state(model, jax.random.key(0), tcfg)
        restored, extra = load(args.ckpt, start,
                               {"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"resumed from checkpoint at step {start}")
    else:
        params, opt = init_train_state(model, jax.random.key(0), tcfg)

    t0 = time.perf_counter()
    for i in range(start, args.steps):
        params, opt, m = step_fn(params, opt, batch_at_step(dcfg, i))
        if (i + 1) % 25 == 0:
            dt = time.perf_counter() - t0
            print(f"step {i+1:4d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} "
                  f"gnorm={float(m['grad_norm']):.2f} "
                  f"({dt/(i+1-start):.2f}s/step)")
        if (i + 1) % 100 == 0:
            save(args.ckpt, i + 1, {"params": params, "opt": opt},
                 extra={"data_step": i + 1})
            print(f"  checkpointed step {i+1}")
    print("done.")


if __name__ == "__main__":
    main()
