"""Quickstart: Aladdin serving a reduced Llama-2-family model on CPU.

Shows the whole control loop on live engines: length prediction -> best-fit
placement (Alg. 1) -> continuous batching -> perf-model refit from traces ->
re-balancing. Runs in ~1 minute on a laptop.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.request import Request
from repro.core.slo import SLO
from repro.models.model import LM
from repro.serving.cluster import ClusterConfig, ServingCluster
from repro.serving.engine import EngineConfig


def main() -> None:
    arch = reduced(get_arch("llama2-7b"), n_layers=2, d_model=64, vocab=256)
    model = LM(arch)
    params = model.init(jax.random.key(0))
    cluster = ServingCluster(
        arch, params, SLO(ttft=5.0, atgt=1.0),
        engine_cfg=EngineConfig(max_batch=4, page_size=8, n_pages=128,
                                max_pages_per_seq=16),
        cfg=ClusterConfig(policy="aladdin"), n_workers=2)

    rng = np.random.default_rng(0)
    print("submitting 8 requests...")
    reqs = []
    for i in range(8):
        r = Request(l_in=int(rng.integers(8, 40)), l_pred=0,
                    l_real=int(rng.integers(4, 12)),
                    arrival=time.perf_counter())
        r.tokens = [int(x) for x in rng.integers(2, arch.vocab, r.l_in)]
        cluster.submit(r)
        reqs.append(r)

    cluster.run_until_drained()
    print(f"finished {len(cluster.finished)}/8, "
          f"SLO attainment {cluster.attainment():.2f}")
    for r in cluster.finished[:3]:
        print(f"  req {r.id}: l_in={r.l_in} generated={r.l_out} "
              f"ttft={r.ttft():.3f}s atgt={r.atgt() or 0:.3f}s/tok "
              f"worker={r.worker}")
    d = cluster.perf.decode
    print(f"fitted decode model: k2={d.k2:.2e} c2={d.c2:.2e} c3={d.c3:.2e}")
    print(f"fit max rel err: {cluster.perf.max_rel_err}")


if __name__ == "__main__":
    main()
