"""The declarative Scenario API: every cell of the topology x scaling x
market matrix runs through the one engine path; ``optimize`` subsumes the
legacy searches and replays one materialized workload; and the public
surface (``repro.serving.__all__``) is guarded against drift from the
documented names."""
import dataclasses
from pathlib import Path

import pytest

import repro.serving as serving
from repro.configs import get_arch
from repro.core import A100_80G, PAPER_SLOS, make_worker_spec
from repro.core.worker_config import spot_variant
from repro.serving import (Colocated, Disaggregated, FixedScale, FleetSpec,
                           Forecast, PolicyScale, PoolSpec, PreemptionEvent,
                           Reactive, Scenario, ScaleSimConfig, SpotMarket,
                           WorkloadConfig, generate_trace, optimize, run)

ARCH = get_arch("llama2-70b")
SLO = PAPER_SLOS["llama2-70b"]
WCFG = WorkloadConfig(mean_rate=2.0, duration=10.0, seed=5, in_mu=5.0,
                      in_sigma=1.1, out_mu=5.3, out_sigma=0.9)


@pytest.fixture(scope="module")
def spec():
    return make_worker_spec(ARCH, A100_80G, SLO, mean_context=450.0)


def _market(spec, prefill_too=False):
    sspec = spot_variant(spec, price=0.35, preempt_hazard=1.0 / 100.0)
    events = [PreemptionEvent(t=3.0, frac=0.5), PreemptionEvent(t=7.0,
                                                                frac=0.5)]
    kw = {}
    if prefill_too:
        kw = dict(prefill_spec=sspec, prefill_events=events)
    return SpotMarket(sspec, events, **kw)


def _fleet(spec, topology, with_spot=False):
    """Fleet for one matrix cell. Under FixedScale a spot market can only
    reclaim workers the fleet actually contains, so ``with_spot`` adds the
    spot twins — otherwise the reclaim path is vacuously unreachable."""
    sspec = spot_variant(spec, price=0.35, preempt_hazard=1.0 / 100.0)
    if isinstance(topology, Disaggregated):
        pools = [PoolSpec(spec, 2, role="prefill"),
                 PoolSpec(spec, 3, role="decode")]
        if with_spot:
            pools += [PoolSpec(sspec, 1, role="prefill"),
                      PoolSpec(sspec, 2, role="decode")]
        return FleetSpec(pools)
    pools = [PoolSpec(spec, 3)]
    if with_spot:
        pools.append(PoolSpec(sspec, 2))
    return FleetSpec(pools)


SCALINGS = [FixedScale(), Reactive(interval=2.0, provision_delay=2.0),
            Forecast(interval=2.0, provision_delay=2.0, period=10.0)]
TOPOLOGIES = [Colocated(), Disaggregated()]


@pytest.mark.parametrize("topo_i", range(len(TOPOLOGIES)))
@pytest.mark.parametrize("scale_i", range(len(SCALINGS)))
@pytest.mark.parametrize("spot", [False, True])
def test_matrix_every_cell_runs(spec, topo_i, scale_i, spot):
    """2 topologies x 3 scaling modes x {on-demand, spot}: every cell runs
    end-to-end through run() with conserved tokens and sane metrics."""
    topology = TOPOLOGIES[topo_i]
    scaling = SCALINGS[scale_i]
    sc = Scenario(workload=lambda: generate_trace(WCFG),
                  fleet=_fleet(spec, topology,
                               with_spot=spot and scale_i == 0),
                  slo=SLO, topology=topology, scaling=scaling,
                  market=_market(spec, prefill_too=topo_i == 1)
                  if spot else None)
    trace = sc.materialize()
    rep = run(dataclasses.replace(sc, workload=trace))
    if spot and scale_i == 0:
        # fixed fleets must actually exercise the reclaim path (a fleet
        # without spot workers makes the spot cell vacuous)
        assert rep.preempted_workers + rep.drained_ok >= 1
    assert rep.schema == "runreport/2"
    assert rep.finished == rep.total == len(trace)
    assert 0.0 <= rep.attainment <= 1.0
    for r in trace:
        assert r.l_out == r.l_real
        assert r.t_first_token is not None and r.t_first_token >= r.arrival
    row = rep.row()
    assert "epochs" not in row and row["topology"] in ("colocated",
                                                       "disaggregated")


def test_run_is_deterministic(spec):
    sc = Scenario(workload=lambda: generate_trace(WCFG),
                  fleet=_fleet(spec, Colocated()), slo=SLO,
                  scaling=Reactive(interval=2.0, provision_delay=2.0),
                  market=_market(spec))
    assert run(sc).row() == run(sc).row()


def test_fixed_fleet_with_market_kills_spot_workers(spec):
    """FixedScale x market: reclaims remove spot workers from a static
    fleet (never replaced), with drains under a notice window."""
    sspec = spot_variant(spec, price=0.35, preempt_hazard=1.0 / 100.0)
    fleet = FleetSpec([PoolSpec(spec, 2), PoolSpec(sspec, 2)])
    events = [PreemptionEvent(t=4.0, frac=1.0)]
    base = Scenario(workload=lambda: generate_trace(WCFG), fleet=fleet,
                    slo=SLO, market=SpotMarket(sspec, events))
    rep = run(base)
    assert rep.finished == rep.total
    assert rep.preempted_workers + rep.drained_ok >= 1
    noticed = run(dataclasses.replace(
        base, market=SpotMarket(sspec, events, notice_s=1e6)))
    assert noticed.preempted_workers == 0 and noticed.requeued == 0


def test_policy_scale_rejected_for_disagg(spec):
    scfg = ScaleSimConfig()
    sc = Scenario(workload=[], fleet=_fleet(spec, Disaggregated()), slo=SLO,
                  topology=Disaggregated(),
                  scaling=PolicyScale(object(), scfg))
    with pytest.raises(ValueError, match="own"):
        run(sc)


# ---- optimize ----------------------------------------------------------------

def test_optimize_accepts_trace_and_trace_fn_identically(spec):
    """The trace vs trace_fn asymmetry is gone: optimize() materializes the
    workload once and replays clones, so a concrete trace and a factory
    producing the same draw yield the same plan."""
    sc = Scenario(workload=lambda: generate_trace(WCFG),
                  fleet=FleetSpec([PoolSpec(spec, 0)]), slo=SLO)
    plan_fn = optimize(sc, attain_target=0.9, hi=8)
    trace = generate_trace(WCFG)
    plan_tr = optimize(dataclasses.replace(sc, workload=trace),
                       attain_target=0.9, hi=8)
    assert plan_fn.n_workers == plan_tr.n_workers
    assert plan_fn.report.row() == plan_tr.report.row()
    # and the caller's trace was NOT consumed by the search (clones ran)
    assert all(r.t_finish is None for r in trace)


def test_optimize_replays_one_materialization(spec):
    """A stateful factory would re-sample per candidate under the legacy
    searches; optimize() calls it exactly once."""
    calls = [0]

    def factory():
        calls[0] += 1
        return generate_trace(WCFG)

    sc = Scenario(workload=factory, fleet=FleetSpec([PoolSpec(spec, 0)]),
                  slo=SLO)
    plan = optimize(sc, attain_target=0.9, hi=8)
    assert calls[0] == 1
    assert plan.evals >= 2          # while the search simulated many fleets


def test_optimize_rejects_policy_scale(spec):
    """The PolicyScale escape hatch wraps a prebuilt policy instance, so
    the policy-space search cannot rebuild it per candidate."""
    sc = Scenario(workload=[], fleet=_fleet(spec, Colocated()), slo=SLO,
                  scaling=PolicyScale(object(), ScaleSimConfig()))
    with pytest.raises(ValueError, match="PolicyScale"):
        optimize(sc)


def test_optimize_rejects_policy_space_for_fixed(spec):
    sc = Scenario(workload=[], fleet=_fleet(spec, Colocated()), slo=SLO)
    with pytest.raises(ValueError, match="policy_space"):
        optimize(sc, policy_space={"headroom": (1.0,)})


def test_optimize_disagg_matches_min_cost_disagg(spec):
    """optimize() on a disaggregated scenario IS the legacy frontier: same
    cheapest point as min_cost_disagg on the same workload."""
    from repro.serving import DisaggConfig, min_cost_disagg
    kw = dict(attain_target=0.9, max_prefill=2, hi_decode=8)
    legacy = min_cost_disagg(lambda: generate_trace(WCFG), SLO,
                             DisaggConfig(), spec, spec, **kw)
    sc = Scenario(workload=lambda: generate_trace(WCFG),
                  fleet=FleetSpec([PoolSpec(spec, 0, role="prefill"),
                                   PoolSpec(spec, 0, role="decode")]),
                  slo=SLO, topology=Disaggregated())
    plan = optimize(sc, **kw)
    assert plan.feasible
    assert plan.disagg_result.row() == legacy.row()


# ---- API surface guard -------------------------------------------------------

def test_public_surface_exists_and_imports():
    assert hasattr(serving, "__all__") and len(serving.__all__) > 0
    for name in serving.__all__:
        assert getattr(serving, name, None) is not None, name
    assert len(set(serving.__all__)) == len(serving.__all__)


def test_public_surface_matches_documented_names():
    """Every public name is documented in the README (the 'Scenario API'
    section's surface listing) — additions must update the docs."""
    readme = Path(__file__).resolve().parent.parent / "README.md"
    text = readme.read_text()
    assert "Scenario API" in text
    missing = [n for n in serving.__all__ if f"`{n}`" not in text]
    assert not missing, f"undocumented public names: {missing}"


def test_scenario_api_names_are_in_all():
    from repro.serving import api
    assert set(api.__all__) <= set(serving.__all__)
