"""Distributed grouped scheduler (App. A), rebalance (Alg. 2), fault
tolerance + straggler mitigation on the live cluster."""
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import (DecodeModel, KVModel, PerfModel, PlacementConfig,
                        PrefillModel, Request, SLO, WorkerState)
from repro.core.distributed_scheduler import (GroupedScheduler,
                                              SchedLatencyModel,
                                              choose_group_count)
from repro.core.rebalance import ErrorTracker, rebalance
from repro.models.model import LM
from repro.serving.cluster import ClusterConfig, ServingCluster
from repro.serving.engine import EngineConfig


def mk_perf():
    return PerfModel(kv=KVModel(1.0, 0.0), prefill=PrefillModel(1e-4, 1e-3),
                     decode=DecodeModel(1e-6, 1e-4, 5e-3))


def mk_worker(i, perf):
    return WorkerState(i, PlacementConfig(kv_capacity=1e7, max_batch=64),
                       perf, SLO(5.0, 0.5))


def test_grouped_scheduler_round_robin_and_placement():
    perf = mk_perf()
    workers = [mk_worker(i, perf) for i in range(8)]
    sched = GroupedScheduler(workers, n_groups=4)
    assert all(len(g) == 2 for g in sched.groups)
    placed = []
    for i in range(16):
        w = sched.place(Request(l_in=64, l_pred=64))
        placed.append(w)
    assert all(w is not None for w in placed)
    # round-robin: each group received 4 requests
    per_group = [sum(len(w.new_batch) + len(w.ongoing) for w in g)
                 for g in sched.groups]
    assert per_group == [4, 4, 4, 4]


def test_choose_group_count_bounds():
    lat = SchedLatencyModel(a=2e-6, b=1e-4)
    g = choose_group_count(rate=1000.0, n_workers=64, error_budget=0.1,
                           t_s=0.01, heartbeat=0.25, lat=lat)
    assert 1 <= g <= 64
    # tighter latency target -> at least as many groups
    g2 = choose_group_count(rate=1000.0, n_workers=64, error_budget=0.1,
                            t_s=0.002, heartbeat=0.25, lat=lat)
    assert g2 >= g


def test_rebalance_moves_from_over_to_under():
    perf = mk_perf()
    w0, w1 = mk_worker(0, perf), mk_worker(1, perf)
    for _ in range(3):
        w0.place(Request(l_in=200, l_pred=200))
    tracker = ErrorTracker()
    tracker.l_e[0] = 5000.0      # w0 badly underestimated
    tracker.b_e[0] = 3.0
    tracker.l_e[1] = -2000.0     # w1 overestimated (has slack)
    tracker.b_e[1] = -2.0
    moves = rebalance([w0, w1], tracker)
    assert moves >= 1
    assert len(w1.new_batch) >= 1


def _mini_cluster(policy="aladdin", n_workers=3):
    arch = reduced(get_arch("llama2-7b"), n_layers=2, d_model=32, vocab=64)
    model = LM(arch)
    params = model.init(jax.random.key(0))
    return ServingCluster(
        arch, params, SLO(ttft=30.0, atgt=5.0),
        engine_cfg=EngineConfig(max_batch=4, page_size=8, n_pages=64,
                                max_pages_per_seq=8),
        cfg=ClusterConfig(policy=policy, min_workers=1,
                          max_workers=4), n_workers=n_workers)


def test_cluster_failure_requeues_and_finishes():
    cluster = _mini_cluster()
    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(6):
        r = Request(l_in=int(rng.integers(6, 20)), l_pred=0,
                    l_real=4, arrival=time.perf_counter())
        r.tokens = [int(x) for x in rng.integers(2, 64, r.l_in)]
        reqs.append(r)
        cluster.submit(r)
    cluster.heartbeat()
    # kill the busiest worker mid-flight
    busiest = max(cluster.workers.values(),
                  key=lambda w: len(w.state.ongoing))
    requeued = cluster.inject_failure(busiest.id)
    assert requeued >= 0
    cluster.run_until_drained(max_beats=200)
    assert len(cluster.finished) == len(reqs), \
        (len(cluster.finished), [r.state for r in reqs])
    assert cluster.failed_events


def test_cluster_snapshot_restore():
    cluster = _mini_cluster()
    r = Request(l_in=8, l_pred=4, l_real=4)
    cluster.submit(r)
    snap = cluster.snapshot()
    c2 = _mini_cluster()
    c2.restore(snap)
    assert len(c2.queued) == len(cluster.queued)
    assert c2.perf.decode.k2 == cluster.perf.decode.k2


def test_straggler_detection_drains():
    cluster = _mini_cluster(n_workers=4)
    ids = list(cluster.workers)
    for wid in ids[:3]:
        cluster.workers[wid].iter_ema = 0.01
    cluster.workers[ids[3]].iter_ema = 10.0     # pathological straggler
    out = cluster._detect_stragglers()
    assert ids[3] in out
    assert cluster.workers[ids[3]].state.draining
