"""Dry-run machinery: sharding policy resolution + a real (subprocess)
lower+compile of one full-size cell against the 256-chip mesh."""
import json
import os
import subprocess
import sys

import pytest

from repro.configs import LONG_500K, get_arch, shape_applicable
from repro.distributed.sharding import Policy
from jax.sharding import PartitionSpec as P


def test_policy_no_mesh_is_noop():
    p = Policy()
    assert p.spec(("batch", None)) == P()
    assert p.constrain(1.5, ("batch",)) == 1.5


def test_spec_for_shape_drops_nondivisible():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    pol = Policy(mesh=FakeMesh(), rules={"batch": ("data",),
                                         "vocab": ("model",)})
    assert pol.spec_for_shape(("batch", "vocab"), (256, 4096)) == \
        P("data", "model")
    # 49155 % 16 != 0 -> vocab dropped
    assert pol.spec_for_shape(("batch", "vocab"), (256, 49155)) == \
        P("data", None)


def test_long500k_applicability():
    assert shape_applicable(get_arch("mamba2-1.3b"), LONG_500K)
    assert shape_applicable(get_arch("zamba2-7b"), LONG_500K)
    assert not shape_applicable(get_arch("qwen2.5-32b"), LONG_500K)
    assert not shape_applicable(get_arch("llama-3.2-vision-90b"), LONG_500K)


@pytest.mark.slow
def test_dryrun_cell_compiles(tmp_path):
    """Full-size granite decode cell lowers + compiles on the 16x16 mesh
    (subprocess: the 512-device XLA flag must be set before jax init)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "granite-3-8b", "--shape", "decode_32k", "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), timeout=540)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    rec = json.load(open(tmp_path / "granite-3-8b_decode_32k_pod1.json"))
    assert rec["ok"]
    assert rec["n_devices"] == 256
    assert rec["peak_bytes_per_device"] < 16 * 2 ** 30, "must fit v5e HBM"
    assert rec["hlo_dot_flops"] > 0
