"""Extra coverage: fused rmsnorm kernel sweep, HLO analyzer units, engine
preemption under page exhaustion, simulator preemption semantics, workload
statistics, Eq. 8 latency model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DecodeModel, KVModel, PerfModel, PrefillModel,
                        Request, SLO)
from repro.core.distributed_scheduler import SchedLatencyModel
from repro.core.request import ReqState
from repro.distributed.hlo_analysis import analyze_hlo, shape_bytes
from repro.kernels.rmsnorm import rmsnorm_pallas, rmsnorm_ref
from repro.serving.simulator import SimConfig, simulate
from repro.serving.workload import WorkloadConfig, generate_trace, \
    sample_lengths


@pytest.mark.parametrize("shape", [(4, 64), (2, 8, 128), (1, 256), (3, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_res", [False, True])
def test_rmsnorm_kernel_sweep(shape, dtype, with_res):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(shape), dtype)
    w = jnp.asarray(rng.standard_normal(shape[-1:]), dtype)
    r = jnp.asarray(rng.standard_normal(shape), dtype) if with_res else None
    ref = rmsnorm_ref(x, w, r)
    out = rmsnorm_pallas(x, w, r, interpret=True, block_rows=2)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_hlo_trip_count_multiplication():
    hlo = """HloModule test, is_scheduled=true

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %ag = f32[8,8]{1,0} all-gather(%gte), dimensions={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%c, %ag)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %c10 = s32[] constant(40)
  ROOT %cmp = pred[] compare(%i, %c10), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  %ar = f32[8,8]{1,0} all-reduce(%gte2), to_apply=%add
  ROOT %r = f32[8,8] get-tuple-element(%w), index=1
}
"""
    res = analyze_hlo(hlo)
    # body all-gather (256B) x 40 trips + entry all-reduce (256B x 2 ring)
    assert res["collectives"]["all-gather"] == 256 * 40
    assert res["collectives"]["all-reduce"] == 256 * 2


def test_shape_bytes_tuples_and_layouts():
    assert shape_bytes("f32[4,4]{1,0}") == 64
    assert shape_bytes("(bf16[2,2], s32[3])") == 8 + 12
    assert shape_bytes("pred[]") == 1


def test_engine_preemption_on_page_exhaustion():
    from repro.configs import get_arch, reduced
    from repro.models.model import LM
    from repro.serving.engine import EngineConfig, PagedEngine
    arch = reduced(get_arch("llama2-7b"), n_layers=2, d_model=32, vocab=64)
    model = LM(arch)
    params = model.init(jax.random.key(0))
    # tiny pool: 15 usable pages of 8 tokens -> forces exhaustion
    eng = PagedEngine(arch, params, EngineConfig(
        max_batch=4, page_size=8, n_pages=16, max_pages_per_seq=8,
        max_new_tokens=64))
    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(3):
        r = Request(l_in=24, l_pred=20, l_real=20)
        r.tokens = [int(x) for x in rng.integers(2, 64, 24)]
        reqs.append(r)
        eng.submit(r)
    for _ in range(200):
        eng.step()
        if all(r.state == ReqState.FINISHED for r in reqs):
            break
    assert all(r.state == ReqState.FINISHED for r in reqs), \
        [r.state for r in reqs]
    assert len(eng.free_pages) == 15, "pages leaked after churn"


def test_simulator_preemption_and_resume():
    """KV overflow preempts the youngest request and later resumes it."""
    perf = PerfModel(kv=KVModel(1.0, 0.0), prefill=PrefillModel(1e-4, 1e-3),
                     decode=DecodeModel(1e-7, 1e-5, 1e-3))
    slo = SLO(ttft=100.0, atgt=10.0)
    trace = [Request(l_in=40, l_pred=50, l_real=50, arrival=0.0),
             Request(l_in=40, l_pred=50, l_real=50, arrival=0.1)]
    res = simulate(trace, perf, slo, kv_capacity=120.0,
                   cfg=SimConfig(policy="jsq"), n_workers=1)
    assert res.finished == 2, "preempted request must still finish"


def test_workload_statistics():
    cfg = WorkloadConfig(mean_rate=5.0, duration=50.0, seed=0)
    trace = generate_trace(cfg)
    # Poisson: ~rate*duration arrivals
    assert 0.6 * 250 < len(trace) < 1.4 * 250
    li, lo = sample_lengths(cfg, 10000)
    assert 4 <= li.min() and li.max() <= cfg.max_context // 2
    # heavy tail: p99 >> median
    assert np.percentile(li, 99) > 4 * np.median(li)


def test_sched_latency_model_fit_and_invert():
    m = SchedLatencyModel(a=1e-6, b=1e-4)
    ns = [10, 100, 1000]
    ts = [m(n) for n in ns]
    f = SchedLatencyModel.fit(ns, ts)
    assert abs(f.a - 1e-6) < 1e-7
    r = f.max_rate(t_s=0.05, heartbeat=0.25)
    assert f(r * 0.25) <= 0.0501


def test_chunked_prefill_matches_full():
    """Sarathi-style chunked prefill must generate the same tokens as the
    one-shot prefill."""
    from repro.configs import get_arch, reduced
    from repro.models.model import LM
    from repro.serving.engine import EngineConfig, PagedEngine
    arch = reduced(get_arch("llama2-13b"), n_layers=2, d_model=64, vocab=128)
    model = LM(arch)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(3)
    prompt = [int(x) for x in rng.integers(2, arch.vocab, 21)]
    outs = {}
    for label, chunk in (("full", 0), ("chunked", 8)):
        eng = PagedEngine(arch, params, EngineConfig(
            max_batch=2, page_size=8, n_pages=64, max_pages_per_seq=16,
            prefill_chunk=chunk))
        r = Request(l_in=len(prompt), l_pred=6, l_real=6)
        r.tokens = list(prompt)
        eng.submit(r)
        for _ in range(30):
            eng.step()
            if r.state == ReqState.FINISHED:
                break
        assert r.state == ReqState.FINISHED
        outs[label] = r.tokens[len(prompt):]
    assert outs["chunked"] == outs["full"], outs


def test_kv_quantization_roundtrip_and_eq6_effect():
    from repro.serving.kv_quant import kv_quant_error, quantize_kv
    from repro.configs import get_arch
    from repro.core.slo import PAPER_SLOS
    from repro.core.worker_config import A100_80G, optimal_worker_config
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 16, 8, 32)), jnp.float32)
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8
    err = kv_quant_error(x)
    assert err < 0.01, err
    # int8 KV doubles M -> per-GPU throughput must not decrease (Eq. 6)
    arch = get_arch("llama2-70b")
    slo = PAPER_SLOS[arch.name]
    bf16 = optimal_worker_config(arch, A100_80G, slo, mean_context=450.0)
    int8 = optimal_worker_config(arch, A100_80G, slo, mean_context=450.0,
                                 kv_dtype_bytes=1)
    assert int8.per_gpu_throughput >= bf16.per_gpu_throughput
