"""Simulator determinism + conservation invariants (observer-hook based):
same seed => identical SimResult; every request is accounted for at every
heartbeat; finished requests have a consistent timeline."""
import dataclasses

import pytest

from repro.core import (DecodeModel, KVModel, PerfModel, PrefillModel,
                        SLO)
from repro.serving import SimConfig, WorkloadConfig, generate_trace, simulate
from repro.serving.length_predictor import LengthPredictor
from repro.serving.workload import sample_lengths


def paper_like_perf():
    return PerfModel(kv=KVModel(h=1.0, j=0.0),
                     prefill=PrefillModel(k1=2.4e-4, c1=8e-3),
                     decode=DecodeModel(k2=1.2e-6, c2=2.8e-4, c3=8e-3))


def make_trace(rate=4.0, seed=0, duration=20.0):
    return generate_trace(WorkloadConfig(mean_rate=rate, duration=duration,
                                         seed=seed))


def fitted_predictor(seed=99):
    cfg = WorkloadConfig(seed=seed)
    li, lo = sample_lengths(cfg, 3000)
    p = LengthPredictor()
    p.fit(li, lo)
    return p


SLO_EASY = SLO(ttft=1.5, atgt=0.05)


@pytest.mark.parametrize("policy", ["aladdin", "jsq", "po2"])
@pytest.mark.parametrize("split", [False, True])
def test_same_seed_identical_result(policy, split):
    cfg = SimConfig(policy=policy, split_phase=split)

    def once():
        return simulate(make_trace(seed=7), paper_like_perf(), SLO_EASY,
                        2e5, cfg, n_workers=4,
                        predictor=fitted_predictor())

    assert dataclasses.asdict(once()) == dataclasses.asdict(once())


def test_conservation_every_heartbeat():
    trace = make_trace(seed=3)
    total = len(trace)
    beats = []

    def observer(t, workers, sims, queued, finished, arrived):
        in_flight = sum(len(w.ongoing) + len(w.new_batch) for w in workers)
        preempted = sum(len(s.preempted) for s in sims.values())
        not_arrived = total - arrived
        assert len(finished) + len(queued) + in_flight + preempted \
            + not_arrived == total, f"request leak at t={t}"
        beats.append(t)

    res = simulate(trace, paper_like_perf(), SLO_EASY, 2e5,
                   SimConfig(), n_workers=4, predictor=fitted_predictor(),
                   observer=observer)
    assert len(beats) > 10
    assert res.finished == res.total == total


def test_conservation_under_kv_pressure():
    """Same invariant when the KV capacity is tight enough to force
    preemptions (requests transit the preempted list and come back)."""
    trace = make_trace(rate=6.0, seed=5)
    total = len(trace)
    preempt_seen = [0]

    def observer(t, workers, sims, queued, finished, arrived):
        in_flight = sum(len(w.ongoing) + len(w.new_batch) for w in workers)
        preempted = sum(len(s.preempted) for s in sims.values())
        preempt_seen[0] = max(preempt_seen[0],
                              sum(s.preemptions for s in sims.values()))
        assert len(finished) + len(queued) + in_flight + preempted \
            + (total - arrived) == total

    res = simulate(trace, paper_like_perf(), SLO_EASY, 4e3,
                   SimConfig(policy="jsq", theta=1.0), n_workers=3,
                   observer=observer)
    assert res.finished == res.total
    assert preempt_seen[0] > 0, "scenario must actually exercise preemption"


def test_finished_request_timeline():
    trace = make_trace(seed=11)
    res = simulate(trace, paper_like_perf(), SLO_EASY, 2e5,
                   SimConfig(), n_workers=4, predictor=fitted_predictor())
    assert res.finished == len(trace)
    for r in trace:
        assert r.t_first_token is not None and r.t_finish is not None
        # causal admission: a request is only seen at the first heartbeat
        # boundary at-or-after its arrival, so the first token can never
        # lead the arrival (the seed admitted intra-beat arrivals a beat
        # early, silently flattering colocated TTFT)
        assert r.arrival <= r.t_first_token <= r.t_finish + 1e-9
        assert r.l_out == r.l_real
        assert r.t_decode_spent <= r.t_finish - r.arrival + 1e-9
        assert (r.atgt() or 0.0) >= 0.0


def test_elastic_mode_conserves_and_finishes():
    trace = make_trace(rate=6.0, seed=13)
    res = simulate(trace, paper_like_perf(), SLO_EASY, 2e5,
                   SimConfig(), n_workers=None, predictor=fitted_predictor())
    assert res.finished == res.total
    assert res.n_workers_peak >= 1
    assert res.gpu_cost >= res.n_workers_peak  # default spec: 1 accel/worker
