"""Heterogeneous-fleet and disaggregated-cluster simulation, end to end:
mixed A100/V100 min_workers_for_slo completes, per-worker budgets are
respected, the prefill/decode pipeline conserves requests and reports a
joint (n_prefill, n_decode) cost."""
import dataclasses

import pytest

from repro.configs import get_arch
from repro.core import (A100_80G, DecodeModel, KVModel, PAPER_SLOS,
                        PerfModel, PlacementConfig, PrefillModel, Request,
                        SLO, V100_32G, WorkerState, best_fit_place,
                        make_worker_spec)
from repro.serving import (DisaggConfig, SimConfig, WorkloadConfig,
                           generate_trace, min_cost_disagg,
                           min_workers_for_slo, simulate,
                           simulate_disaggregated)

ARCH = get_arch("llama2-70b")
SLO_70B = PAPER_SLOS["llama2-70b"]
WCFG = WorkloadConfig(mean_rate=2.0, duration=15.0, seed=3, in_mu=5.0,
                      in_sigma=1.1, out_mu=5.3, out_sigma=0.9)


@pytest.fixture(scope="module")
def specs():
    a100 = make_worker_spec(ARCH, A100_80G, SLO_70B, mean_context=450.0)
    v100 = make_worker_spec(ARCH, V100_32G, SLO_70B, n_g=8,
                            mean_context=450.0)
    return a100, v100


def test_worker_specs_are_heterogeneous(specs):
    a100, v100 = specs
    assert a100.n_accelerators != v100.n_accelerators
    assert a100.kv_capacity != v100.kv_capacity
    assert a100.perf.decode.k2 != v100.perf.decode.k2


def test_mixed_fleet_simulation_completes(specs):
    a100, v100 = specs
    fleet = [a100, v100, a100, v100]
    res = simulate(generate_trace(WCFG), a100.perf, SLO_70B,
                   a100.kv_capacity, SimConfig(), fleet=fleet)
    assert res.finished == res.total
    assert res.gpu_cost == sum(s.n_accelerators for s in fleet)


def test_mixed_fleet_min_workers_for_slo(specs):
    a100, v100 = specs

    def fleet_fn(n):
        return [(a100 if i % 2 == 0 else v100) for i in range(n)]

    n = min_workers_for_slo(lambda: generate_trace(WCFG), a100.perf, SLO_70B,
                            a100.kv_capacity, SimConfig(), 0.9, hi=16,
                            fleet_fn=fleet_fn)
    assert 1 <= n <= 16
    # the returned fleet attains what the search claims
    res = simulate(generate_trace(WCFG), a100.perf, SLO_70B,
                   a100.kv_capacity, SimConfig(), fleet=fleet_fn(n))
    assert res.attainment >= 0.9 and res.finished == res.total


def test_mixed_fleet_respects_per_worker_budgets(specs):
    a100, v100 = specs
    fleet = [a100, v100]

    def observer(t, workers, sims, queued, finished, arrived):
        caps = {w.id: (w.cfg.max_batch, w.cfg.kv_capacity) for w in workers}
        assert len(set(caps.values())) == 2, "fleet must stay heterogeneous"
        for w in workers:
            assert w.batch_size <= w.cfg.max_batch

    simulate(generate_trace(WCFG), a100.perf, SLO_70B, a100.kv_capacity,
             SimConfig(), fleet=fleet, observer=observer)


def test_best_fit_respects_per_worker_kv_budget():
    """A request whose KV trajectory only fits the big worker must land on
    the big worker even when the small one is emptier."""
    perf = PerfModel(kv=KVModel(h=1.0, j=0.0),
                     prefill=PrefillModel(k1=1e-5, c1=1e-3),
                     decode=DecodeModel(k2=1e-8, c2=1e-6, c3=1e-4))
    slo = SLO(ttft=2.0, atgt=0.1)
    small = WorkerState(1, PlacementConfig(theta=1.0, kv_capacity=100.0,
                                           max_batch=8), perf, slo)
    big = WorkerState(2, PlacementConfig(theta=1.0, kv_capacity=1e5,
                                         max_batch=8), perf, slo)
    big.place(Request(l_in=50, l_pred=50))      # big is the fuller bin
    r = Request(l_in=400, l_pred=400)           # kv peak 800 > small's 100
    w = best_fit_place([small, big], r, allow_new=False)
    assert w is big
    assert not small.new_batch


def test_best_fit_respects_per_worker_ttft_budget():
    """Constraint (c) binds per worker: a slow-prefill worker is infeasible
    for a prompt a fast worker accepts."""
    slo = SLO(ttft=0.5, atgt=0.1)
    slow = PerfModel(kv=KVModel(h=1.0, j=0.0),
                     prefill=PrefillModel(k1=1e-2, c1=0.0),   # 10ms/token
                     decode=DecodeModel(k2=1e-8, c2=1e-6, c3=1e-4))
    fast = PerfModel(kv=KVModel(h=1.0, j=0.0),
                     prefill=PrefillModel(k1=1e-5, c1=0.0),
                     decode=DecodeModel(k2=1e-8, c2=1e-6, c3=1e-4))
    cfg = PlacementConfig(theta=1.0, kv_capacity=1e6, max_batch=8)
    w_slow = WorkerState(1, cfg, slow, slo)
    w_fast = WorkerState(2, cfg, fast, slo)
    r = Request(l_in=200, l_pred=50)            # 2s on slow, 2ms on fast
    w = best_fit_place([w_slow, w_fast], r, allow_new=False)
    assert w is w_fast


# ---- disaggregated pipeline --------------------------------------------------

def test_disagg_completes_and_conserves(specs):
    a100, _ = specs
    trace = generate_trace(WCFG)
    total = len(trace)

    def observer(t, pool_p, states_d, queued_p, in_transfer, queued_d,
                 finished, arrived):
        in_prefill = sum(len(w.queue) for w in pool_p)
        in_decode = sum(len(w.ongoing) + len(w.new_batch) for w in states_d)
        assert len(finished) + len(queued_p) + in_prefill \
            + len(in_transfer) + len(queued_d) + in_decode \
            + (total - arrived) == total, f"request leak at t={t}"

    res = simulate_disaggregated(trace, SLO_70B, DisaggConfig(), a100, a100,
                                 n_prefill=2, n_decode=4, observer=observer)
    assert res.finished == res.total == total
    assert res.mean_transfer > 0.0
    assert res.gpu_cost == 6 * a100.n_accelerators
    for r in trace:
        assert r.t_first_token is not None and r.t_finish is not None
        assert r.arrival <= r.t_first_token <= r.t_finish + 1e-9
        assert r.l_out == r.l_real


def test_disagg_deterministic(specs):
    a100, _ = specs

    def once():
        return simulate_disaggregated(generate_trace(WCFG), SLO_70B,
                                      DisaggConfig(), a100, a100,
                                      n_prefill=1, n_decode=3)

    assert dataclasses.asdict(once()) == dataclasses.asdict(once())


def test_disagg_transfer_time_scales_with_bandwidth(specs):
    a100, _ = specs
    fast = simulate_disaggregated(generate_trace(WCFG), SLO_70B,
                                  DisaggConfig(kv_transfer_bw=640e9), a100,
                                  a100, n_prefill=1, n_decode=3)
    slow = simulate_disaggregated(generate_trace(WCFG), SLO_70B,
                                  DisaggConfig(kv_transfer_bw=6.4e9), a100,
                                  a100, n_prefill=1, n_decode=3)
    assert slow.mean_transfer > fast.mean_transfer


def test_min_cost_disagg_frontier(specs):
    a100, _ = specs
    best = min_cost_disagg(lambda: generate_trace(WCFG), SLO_70B,
                           DisaggConfig(), a100, a100, 0.9, max_prefill=4,
                           hi_decode=16)
    assert best is not None
    assert best.attainment >= 0.9 and best.finished == best.total
    assert best.n_prefill >= 1 and best.n_decode >= 1
    assert best.gpu_cost == (best.n_prefill + best.n_decode) \
        * a100.n_accelerators


# ---- heterogeneous pools -----------------------------------------------------

def test_two_pool_disagg_completes_and_conserves(specs):
    a100, v100 = specs
    trace = generate_trace(WCFG)
    total = len(trace)

    def observer(t, pool_p, states_d, queued_p, in_transfer, queued_d,
                 finished, arrived):
        in_prefill = sum(len(w.queue) for w in pool_p)
        in_decode = sum(len(w.ongoing) + len(w.new_batch) for w in states_d)
        assert len(finished) + len(queued_p) + in_prefill \
            + len(in_transfer) + len(queued_d) + in_decode \
            + (total - arrived) == total, f"request leak at t={t}"

    res = simulate_disaggregated(
        trace, SLO_70B, DisaggConfig(), observer=observer,
        prefill_pools=[(a100, 1), (v100, 1)],
        decode_pools=[(a100, 2), (v100, 2)])
    assert res.finished == res.total == total
    assert res.n_prefill == 2 and res.n_decode == 4
    assert res.gpu_cost == 3 * a100.gpu_cost + 3 * v100.gpu_cost
    assert a100.name in res.pool_mix and v100.name in res.pool_mix
    for r in trace:
        assert r.t_first_token is not None and r.arrival <= r.t_first_token


def test_two_pool_legacy_single_pool_results_agree(specs):
    """A one-type pool list must reproduce the legacy spec+count form
    exactly (the router degenerates to the seed's ranking)."""
    a100, _ = specs
    legacy = simulate_disaggregated(generate_trace(WCFG), SLO_70B,
                                    DisaggConfig(), a100, a100,
                                    n_prefill=2, n_decode=3)
    pooled = simulate_disaggregated(generate_trace(WCFG), SLO_70B,
                                    DisaggConfig(),
                                    prefill_pools=[(a100, 2)],
                                    decode_pools=[(a100, 3)])
    le, po = dataclasses.asdict(legacy), dataclasses.asdict(pooled)
    assert le == po


def test_affine_router_crossover_and_ttft_fallthrough(specs):
    """The affine score routes short prompts to the cheap pool and long
    prompts to the fast one (crossover), and prompts the cheap pool cannot
    prefill within TTFT fall through to the fast pool instead of starving.

    cheap: score = 1e-3 * l_in            (1 accel, k1=1e-3, c1=0)
    fast:  score = 4e-4 * l_in + 0.2      (4 accels, k1=1e-4, c1=0.05)
    crossover at l_in ~ 333; cheap infeasible once prefill > TTFT."""
    a100, _ = specs
    from repro.serving.disagg import prefill_affinity
    cheap = dataclasses.replace(
        a100, perf=PerfModel(kv=a100.perf.kv,
                             prefill=PrefillModel(k1=1e-3, c1=0.0),
                             decode=a100.perf.decode),
        n_accelerators=1, name="cheap")
    fast = dataclasses.replace(
        a100, perf=PerfModel(kv=a100.perf.kv,
                             prefill=PrefillModel(k1=1e-4, c1=0.05),
                             decode=a100.perf.decode),
        n_accelerators=4, name="fast")
    assert prefill_affinity(cheap, 100) < prefill_affinity(fast, 100)
    assert prefill_affinity(cheap, 1000) > prefill_affinity(fast, 1000)

    trace = generate_trace(WCFG)
    iters = {}

    def observer(t, pool_p, **kw):
        for w in pool_p:
            iters[w.id] = w.iters

    res = simulate_disaggregated(trace, SLO_70B, DisaggConfig(),
                                 observer=observer,
                                 prefill_pools=[(cheap, 1), (fast, 1)],
                                 decode_pools=[(a100, 4)])
    assert res.finished == res.total      # TTFT-infeasible prompts fell
    for r in trace:                       # through instead of starving
        assert r.ttft() is not None and r.arrival <= r.t_first_token
    assert iters.get(1, 0) > 0, "cheap pool never served a short prompt"
    assert iters.get(2, 0) > 0, "fast pool never served a long prompt"


def test_min_cost_disagg_prune_matches_exhaustive_grid(specs):
    """The frontier walk's break on the n_p cost lower bound must never
    skip a cheaper feasible point: compare against brute force over the
    whole (n_p, n_d) grid on the same traces."""
    a100, _ = specs
    cfg = DisaggConfig()
    max_p, max_d = 3, 6
    target = 0.9

    for seed in (3, 5):
        wcfg = dataclasses.replace(WCFG, seed=seed, duration=10.0)

        def tf():
            return generate_trace(wcfg)

        got = min_cost_disagg(tf, SLO_70B, cfg, a100, a100, target,
                              max_prefill=max_p, hi_decode=max_d)
        # brute force
        best_cost = None
        for n_p in range(1, max_p + 1):
            for n_d in range(1, max_d + 1):
                res = simulate_disaggregated(tf(), SLO_70B, cfg, a100, a100,
                                             n_prefill=n_p, n_decode=n_d)
                if res.attainment >= target and res.finished == res.total:
                    if best_cost is None or res.gpu_cost < best_cost:
                        best_cost = res.gpu_cost
        if best_cost is None:
            assert got is None
        else:
            assert got is not None
            assert got.gpu_cost == best_cost, \
                f"seed {seed}: prune found {got.gpu_cost}, grid {best_cost}"
