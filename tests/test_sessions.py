"""Multi-turn sessions with prefix-cache-aware routing (ISSUE 10).

The session subsystem must be a strict *extension* of the single-shot
simulator: a degenerate session workload (one turn, no prefix) and a
cache-disabled run must reproduce the independent-request path
**bit-for-bit** — same per-request clocks, same token counts — which is
what keeps every earlier pinned result meaningful. On top of that oracle
pin, the sticky router must never place a turn on an infeasible home
worker (constraint (c) pressure falls through to the placement policy),
attainment must be monotone in prefix-cache capacity, the ManagedPool
drain path must flush per-worker cache state, and the compiled cores must
reject what they cannot price."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import A100_80G, PAPER_SLOS, make_worker_spec
from repro.core.request import Request
from repro.core.worker_config import spot_variant
from repro.serving import (Colocated, FixedScale, FleetSpec, PoolSpec,
                           PreemptionEvent, Reactive, Scenario, SessionSpec,
                           SpotMarket, clone_trace, run, session_trace)

ARCH = get_arch("llama2-70b")
SLO = PAPER_SLOS["llama2-70b"]
SESS = SessionSpec(mean_rate=0.8, duration=120.0, seed=3)


@pytest.fixture(scope="module")
def spec():
    return make_worker_spec(ARCH, A100_80G, SLO, mean_context=450.0)


def _strip(trace):
    """The same arrivals/lengths with the session tags removed: the
    single-shot comparator every session run is pinned against."""
    out = clone_trace(trace)
    for r in out:
        r.session_id, r.turn, r.prefix_len = -1, 0, 0
    return out


def _clocks(trace):
    return [(r.t_first_token, r.t_finish, r.l_out, r.t_decode_spent)
            for r in trace]


def _scenario(trace, spec, n=4, **topo):
    return Scenario(workload=trace, fleet=FleetSpec([PoolSpec(spec, n)]),
                    slo=SLO, topology=Colocated(**topo),
                    scaling=FixedScale())


# ---- oracle pins: sessions degenerate to the single-shot path --------------

def test_single_turn_sessions_match_single_shot_bit_for_bit(spec):
    """max_turns=1 sessions carry no reusable prefix: the full session
    machinery (sticky router, LRU cache, store/shed) must be arithmetically
    invisible — per-request clocks identical to the untagged trace."""
    sess = dataclasses.replace(SESS, max_turns=1)
    trace = session_trace(sess)
    assert trace and all(r.turn == 0 and r.prefix_len == 0 for r in trace)
    tagged, plain = clone_trace(trace), _strip(trace)
    rep_s = run(_scenario(tagged, spec, router="sticky"))
    rep_p = run(_scenario(plain, spec))
    assert _clocks(tagged) == _clocks(plain)
    assert rep_s.attainment == rep_p.attainment
    assert rep_s.p99_ttft == rep_p.p99_ttft
    # no prefix ever granted: zero-length turns count neither hit nor miss
    assert rep_s.cache_hit_rate == 0.0


def test_cache_off_blind_equals_single_shot_bit_for_bit(spec):
    """prefix_cache='off' + the blind router IS single-shot semantics,
    even on a real multi-turn trace: tags ride along, clocks do not move."""
    trace = session_trace(SESS)
    assert any(r.prefix_len > 0 for r in trace)
    tagged, plain = clone_trace(trace), _strip(trace)
    rep_t = run(_scenario(tagged, spec, prefix_cache="off"))
    rep_p = run(_scenario(plain, spec))
    assert _clocks(tagged) == _clocks(plain)
    assert rep_t.cache_hit_rate == 0.0
    assert rep_t.prefix_evictions == 0


def test_cache_discount_moves_the_clocks(spec):
    """The inverse control for the pins above: with the cache ON, a
    multi-turn trace must NOT match the stripped run (hits discount
    prefill), and sticky must out-hit blind on this trace."""
    trace = session_trace(SESS)
    tagged, plain = clone_trace(trace), _strip(trace)
    rep_b = run(_scenario(tagged, spec))                     # blind + lru
    run(_scenario(plain, spec))
    assert _clocks(tagged) != _clocks(plain)
    sticky = clone_trace(trace)
    rep_s = run(_scenario(sticky, spec, router="sticky"))
    assert rep_s.cache_hit_rate > rep_b.cache_hit_rate > 0.0


# ---- sticky fall-through under constraint-(c) pressure ---------------------

def _topology(spec, cfg):
    from repro.serving.simulator import (ColocatedTopology, FixedPool,
                                         make_worker_state)
    workers = [make_worker_state(i + 1, spec, cfg, SLO) for i in range(2)]
    pool = FixedPool(workers, {}, np.random.default_rng(0))
    return ColocatedTopology(SLO, cfg, pool, np.random.default_rng(0))


def test_sticky_falls_through_when_home_infeasible(spec):
    from repro.serving.simulator import SimConfig
    topo = _topology(spec, SimConfig(router="sticky"))
    home, other = topo.pool.serving()
    r = Request(l_in=400, l_pred=64, l_real=64, arrival=0.0,
                session_id=7, turn=1, prefix_len=200)
    topo.session_home[7] = home.id
    # feasible home takes its session's turn
    assert topo._try_home(r) is home
    home.unplace(r)
    # pile prompt tokens onto the home until constraint (c) rejects r
    for _ in range(512):
        if not home.feasible([r]):
            break
        home.place(Request(l_in=1800, l_pred=64, l_real=64, arrival=0.0))
    assert not home.feasible([r])
    assert topo._try_home(r) is None
    assert r.cached_len == 0            # no stale discount off-home
    # the full placement pass routes the turn to the feasible worker
    # (manual home.place() calls above bypassed sim creation — install
    # execution models for both workers so the beat can advance)
    from repro.serving.simulator import SimWorker
    for w in topo.pool.serving():
        topo.pool.sims[w.id] = SimWorker(w, w.perf, 0.0, False)
    topo.admit(r)
    topo.step(0.0, 0.02, 1)
    assert r.worker == other.id
    # ... and sticky re-homes the session where the turn actually landed
    assert topo.session_home[7] == other.id


def test_sticky_skips_dead_and_draining_homes(spec):
    from repro.serving.simulator import SimConfig
    topo = _topology(spec, SimConfig(router="sticky"))
    home, _ = topo.pool.serving()
    r = Request(l_in=200, l_pred=32, l_real=32, arrival=0.0,
                session_id=1, turn=1, prefix_len=100)
    topo.session_home[1] = home.id
    home.draining = True
    assert topo._try_home(r) is None
    home.draining, home.alive = False, False
    assert topo._try_home(r) is None
    topo.session_home[1] = 999          # vanished worker id
    assert topo._try_home(r) is None


# ---- attainment monotone in cache capacity ---------------------------------

def test_attainment_monotone_in_cache_capacity(spec):
    """Fixed seed, fixed fleet: a bigger prefix cache can only help. The
    cache_tokens=0 endpoint sheds every entry at store time — semantically
    cache-off — and the unlimited cache dominates both."""
    sess = dataclasses.replace(SESS, mean_rate=2.2, duration=90.0, seed=5)
    trace = session_trace(sess)
    attain, hits = {}, {}
    for cap in (0, 2048, None):
        t = clone_trace(trace)
        rep = run(_scenario(t, spec, n=3, router="sticky",
                            cache_tokens=cap))
        attain[cap], hits[cap] = rep.attainment, rep.cache_hit_rate
    assert hits[0] == 0.0
    assert hits[0] < hits[2048] <= hits[None]
    assert attain[0] <= attain[2048] <= attain[None]
    assert attain[None] > attain[0]     # the cache buys real attainment


# ---- ManagedPool drain/boot interaction with cache state -------------------

def test_managed_pool_remove_flushes_prefix_cache(spec):
    """A voluntarily drained retirement never passes through on_kill:
    ManagedPool._remove itself must pop the worker's execution model and
    vaporize its cached prefixes, or the ledger leaks."""
    from repro.serving.forecast import ManagedPool, ScaleSimConfig
    from repro.serving.simulator import (CacheStats, PrefixCache, SimConfig,
                                         SimWorker, make_worker_state)
    sims, made = {}, []

    def new_worker(wspec):
        w = make_worker_state(len(made) + 1, wspec, SimConfig(), SLO)
        made.append(w)
        return w

    pool = ManagedPool(spec, ScaleSimConfig(initial_workers=2,
                                            min_workers=1),
                       policy=None, heartbeat=0.02,
                       rng=np.random.default_rng(0), new_worker=new_worker,
                       on_spawn=lambda w, t: sims.setdefault(
                           w.id, SimWorker(w, w.perf, t, False)),
                       on_kill=lambda w: [], load=lambda w: 0.0,
                       idle=lambda w: True, sims=sims)
    stats = CacheStats()
    victim = pool.online[-1]
    cache = sims[victim.id].cache = PrefixCache(stats)
    cache.store(42, 500)
    assert cache.resident == 500
    pool._remove(victim)
    assert victim.id not in sims        # execution model flushed
    assert stats.evictions == 1         # vaporized prefixes are counted
    assert cache.resident == 0 and not cache.entries


def test_reactive_scaling_conserves_sessions_and_counts_evictions(spec):
    """End to end through api.run: a policy-scaled fleet booting and
    draining workers under a session workload loses no request, conserves
    tokens, and surfaces drain-vaporized prefixes in the report."""
    sess = dataclasses.replace(SESS, mean_rate=2.5, duration=90.0, seed=9)
    trace = session_trace(sess)
    sc = Scenario(workload=clone_trace(trace),
                  fleet=FleetSpec([PoolSpec(spec, 2)]), slo=SLO,
                  topology=Colocated(router="sticky"),
                  scaling=Reactive(min_workers=1))
    rep = run(sc)
    assert rep.finished == rep.total == len(trace)
    for r in sc.workload:
        assert r.t_finish is not None and r.l_out == r.l_real
    assert rep.cache_hit_rate > 0.0
    assert rep.prefix_evictions > 0     # scale-downs vaporized live caches


# ---- spot reclaims vaporize cached prefixes --------------------------------

def test_reclaim_vaporizes_cache_and_conserves(spec):
    sess = dataclasses.replace(SESS, mean_rate=1.5, duration=90.0, seed=4)
    trace = session_trace(sess)
    sspot = spot_variant(spec, price=0.35, preempt_hazard=1.0 / 300.0)
    events = [PreemptionEvent(t=30.0, frac=0.5),
              PreemptionEvent(t=60.0, frac=0.5)]
    sc = Scenario(workload=clone_trace(trace),
                  fleet=FleetSpec([PoolSpec(sspot, 4)]), slo=SLO,
                  topology=Colocated(router="sticky"),
                  scaling=FixedScale(),
                  market=SpotMarket(sspot, events))
    rep = run(sc)
    assert rep.finished == rep.total == len(trace)
    for r in sc.workload:
        assert r.t_finish is not None and r.l_out == r.l_real
        assert r.t_preempted is None
    assert rep.preempted_workers > 0
    assert rep.prefix_evictions > 0     # dead workers' prefixes vaporized


# ---- compiled cores reject what they cannot price --------------------------

@pytest.mark.parametrize("topo", [dict(router="sticky"),
                                  dict(cache_tokens=4096)])
def test_vectorized_engine_rejects_session_knobs(spec, topo):
    sc = _scenario([], spec, **topo)
    sc = dataclasses.replace(sc, engine="vectorized")
    with pytest.raises(ValueError, match="reference-engine only"):
        run(sc)


@pytest.mark.parametrize("engine", ["vectorized", "jax"])
def test_compiled_engines_reject_session_traces(spec, engine):
    if engine == "jax":
        pytest.importorskip("jax")
    trace = session_trace(dataclasses.replace(SESS, duration=10.0))
    sc = dataclasses.replace(_scenario(trace, spec), engine=engine)
    with pytest.raises(ValueError, match="reference-engine only"):
        run(sc)


def test_unknown_router_and_cache_mode_rejected(spec):
    with pytest.raises(ValueError, match="router"):
        run(_scenario([], spec, router="warm"))
    with pytest.raises(ValueError, match="prefix_cache"):
        run(_scenario([], spec, prefix_cache="lfu"))
