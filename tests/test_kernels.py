"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp oracle (pallas kernels run in interpret mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (decode_attention_ref,
                                            paged_decode_attention_pallas,
                                            paged_decode_ref)
from repro.kernels.flash_attention import (attention_dense_ref,
                                           flash_attention_pallas,
                                           flash_attention_ref)
from repro.kernels.ssd_scan import ssd_chunked_ref, ssd_ref, ssd_scan_pallas


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,sq,skv,hq,hkv,d", [
    (1, 128, 128, 4, 4, 64),      # MHA square
    (2, 64, 64, 8, 2, 32),        # GQA
    (2, 128, 128, 8, 1, 64),      # MQA
    (1, 32, 128, 4, 4, 128),      # rectangular (chunked prefill q block)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_pallas_sweep(b, sq, skv, hq, hkv, d, dtype, causal):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, sq, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, skv, hkv, d)), dtype)
    off = skv - sq if causal else 0
    ref = attention_dense_ref(q, k, v, causal=causal, q_offset=off)
    out = flash_attention_pallas(q, k, v, causal=causal, q_offset=off,
                                 block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("kv_chunk", [16, 64, 256])
def test_flash_ref_chunk_invariance(kv_chunk):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 64, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 2, 32)), jnp.float32)
    kvlen = jnp.array([100, 256])
    ref = attention_dense_ref(q, k, v, causal=True, q_offset=192, kv_len=kvlen)
    out = flash_attention_ref(q, k, v, causal=True, q_offset=192,
                              kv_len=kvlen, kv_chunk=kv_chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,hq,hkv,d,page,npages,maxp", [
    (2, 8, 2, 64, 16, 32, 4),
    (4, 4, 4, 32, 8, 16, 8),
    (1, 16, 1, 128, 32, 8, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_sweep(b, hq, hkv, d, page, npages, maxp, dtype):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dtype)
    kp = jnp.asarray(rng.standard_normal((npages, page, hkv, d)), dtype)
    vp = jnp.asarray(rng.standard_normal((npages, page, hkv, d)), dtype)
    bt = jnp.asarray(rng.integers(0, npages, (b, maxp)), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, maxp * page + 1, (b,)), jnp.int32)
    ref = paged_decode_ref(q, kp, vp, bt, lengths)
    out = paged_decode_attention_pallas(q, kp, vp, bt, lengths,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_ref_matches_flash_path():
    """Contiguous decode ref == dense attention on the same cache."""
    rng = np.random.default_rng(3)
    b, h, d, s = 2, 4, 32, 64
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    lengths = jnp.array([40, 64])
    out = decode_attention_ref(q, k, v, lengths)
    ref = attention_dense_ref(q[:, None], k, v, causal=False,
                              kv_len=lengths)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 128, 4, 16, 2, 8, 32),
    (1, 64, 8, 32, 1, 16, 16),
    (2, 256, 2, 64, 2, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(b, s, h, p, g, n, chunk, dtype):
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, s, g, n)), dtype)
    Cm = jnp.asarray(rng.standard_normal((b, s, g, n)), dtype)
    D = jnp.asarray(rng.standard_normal((h,)), jnp.float32)
    st = jnp.asarray(rng.standard_normal((b, h, p, n)), jnp.float32) * 0.1
    y_ref, f_ref = ssd_ref(x, dt, A, Bm, Cm, D, st)
    y_c, f_c = ssd_chunked_ref(x, dt, A, Bm, Cm, D, st, chunk=chunk)
    y_p, f_p = ssd_scan_pallas(x, dt, A, Bm, Cm, D, st, chunk=chunk,
                               interpret=True)
    tol = _tol(dtype)
    np.testing.assert_allclose(np.asarray(y_c, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(y_p, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(f_c), np.asarray(f_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(f_p), np.asarray(f_ref),
                               rtol=1e-3, atol=1e-3)


def test_ssd_no_init_state():
    rng = np.random.default_rng(5)
    b, s, h, p, g, n = 2, 64, 4, 16, 1, 8
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, s, g, n)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, s, g, n)), jnp.float32)
    D = jnp.asarray(rng.standard_normal((h,)), jnp.float32)
    y_ref, _ = ssd_ref(x, dt, A, Bm, Cm, D)
    y_p, _ = ssd_scan_pallas(x, dt, A, Bm, Cm, D, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
