"""Paged engine correctness: continuous batching must reproduce the staged-
cache model path token-for-token, and page accounting must hold."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.request import ReqState, Request
from repro.models.model import LM, ExecConfig
from repro.serving.engine import EngineConfig, PagedEngine


def _setup(max_batch=4):
    arch = reduced(get_arch("granite-3-8b"), n_layers=2, d_model=64,
                   vocab=128)
    model = LM(arch, exec_cfg=ExecConfig(recent_window=8))
    params = model.init(jax.random.key(0))
    eng = PagedEngine(arch, params, EngineConfig(
        max_batch=max_batch, page_size=8, n_pages=128, max_pages_per_seq=16,
        max_new_tokens=64))
    return arch, model, params, eng


def _reference_generate(model, params, prompt, n_new):
    logits, cache = jax.jit(
        lambda p, t: model.prefill(p, tokens=t,
                                   s_max=len(prompt) + n_new + 8))(
        params, jnp.asarray([prompt]))
    out = [int(np.asarray(jnp.argmax(logits, -1))[0])]
    step = jax.jit(model.decode_step)
    for _ in range(n_new - 1):
        lg, cache = step(params, cache, jnp.asarray([out[-1]]))
        out.append(int(np.asarray(jnp.argmax(lg, -1))[0]))
    return out


def test_engine_matches_model_single():
    arch, model, params, eng = _setup()
    rng = np.random.default_rng(0)
    prompt = [int(x) for x in rng.integers(2, arch.vocab, 12)]
    n_new = 8
    ref = _reference_generate(model, params, prompt, n_new)
    req = Request(l_in=len(prompt), l_pred=n_new, l_real=n_new)
    req.tokens = list(prompt)
    eng.submit(req)
    while req.state != ReqState.FINISHED:
        eng.step()
    got = req.tokens[len(prompt):]
    assert got == ref, (got, ref)


def test_engine_continuous_batching_isolation():
    """Two interleaved requests must each match their solo generation."""
    arch, model, params, eng = _setup()
    rng = np.random.default_rng(1)
    p1 = [int(x) for x in rng.integers(2, arch.vocab, 10)]
    p2 = [int(x) for x in rng.integers(2, arch.vocab, 17)]
    ref1 = _reference_generate(model, params, p1, 6)
    ref2 = _reference_generate(model, params, p2, 6)
    r1 = Request(l_in=len(p1), l_pred=6, l_real=6)
    r1.tokens = list(p1)
    r2 = Request(l_in=len(p2), l_pred=6, l_real=6)
    r2.tokens = list(p2)
    eng.submit(r1)
    eng.step()                      # prefill r1
    eng.step()                      # decode r1 once
    eng.submit(r2)                  # r2 arrives mid-flight
    for _ in range(40):
        eng.step()
        if r1.state == ReqState.FINISHED and r2.state == ReqState.FINISHED:
            break
    assert r1.tokens[len(p1):] == ref1
    assert r2.tokens[len(p2):] == ref2


def test_engine_page_accounting():
    arch, model, params, eng = _setup()
    free0 = len(eng.free_pages)
    rng = np.random.default_rng(2)
    reqs = []
    for i in range(3):
        p = [int(x) for x in rng.integers(2, arch.vocab, 9 + i)]
        r = Request(l_in=len(p), l_pred=5, l_real=5)
        r.tokens = list(p)
        reqs.append(r)
        eng.submit(r)
    for _ in range(60):
        eng.step()
        if all(r.state == ReqState.FINISHED for r in reqs):
            break
    assert all(r.state == ReqState.FINISHED for r in reqs)
    assert len(eng.free_pages) == free0, "pages leaked"
    assert eng.traces.decode_batches, "decode traces recorded"
    assert eng.traces.prefill_inputs, "prefill traces recorded"
