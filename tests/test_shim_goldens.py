"""Golden pins for the legacy-entry-point deprecation shims.

The Scenario API refactor (repro.serving.api) turned ``simulate``,
``simulate_disaggregated`` and ``simulate_autoscaled`` into thin shims that
build the equivalent declarative ``Scenario`` and delegate to ``api.run``.
The shims' contract is bit-for-bit reproduction of the pre-refactor
metrics: every number below was captured on the pre-refactor tree with
``scripts/capture_goldens.py`` (fixed seeds, fixed configs) and must keep
matching exactly — any drift means the engine path diverged from the
legacy step loops, not a tolerable modeling change.
"""
import pytest

from repro.configs import get_arch
from repro.core import A100_80G, PAPER_SLOS, SpotMixConfig, make_worker_spec
from repro.core.worker_config import spot_variant
from repro.serving import (DisaggConfig, ForecastConfig, ForecastPolicy,
                           PreemptionEvent, ReactivePolicy, ScaleSimConfig,
                           SeasonalNaiveForecaster, SimConfig, SpotMarket,
                           WorkloadConfig, diurnal_trace, generate_trace,
                           simulate, simulate_autoscaled,
                           simulate_disaggregated)

ARCH = get_arch("llama2-70b")
SLO = PAPER_SLOS["llama2-70b"]
WCFG = WorkloadConfig(mean_rate=3.0, duration=15.0, seed=9, in_mu=5.0,
                      in_sigma=1.1, out_mu=5.3, out_sigma=0.9)
DIURNAL_CFG = WorkloadConfig(mean_rate=4.0, duration=240.0, seed=21,
                             in_mu=5.0, in_sigma=1.1, out_mu=5.3,
                             out_sigma=0.9)

# captured by scripts/capture_goldens.py on the pre-refactor tree
GOLDEN = {
    "colocated_fixed": {
        "n_workers_peak": 4, "attainment": 1.0,
        "p99_atgt": 0.06429463509567153, "p99_ttft": 0.9827317616941065,
        "mean_atgt": 0.05541041167791266, "finished": 43, "total": 43,
        "moves": 0, "gpu_cost": 4},
    "colocated_elastic_po2": {
        "n_workers_peak": 1, "attainment": 0.6046511627906976,
        "p99_atgt": 0.12834974143653904, "p99_ttft": 0.9628434970981319,
        "mean_atgt": 0.07810271024434604, "finished": 43, "total": 43,
        "moves": 0, "gpu_cost": 1},
    "disagg_fixed": {
        "n_prefill": 2, "n_decode": 4, "gpu_cost": 12.0, "attainment": 1.0,
        "p99_ttft": 0.7686580152156194, "p99_atgt": 0.06824715112724927,
        "mean_transfer": 0.0037580651162790702, "finished": 43, "total": 43,
        "pool_mix": "p:a100-80g-tp2x2|d:a100-80g-tp2x4"},
    "autoscaled_reactive": {
        "policy": "reactive", "gpu_seconds": 5317.5,
        "attainment": 0.9894291754756871, "p99_ttft": 1.6510710421527965,
        "p99_atgt": 0.07142007382595672, "mean_atgt": 0.06482130723865705,
        "finished": 946, "total": 946, "peak_workers": 9,
        "spot_gpu_seconds": 0.0, "preempted_workers": 0, "requeued": 0},
    "autoscaled_forecast": {
        "policy": "forecast", "gpu_seconds": 4977.0,
        "attainment": 0.9873150105708245, "p99_ttft": 2.1476625225148886,
        "p99_atgt": 0.07142007382595672, "mean_atgt": 0.06486760078579426,
        "finished": 946, "total": 946, "peak_workers": 9,
        "spot_gpu_seconds": 0.0, "preempted_workers": 0, "requeued": 0},
    "autoscaled_spot": {
        "policy": "forecast", "gpu_seconds": 3504.550000000042,
        "attainment": 0.9873150105708245, "p99_ttft": 2.1142775518054373,
        "p99_atgt": 0.07193432009395027, "mean_atgt": 0.06475582349729299,
        "finished": 946, "total": 946, "peak_workers": 10,
        "spot_gpu_seconds": 1173.5499999999881, "preempted_workers": 4,
        "requeued": 2},
}


@pytest.fixture(scope="module")
def spec():
    return make_worker_spec(ARCH, A100_80G, SLO, mean_context=450.0)


def _scfg():
    return ScaleSimConfig(interval=5.0, provision_delay=10.0,
                          initial_workers=3)


def test_simulate_shim_matches_prerefactor_fixed(spec):
    res = simulate(generate_trace(WCFG), spec.perf, SLO, spec.kv_capacity,
                   SimConfig(), n_workers=4)
    assert res.row() == GOLDEN["colocated_fixed"]


def test_simulate_shim_matches_prerefactor_elastic_po2(spec):
    res = simulate(generate_trace(WCFG), spec.perf, SLO, spec.kv_capacity,
                   SimConfig(policy="po2", seed=4), n_workers=None)
    assert res.row() == GOLDEN["colocated_elastic_po2"]


def test_disagg_shim_matches_prerefactor(spec):
    res = simulate_disaggregated(generate_trace(WCFG), SLO, DisaggConfig(),
                                 spec, spec, n_prefill=2, n_decode=4)
    assert res.row() == GOLDEN["disagg_fixed"]


def test_autoscaled_shim_matches_prerefactor_reactive(spec):
    scfg = _scfg()
    res = simulate_autoscaled(
        diurnal_trace(DIURNAL_CFG, amplitude=0.6, period=120.0), spec, SLO,
        SimConfig(), scfg, ReactivePolicy(scfg))
    assert res.row() == GOLDEN["autoscaled_reactive"]


def test_autoscaled_shim_matches_prerefactor_forecast(spec):
    scfg = _scfg()
    fc = SeasonalNaiveForecaster(ForecastConfig(period=120.0, bin_width=5.0))
    res = simulate_autoscaled(
        diurnal_trace(DIURNAL_CFG, amplitude=0.6, period=120.0), spec, SLO,
        SimConfig(), scfg, ForecastPolicy(scfg, fc))
    assert res.row() == GOLDEN["autoscaled_forecast"]


def test_autoscaled_shim_matches_prerefactor_spot(spec):
    scfg = _scfg()
    fc = SeasonalNaiveForecaster(ForecastConfig(period=120.0, bin_width=5.0))
    mix = SpotMixConfig(discount=0.35, hazard=1.0 / 600.0, spot_frac=0.6)
    pol = ForecastPolicy(scfg, fc, spot_mix=mix)
    market = SpotMarket(
        spot_variant(spec, price=0.35, preempt_hazard=1.0 / 600.0),
        [PreemptionEvent(t=35.0, frac=0.5),
         PreemptionEvent(t=160.0, frac=0.5)])
    res = simulate_autoscaled(
        diurnal_trace(DIURNAL_CFG, amplitude=0.6, period=120.0), spec, SLO,
        SimConfig(), scfg, pol, spot=market)
    assert res.row() == GOLDEN["autoscaled_spot"]
