"""End-to-end behaviour tests for the whole Aladdin system: the full control
loop on live engines, and the co-adaptive property the paper claims —
placement + scaling respond to workload features, not just counts."""
import time

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.core.request import ReqState, Request
from repro.core.slo import SLO
from repro.models.model import LM
from repro.serving.cluster import ClusterConfig, ServingCluster
from repro.serving.engine import EngineConfig


def _cluster(policy="aladdin", n_workers=2, max_batch=4):
    arch = reduced(get_arch("llama2-7b"), n_layers=2, d_model=48, vocab=96)
    model = LM(arch)
    params = model.init(jax.random.key(0))
    return arch, ServingCluster(
        arch, params, SLO(ttft=30.0, atgt=5.0),
        engine_cfg=EngineConfig(max_batch=max_batch, page_size=8, n_pages=96,
                                max_pages_per_seq=8),
        cfg=ClusterConfig(policy=policy), n_workers=n_workers)


def _mk_req(rng, arch, l_in=None, l_real=None):
    r = Request(l_in=int(l_in or rng.integers(6, 24)), l_pred=0,
                l_real=int(l_real or rng.integers(3, 8)),
                arrival=time.perf_counter())
    r.tokens = [int(x) for x in rng.integers(2, arch.vocab, r.l_in)]
    return r


def test_full_serving_loop_end_to_end():
    """Submit a stream, run the control loop, verify every request finishes
    with coherent bookkeeping and the perf model was fitted from traces."""
    arch, cluster = _cluster()
    rng = np.random.default_rng(0)
    reqs = [_mk_req(rng, arch) for _ in range(10)]
    for r in reqs:
        cluster.submit(r)
        cluster.heartbeat()
    cluster.run_until_drained(max_beats=300)
    assert all(r.state == ReqState.FINISHED for r in reqs)
    assert all(len(r.tokens) == r.l_in + r.l_out for r in reqs)
    assert all(r.t_first_token is not None and r.t_finish is not None
               for r in reqs)
    # traces fitted the decode model (workflow step 3)
    assert cluster.perf.decode.k2 != 0.0 or cluster.perf.decode.c2 != 0.0
    # predictor learned from completions
    assert cluster.predictor.predict(16) > 0


def test_placement_is_length_aware():
    """Two long-prompt and two long-output requests: Aladdin's (e)-aware
    best-fit must not stack both long prompts on one worker when capacity
    makes that the peak-KV-violating choice (the Fig. 3 behaviour, live)."""
    arch, cluster = _cluster(n_workers=2, max_batch=2)
    # shrink each worker's believed KV capacity so pairing two long requests
    # violates the predicted peak
    rng = np.random.default_rng(1)
    long_in = [_mk_req(rng, arch, l_in=40, l_real=4) for _ in range(2)]
    long_out = [_mk_req(rng, arch, l_in=6, l_real=30) for _ in range(2)]
    for w in cluster.workers.values():
        w.state.cfg.kv_capacity = cluster.perf.kv(64) * 1.6
    for r in long_in + long_out:
        cluster.submit(r)
    cluster._place_all()
    per_worker = {}
    for r in long_in + long_out:
        if r.worker is not None:
            per_worker.setdefault(r.worker, []).append(r.l_in)
    for wid, lins in per_worker.items():
        assert lins not in ([40, 40],), "stacked both long prompts"


def test_jsq_vs_aladdin_same_completion():
    """Both policies complete the same stream (correctness parity)."""
    for policy in ("aladdin", "jsq"):
        arch, cluster = _cluster(policy=policy)
        rng = np.random.default_rng(2)
        reqs = [_mk_req(rng, arch) for _ in range(6)]
        for r in reqs:
            cluster.submit(r)
        cluster.run_until_drained(max_beats=300)
        assert all(r.state == ReqState.FINISHED for r in reqs), policy
