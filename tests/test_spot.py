"""Spot-market layer invariants: mix-planner economics, causal reclaim-event
delivery, no double counting of preempted requests, token conservation across
requeues, zero-hazard bit-for-bit equivalence with on-demand, the spot-vs-
on-demand cost acceptance, and the disaggregated pool-ratio search."""
import dataclasses

import pytest

from repro.configs import get_arch
from repro.core import (A100_80G, PAPER_SLOS, SpotMixConfig, V100_32G,
                        make_worker_spec, split_spot_mix, spot_variant)
from repro.core.request import Request
from repro.serving import (DisaggConfig, ForecastConfig, ForecastPolicy,
                           PreemptionEvent, ScaleSimConfig,
                           SeasonalNaiveForecaster, SimConfig, SpotMarket,
                           WorkloadConfig, diurnal_trace, min_cost_disagg,
                           preemption_trace, simulate_autoscaled)
from repro.serving.disagg import pool_cost, ratio_pool_fn
from repro.serving.simulator import run_heartbeat_loop

ARCH = get_arch("llama2-70b")
SLO_70B = PAPER_SLOS["llama2-70b"]


@pytest.fixture(scope="module")
def spec():
    return make_worker_spec(ARCH, A100_80G, SLO_70B, mean_context=450.0)


# ---- mix planner economics ---------------------------------------------------

def test_split_spot_mix_uneconomical_hazard_stays_on_demand():
    # survival so low the attrition premium eats the discount
    mix = SpotMixConfig(discount=0.5, hazard=1.0, horizon=15.0)
    assert split_spot_mix(10, mix) == (10, 0)
    assert split_spot_mix(0, mix) == (0, 0)


def test_split_spot_mix_expected_cost_beats_on_demand():
    mix = SpotMixConfig(discount=0.35, hazard=1.0 / 1800.0, horizon=15.0,
                        max_spot_frac=0.7)
    for target in (1, 3, 10, 57):
        n_od, n_spot = split_spot_mix(target, mix)
        # expected surviving capacity covers the target...
        assert n_od + n_spot * mix.survival() >= target - 1e-9
        # ...and the billed cost is never above all-on-demand
        assert n_od + n_spot * mix.discount <= target + 1e-9


def test_split_spot_mix_forced_fraction_is_exact_at_zero_hazard():
    mix = SpotMixConfig(discount=1.0, hazard=0.0, spot_frac=0.5)
    assert split_spot_mix(10, mix) == (5, 5)
    assert split_spot_mix(1, mix) == (1, 0)  # round(0.5) banks to even


def test_split_spot_mix_forced_fraction_respects_survival_guard():
    # a forced share must not inflate to absurdity when nothing survives
    mix = SpotMixConfig(spot_frac=0.5, hazard=1.0, horizon=100.0)
    assert split_spot_mix(10, mix) == (10, 0)


def test_split_spot_mix_break_even_ceil_inflation_falls_back():
    # discount/survival = 0.946 < 1 marginally, but ceil() inflation makes
    # the realized bill (3 + 8*0.9 = 10.2) worse than all-on-demand
    mix = SpotMixConfig(discount=0.9, hazard=0.01, horizon=5.0,
                        max_spot_frac=0.7)
    assert split_spot_mix(10, mix) == (10, 0)


def test_forecast_policy_does_not_mutate_callers_mix_config(spec):
    mix = SpotMixConfig(hazard=1.0 / 600.0, horizon=60.0)
    scfg = ScaleSimConfig(interval=5.0, provision_delay=10.0)
    fc = SeasonalNaiveForecaster(ForecastConfig())
    pol = ForecastPolicy(scfg, fc, spot_mix=mix)
    assert mix.horizon == 60.0                      # caller's copy untouched
    assert pol.spot_mix.horizon == 15.0             # policy derives its own


# ---- market-event plumbing ---------------------------------------------------

def test_preemption_trace_deterministic_and_in_horizon():
    a = preemption_trace(300.0, event_rate=1.0 / 30.0, frac=0.3, seed=5)
    b = preemption_trace(300.0, event_rate=1.0 / 30.0, frac=0.3, seed=5)
    assert a == b and len(a) > 0
    assert all(0.0 < e.t < 300.0 and 0.0 < e.frac <= 1.0 for e in a)


def test_heartbeat_loop_delivers_events_at_first_boundary_at_or_after():
    fired = []
    trace = [Request(l_in=8, l_pred=8, l_real=8, arrival=t)
             for t in (0.0, 3.0)]
    done = [False]

    def admit(r):
        pass

    def step(t, t_next, arrived):
        done[0] = t >= 4.0

    events = [PreemptionEvent(t=1.3), PreemptionEvent(t=2.0)]
    run_heartbeat_loop(trace, 0.5, admit, step, lambda: done[0],
                       events=events, fire=lambda t, e: fired.append((t, e)))
    assert [e.t for _, e in fired] == [1.3, 2.0]
    for t_fire, e in fired:
        assert t_fire >= e.t            # never delivered early...
        assert t_fire - e.t < 0.5       # ...and at the very next boundary
    with pytest.raises(ValueError):     # events without a deliverer is a bug
        run_heartbeat_loop(trace, 0.5, admit, step, lambda: done[0],
                           events=events)


# ---- preemption invariants in the autoscaled simulator -----------------------

def _wcfg(rate=4.0, duration=240.0, seed=21):
    return WorkloadConfig(mean_rate=rate, duration=duration, seed=seed,
                          in_mu=5.0, in_sigma=1.1, out_mu=5.3, out_sigma=0.9)


def _spot_run(spec, events, price=0.35, hazard=1.0 / 600.0, spot_frac=None,
              duration=240.0, period=120.0):
    scfg = ScaleSimConfig(interval=5.0, provision_delay=10.0,
                          initial_workers=3)
    fc = SeasonalNaiveForecaster(ForecastConfig(period=period, bin_width=5.0))
    mix = SpotMixConfig(discount=price, hazard=hazard, spot_frac=spot_frac)
    pol = ForecastPolicy(scfg, fc, spot_mix=mix)
    market = SpotMarket(spot_variant(spec, price=price, preempt_hazard=hazard),
                        events)
    trace = diurnal_trace(_wcfg(duration=duration), amplitude=0.6,
                          period=period)
    return simulate_autoscaled(trace, spec, SLO_70B, SimConfig(), scfg, pol,
                               spot=market), trace


# reclaim half the spot pool twice, mid-ramp, where in-flight work is dense
EVENTS = [PreemptionEvent(t=35.0, frac=0.5), PreemptionEvent(t=160.0,
                                                             frac=0.5)]


def test_preempted_requests_never_double_counted(spec):
    res, trace = _spot_run(spec, EVENTS, spot_frac=0.6)
    assert res.preempted_workers > 0, "events must actually kill workers"
    assert res.requeued > 0, "kills must catch in-flight requests"
    assert res.finished == res.total == len(trace)
    # attainment's denominator is the offered trace: a preempted request
    # appears exactly once no matter how many times it was requeued
    assert 0.0 <= res.attainment <= 1.0


def test_requeued_work_conserves_token_counts(spec):
    res, trace = _spot_run(spec, EVENTS, spot_frac=0.6)
    preempted = [r for r in trace if r.preempt_count > 0]
    assert preempted, "at least one in-flight request must be reclaimed"
    for r in trace:
        assert r.l_out == r.l_real      # no token lost, none generated twice
        assert r.t_preempted is None    # every reclaim stall was settled
    # recovery is not free: a reclaimed request's decode clock includes the
    # stall, so its effective ATGT can exceed an undisturbed request's
    assert all(r.t_finish is not None for r in preempted)


def test_split_phase_requeue_settles_stall_without_double_charge(spec):
    """Decode-pool-only (split_phase) fleets requeue reclaimed work too: the
    stall is charged from the reclaim instant — not from t_first_token,
    which would re-bill decode time already on the clock — and t_preempted
    is always settled."""
    scfg = ScaleSimConfig(interval=5.0, provision_delay=10.0,
                          initial_workers=3)
    fc = SeasonalNaiveForecaster(ForecastConfig(period=120.0, bin_width=5.0))
    mix = SpotMixConfig(discount=0.35, hazard=1.0 / 600.0, spot_frac=0.6)
    pol = ForecastPolicy(scfg, fc, spot_mix=mix)
    # wipe the whole spot pool every 25 s: split-phase decode drains fast
    # and best-fit concentrates load on the senior on-demand workers, so
    # only a sustained full-pool reclaim reliably catches in-flight work
    events = [PreemptionEvent(t=25.0 * k, frac=1.0) for k in range(1, 10)]
    market = SpotMarket(spot_variant(spec, price=0.35,
                                     preempt_hazard=1.0 / 600.0), events)
    trace = diurnal_trace(_wcfg(), amplitude=0.6, period=120.0)
    res = simulate_autoscaled(trace, spec, SLO_70B,
                              SimConfig(split_phase=True), scfg, pol,
                              spot=market)
    assert res.preempted_workers > 0 and res.requeued > 0
    assert res.finished == res.total
    for r in trace:
        assert r.t_preempted is None
        assert r.l_out == r.l_real
        # ATGT = t_decode_spent / (l_real - 1) must stay physical: a
        # double-charged stall would make it exceed total wall time
        if r.t_finish is not None and r.l_real > 1:
            assert r.t_decode_spent <= (r.t_finish - r.arrival) + 1e-9


def test_spot_epochs_report_the_mix(spec):
    res, _ = _spot_run(spec, EVENTS, spot_frac=0.6)
    assert any(e.target_spot > 0 for e in res.epochs)
    assert any(e.online_spot > 0 for e in res.epochs)
    assert res.spot_gpu_seconds > 0.0
    assert res.spot_gpu_seconds < res.gpu_seconds


def test_zero_hazard_spot_pool_reproduces_on_demand_bit_for_bit(spec):
    """An undiscounted, never-reclaimed spot pool is on-demand capacity by
    another name: the spot machinery (split, class-aware booting, priced
    billing) must change nothing at all."""
    period, duration = 120.0, 240.0
    scfg = ScaleSimConfig(interval=5.0, provision_delay=10.0,
                          initial_workers=3)

    def run(spot, mix):
        fc = SeasonalNaiveForecaster(ForecastConfig(period=period,
                                                    bin_width=5.0))
        pol = ForecastPolicy(scfg, fc, spot_mix=mix)
        trace = diurnal_trace(_wcfg(), amplitude=0.6, period=period)
        return simulate_autoscaled(trace, spec, SLO_70B, SimConfig(), scfg,
                                   pol, spot=spot)

    base = run(None, None)
    twin_spec = dataclasses.replace(spec, name=f"{spec.name}-spot")
    twin = run(SpotMarket(twin_spec, events=[]),
               SpotMixConfig(discount=1.0, hazard=0.0, spot_frac=0.5))
    assert twin.row() == base.row()


def test_spot_mix_cheaper_than_on_demand_at_target(spec):
    """The PR's claim in miniature: on a diurnal trace with a live spot
    market, the mix attains the target at strictly lower billed cost."""
    duration, period = 300.0, 150.0
    hazard = 1.0 / 600.0
    events = preemption_trace(duration, event_rate=hazard / 0.25, frac=0.25,
                              seed=13)
    spot_res, _ = _spot_run(spec, events, hazard=hazard, duration=duration,
                            period=period)
    scfg = ScaleSimConfig(interval=5.0, provision_delay=10.0,
                          initial_workers=3)
    fc = SeasonalNaiveForecaster(ForecastConfig(period=period, bin_width=5.0))
    od_res = simulate_autoscaled(
        diurnal_trace(_wcfg(duration=duration), amplitude=0.6, period=period),
        spec, SLO_70B, SimConfig(), scfg, ForecastPolicy(scfg, fc))
    assert spot_res.attainment >= 0.99
    assert spot_res.gpu_seconds < od_res.gpu_seconds
    assert spot_res.finished == spot_res.total


# ---- disaggregated pool-ratio search -----------------------------------------

def test_ratio_pool_fn_counts_and_cost_are_monotone():
    a = make_worker_spec(ARCH, A100_80G, SLO_70B, mean_context=450.0)
    v = make_worker_spec(ARCH, V100_32G, SLO_70B, n_g=8, mean_context=450.0)
    for ratio in (0.0, 0.3, 0.5, 0.75, 1.0):
        fn = ratio_pool_fn([a, v], ratio)
        prev_cost = 0.0
        for n in range(1, 12):
            pools = fn(n)
            assert sum(k for _, k in pools) == n
            cost = pool_cost(pools)
            assert cost >= prev_cost
            prev_cost = cost


def test_ratio_pool_fn_single_spec_ignores_ratio():
    a = make_worker_spec(ARCH, A100_80G, SLO_70B, mean_context=450.0)
    assert ratio_pool_fn([a], 0.3)(4) == [(a, 4)]
    with pytest.raises(ValueError):
        ratio_pool_fn([a, a, a], 0.5)


def test_min_cost_disagg_ratio_search_never_worse_than_fixed_ratio():
    a = make_worker_spec(ARCH, A100_80G, SLO_70B, mean_context=450.0)
    v = make_worker_spec(ARCH, V100_32G, SLO_70B, n_g=8, mean_context=450.0)
    wcfg = WorkloadConfig(mean_rate=1.5, duration=8.0, seed=3, in_mu=5.0,
                          in_sigma=1.1, out_mu=5.3, out_sigma=0.9)

    from repro.serving import generate_trace
    trace_fn = lambda: generate_trace(wcfg)   # noqa: E731
    kw = dict(attain_target=0.95, max_prefill=2, hi_decode=8)
    fixed = min_cost_disagg(trace_fn, SLO_70B, DisaggConfig(),
                            prefill_pool_fn=ratio_pool_fn([a, v], 0.5),
                            decode_pool_fn=ratio_pool_fn([a, v], 0.5), **kw)
    searched = min_cost_disagg(trace_fn, SLO_70B, DisaggConfig(),
                               prefill_mix=[a, v], decode_mix=[a, v],
                               ratio_grid=(0.5, 1.0), **kw)
    assert searched is not None
    if fixed is not None:
        assert searched.gpu_cost <= fixed.gpu_cost
