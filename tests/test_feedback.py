"""SLO-feedback scaling: the AttainmentController state machine, the
FeedbackScale scenario plumbing, and the policy-space optimize() round trip
(ISSUE 5 tentpole + satellites).

Controller contracts pinned here:
  * deadband hysteresis — a flat attainment trace inside the deadband never
    moves the gain (no oscillation);
  * monotone response — observing *lower* attainment never yields a lower
    gain than observing higher attainment from the same state; in
    particular low attainment never scales the target down;
  * open-loop equivalence — an infinite deadband makes FeedbackScale
    reproduce its open-loop base bit-for-bit through run().
"""
import dataclasses
import math

import pytest

from repro.configs import get_arch
from repro.core import (A100_80G, PAPER_SLOS, AttainmentController,
                        FeedbackConfig, make_worker_spec)
from repro.serving import (Colocated, Disaggregated, FeedbackScale,
                           FleetSpec, Forecast, PoolSpec, Reactive, Scenario,
                           SideOverride, WorkloadConfig,
                           drifting_diurnal_trace, generate_trace, optimize,
                           run)
from repro.serving.api import _build_policy, _scale_cfg
from repro.serving.forecast import FeedbackPolicy, ScaleSimConfig

ARCH = get_arch("llama2-70b")
SLO = PAPER_SLOS["llama2-70b"]
WCFG = WorkloadConfig(mean_rate=3.0, duration=30.0, seed=5, in_mu=5.0,
                      in_sigma=1.1, out_mu=5.3, out_sigma=0.9)


@pytest.fixture(scope="module")
def spec():
    return make_worker_spec(ARCH, A100_80G, SLO, mean_context=450.0)


# ---- controller state machine ------------------------------------------------

def _ctl(**kw) -> AttainmentController:
    return AttainmentController(FeedbackConfig(**kw))


def test_deadband_hysteresis_no_oscillation_on_flat_trace():
    """Attainment sitting anywhere inside the deadband holds the gain at
    exactly 1.0, epoch after epoch."""
    c = _ctl(slo_target=0.99, deadband=0.01)
    for k, att in enumerate([0.99, 0.985, 0.995, 0.99, 0.981] * 10):
        c.observe(float(k), int(att * 1000), 1000)
        assert c.gain == 1.0


def test_deadband_hysteresis_holds_a_raised_gain():
    """After a boost, in-deadband samples neither re-boost nor release —
    the gain parks until the attainment leaves the band."""
    c = _ctl(slo_target=0.99, deadband=0.01, boost=1.5)
    c.observe(0.0, 900, 1000)              # 0.90 < 0.98: attack
    g = c.gain
    assert g == 1.5
    for k in range(20):
        c.observe(100.0 + k, 990, 1000)    # inside the band: hold
        assert c.gain == g


def test_monotone_response_in_attainment():
    """From identical states, a lower observed attainment never produces a
    smaller gain — and low attainment never scales the target down."""
    grid = [i / 100.0 for i in range(80, 101)]
    gains = []
    for att in grid:
        c = _ctl(slo_target=0.99, deadband=0.005, min_gain=0.7)
        c.gain = 1.5                        # a mid-range prior state
        c.observe(1e9, int(round(att * 10000)), 10000)
        gains.append(c.gain)
    for lo_gain, hi_gain in zip(gains, gains[1:]):
        assert lo_gain >= hi_gain
    # low attainment boosts (never shrinks) the applied target
    c = _ctl(slo_target=0.99, deadband=0.005)
    before = c.apply(10)
    c.observe(1e9, 0, 1000)
    assert c.apply(10) >= before


def test_attack_cooldown_rate_limits_boosts():
    """The misses that triggered a boost stay in the window; re-observing
    them within the cooldown must not compound the gain."""
    c = _ctl(slo_target=0.99, deadband=0.005, boost=2.0, window=30.0,
             max_gain=8.0)
    c.observe(0.0, 0, 100)
    assert c.gain == 2.0
    c.observe(5.0, 0, 100)                 # same stale window: no re-boost
    assert c.gain == 2.0
    c.observe(31.0, 0, 100)                # window refreshed: attack again
    assert c.gain == 4.0


def test_min_samples_keeps_controller_inert():
    c = _ctl(slo_target=0.99, min_samples=8)
    c.observe(0.0, 0, 7)                   # too few to judge
    assert c.gain == 1.0


def test_gain_bounds_and_identity_apply():
    c = _ctl(slo_target=0.99, deadband=0.001, boost=10.0, max_gain=2.5,
             decay=1.0, min_gain=0.6, window=1.0)
    c.observe(0.0, 0, 100)
    c.observe(10.0, 0, 100)
    assert c.gain == 2.5                   # capped at max_gain
    for k in range(10):
        c.observe(20.0 + k, 100, 100)
        assert c.gain >= 0.6
    assert c.gain == 0.6                   # floored at min_gain
    c.gain = 1.0
    assert c.apply(7) == 7                 # gain 1.0 is the exact identity


# ---- FeedbackPolicy wrapper --------------------------------------------------

class _ConstPolicy:
    scfg = ScaleSimConfig()
    spot_mix = None

    def target(self, t, rate, needed, queued):
        return 10

    def split(self, t, target):
        return target, 0


def test_feedback_policy_never_scales_down_on_misses():
    pol = FeedbackPolicy(_ConstPolicy(), FeedbackConfig(slo_target=0.99))
    base = pol.target(0.0, 1.0, 1, 0)
    pol.observe_slo(100.0, 0, 100)
    assert pol.target(100.0, 1.0, 1, 0) >= base


def test_feedback_policy_infinite_deadband_is_identity():
    pol = FeedbackPolicy(_ConstPolicy(),
                         FeedbackConfig(deadband=float("inf"), min_gain=0.5))
    for k in range(50):
        pol.observe_slo(float(k * 100), k % 2 * 100, 100)
        assert pol.gain == 1.0
        assert pol.target(float(k), 1.0, 1, 0) == 10


# ---- bit-for-bit open-loop equivalence through run() -------------------------

def _drift_fn(duration=120.0, period=60.0, seed=9):
    wcfg = dataclasses.replace(WCFG, mean_rate=4.0, duration=duration,
                               seed=seed)
    return lambda: drifting_diurnal_trace(wcfg, amplitude=0.6,
                                          period=period, drift=1.0)


@pytest.mark.parametrize("base", [
    Forecast(period=60.0, min_workers=2),
    Reactive(interval=5.0, provision_delay=10.0),
])
def test_infinite_deadband_reproduces_open_loop_colocated(spec, base):
    sc = Scenario(workload=_drift_fn(), fleet=FleetSpec([PoolSpec(spec, 3)]),
                  slo=SLO, topology=Colocated(), scaling=base)
    closed = dataclasses.replace(
        sc, scaling=FeedbackScale(base=base, deadband=float("inf"),
                                  min_gain=0.5))
    r_open, r_closed = run(sc).row(), run(closed).row()
    assert r_closed.pop("scaling") == "feedback"
    r_open.pop("scaling")
    assert r_open == r_closed


def test_infinite_deadband_reproduces_open_loop_disagg(spec):
    base = Forecast(period=60.0, min_workers=2, headroom=1.2,
                    prefill=SideOverride(lead=5.0),
                    decode=SideOverride(lead=20.0))
    sc = Scenario(workload=_drift_fn(),
                  fleet=FleetSpec([PoolSpec(spec, 2, role="prefill"),
                                   PoolSpec(spec, 4, role="decode")]),
                  slo=SLO,
                  topology=Disaggregated(prefill_router="earliest",
                                         decode_router="earliest"),
                  scaling=base)
    closed = dataclasses.replace(
        sc, scaling=FeedbackScale(base=base, deadband=float("inf")))
    r_open, r_closed = run(sc).row(), run(closed).row()
    assert r_closed.pop("scaling") == "feedback"
    r_open.pop("scaling")
    assert r_open == r_closed


def test_feedback_boosts_capacity_under_sustained_misses(spec):
    """An under-provisioned base that misses persistently must end with a
    gain above 1.0 and more capacity than the open loop bought."""
    base = Reactive(interval=5.0, provision_delay=10.0, max_workers=64)
    wcfg = dataclasses.replace(WCFG, mean_rate=8.0, duration=90.0)
    sc = Scenario(workload=lambda: generate_trace(wcfg),
                  fleet=FleetSpec([PoolSpec(spec, 1)]), slo=SLO,
                  scaling=base)
    r_open = run(sc)
    r_fb = run(dataclasses.replace(
        sc, scaling=FeedbackScale(base=base, slo_target=0.99)))
    assert r_fb.peak_workers >= r_open.peak_workers
    assert r_fb.attainment >= r_open.attainment - 1e-9


# ---- per-side resolution -----------------------------------------------------

def test_per_side_metric_and_lead_resolution():
    base = Forecast(interval=4.0, provision_delay=8.0, headroom=1.1,
                    prefill=SideOverride(lead=3.0, window=12.0),
                    decode=SideOverride(lead=25.0, headroom=1.3))
    s = FeedbackScale(base=base, window=40.0)
    scfg_p = _scale_cfg(s, 2, side="prefill")
    scfg_d = _scale_cfg(s, 2, side="decode")
    assert scfg_p.lead == 3.0 and scfg_d.lead == 25.0
    assert scfg_p.headroom == 1.1 and scfg_d.headroom == 1.3
    pol_p = _build_policy(s, scfg_p, None, side="prefill")
    pol_d = _build_policy(s, scfg_d, None, side="decode")
    pol_c = _build_policy(s, _scale_cfg(s, 2), None)
    assert (pol_p.metric, pol_d.metric, pol_c.metric) == ("ttft", "atgt",
                                                          "both")
    assert pol_p.window == 12.0 and pol_d.window == 40.0
    explicit = dataclasses.replace(s, metric="both")
    assert _build_policy(explicit, scfg_p, None, side="prefill").metric \
        == "both"


# ---- policy-space optimize() round trip --------------------------------------

def _roundtrip(scenario, **kw):
    plan = optimize(scenario, **kw)
    assert plan.feasible
    rep = run(plan.scenario)
    assert rep.row() == plan.report.row()
    return plan


def test_optimize_policy_space_roundtrip_colocated_feedback(spec):
    sc = Scenario(workload=_drift_fn(),
                  fleet=FleetSpec([PoolSpec(spec, 3)]), slo=SLO,
                  scaling=FeedbackScale(base=Forecast(period=60.0,
                                                      min_workers=2)))
    plan = _roundtrip(sc, attain_target=0.9,
                      policy_space={"headroom": (0.9, 1.0, 1.2),
                                    "theta": (0.8, 0.9)})
    assert set(plan.params) <= {"headroom", "theta"}
    assert plan.evals >= 4
    assert math.isfinite(plan.cost)


def test_optimize_policy_space_roundtrip_colocated_reactive(spec):
    sc = Scenario(workload=lambda: generate_trace(WCFG),
                  fleet=FleetSpec([PoolSpec(spec, 2)]), slo=SLO,
                  scaling=Reactive(interval=2.0, provision_delay=2.0))
    _roundtrip(sc, attain_target=0.5, policy_space={"headroom": (1.0, 1.2)})


def test_optimize_policy_space_roundtrip_disagg_per_side_leads(spec):
    sc = Scenario(workload=lambda: generate_trace(WCFG),
                  fleet=FleetSpec([PoolSpec(spec, 2, role="prefill"),
                                   PoolSpec(spec, 3, role="decode")]),
                  slo=SLO,
                  topology=Disaggregated(prefill_router="earliest",
                                         decode_router="earliest"),
                  scaling=Forecast(interval=2.0, provision_delay=2.0,
                                   period=10.0, min_workers=2))
    plan = _roundtrip(sc, attain_target=0.5,
                      policy_space={"prefill_lead": (2.0, 4.0),
                                    "decode_lead": (4.0, 8.0)})
    assert set(plan.params) <= {"prefill_lead", "decode_lead"}


def test_optimize_policy_space_materializes_once(spec):
    calls = [0]

    def factory():
        calls[0] += 1
        return generate_trace(WCFG)

    sc = Scenario(workload=factory, fleet=FleetSpec([PoolSpec(spec, 2)]),
                  slo=SLO, scaling=Reactive(interval=2.0,
                                            provision_delay=2.0))
    plan = optimize(sc, attain_target=0.5,
                    policy_space={"headroom": (1.0, 1.2, 1.4)})
    assert calls[0] == 1
    assert plan.evals >= 3


def test_default_policy_space_shape(spec):
    from repro.serving.api import default_policy_space
    colo = Scenario(workload=[], fleet=FleetSpec([PoolSpec(spec, 1)]),
                    slo=SLO, scaling=Forecast())
    space = default_policy_space(colo)
    assert "headroom" in space and "theta" in space
    assert "prefill_lead" not in space and "max_spot_frac" not in space
    disagg = dataclasses.replace(colo, topology=Disaggregated())
    space = default_policy_space(disagg)
    assert "prefill_lead" in space and "decode_lead" in space
