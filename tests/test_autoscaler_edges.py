"""Autoscaler (Eq. 7) edge cases: empty history, constant streams, clamping,
and exact fit recovery — including the O(1) running-sum fit's trim/rebuild
path."""
import numpy as np
import pytest

from repro.core import Autoscaler, AutoscalerConfig


def test_empty_history_falls_back_to_min_workers():
    sc = Autoscaler(AutoscalerConfig(min_workers=3, max_workers=10))
    assert sc.predict_workers(50.0) == 3
    assert sc.k5 is None and sc.c5 is None


def test_last_needed_fallback_below_rate_floor():
    sc = Autoscaler(AutoscalerConfig(min_workers=1, max_workers=100,
                                     headroom=1.5))
    # below the rate floor (no fit yet): most recent requirement + head-room
    assert sc.predict_workers(1.0, last_needed=4) == 6


def test_constant_rate_no_change_point():
    sc = Autoscaler()
    for _ in range(40):
        sc.rates.append(12.0)
    assert not sc.change_point()
    # mild noise around a constant mean must not trigger either
    rng = np.random.default_rng(0)
    sc2 = Autoscaler()
    for _ in range(40):
        sc2.rates.append(12.0 + float(rng.normal(0, 0.2)))
    assert not sc2.change_point()


def test_change_point_on_demand_jump():
    sc = Autoscaler()
    for _ in range(16):
        sc.rates.append(5.0)
    for _ in range(sc.cfg.change_window):
        sc.rates.append(25.0)
    assert sc.change_point()


def test_predict_clamps_to_min_and_max():
    sc = Autoscaler(AutoscalerConfig(min_workers=2, max_workers=8))
    for r in (20.0, 40.0, 60.0, 80.0, 100.0):
        sc.observe(r, int(0.5 * r + 1))
    assert sc.predict_workers(1000.0) == 8     # ceil(501) -> max
    assert sc.predict_workers(11.0) >= 2       # above floor, small fit value
    sc2 = Autoscaler(AutoscalerConfig(min_workers=2, max_workers=8))
    assert sc2.predict_workers(0.0, last_needed=0) == 2   # floor clamp


def test_eq7_exact_recovery_on_linear_data():
    sc = Autoscaler()
    # noiseless y = 0.5 r + 3 at even rates (integer worker counts)
    for r in range(12, 60, 2):
        sc.observe(float(r), int(0.5 * r + 3))
    assert sc.k5 == pytest.approx(0.5, abs=1e-9)
    assert sc.c5 == pytest.approx(3.0, abs=1e-7)
    assert sc.predict_workers(40.0) == 23      # ceil(0.5*40 + 3)


def test_constant_rate_history_keeps_previous_fit():
    """A degenerate design matrix (all rates equal) must not produce a wild
    fit — the previous coefficients are kept."""
    sc = Autoscaler()
    for r in range(12, 28, 2):
        sc.observe(float(r), int(0.5 * r + 3))
    k5, c5 = sc.k5, sc.c5
    sc2 = Autoscaler()
    for _ in range(10):
        sc2.observe(20.0, 13)
    assert sc2.k5 is None or np.isfinite(sc2.k5)
    assert sc.k5 == k5 and sc.c5 == c5


def test_incremental_fit_survives_history_trim():
    sc = Autoscaler()
    rng = np.random.default_rng(1)
    for i in range(5000):                      # crosses the 4096 trim point
        r = float(rng.uniform(12, 80))
        sc.observe(r, int(round(0.5 * r + 3)))
    assert sc.k5 == pytest.approx(0.5, abs=0.02)
    assert sc.c5 == pytest.approx(3.0, abs=1.0)


def test_rates_are_trimmed_with_history():
    """Regression: ``rates`` grew without bound (history was trimmed at
    4096, rates never was)."""
    from repro.core.scaling import HISTORY_MAX
    sc = Autoscaler()
    for i in range(3 * HISTORY_MAX):
        sc.observe(float(i % 50 + 10), 5)
    assert len(sc.rates) <= HISTORY_MAX
    assert len(sc.history) <= HISTORY_MAX
    # trimming must not break change-point detection on the recent window
    for _ in range(sc.cfg.change_window):
        sc.observe(500.0, 100)
    assert sc.change_point()


def test_rate_floor_signature_and_value():
    """Regression: rate_floor() took (sigma_tokens, mean_interval) and
    ignored both; the SEM target is relative so the floor depends only on
    (sem_target, heartbeat)."""
    import inspect
    sc = Autoscaler(AutoscalerConfig(heartbeat=10.0, sem_target=0.1))
    params = inspect.signature(sc.rate_floor).parameters
    assert len(params) == 0, "rate_floor must not take unused arguments"
    # n_min = 1/0.1^2 = 100 samples over a 10 s heartbeat -> 10 req/s
    assert sc.rate_floor() == pytest.approx(10.0)
    sc2 = Autoscaler(AutoscalerConfig(heartbeat=5.0, sem_target=0.2))
    assert sc2.rate_floor() == pytest.approx(5.0)
