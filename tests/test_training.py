"""Training substrate: loss decreases, microbatch equivalence, checkpoint
round-trip + restart determinism, grad compression error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models.model import LM, ExecConfig
from repro.training import (AdamWConfig, DataConfig, TrainConfig,
                            batch_at_step, init_train_state, latest_step,
                            load, make_train_step, save)
from repro.training.optimizer import (compress_int8,
                                      compressed_grads_with_ef,
                                      decompress_int8)
from repro.training.train_step import loss_and_grads


def _setup(microbatches=1, compression=False):
    arch = reduced(get_arch("phi4-mini-3.8b"), n_layers=2, d_model=32,
                   vocab=64, d_ff=64)
    model = LM(arch, exec_cfg=ExecConfig(loss_chunk=8))
    cfg = TrainConfig(adamw=AdamWConfig(lr=1e-2, warmup_steps=2,
                                        total_steps=50),
                      microbatches=microbatches,
                      grad_compression=compression)
    params, opt = init_train_state(model, jax.random.key(0), cfg)
    dcfg = DataConfig(vocab=arch.vocab, seq_len=16, global_batch=4)
    return arch, model, cfg, params, opt, dcfg


def test_loss_decreases():
    arch, model, cfg, params, opt, dcfg = _setup()
    step = jax.jit(make_train_step(model, cfg))
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, batch_at_step(dcfg, i % 2))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::10]
    assert np.isfinite(losses).all()


def test_microbatch_equivalence():
    arch, model, cfg, params, opt, dcfg = _setup()
    batch = batch_at_step(dcfg, 0)
    l1, g1, _ = loss_and_grads(model, params, batch, microbatches=1)
    l2, g2, _ = loss_and_grads(model, params, batch, microbatches=2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-2)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.1, atol=0.02)


def test_checkpoint_roundtrip_and_restart(tmp_path):
    arch, model, cfg, params, opt, dcfg = _setup()
    step = jax.jit(make_train_step(model, cfg))
    for i in range(3):
        params, opt, _ = step(params, opt, batch_at_step(dcfg, i))
    save(str(tmp_path), 3, {"params": params, "opt": opt},
         extra={"data_step": 3})
    # continue 2 more steps
    p2, o2 = params, opt
    for i in range(3, 5):
        p2, o2, m_direct = step(p2, o2, batch_at_step(dcfg, i))
    # restart from checkpoint and replay
    assert latest_step(str(tmp_path)) == 3
    restored, extra = load(str(tmp_path), 3, {"params": params, "opt": opt})
    assert extra["data_step"] == 3
    p3, o3 = restored["params"], restored["opt"]
    for i in range(3, 5):
        p3, o3, m_restart = step(p3, o3, batch_at_step(dcfg, i))
    np.testing.assert_allclose(float(m_direct["loss"]),
                               float(m_restart["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_compression_roundtrip_and_ef():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    q, s = compress_int8(g)
    deq = decompress_int8(q, s)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) * 0.51
    # error feedback: accumulated compressed grads converge to the truth
    grads = {"w": g}
    ef = {"w": jnp.zeros_like(g)}
    acc = jnp.zeros_like(g)
    for _ in range(16):
        cg, ef = compressed_grads_with_ef(grads, ef)
        acc = acc + cg["w"]
    np.testing.assert_allclose(np.asarray(acc / 16), np.asarray(g),
                               atol=float(s) * 0.2)


def test_compressed_training_still_converges():
    arch, model, cfg, params, opt, dcfg = _setup(compression=True)
    step = jax.jit(make_train_step(model, cfg))
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, batch_at_step(dcfg, i % 2))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9


def test_elastic_resharding_load(tmp_path):
    """A checkpoint saved under one sharding loads under another (elastic
    scale-up/down): shardings tree drives jax.device_put on load."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax.sharding import Mesh
    arch, model, cfg, params, opt, dcfg = _setup()
    save(str(tmp_path), 1, {"params": params})
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shardings = {"params": jax.tree.map(
        lambda t: NamedSharding(mesh, P()), params)}
    restored, _ = load(str(tmp_path), 1, {"params": params},
                       shardings=shardings)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(
            restored["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
