"""Causal-time regressions shared by both simulators: no request may be
admitted — let alone prefilled — before its arrival timestamp, and every
result reports the one canonical attainment definition (ok / total)."""
import pytest

from repro.configs import get_arch
from repro.core import (A100_80G, PAPER_SLOS, make_worker_spec,
                        slo_attainment)
from repro.core.request import Request
from repro.serving import (DisaggConfig, SimConfig, WorkloadConfig,
                           generate_trace, simulate, simulate_disaggregated)
from repro.serving.simulator import run_heartbeat_loop

ARCH = get_arch("llama2-70b")
SLO_70B = PAPER_SLOS["llama2-70b"]
WCFG = WorkloadConfig(mean_rate=3.0, duration=15.0, seed=9, in_mu=5.0,
                      in_sigma=1.1, out_mu=5.3, out_sigma=0.9)


@pytest.fixture(scope="module")
def spec():
    return make_worker_spec(ARCH, A100_80G, SLO_70B, mean_context=450.0)


def test_colocated_first_token_never_leads_arrival(spec):
    """Regression: the seed's colocated loop admitted arrivals with
    arrival < t_next at heartbeat start t, stamping first tokens up to one
    heartbeat before the request existed."""
    trace = generate_trace(WCFG)
    res = simulate(trace, spec.perf, SLO_70B, spec.kv_capacity, SimConfig(),
                   n_workers=4)
    assert res.finished == res.total
    for r in trace:
        assert r.t_first_token is not None
        assert r.t_first_token >= r.arrival, \
            f"request {r.id} prefilled {r.arrival - r.t_first_token:.3f}s " \
            "before it arrived"


def test_disagg_first_token_never_leads_arrival(spec):
    trace = generate_trace(WCFG)
    res = simulate_disaggregated(trace, SLO_70B, DisaggConfig(), spec, spec,
                                 n_prefill=2, n_decode=4)
    assert res.finished == res.total
    for r in trace:
        assert r.t_first_token is not None
        assert r.t_first_token >= r.arrival


def test_heartbeat_core_admits_causally():
    """The shared event core itself: admit is called at the first boundary
    at-or-after each arrival, in timestamp order."""
    trace = [Request(l_in=8, l_pred=8, l_real=8, arrival=a)
             for a in (0.0, 0.1, 0.25, 0.6, 0.6, 2.0)]
    admitted = []

    def admit(r):
        admitted.append(r)

    seen = []

    def step(t, t_next, arrived):
        for r in admitted[len(seen):]:
            assert r.arrival <= t + 1e-12
            seen.append(r)

    run_heartbeat_loop(trace, 0.25, admit, step, lambda: True, tail=1.0)
    assert len(admitted) == len(trace)
    assert [r.arrival for r in admitted] == sorted(r.arrival for r in trace)


def test_scenario_api_cells_admit_causally(spec):
    """The Scenario API's new matrix cells run the same causal core: no
    first token may lead its arrival, even through boot delays, market
    reclaims and the re-prefill recovery path (extends the per-simulator
    pins above to the one-engine run() path)."""
    import dataclasses

    from repro.core.worker_config import spot_variant
    from repro.serving import (Disaggregated, FleetSpec, Forecast, PoolSpec,
                               PreemptionEvent, Scenario, SpotMarket, run)
    dspec = dataclasses.replace(spec, max_batch=24)
    market = SpotMarket(
        spot_variant(dspec, price=0.35, preempt_hazard=1.0 / 100.0),
        [PreemptionEvent(t=4.0, frac=0.6), PreemptionEvent(t=9.0, frac=0.6)],
        prefill_spec=spot_variant(spec, price=0.35,
                                  preempt_hazard=1.0 / 200.0),
        prefill_events=[PreemptionEvent(t=6.0, frac=0.5)])
    trace = generate_trace(WCFG)
    rep = run(Scenario(
        workload=trace,
        fleet=FleetSpec([PoolSpec(spec, 2, role="prefill"),
                         PoolSpec(dspec, 4, role="decode")]),
        slo=SLO_70B,
        topology=Disaggregated(heartbeat=0.02, theta=0.7,
                               prefill_router="earliest"),
        scaling=Forecast(interval=2.0, provision_delay=2.0, period=15.0,
                         min_workers=2),
        market=market))
    assert rep.finished == rep.total == len(trace)
    for r in trace:
        assert r.t_first_token is not None
        assert r.t_first_token >= r.arrival
        assert r.t_finish >= r.t_first_token


def test_attainment_is_ok_over_total_everywhere(spec):
    """Both simulators must report the shared ok/total definition — the
    seed encoded ok/finished * finished/total on one side and ok/total on
    the other."""
    trace = generate_trace(WCFG)
    res = simulate(trace, spec.perf, SLO_70B, spec.kv_capacity, SimConfig(),
                   n_workers=4)
    ok = sum(1 for r in trace if r.t_finish is not None
             and r.slo_ok(SLO_70B))
    assert res.attainment == pytest.approx(ok / len(trace))

    trace_d = generate_trace(WCFG)
    res_d = simulate_disaggregated(trace_d, SLO_70B, DisaggConfig(), spec,
                                   spec, n_prefill=2, n_decode=4)
    ok_d = sum(1 for r in trace_d if r.t_finish is not None
               and r.slo_ok(SLO_70B))
    assert res_d.attainment == pytest.approx(ok_d / len(trace_d))


def test_slo_attainment_counts_unfinished_as_misses():
    good = Request(l_in=8, l_pred=8, l_real=8)
    good.t_first_token = 0.1
    good.t_finish = 0.5
    good.t_decode_spent = 0.2
    bad = Request(l_in=8, l_pred=8, l_real=8)
    bad.t_first_token = 99.0            # blown TTFT
    bad.t_finish = 99.5
    slo = PAPER_SLOS["llama2-70b"]
    # two finished (one ok), four offered: attainment = 1/4, not 1/2
    assert slo_attainment([good, bad], 4, slo) == pytest.approx(0.25)
    assert slo_attainment([], 4, slo) == 0.0
    assert slo_attainment([], 0, slo) == 0.0
