"""Property battery for the unified WorkerLifecycle machine (ISSUE 5).

PR 5 collapsed the four condemn/kill/reap copies (FixedPool,
FixedPrefillSide, FixedDecodeSide, forecast.ManagedPool) onto one
``repro.serving.lifecycle.WorkerLifecycle``. This battery hypothesis-fuzzes
reclaim schedules — event times, reclaim fractions, notice windows — and
drives the SAME schedule through all four former call sites, asserting the
machine's invariants hold identically everywhere:

  * token conservation — every offered request finishes with exactly
    ``l_real`` tokens, none generated twice, no dangling reclaim stall;
  * no lost requests — finished == offered on every topology, whatever the
    market kills mid-flight;
  * settlement — every KV-loss requeue is stamped exactly once
    (``sum(preempt_count) == requeued``), a fixed fleet's accelerator cost
    is conserved across kills (live + retired == initial), an unbounded
    notice kills nothing and loses no KV, and decode-side victims are the
    only ones re-crossing the interconnect.

Marked ``slow`` so tier-1 stays fast; hypothesis is a CI-only dependency
(requirements-ci.txt) and the battery skips where it is not installed.
"""
import dataclasses

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.core import A100_80G, PAPER_SLOS, make_worker_spec  # noqa: E402
from repro.core.worker_config import spot_variant  # noqa: E402
from repro.serving import (Colocated, Disaggregated, FixedScale,  # noqa: E402
                           FleetSpec, Forecast, PoolSpec, PreemptionEvent,
                           Scenario, SpotMarket, WorkloadConfig,
                           diurnal_trace, run)

ARCH = get_arch("llama2-70b")
SLO = PAPER_SLOS["llama2-70b"]
SPEC = make_worker_spec(ARCH, A100_80G, SLO, mean_context=450.0)
SPOT = spot_variant(SPEC, price=0.35, preempt_hazard=1.0 / 200.0)
DSPEC = dataclasses.replace(SPEC, max_batch=24)
DSPOT = spot_variant(DSPEC, price=0.35, preempt_hazard=1.0 / 200.0)

events_st = st.lists(
    st.builds(PreemptionEvent,
              t=st.floats(5.0, 35.0, allow_nan=False),
              frac=st.floats(0.2, 1.0, allow_nan=False)),
    min_size=1, max_size=4).map(lambda evs: sorted(evs, key=lambda e: e.t))

notice_st = st.sampled_from([0.0, 8.0, 1e9])
seed_st = st.integers(0, 3)


def _workload(seed: int):
    wcfg = WorkloadConfig(mean_rate=3.0, duration=40.0, seed=seed,
                          in_mu=5.0, in_sigma=1.1, out_mu=5.3, out_sigma=0.9)
    return lambda: diurnal_trace(wcfg, amplitude=0.5, period=20.0)


def _sites(events, notice, seed):
    """The four former condemn/kill/reap call sites, one Scenario each, all
    fed the same reclaim schedule."""
    market = SpotMarket(SPOT, events, notice_s=notice)
    dmarket = SpotMarket(DSPOT, events, notice_s=notice, prefill_spec=SPOT,
                         prefill_events=events)
    wl = _workload(seed)
    return {
        "FixedPool": Scenario(
            workload=wl, fleet=FleetSpec([PoolSpec(SPEC, 2),
                                          PoolSpec(SPOT, 2)]),
            slo=SLO, topology=Colocated(), scaling=FixedScale(),
            market=market, seed=seed),
        "ManagedPool": Scenario(
            workload=wl, fleet=FleetSpec([PoolSpec(SPEC, 3)]),
            slo=SLO, topology=Colocated(),
            scaling=Forecast(period=20.0, min_workers=2),
            market=market, seed=seed),
        "FixedSides": Scenario(
            workload=wl,
            fleet=FleetSpec([PoolSpec(SPEC, 2, role="prefill"),
                             PoolSpec(SPOT, 1, role="prefill"),
                             PoolSpec(DSPEC, 3, role="decode"),
                             PoolSpec(DSPOT, 2, role="decode")]),
            slo=SLO, topology=Disaggregated(), scaling=FixedScale(),
            market=dmarket, seed=seed),
        "ManagedSides": Scenario(
            workload=wl,
            fleet=FleetSpec([PoolSpec(SPEC, 2, role="prefill"),
                             PoolSpec(DSPEC, 4, role="decode")]),
            slo=SLO,
            topology=Disaggregated(prefill_router="earliest",
                                   decode_router="earliest"),
            scaling=Forecast(period=20.0, min_workers=2, headroom=1.2),
            market=dmarket, seed=seed),
    }


def _fleet_cost(fleet: FleetSpec) -> float:
    return sum(p.spec.n_accelerators * p.count for p in fleet.pools)


def _check_invariants(site: str, sc: Scenario, notice: float) -> None:
    trace = sc.materialize()
    rep = run(dataclasses.replace(sc, workload=trace))
    # -- no lost requests, tokens conserved, every stall settled
    assert rep.finished == rep.total == len(trace), site
    for r in trace:
        assert r.t_finish is not None, site
        assert r.l_out == r.l_real, site
        assert r.t_preempted is None, site
    # -- settlement: each requeue stamped exactly once
    assert sum(r.preempt_count for r in trace) == rep.requeued, site
    assert rep.kv_retransfers <= rep.requeued, site
    if notice >= 1e9:
        # an unbounded notice never reaches a deadline: nothing is killed,
        # no KV is ever lost
        assert rep.preempted_workers == 0, site
        assert rep.requeued == 0, site
    if isinstance(sc.scaling, FixedScale):
        assert rep.gpu_seconds == 0.0, site
        if isinstance(sc.topology, Colocated):
            # accelerator-cost conservation across kills: the report prices
            # live plus retired workers, which must equal the declared fleet
            assert rep.gpu_cost == pytest.approx(_fleet_cost(sc.fleet)), site
    else:
        assert rep.gpu_seconds > 0.0, site
        assert rep.spot_gpu_seconds <= rep.gpu_seconds + 1e-9, site


@pytest.mark.slow
@given(events=events_st, notice=notice_st, seed=seed_st)
@settings(max_examples=10, deadline=None)
def test_same_schedule_through_all_four_call_sites(events, notice, seed):
    for site, sc in _sites(events, notice, seed).items():
        _check_invariants(site, sc, notice)


@pytest.mark.slow
@given(events=events_st, seed=seed_st)
@settings(max_examples=6, deadline=None)
def test_notice_monotone_requeues_everywhere(events, seed):
    """Across every call site, a longer notice can only reduce KV-loss
    requeues — draining strictly dominates killing."""
    for site in ("FixedPool", "ManagedPool", "FixedSides", "ManagedSides"):
        requeues = []
        for notice in (0.0, 8.0, 1e9):
            sc = _sites(events, notice, seed)[site]
            rep = run(sc)
            requeues.append(rep.requeued)
        assert requeues[0] >= requeues[1] >= requeues[2] == 0, site
