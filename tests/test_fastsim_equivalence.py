"""The struct-of-arrays engine against its oracle.

``serving.fastsim`` re-implements the colocated fixed-fleet simulation over
numpy arrays; the per-object Python engine stays the semantic oracle. These
tests pin **bit-for-bit** equality — identical per-request
``(t_first_token, t_finish, l_out, t_decode_spent)`` and identical
``RunReport.row()`` — across a policy x KV-pressure x heavy-tail grid,
including preemption/resume churn and heterogeneous fleets (the same idiom
``test_shim_goldens.py`` uses to pin the legacy shims).

The vectorized grid also covers the pooled envelope: policy-scaled fleets
(``Reactive``/``Forecast``/``FeedbackScale``), spot markets with and
without reclaim notice, and KV-pressure churn colliding with scale-downs —
all still bit-for-bit against the reference.

The jax engine (``serving.fastsim_jax``) compiles the same semantics; its
grid runs under ``importorskip`` and allows last-ulp drift (XLA may fuse
multiply-add chains), with integer outputs still exact. po2 on jax draws
its two candidates from the jax PRNG rather than the reference's numpy
Generator, so those cells pin determinism (same seed -> same rows) and
coarse agreement instead of equality.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.perf_model import (DecodeModel, KVModel, PerfModel,
                                   PrefillModel)
from repro.core.request import Request
from repro.core.slo import SLO
from repro.core.worker_config import WorkerSpec, spot_variant
from repro.serving import api
from repro.serving.tenants import materialize_tenants
from repro.serving.workload import (WorkloadConfig, clone_trace,
                                    generate_trace, preemption_trace)

SLO_GRID = SLO(ttft=2.0, atgt=0.2)


def _spec(kv: str) -> WorkerSpec:
    if kv == "tight":
        kvm, cap = KVModel(h=1.0, j=16.0), 6000.0
    elif kv == "crush":
        # overflow mid-decode: constant preempt/resume churn
        kvm, cap = KVModel(h=1.0, j=8.0), 2500.0
    else:
        kvm, cap = KVModel(h=0.0, j=0.0), 1e18
    perf = PerfModel(kv=kvm,
                     prefill=PrefillModel(k1=2.2e-5, c1=8e-3),
                     decode=DecodeModel(k2=6e-6, c2=3.5e-4, c3=9e-3))
    return WorkerSpec(perf=perf, kv_capacity=cap, max_batch=24,
                      n_accelerators=2, name=f"eq-{kv}")


def _scenario(trace, pools, policy, engine, seed=0):
    return api.Scenario(
        workload=trace, fleet=api.FleetSpec(pools), slo=SLO_GRID,
        topology=api.Colocated(policy=policy), scaling=api.FixedScale(),
        seed=seed, engine=engine)


def _run_both(trace, pools, policy, seed=0, engine="vectorized"):
    ref_t, vec_t = clone_trace(trace), clone_trace(trace)
    ref = api.run(_scenario(ref_t, pools, policy, "reference", seed))
    vec = api.run(_scenario(vec_t, pools, policy, engine, seed))
    return ref, vec, ref_t, vec_t


def _assert_bitwise(ref, vec, ref_t, vec_t):
    key = lambda r: r.arrival
    for a, b in zip(sorted(ref_t, key=key), sorted(vec_t, key=key)):
        assert a.t_first_token == b.t_first_token
        assert a.t_finish == b.t_finish
        assert a.l_out == b.l_out
        assert a.t_decode_spent == b.t_decode_spent
    ra, va = ref.row(), vec.row()
    for k in ra:
        if isinstance(ra[k], float) and np.isnan(ra[k]):
            assert np.isnan(va[k])
        else:
            assert ra[k] == va[k], k


@pytest.mark.parametrize("policy", ["aladdin", "jsq", "po2"])
@pytest.mark.parametrize("kv", ["tight", "loose"])
def test_grid_policy_x_kv_x_tail(policy, kv):
    trace = generate_trace(WorkloadConfig(
        mean_rate=3.0, duration=20.0, seed=11, tail_frac=0.3,
        in_mu=4.6, out_mu=4.4, out_sigma=1.0))
    ref, vec, ref_t, vec_t = _run_both(trace, [api.PoolSpec(_spec(kv), 2)],
                                       policy)
    assert ref.finished > 0
    _assert_bitwise(ref, vec, ref_t, vec_t)


@pytest.mark.parametrize("policy", ["aladdin", "jsq", "po2"])
def test_preemption_resume_churn(policy):
    # KV crush: hundreds of mid-decode preemptions and resumed victims
    trace = generate_trace(WorkloadConfig(
        mean_rate=4.0, duration=25.0, seed=3, tail_frac=0.25,
        in_mu=5.0, out_mu=4.8, out_sigma=1.1))
    ref, vec, ref_t, vec_t = _run_both(trace,
                                       [api.PoolSpec(_spec("crush"), 2)],
                                       policy)
    _assert_bitwise(ref, vec, ref_t, vec_t)


def test_heterogeneous_fleet():
    trace = generate_trace(WorkloadConfig(
        mean_rate=4.0, duration=25.0, seed=2, tail_frac=0.25,
        in_mu=5.0, out_mu=4.8, out_sigma=1.1))
    big = dataclasses.replace(
        _spec("tight"), kv_capacity=9000.0, max_batch=32, n_accelerators=4,
        perf=PerfModel(kv=KVModel(h=0.5, j=4.0),
                       prefill=PrefillModel(k1=1.1e-5, c1=5e-3),
                       decode=DecodeModel(k2=3e-6, c2=2.0e-4, c3=6e-3)))
    pools = [api.PoolSpec(_spec("crush"), 1), api.PoolSpec(big, 2)]
    for policy in ("aladdin", "jsq", "po2"):
        ref, vec, ref_t, vec_t = _run_both(trace, pools, policy)
        _assert_bitwise(ref, vec, ref_t, vec_t)


def test_congestion_with_unplaced_tail():
    # rate far above capacity: the queue backs up and some requests never
    # finish — exercises the still-queued FIFO path and the drain rule
    trace = generate_trace(WorkloadConfig(
        mean_rate=12.0, duration=12.0, seed=7, tail_frac=0.4,
        in_mu=5.4, out_mu=5.0))
    for policy in ("aladdin", "jsq"):
        ref_t, vec_t = clone_trace(trace), clone_trace(trace)
        slo = SLO(ttft=0.5, atgt=0.05)
        mk = lambda tr, eng: dataclasses.replace(
            _scenario(tr, [api.PoolSpec(_spec("crush"), 1)], policy, eng),
            slo=slo)
        ref = api.run(mk(ref_t, "reference"))
        vec = api.run(mk(vec_t, "vectorized"))
        assert ref.finished < ref.total
        _assert_bitwise(ref, vec, ref_t, vec_t)


@pytest.mark.parametrize("kv", ["tight", "loose"])
def test_zero_request_trace(kv):
    # empty-trace beat loop: the engines must agree on an immediate drain
    # with nan attainment rather than crash or spin
    for policy in ("aladdin", "jsq", "po2"):
        ref, vec, ref_t, vec_t = _run_both(
            [], [api.PoolSpec(_spec(kv), 2)], policy)
        assert ref.total == vec.total == 0
        assert ref.finished == vec.finished == 0
        _assert_bitwise(ref, vec, ref_t, vec_t)


@pytest.mark.parametrize("arrival", [0.0, 1.7])
def test_single_request_trace(arrival):
    # one arrival exercises the event-skip path (the whole horizon after
    # the lone prefill/decode is arrival-free) and the drain rule
    for policy in ("aladdin", "jsq", "po2"):
        trace = [Request(l_in=96, l_pred=0, l_real=40, arrival=arrival)]
        ref, vec, ref_t, vec_t = _run_both(
            trace, [api.PoolSpec(_spec("tight"), 2)], policy)
        assert ref.finished == vec.finished == 1
        assert vec_t[0].t_first_token is not None
        assert vec_t[0].t_first_token >= arrival
        _assert_bitwise(ref, vec, ref_t, vec_t)


def test_optimize_parity_and_batched_evaluation():
    trace = generate_trace(WorkloadConfig(mean_rate=6.0, duration=30.0,
                                          seed=3))
    slo = SLO(ttft=1.0, atgt=0.1)
    plans = {}
    for eng in ("reference", "vectorized"):
        sc = api.Scenario(
            workload=trace, fleet=api.FleetSpec(
                [api.PoolSpec(_spec("tight"), 1)]),
            slo=slo, topology=api.Colocated(policy="aladdin"),
            scaling=api.FixedScale(), engine=eng)
        plans[eng] = api.optimize(sc, attain_target=0.95, lo=1, hi=16)
    assert plans["reference"].n_workers == plans["vectorized"].n_workers
    assert plans["reference"].report.row() \
        == plans["vectorized"].report.row()
    # the multisection probe evaluates whole candidate brackets at once
    assert plans["vectorized"].evals >= plans["reference"].evals


def test_envelope_rejects_unsupported_features():
    trace = generate_trace(WorkloadConfig(mean_rate=2.0, duration=5.0))
    fleet = api.FleetSpec([api.PoolSpec(_spec("loose"), 1)])
    base = api.Scenario(workload=trace, fleet=fleet, slo=SLO_GRID,
                        engine="vectorized")
    with pytest.raises(ValueError, match="split_phase"):
        api.run(dataclasses.replace(
            base, topology=api.Colocated(split_phase=True)))
    with pytest.raises(ValueError, match="predictor"):
        api.run(dataclasses.replace(base, predictor=object()))
    with pytest.raises(ValueError, match="observer"):
        api.run(dataclasses.replace(base, observer=object()))
    with pytest.raises(ValueError, match="prefill_spec"):
        api.run(dataclasses.replace(
            base, market=api.SpotMarket(_spec("loose"), [],
                                        prefill_spec=_spec("loose"))))
    with pytest.raises(ValueError, match="elastic"):
        api.run(dataclasses.replace(
            base, fleet=api.FleetSpec([api.PoolSpec(_spec("loose"), 0)])))
    with pytest.raises(ValueError, match="Colocated"):
        api.run(dataclasses.replace(base, topology=api.Disaggregated()))
    with pytest.raises(ValueError, match="unknown engine"):
        api.run(dataclasses.replace(base, engine="warp"))


# ---- the pooled envelope: policy-scaled fleets, markets, KV collisions -------


SCALINGS = {
    "reactive": lambda: api.Reactive(interval=5.0, min_workers=2),
    "forecast": lambda: api.Forecast(period=30.0, min_workers=2),
    "feedback": lambda: api.FeedbackScale(
        base=api.Forecast(period=30.0, min_workers=2),
        min_gain=0.85, max_gain=1.3, boost=1.2, decay=0.02, window=20.0),
}


def _pooled_trace(seed=21, rate=3.0):
    return generate_trace(WorkloadConfig(
        mean_rate=rate, duration=30.0, seed=seed, tail_frac=0.3,
        in_mu=4.6, out_mu=4.4, out_sigma=1.0))


def _mk_pooled(trace, scaling, engine, *, policy="aladdin", market=None,
               spec=None, n=3, seed=0):
    sp = spec if spec is not None else _spec("tight")
    return api.Scenario(
        workload=trace, fleet=api.FleetSpec([api.PoolSpec(sp, n)]),
        slo=SLO_GRID, topology=api.Colocated(policy=policy),
        scaling=scaling, market=market, seed=seed, engine=engine)


@pytest.mark.parametrize("scaling", sorted(SCALINGS))
def test_policy_scaled_fleet_matches_reference(scaling):
    trace = _pooled_trace()
    ref_t, vec_t = clone_trace(trace), clone_trace(trace)
    ref = api.run(_mk_pooled(ref_t, SCALINGS[scaling](), "reference"))
    vec = api.run(_mk_pooled(vec_t, SCALINGS[scaling](), "vectorized"))
    assert ref.finished > 0
    assert ref.epochs and ref.epochs.get("serve")
    _assert_bitwise(ref, vec, ref_t, vec_t)


def test_spot_market_reclaims_match_reference():
    trace = _pooled_trace(seed=5)
    events = preemption_trace(30.0, event_rate=1.0 / 8.0, frac=0.5, seed=13)
    sspec = spot_variant(_spec("tight"), price=0.35,
                         preempt_hazard=1.0 / 60.0)
    churn = 0
    for scaling, notice in ((api.FixedScale(), 0.0),
                            (api.FixedScale(), 4.0),
                            (api.Reactive(interval=5.0, min_workers=2),
                             0.0)):
        market = api.SpotMarket(sspec, events, notice_s=notice)
        ref_t, vec_t = clone_trace(trace), clone_trace(trace)
        ref = api.run(_mk_pooled(ref_t, scaling, "reference",
                                 market=market, spec=sspec))
        vec = api.run(_mk_pooled(vec_t, scaling, "vectorized",
                                 market=market, spec=sspec))
        churn += ref.preempted_workers + ref.drained_ok
        _assert_bitwise(ref, vec, ref_t, vec_t)
    assert churn > 0        # the reclaim machinery actually fired


def test_kv_pressure_scale_down_collision():
    # the chaos cell: a KV-crushed spec preempts rows mid-decode on the
    # same beats Reactive scale-downs drain lanes and market events
    # reclaim them — placement, lifecycle and KV paging interleave
    trace = _pooled_trace(seed=9, rate=5.0)
    events = preemption_trace(30.0, event_rate=1.0 / 6.0, frac=0.4,
                              seed=2)
    sspec = spot_variant(_spec("crush"), price=0.35,
                         preempt_hazard=1.0 / 60.0)
    scaling = api.Reactive(interval=4.0, min_workers=1, max_workers=5)
    market = api.SpotMarket(sspec, events)
    ref_t, vec_t = clone_trace(trace), clone_trace(trace)
    ref = api.run(_mk_pooled(ref_t, scaling, "reference", market=market,
                             spec=sspec))
    vec = api.run(_mk_pooled(vec_t, scaling, "vectorized", market=market,
                             spec=sspec))
    assert ref.preempted_workers > 0        # market churn fired
    assert any(r.t_first_token is not None and r.t_first_token
               - r.arrival > SLO_GRID.ttft for r in ref_t)   # KV backlog
    _assert_bitwise(ref, vec, ref_t, vec_t)


# ---- the compiled engine (importorskip: CI images without jax skip) ----------


def _jax_spec() -> WorkerSpec:
    # inert KV (h == j == 0): the legacy whole-trace kernel's fast path
    perf = PerfModel(kv=KVModel(h=0.0, j=0.0),
                     prefill=PrefillModel(k1=2.2e-5, c1=8e-3),
                     decode=DecodeModel(k2=6e-6, c2=3.5e-4, c3=9e-3))
    return WorkerSpec(perf=perf, kv_capacity=1e18, max_batch=24,
                      n_accelerators=2, name="eq-jax")


@pytest.mark.parametrize("policy", ["aladdin", "jsq"])
def test_jax_engine_matches_reference(policy):
    pytest.importorskip("jax")
    trace = generate_trace(WorkloadConfig(
        mean_rate=3.0, duration=20.0, seed=11, tail_frac=0.3,
        in_mu=4.6, out_mu=4.4, out_sigma=1.0))
    pools = [api.PoolSpec(_jax_spec(), 2)]
    ref, jx, ref_t, jx_t = _run_both(trace, pools, policy, engine="jax")
    key = lambda r: r.arrival
    for a, b in zip(sorted(ref_t, key=key), sorted(jx_t, key=key)):
        # integers exact; floats to the last few ulps (XLA may contract)
        assert a.l_out == b.l_out
        assert (a.t_finish is None) == (b.t_finish is None)
        if a.t_first_token is not None:
            assert b.t_first_token == pytest.approx(a.t_first_token,
                                                    rel=1e-12)
        if a.t_finish is not None:
            assert b.t_finish == pytest.approx(a.t_finish, rel=1e-12)
            assert b.t_decode_spent == pytest.approx(a.t_decode_spent,
                                                     rel=1e-12)
    assert jx.finished == ref.finished
    assert jx.attainment == pytest.approx(ref.attainment)
    assert jx.p99_atgt == pytest.approx(ref.p99_atgt, rel=1e-9)
    assert jx.p99_ttft == pytest.approx(ref.p99_ttft, rel=1e-9)


@pytest.mark.parametrize("n_req", [0, 1])
def test_jax_engine_edge_traces(n_req):
    # the compiled beat loop on an empty trace (drain on the first beat)
    # and a lone arrival (the event skipper covers the whole tail gap)
    pytest.importorskip("jax")
    trace = [Request(l_in=96, l_pred=0, l_real=40, arrival=0.4)][:n_req]
    for policy in ("aladdin", "jsq"):
        ref, jx, ref_t, jx_t = _run_both(
            trace, [api.PoolSpec(_jax_spec(), 2)], policy, engine="jax")
        assert jx.total == ref.total == n_req
        assert jx.finished == ref.finished == n_req
        if n_req:
            assert jx_t[0].l_out == ref_t[0].l_out
            assert jx_t[0].t_finish == pytest.approx(ref_t[0].t_finish,
                                                     rel=1e-12)


def test_jax_candidate_batch_matches_singles():
    pytest.importorskip("jax")
    from repro.serving import fastsim_jax
    trace = generate_trace(WorkloadConfig(mean_rate=6.0, duration=15.0,
                                          seed=5))
    slo = SLO(ttft=1.0, atgt=0.1)
    scs = [api.Scenario(
        workload=clone_trace(trace),
        fleet=api.FleetSpec([api.PoolSpec(_jax_spec(), n)]), slo=slo,
        topology=api.Colocated(policy="aladdin"),
        scaling=api.FixedScale(), engine="jax") for n in (2, 4, 6)]
    batch = fastsim_jax.run_candidate_batch(scs)
    for sc, rep in zip(scs, batch):
        single = api.run(dataclasses.replace(
            sc, workload=clone_trace(trace)))
        assert rep.finished == single.finished
        assert rep.attainment == pytest.approx(single.attainment)
        assert rep.p99_atgt == pytest.approx(single.p99_atgt, rel=1e-9)


def _assert_close_report(ref, jx, rel=1e-9):
    ra, ja = ref.row(), jx.row()
    for k in ra:
        if isinstance(ra[k], float):
            if np.isnan(ra[k]):
                assert np.isnan(ja[k]), k
            else:
                assert ja[k] == pytest.approx(ra[k], rel=rel, abs=1e-12), k
        else:
            assert ra[k] == ja[k], k


@pytest.mark.parametrize("scaling", sorted(SCALINGS))
def test_jax_policy_scaled_fleet(scaling):
    # the chunked kernel + host pool driver against the reference: lane
    # activation masks, epoch replay, KV paging — tolerance-pinned (XLA
    # may contract multiply-adds), integer counters exact
    pytest.importorskip("jax")
    trace = _pooled_trace()
    ref_t, jx_t = clone_trace(trace), clone_trace(trace)
    ref = api.run(_mk_pooled(ref_t, SCALINGS[scaling](), "reference"))
    jx = api.run(_mk_pooled(jx_t, SCALINGS[scaling](), "jax"))
    _assert_close_report(ref, jx)
    key = lambda r: r.arrival
    for a, b in zip(sorted(ref_t, key=key), sorted(jx_t, key=key)):
        assert a.l_out == b.l_out
        assert (a.t_finish is None) == (b.t_finish is None)
        if a.t_finish is not None:
            assert b.t_finish == pytest.approx(a.t_finish, rel=1e-9)


def test_jax_spot_and_kv_collision():
    # fixed spot fleet with reclaim notice, then the chaos cell (KV
    # pressure + scale-down + reclaim on shared beats) on the compiled core
    pytest.importorskip("jax")
    trace = _pooled_trace(seed=5)
    events = preemption_trace(30.0, event_rate=1.0 / 8.0, frac=0.5, seed=13)
    sspec = spot_variant(_spec("tight"), price=0.35,
                         preempt_hazard=1.0 / 60.0)
    market = api.SpotMarket(sspec, events, notice_s=4.0)
    ref_t, jx_t = clone_trace(trace), clone_trace(trace)
    ref = api.run(_mk_pooled(ref_t, api.FixedScale(), "reference",
                             market=market, spec=sspec))
    jx = api.run(_mk_pooled(jx_t, api.FixedScale(), "jax",
                            market=market, spec=sspec))
    _assert_close_report(ref, jx)

    chaos = _pooled_trace(seed=9, rate=5.0)
    cspec = spot_variant(_spec("crush"), price=0.35,
                         preempt_hazard=1.0 / 60.0)
    scaling = api.Reactive(interval=4.0, min_workers=1, max_workers=5)
    cmarket = api.SpotMarket(cspec, preemption_trace(
        30.0, event_rate=1.0 / 6.0, frac=0.4, seed=2))
    ref_t, jx_t = clone_trace(chaos), clone_trace(chaos)
    ref = api.run(_mk_pooled(ref_t, scaling, "reference", market=cmarket,
                             spec=cspec))
    jx = api.run(_mk_pooled(jx_t, scaling, "jax", market=cmarket,
                            spec=cspec))
    assert ref.preempted_workers > 0
    _assert_close_report(ref, jx)


def test_jax_po2_pooled_deterministic():
    # po2 on jax draws from its own PRNG: pinned as seed-deterministic
    # (identical rows across runs) plus coarse agreement with the reference
    pytest.importorskip("jax")
    trace = _pooled_trace()
    rows, finishes = [], []
    for _ in range(2):
        t = clone_trace(trace)
        rep = api.run(_mk_pooled(
            t, api.Reactive(interval=5.0, min_workers=2), "jax",
            policy="po2"))
        rows.append(rep.row())
        finishes.append([(r.l_out, r.t_first_token, r.t_finish)
                         for r in t])
    assert rows[0] == rows[1]
    assert finishes[0] == finishes[1]
    ref = api.run(_mk_pooled(clone_trace(trace),
                             api.Reactive(interval=5.0, min_workers=2),
                             "reference", policy="po2"))
    assert rows[0]["attainment"] == pytest.approx(ref.attainment, abs=0.15)


def test_jax_policy_candidate_batch_matches_singles():
    # the lockstep-batched theta bracket returns exactly what per-candidate
    # chunked runs return (one vmapped call per round)
    pytest.importorskip("jax")
    from repro.serving import fastsim_jax

    trace = _pooled_trace()

    def mk(theta):
        sc = _mk_pooled(clone_trace(trace),
                        api.Reactive(interval=5.0, min_workers=2), "jax")
        return dataclasses.replace(
            sc, topology=dataclasses.replace(sc.topology, theta=theta))

    thetas = (0.7, 0.85, 1.0)
    batch = fastsim_jax.run_policy_candidate_batch(
        [mk(th) for th in thetas])
    for th, rep in zip(thetas, batch):
        single = fastsim_jax.run_colocated_jax(mk(th))
        assert rep.row() == single.row()


# ---- multi-tenant: EDF admission, tagged constraints, per-tenant rows --------


def _solo_tenants(trace, slo=SLO_GRID):
    # the tenant-form of a scalar scenario: one TenantSpec carrying the
    # scenario SLO, pre-merged so the test holds the simulated requests
    tenants = [api.TenantSpec(name="solo", workload=lambda: trace,
                              slo=slo)]
    return tenants, materialize_tenants(tenants)


def _two_tenants(rate=(2.0, 1.5)):
    chat = api.TenantSpec(
        name="chat",
        workload=lambda: generate_trace(WorkloadConfig(
            mean_rate=rate[0], duration=20.0, seed=17, tail_frac=0.2,
            in_mu=4.6, out_mu=4.2, out_sigma=1.0)),
        slo=SLO(ttft=0.6, atgt=0.060), priority=1, tier="interactive")
    ev = api.TenantSpec(
        name="eval",
        workload=lambda: generate_trace(WorkloadConfig(
            mean_rate=rate[1], duration=20.0, seed=23, tail_frac=0.3,
            in_mu=5.0, out_mu=4.8, out_sigma=1.1)),
        slo=SLO(ttft=5.0, atgt=0.200), priority=0, tier="batch")
    tenants = [chat, ev]
    return tenants, materialize_tenants(tenants)


def _tenant_scenario(merged, tenants, pools, policy, engine):
    # merged workload passed explicitly (clone keeps the tenant stamps)
    # so each engine run mutates a trace the test can inspect
    return api.Scenario(
        workload=merged, fleet=api.FleetSpec(pools), tenants=tenants,
        topology=api.Colocated(policy=policy), scaling=api.FixedScale(),
        engine=engine)


@pytest.mark.parametrize("policy", ["aladdin", "jsq"])
@pytest.mark.parametrize("kv", ["tight", "crush", "loose"])
def test_single_tenant_pin_matches_scalar(policy, kv):
    # Scenario(tenants=[one]) must reproduce the scalar path bit-for-bit:
    # the tagged per-request budgets all equal the planning SLO, so the
    # constraint arithmetic is float-identical even though every request
    # carries finite budgets through the tenant plumbing
    trace = generate_trace(WorkloadConfig(
        mean_rate=3.0, duration=20.0, seed=11, tail_frac=0.3,
        in_mu=4.6, out_mu=4.4, out_sigma=1.0))
    pools = [api.PoolSpec(_spec(kv), 2)]
    tenants, merged = _solo_tenants(trace)
    for engine in ("reference", "vectorized"):
        base_t = clone_trace(trace)
        base = api.run(_scenario(base_t, pools, policy, engine))
        ten_t = clone_trace(merged)
        ten = api.run(_tenant_scenario(ten_t, tenants, pools, policy,
                                       engine))
        _assert_bitwise(base, ten, base_t, ten_t)
        assert len(ten.tenant_rows) == 1
        assert ten.tenant_rows[0]["finished"] == base.finished


@pytest.mark.parametrize("policy", ["aladdin", "jsq"])
def test_single_tenant_pin_jax(policy):
    # the compiled core: the tenant form flips the tagged/EDF static flags
    # off for a single tenant, so the graph — and the floats — are the
    # scalar path's exactly, on both the legacy and the chunked kernel
    pytest.importorskip("jax")
    trace = generate_trace(WorkloadConfig(
        mean_rate=3.0, duration=20.0, seed=11, tail_frac=0.3,
        in_mu=4.6, out_mu=4.4, out_sigma=1.0))
    tenants, merged = _solo_tenants(trace)
    for spec in (_jax_spec(), _spec("tight")):
        pools = [api.PoolSpec(spec, 2)]
        base_t = clone_trace(trace)
        base = api.run(_scenario(base_t, pools, policy, "jax"))
        ten_t = clone_trace(merged)
        ten = api.run(_tenant_scenario(ten_t, tenants, pools, policy,
                                       "jax"))
        _assert_bitwise(base, ten, base_t, ten_t)


@pytest.mark.parametrize("policy", ["aladdin", "jsq", "po2"])
@pytest.mark.parametrize("kv", ["tight", "crush", "loose"])
def test_multi_tenant_vectorized_matches_reference(policy, kv):
    # two tenants with different SLOs and priorities: EDF queue ordering
    # and per-request tagged constraint budgets, still bit-for-bit between
    # the reference loop and the numpy core — per-tenant rows included
    tenants, merged = _two_tenants()
    pools = [api.PoolSpec(_spec(kv), 2)]
    ref_t, vec_t = clone_trace(merged), clone_trace(merged)
    ref = api.run(_tenant_scenario(ref_t, tenants, pools, policy,
                                   "reference"))
    vec = api.run(_tenant_scenario(vec_t, tenants, pools, policy,
                                   "vectorized"))
    assert ref.finished > 0
    _assert_bitwise(ref, vec, ref_t, vec_t)
    assert [r["tenant"] for r in ref.tenant_rows] == ["chat", "eval"]
    for rr, vr in zip(ref.tenant_rows, vec.tenant_rows):
        for k in rr:
            if isinstance(rr[k], float) and np.isnan(rr[k]):
                assert np.isnan(vr[k]), k
            else:
                assert rr[k] == vr[k], k


@pytest.mark.parametrize("policy", ["aladdin", "jsq"])
def test_multi_tenant_jax_matches_reference(policy):
    # the compiled core with the EDF + tagged static flags on, against the
    # reference: the legacy whole-trace kernel (inert KV) and the chunked
    # kernel (live KV) both replay the merged two-tenant trace within the
    # usual last-ulp tolerance, integers exact
    pytest.importorskip("jax")
    from repro.serving import fastsim_jax
    tenants, merged = _two_tenants()
    for spec in (_jax_spec(), _spec("tight")):
        pools = [api.PoolSpec(spec, 2)]
        sc = _tenant_scenario(clone_trace(merged), tenants, pools,
                              policy, "jax")
        want_legacy = spec.perf.kv.h == 0.0
        assert fastsim_jax._legacy_ok(
            api.resolve_scenario(sc),
            [p.spec for p in pools for _ in range(p.count)]) \
            == want_legacy
        ref_t, jx_t = clone_trace(merged), clone_trace(merged)
        ref = api.run(_tenant_scenario(ref_t, tenants, pools, policy,
                                       "reference"))
        jx = api.run(_tenant_scenario(jx_t, tenants, pools, policy,
                                      "jax"))
        key = lambda r: r.arrival
        for a, b in zip(sorted(ref_t, key=key), sorted(jx_t, key=key)):
            assert a.l_out == b.l_out
            assert a.tenant == b.tenant
            assert (a.t_finish is None) == (b.t_finish is None)
            if a.t_first_token is not None:
                assert b.t_first_token == pytest.approx(
                    a.t_first_token, rel=1e-12)
            if a.t_finish is not None:
                assert b.t_finish == pytest.approx(a.t_finish, rel=1e-12)
        _assert_close_report(ref, jx)
        for rr, jr in zip(ref.tenant_rows, jx.tenant_rows):
            for k in rr:
                if isinstance(rr[k], float):
                    if np.isnan(rr[k]):
                        assert np.isnan(jr[k]), k
                    else:
                        assert jr[k] == pytest.approx(rr[k], rel=1e-9), k
                else:
                    assert rr[k] == jr[k], k


def test_multi_tenant_priority_bites():
    # under contention the high-priority interactive tenant must beat the
    # batch tenant's queueing delay — the EDF admission order is not a
    # no-op on a congested fleet
    tenants, merged = _two_tenants(rate=(4.0, 4.0))
    pools = [api.PoolSpec(_spec("tight"), 1)]
    t = clone_trace(merged)
    rep = api.run(_tenant_scenario(t, tenants, pools, "aladdin",
                                   "vectorized"))
    rows = {r["tenant"]: r for r in rep.tenant_rows}
    assert rep.p99_ttft > SLO_GRID.ttft          # fleet is congested
    assert rows["chat"]["mean_queue_delay"] \
        < rows["eval"]["mean_queue_delay"]
