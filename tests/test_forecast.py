"""Forecast-aware scaling: forecaster units, the autoscaled-simulation
driver's invariants, the ramp-peak provisioning property, and the
reactive-vs-forecast cost acceptance on the default diurnal trace."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import A100_80G, PAPER_SLOS, make_worker_spec
from repro.serving import (ForecastConfig, ForecastPolicy, ReactivePolicy,
                           ScaleSimConfig, SeasonalNaiveForecaster,
                           EWMAForecaster, SimConfig, WorkloadConfig,
                           diurnal_rate_fn, diurnal_trace,
                           simulate_autoscaled)

ARCH = get_arch("llama2-70b")
SLO_70B = PAPER_SLOS["llama2-70b"]


@pytest.fixture(scope="module")
def spec():
    return make_worker_spec(ARCH, A100_80G, SLO_70B, mean_context=450.0)


# ---- forecaster units --------------------------------------------------------

def test_seasonal_naive_recalls_last_period():
    fc = SeasonalNaiveForecaster(ForecastConfig(period=100.0, bin_width=10.0,
                                                ewma_alpha=0.5))
    for t in range(0, 100, 10):
        fc.observe(float(t), 10.0 + t / 10.0)   # rates 10..19 over period 1
    # forecasting any phase of period 2 returns the period-1 observation
    assert fc.forecast(100.0) == pytest.approx(10.0)
    assert fc.forecast(100.0, lead=50.0) == pytest.approx(15.0)


def test_seasonal_naive_cold_start_falls_back_to_level():
    fc = SeasonalNaiveForecaster(ForecastConfig(period=100.0, bin_width=10.0))
    assert fc.forecast(0.0) == 0.0              # nothing observed yet
    fc.observe(0.0, 8.0)
    # unseen phase -> EWMA level, seen phase -> seasonal value
    assert fc.forecast(50.0) == pytest.approx(8.0)
    assert fc.forecast(100.0) == pytest.approx(8.0)


def test_seasonal_naive_ewma_residual_tracks_level_shift():
    fc = SeasonalNaiveForecaster(ForecastConfig(period=100.0, bin_width=10.0,
                                                ewma_alpha=1.0))
    for t in range(0, 100, 10):
        fc.observe(float(t), 10.0)
    # period 2 runs 50% hotter; the residual lifts the seasonal forecast
    fc.observe(100.0, 15.0)
    assert fc.forecast(100.0, lead=10.0) == pytest.approx(15.0)


def test_ewma_forecaster_is_lead_invariant():
    fc = EWMAForecaster(alpha=0.5)
    fc.observe(0.0, 4.0)
    fc.observe(5.0, 8.0)
    assert fc.forecast(5.0, lead=0.0) == fc.forecast(5.0, lead=100.0) \
        == pytest.approx(6.0)


# ---- autoscaled driver -------------------------------------------------------

def _wcfg(seed=21, rate=4.0, duration=240.0):
    return WorkloadConfig(mean_rate=rate, duration=duration, seed=seed,
                          in_mu=5.0, in_sigma=1.1, out_mu=5.3, out_sigma=0.9)


def _scfg(**kw):
    base = dict(interval=5.0, provision_delay=10.0, cooldown=60.0,
                initial_workers=3)
    base.update(kw)
    return ScaleSimConfig(**base)


def _run(policy_name, trace, spec, scfg, period):
    if policy_name == "reactive":
        pol = ReactivePolicy(scfg)
    else:
        fc = SeasonalNaiveForecaster(ForecastConfig(period=period,
                                                    bin_width=scfg.interval))
        pol = ForecastPolicy(scfg, fc)
    return simulate_autoscaled(trace, spec, SLO_70B, SimConfig(), scfg, pol)


def test_autoscaled_completes_conserves_and_bills(spec):
    period = 120.0
    scfg = _scfg()
    res = _run("forecast", diurnal_trace(_wcfg(), amplitude=0.6,
                                         period=period), spec, scfg, period)
    assert res.finished == res.total > 0
    assert res.gpu_seconds > 0.0
    assert res.peak_workers >= scfg.initial_workers
    assert len(res.epochs) > 10
    # billed time is at least (workers online at each epoch) * interval
    lower = sum(e.online for e in res.epochs) * scfg.interval \
        * spec.n_accelerators * 0.5
    assert res.gpu_seconds > lower * 0.1


def test_autoscaled_deterministic(spec):
    period = 120.0

    def once():
        res = _run("forecast", diurnal_trace(_wcfg(), amplitude=0.6,
                                             period=period), spec,
                   _scfg(), period)
        return dataclasses.asdict(res)

    assert once() == once()


def test_autoscaled_respects_min_workers(spec):
    period = 120.0
    scfg = _scfg(min_workers=2)
    res = _run("reactive", diurnal_trace(_wcfg(duration=120.0), amplitude=0.6,
                                         period=period), spec, scfg, period)
    for e in res.epochs:
        assert e.online >= scfg.min_workers
        assert e.target >= scfg.min_workers


@pytest.mark.parametrize("seed", [21, 22, 23])
def test_forecast_never_under_provisions_ramp_peak(spec, seed):
    """Property (satellite): on a diurnal trace, the forecast policy never
    provisions fewer workers at the ramp peak than the reactive scaler
    observed it needed at the same phase one period earlier."""
    period, duration = 120.0, 240.0
    wcfg = _wcfg(seed=seed, duration=duration)
    scfg = _scfg()
    reactive = _run("reactive", diurnal_trace(wcfg, amplitude=0.6,
                                              period=period), spec, scfg,
                    period)
    forecast = _run("forecast", diurnal_trace(wcfg, amplitude=0.6,
                                              period=period), spec, scfg,
                    period)
    # ramp peak of the sinusoid is at phase period/4; window +- period/8.
    # Compare phase-by-phase: the forecast target at phase phi in period 2
    # must cover what reactive observed it needed at the same phi in
    # period 1 (the seasonal floor + look-ahead make this structural).
    peak_phase = period / 4.0

    def at_peak(t):
        return abs((t % period) - peak_phase) <= period / 8.0

    needed_p1 = {e.t: e.needed for e in reactive.epochs
                 if e.t < period and at_peak(e.t)}
    checked = 0
    for e in forecast.epochs:
        if not (period <= e.t < 2 * period and at_peak(e.t)):
            continue
        phi = e.t - period
        if phi in needed_p1:
            checked += 1
            assert e.target >= needed_p1[phi], \
                f"phase {phi}: forecast target {e.target} < period-1 " \
                f"need {needed_p1[phi]}"
    assert checked >= 3, "trace must cover the second-period ramp peak"


def test_forecast_beats_reactive_on_default_diurnal(spec):
    """Acceptance: on the default diurnal trace, forecast-aware scaling
    attains >= 0.99 with strictly lower billed GPU-seconds than the
    reactive Eq. 7 scaler."""
    period, duration, rate = 300.0, 600.0, 6.0
    wcfg = _wcfg(seed=21, rate=rate, duration=duration)
    scfg = _scfg(initial_workers=5)
    reactive = _run("reactive", diurnal_trace(wcfg, amplitude=0.6,
                                              period=period), spec, scfg,
                    period)
    forecast = _run("forecast", diurnal_trace(wcfg, amplitude=0.6,
                                              period=period), spec, scfg,
                    period)
    assert forecast.attainment >= 0.99
    assert forecast.gpu_seconds < reactive.gpu_seconds
    assert forecast.finished == forecast.total


def test_diurnal_rate_fn_matches_trace_intensity():
    cfg = WorkloadConfig(mean_rate=10.0, duration=100.0, seed=0)
    fn = diurnal_rate_fn(cfg, amplitude=0.5, period=100.0)
    assert fn(0.0) == pytest.approx(10.0)
    assert fn(25.0) == pytest.approx(15.0)
    assert fn(75.0) == pytest.approx(5.0)
    assert min(fn(t) for t in np.linspace(0, 100, 101)) >= 0.0
