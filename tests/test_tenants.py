"""Multi-tenant serving: TenantSpec validation, trace merging, dedicated
pools, LoRA adapter accounting, the joint placement search, and the
hypothesis-backed behavioral properties of EDF admission (token
conservation, no batch-tier starvation under bounded load, interactive
attainment monotone in priority)."""
import dataclasses

import pytest

from repro.core.perf_model import (DecodeModel, KVModel, PerfModel,
                                   PrefillModel)
from repro.core.request import Request
from repro.core.slo import SLO
from repro.core.worker_config import WorkerSpec
from repro.serving import api
from repro.serving.tenants import (materialize_tenants, planning_slo,
                                   tenant_attainment)
from repro.serving.workload import (WorkloadConfig, clone_trace,
                                    generate_trace, mixture_trace)


def _spec(**over) -> WorkerSpec:
    perf = PerfModel(kv=KVModel(h=0.0, j=0.0),
                     prefill=PrefillModel(k1=2.2e-5, c1=8e-3),
                     decode=DecodeModel(k2=6e-6, c2=3.5e-4, c3=9e-3))
    kw = dict(perf=perf, kv_capacity=1e18, max_batch=24,
              n_accelerators=2, name="mt")
    kw.update(over)
    return WorkerSpec(**kw)


def _wl(seed, rate=2.0, duration=20.0, **over):
    kw = dict(mean_rate=rate, duration=duration, seed=seed, tail_frac=0.2,
              in_mu=4.6, out_mu=4.2, out_sigma=1.0)
    kw.update(over)
    return lambda: generate_trace(WorkloadConfig(**kw))


def _pair(chat_priority=1, chat_rate=2.0, eval_rate=1.5, lora=(None, None),
          duration=20.0):
    return [
        api.TenantSpec(name="chat", workload=_wl(17, chat_rate, duration),
                       slo=SLO(ttft=0.6, atgt=0.060),
                       priority=chat_priority, lora=lora[0],
                       tier="interactive"),
        api.TenantSpec(name="eval", workload=_wl(23, eval_rate, duration),
                       slo=SLO(ttft=5.0, atgt=0.200), priority=0,
                       lora=lora[1], tier="batch"),
    ]


def _sc(tenants, pools, engine="reference", **over):
    kw = dict(fleet=api.FleetSpec(pools), tenants=tenants,
              topology=api.Colocated(policy="aladdin"),
              scaling=api.FixedScale(), engine=engine)
    kw.update(over)
    return api.Scenario(**kw)


# ---- the merge and the planning SLO ------------------------------------------


def test_mixture_trace_stable_tie_break():
    # equal arrivals: lower tenant index first, then within-tenant stream
    # order — the documented total order the engines all replay
    t0 = [Request(l_in=8, l_pred=0, l_real=4, arrival=a)
          for a in (0.5, 1.0, 1.0)]
    t1 = [Request(l_in=8, l_pred=0, l_real=4, arrival=a)
          for a in (1.0, 0.5)]
    merged = mixture_trace([t0, t1])
    assert merged == [t0[0], t1[1], t0[1], t0[2], t1[0]]
    assert [r.tenant for r in merged] == [0, 1, 0, 0, 1]
    # pure reorder: same objects, each exactly once
    assert sorted(map(id, merged)) == sorted(map(id, t0 + t1))


def test_planning_slo_is_strictest_per_axis():
    tens = [api.TenantSpec(name="a", workload=_wl(1),
                           slo=SLO(ttft=0.5, atgt=0.2)),
            api.TenantSpec(name="b", workload=_wl(2),
                           slo=SLO(ttft=2.0, atgt=0.05))]
    assert planning_slo(tens) == SLO(ttft=0.5, atgt=0.05)
    assert planning_slo(tens[:1]) == tens[0].slo


def test_materialize_tenants_stamps_budgets():
    tens = _pair()
    merged = materialize_tenants(tens)
    assert all(r.arrival <= s.arrival for r, s in zip(merged, merged[1:]))
    for r in merged:
        spec = tens[r.tenant]
        assert r.priority == spec.priority
        assert r.slo_ttft == spec.slo.ttft
        assert r.slo_atgt == spec.slo.atgt
        assert r.deadline == r.arrival + spec.slo.ttft
    assert {r.tenant for r in merged} == {0, 1}


# ---- validation --------------------------------------------------------------


def test_tenant_scenario_validation():
    pools = [api.PoolSpec(_spec(), 2)]
    with pytest.raises(ValueError, match="non-empty"):
        api.run(_sc([], pools))
    with pytest.raises(ValueError, match="Colocated"):
        api.run(_sc(_pair(), pools, topology=api.Disaggregated()))
    with pytest.raises(ValueError, match="unique"):
        api.run(_sc([_pair()[0], _pair()[0]], pools, engine="vectorized"))
    with pytest.raises(ValueError, match="tier"):
        bad = dataclasses.replace(_pair()[0], tier="offline")
        api.run(_sc([bad, _pair()[1]], pools, engine="vectorized"))
    with pytest.raises(ValueError, match="positive"):
        bad = dataclasses.replace(_pair()[0], slo=SLO(ttft=0.0, atgt=0.1))
        api.run(_sc([bad, _pair()[1]], pools, engine="vectorized"))
    with pytest.raises(ValueError, match="attain_target"):
        bad = dataclasses.replace(_pair()[0], attain_target=1.5)
        api.run(_sc([bad, _pair()[1]], pools, engine="vectorized"))
    with pytest.raises(ValueError, match="unknown"):
        api.run(_sc(_pair(), [api.PoolSpec(_spec(), 2,
                                           tenants=["nobody"])]))
    with pytest.raises(ValueError, match="Scenario.tenants"):
        api.run(api.Scenario(
            workload=_wl(3), fleet=api.FleetSpec(
                [api.PoolSpec(_spec(), 2, tenants=["chat"])]),
            slo=SLO(ttft=1.0, atgt=0.1), scaling=api.FixedScale()))
    with pytest.raises(ValueError, match="FixedScale"):
        api.run(_sc(_pair(lora=("ad-a", None)), pools,
                    scaling=api.Reactive(interval=5.0, min_workers=1)))


@pytest.mark.parametrize("engine", ["vectorized", "jax"])
def test_compiled_engines_reject_restricted_fleets(engine):
    if engine == "jax":
        pytest.importorskip("jax")
    with pytest.raises(ValueError, match="[Ll]o[Rr][Aa]"):
        api.run(_sc(_pair(lora=("ad-a", None)),
                    [api.PoolSpec(_spec(lora_slots=4), 2)], engine=engine))
    with pytest.raises(ValueError, match="dedicated"):
        api.run(_sc(_pair(), [api.PoolSpec(_spec(), 1, tenants=["chat"]),
                              api.PoolSpec(_spec(), 1)], engine=engine))


# ---- dedicated pools and LoRA residency (reference engine) -------------------


def test_dedicated_pool_fences_placement():
    # a fleet whose only pool is dedicated to chat: eval traffic has no
    # eligible worker and starves; chat is unaffected
    tens = _pair()
    rep = api.run(_sc(tens, [api.PoolSpec(_spec(), 2,
                                          tenants=["chat"])]))
    rows = {r["tenant"]: r for r in rep.tenant_rows}
    assert rows["eval"]["finished"] == 0
    assert rows["chat"]["finished"] == rows["chat"]["total"] > 0
    # give eval its own pool and both classes drain
    rep2 = api.run(_sc(tens, [
        api.PoolSpec(_spec(), 2, tenants=["chat"]),
        api.PoolSpec(_spec(), 2, tenants=["eval"])]))
    rows2 = {r["tenant"]: r for r in rep2.tenant_rows}
    assert rows2["chat"]["finished"] == rows2["chat"]["total"]
    assert rows2["eval"]["finished"] == rows2["eval"]["total"] > 0


def test_lora_fence_and_swap_accounting():
    # two LoRA tenants multiplexed on one single-slot worker: every
    # cross-tenant placement faults the other adapter in (LRU eviction),
    # so swaps well exceed the two cold loads; a two-slot worker loads
    # each adapter exactly once
    tens = _pair(lora=("ad-chat", "ad-eval"), chat_rate=1.5, eval_rate=1.5)
    one_slot = _spec(lora_slots=1, lora_overhead=50.0, lora_swap_s=0.002)
    rep = api.run(_sc(tens, [api.PoolSpec(one_slot, 1)]))
    assert rep.lora_swaps > 2
    assert rep.row()["lora_swaps"] == rep.lora_swaps
    two_slot = _spec(lora_slots=2, lora_overhead=50.0, lora_swap_s=0.002)
    rep2 = api.run(_sc(tens, [api.PoolSpec(two_slot, 1)]))
    assert rep2.lora_swaps == 2
    # adapter-less workers are ineligible for LoRA traffic: a fleet with
    # no slots anywhere starves both tenants
    rep3 = api.run(_sc(tens, [api.PoolSpec(_spec(lora_slots=0), 2)]))
    assert rep3.finished == 0


def test_lora_swap_stall_charges_atgt():
    tens = _pair(lora=("ad-chat", "ad-eval"), chat_rate=1.5, eval_rate=1.5)
    mk = lambda swap_s: _sc(
        tens, [api.PoolSpec(_spec(lora_slots=1, lora_overhead=50.0,
                                  lora_swap_s=swap_s), 1)])
    fast = api.run(mk(0.0))
    slow = api.run(mk(0.05))
    assert slow.mean_atgt > fast.mean_atgt


# ---- the joint placement search ----------------------------------------------


def test_optimize_tenants_joint_search():
    tens = _pair()
    plan = api.optimize(_sc(tens, [api.PoolSpec(_spec(), 1)],
                            engine="vectorized"),
                        attain_target=0.95, lo=1, hi=16)
    assert plan.feasible
    assert plan.n_workers >= 1
    assert plan.cost == plan.report.gpu_cost
    assert "pools" in plan.params          # the winning partition
    rows = {r["tenant"]: r for r in plan.report.tenant_rows}
    assert rows["chat"]["attainment"] >= 0.95
    assert rows["eval"]["attainment"] >= 0.95
    # per-tenant attain_target overrides the blanket target
    tight = [dataclasses.replace(tens[0], attain_target=0.99), tens[1]]
    plan2 = api.optimize(_sc(tight, [api.PoolSpec(_spec(), 1)],
                             engine="vectorized"),
                         attain_target=0.9, lo=1, hi=16)
    assert plan2.feasible
    rows2 = {r["tenant"]: r for r in plan2.report.tenant_rows}
    assert rows2["chat"]["attainment"] >= 0.99


def test_optimize_single_tenant_matches_scalar():
    # tenants=[one] routes through the scalar optimizer: same worker
    # count as the equivalent scalar scenario
    slo = SLO(ttft=1.0, atgt=0.1)
    wl = _wl(3, rate=4.0)
    trace = wl()
    scalar = api.optimize(api.Scenario(
        workload=clone_trace(trace),
        fleet=api.FleetSpec([api.PoolSpec(_spec(), 1)]), slo=slo,
        topology=api.Colocated(policy="aladdin"),
        scaling=api.FixedScale(), engine="vectorized"),
        attain_target=0.95, lo=1, hi=16)
    solo = api.optimize(_sc(
        [api.TenantSpec(name="solo", workload=lambda: clone_trace(trace),
                        slo=slo)],
        [api.PoolSpec(_spec(), 1)], engine="vectorized"),
        attain_target=0.95, lo=1, hi=16)
    assert solo.feasible and scalar.feasible
    assert solo.n_workers == scalar.n_workers


# ---- behavioral properties ---------------------------------------------------
#
# Property-based when hypothesis is installed (derandomized so CI is
# stable); otherwise the same properties run over a fixed seed set — the
# image this repo targets does not ship hypothesis, and the properties
# are worth checking either way.

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


def _property_seeds(fn):
    if HAVE_HYPOTHESIS:
        return settings(max_examples=12, deadline=None, derandomize=True)(
            given(seed=st.integers(min_value=0, max_value=10**6))(fn))
    return pytest.mark.parametrize(
        "seed", [0, 7, 101, 5552, 90210, 424242])(fn)


def _seeded_pair(seed, chat_priority=1, rate=2.0):
    return [
        api.TenantSpec(name="chat", workload=_wl(seed, rate),
                       slo=SLO(ttft=0.6, atgt=0.060),
                       priority=chat_priority),
        api.TenantSpec(name="eval", workload=_wl(seed + 1000, rate),
                       slo=SLO(ttft=5.0, atgt=0.200), priority=0,
                       tier="batch"),
    ]


@_property_seeds
def test_edf_conserves_tokens(seed):
    # the priority/EDF reorder is an ordering, not a scheduler with loss:
    # every request appears once in exactly one terminal state, finished
    # requests generated exactly their ground-truth lengths, and the
    # per-tenant rows partition the fleet totals
    tens = _seeded_pair(seed, rate=3.0)
    merged = materialize_tenants(tens)
    trace = clone_trace(merged)
    rep = api.run(_sc(tens, [api.PoolSpec(_spec(max_batch=8), 1)],
                      engine="vectorized", workload=trace))
    assert rep.total == len(trace)
    for r in trace:
        if r.t_finish is not None:
            assert r.l_out == r.l_real
        else:
            assert 0 <= r.l_out <= r.l_real
    assert sum(row["finished"] for row in rep.tenant_rows) == rep.finished
    assert sum(row["total"] for row in rep.tenant_rows) == rep.total
    assert rep.attainment == pytest.approx(tenant_attainment(trace))


@_property_seeds
def test_batch_tier_not_starved_under_bounded_load(seed):
    # bounded load (fleet capacity comfortably above the offered rate):
    # priority admission must not starve the batch tier — every eval
    # request still finishes
    tens = _seeded_pair(seed, rate=1.5)
    rep = api.run(_sc(tens, [api.PoolSpec(_spec(), 2)],
                      engine="vectorized"))
    rows = {r["tenant"]: r for r in rep.tenant_rows}
    assert rows["eval"]["total"] > 0
    assert rows["eval"]["finished"] == rows["eval"]["total"]


@_property_seeds
def test_interactive_attainment_monotone_in_priority(seed):
    # raising the interactive tenant's priority (all else equal, same
    # arrivals) never hurts its own attainment: priority 2 places chat
    # strictly ahead of priority-0 ties in the EDF order
    def attain(prio):
        tens = _seeded_pair(seed, chat_priority=prio, rate=3.5)
        rep = api.run(_sc(tens, [api.PoolSpec(_spec(max_batch=8), 1)],
                          engine="vectorized"))
        return {r["tenant"]: r["attainment"] for r in rep.tenant_rows}

    lo, hi = attain(0), attain(2)
    assert hi["chat"] >= lo["chat"]
