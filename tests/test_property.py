"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (DecodeModel, KVModel, PerfModel, PlacementConfig,
                        PrefillModel, Request, SLO, WorkerState,
                        best_fit_place)
from repro.core.rebalance import ErrorTracker, rebalance
from repro.distributed.hlo_analysis import shape_bytes
from repro.serving.length_predictor import LengthPredictor

perf_st = st.builds(
    PerfModel,
    kv=st.builds(KVModel, h=st.floats(0.1, 10.0), j=st.floats(0.0, 100.0)),
    prefill=st.builds(PrefillModel, k1=st.floats(1e-6, 1e-3),
                      c1=st.floats(0.0, 0.1)),
    decode=st.builds(DecodeModel, k2=st.floats(1e-8, 1e-5),
                     c2=st.floats(1e-6, 1e-3), c3=st.floats(1e-4, 2e-2)))

req_st = st.builds(Request, l_in=st.integers(1, 2048),
                   l_pred=st.integers(1, 2048))


@given(perf_st, st.integers(1, 256), st.floats(0.02, 0.5))
@settings(max_examples=50, deadline=None)
def test_eq4_budget_inverts_eq3(perf, b, t_dec):
    """Eq. 4 is the exact inversion of Eq. 3: at the returned context budget
    the decode iteration time equals the SLO (when feasible)."""
    c = perf.decode.max_total_context(b, t_dec)
    if c > 0 and np.isfinite(c):
        t = perf.decode(b, c)
        assert t <= t_dec + 1e-6
        assert perf.decode(b, c + 2 / perf.decode.k2 * 1e-3) >= t


@given(st.lists(req_st, min_size=1, max_size=12), perf_st)
@settings(max_examples=30, deadline=None)
def test_placement_respects_all_constraints(reqs, perf):
    """Whatever best-fit does, no worker ends up violating (b)/(e)."""
    cfg = PlacementConfig(gamma=0.5, theta=0.9,
                          kv_capacity=5e5, max_batch=8)
    slo = SLO(ttft=5.0, atgt=0.2)
    n = [0]

    def factory():
        n[0] += 1
        return WorkerState(n[0], cfg, perf, slo)

    workers = []
    for r in reqs:
        best_fit_place(workers, r, new_worker_factory=factory)
    for w in workers:
        assert w.kv_peak() <= cfg.theta * cfg.kv_capacity + 1e-6
        assert w.batch_size <= cfg.max_batch
        budget = perf.decode.max_total_context(w.batch_size, slo.atgt)
        assert w.weighted_context() <= cfg.theta * budget + 1e-6


@given(st.lists(st.tuples(st.integers(1, 2048), st.integers(1, 2048)),
                min_size=20, max_size=200))
@settings(max_examples=20, deadline=None)
def test_predictor_bucket_mean_is_unbiased(pairs):
    p = LengthPredictor()
    for a, b in pairs:
        p.observe(a, b)
    # per bucket, the mean prediction error is ~0 by construction
    errs = []
    for a, b in pairs:
        errs.append(p.predict(a) - b)
    assert abs(np.mean(errs)) <= np.std(errs) + 1.0


@given(st.lists(st.floats(0.0, 1e6), min_size=1, max_size=6),
       st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_rebalance_never_increases_total_error(l_errs, moves):
    perf = PerfModel(decode=DecodeModel(k2=1e-6, c2=1e-4, c3=1e-3))
    cfg = PlacementConfig(kv_capacity=1e9, max_batch=64)
    slo = SLO(5.0, 0.5)
    workers = []
    tracker = ErrorTracker()
    for i, le in enumerate(l_errs):
        w = WorkerState(i, cfg, perf, slo)
        for j in range(2):
            w.place(Request(l_in=100, l_pred=100))
        workers.append(w)
        tracker.l_e[i] = le
        tracker.b_e[i] = 1.0 if le > 0 else 0.0
    k2, c2 = perf.decode.k2, perf.decode.c2
    before = sum(abs(tracker.err(w.id, k2, c2)) for w in workers)
    rebalance(workers, tracker, max_moves=moves)
    # errors tracked in the tracker are unchanged; the *projected* error
    # (after moves) must not exceed the original
    after_proj = 0.0
    for w in workers:
        e = tracker.err(w.id, k2, c2)
        after_proj += abs(e)
    assert after_proj <= before + 1e-9


@given(st.sampled_from(["f32", "bf16", "s32", "u8"]),
       st.lists(st.integers(1, 64), min_size=0, max_size=4))
@settings(max_examples=50, deadline=None)
def test_shape_bytes_parser(dtype, dims):
    s = f"{dtype}[{','.join(map(str, dims))}]"
    n = 1
    for d in dims:
        n *= d
    per = {"f32": 4, "bf16": 2, "s32": 4, "u8": 1}[dtype]
    assert shape_bytes(s) == n * per
    # tuples sum
    assert shape_bytes(f"({s}, {s})") == 2 * n * per


@given(st.integers(1, 10 ** 6), st.integers(0, 10 ** 6))
@settings(max_examples=50, deadline=None)
def test_kv_model_linear(tok, j):
    m = KVModel(h=2.0, j=float(j))
    assert m(tok) == 2.0 * tok + j
