"""Per-architecture smoke tests: reduced same-family configs, one forward /
train / prefill+decode step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_arch, reduced
from repro.models.model import LM, ExecConfig


def _batch_for(arch, b=2, s=16):
    rng = np.random.default_rng(0)
    batch = {"labels": jnp.asarray(rng.integers(0, arch.vocab, (b, s)))}
    if arch.family.value == "audio":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, s, arch.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, arch.vocab, (b, s)))
    if arch.family.value == "vlm":
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((b, arch.n_frontend_tokens, arch.d_model)),
            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def models():
    return {}


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_train_step_smoke(name):
    arch = reduced(get_arch(name))
    model = LM(arch, exec_cfg=ExecConfig(loss_chunk=8, scan_layers=True))
    params = model.init(jax.random.key(0))
    batch = _batch_for(arch)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert np.isfinite(float(loss)), (name, loss)
    assert float(loss) > 0
    # gradients exist and are finite
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat), name


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_prefill_decode_smoke(name):
    arch = reduced(get_arch(name))
    model = LM(arch, exec_cfg=ExecConfig(recent_window=8))
    params = model.init(jax.random.key(1))
    b, s = 2, 16
    batch = _batch_for(arch, b, s)
    logits, cache = jax.jit(lambda p: model.prefill(
        p, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        frontend=batch.get("frontend"), s_max=s + 8))(params)
    assert logits.shape == (b, arch.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    step = jax.jit(model.decode_step)
    for i in range(3):
        logits, cache = step(params, cache, tok)
        assert logits.shape == (b, arch.vocab)
        assert np.all(np.isfinite(np.asarray(logits))), (name, i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_decode_matches_prefill_dense():
    """Teacher-forcing consistency: decoding token t must reproduce the
    prefill logits at position t (dense arch)."""
    arch = reduced(get_arch("granite-3-8b"))
    model = LM(arch, exec_cfg=ExecConfig(recent_window=8))
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(3)
    b, s = 2, 12
    toks = jnp.asarray(rng.integers(0, arch.vocab, (b, s)))
    # full prefill logits at the last position
    logits_full, _ = jax.jit(lambda p, t: model.prefill(p, tokens=t,
                                                        s_max=s + 4))(
        params, toks)
    # prefill on the prefix, then decode the remaining tokens one by one
    cut = 8
    logits, cache = jax.jit(lambda p, t: model.prefill(p, tokens=t,
                                                       s_max=s + 4))(
        params, toks[:, :cut])
    step = jax.jit(model.decode_step)
    for t in range(cut, s):
        logits, cache = step(params, cache, toks[:, t])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_full),
                               rtol=5e-2, atol=1e-1)


def test_decode_matches_prefill_ssm():
    arch = reduced(get_arch("mamba2-1.3b"))
    model = LM(arch, exec_cfg=ExecConfig(recent_window=8))
    params = model.init(jax.random.key(4))
    rng = np.random.default_rng(5)
    b, s, cut = 2, 12, 8
    toks = jnp.asarray(rng.integers(0, arch.vocab, (b, s)))
    logits_full, _ = jax.jit(lambda p, t: model.prefill(p, tokens=t))(
        params, toks)
    logits, cache = jax.jit(lambda p, t: model.prefill(p, tokens=t))(
        params, toks[:, :cut])
    step = jax.jit(model.decode_step)
    for t in range(cut, s):
        logits, cache = step(params, cache, toks[:, t])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_full),
                               rtol=5e-2, atol=1e-1)


def test_flush_preserves_decode():
    """Flushing recent->big must not change subsequent logits."""
    arch = reduced(get_arch("mistral-nemo-12b"))
    model = LM(arch, exec_cfg=ExecConfig(recent_window=8))
    params = model.init(jax.random.key(6))
    rng = np.random.default_rng(7)
    b, s = 2, 8
    toks = jnp.asarray(rng.integers(0, arch.vocab, (b, s)))
    _, cache = jax.jit(lambda p, t: model.prefill(p, tokens=t, s_max=32))(
        params, toks)
    step = jax.jit(model.decode_step)
    tok = jnp.zeros((b,), jnp.int32)
    for _ in range(4):
        l1, cache = step(params, cache, tok)
    flushed = jax.jit(model.maybe_flush)(cache)
    l_a, _ = step(params, cache, tok)
    l_b, _ = step(params, flushed, tok)
    np.testing.assert_allclose(np.asarray(l_a), np.asarray(l_b),
                               rtol=5e-2, atol=1e-1)
