"""`check_bench.py --update` merge semantics: fresh metrics win, but
positive us_per_call canaries survive untimed runs."""
import importlib.util
import json
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

spec = importlib.util.spec_from_file_location(
    "check_bench", REPO_ROOT / "scripts" / "check_bench.py")
check_bench = importlib.util.module_from_spec(spec)
spec.loader.exec_module(check_bench)


def _write(path, rows):
    path.write_text(json.dumps({"rows": rows}))


def test_update_refreshes_metrics_and_timed_canary(tmp_path):
    base_dir = tmp_path / "baselines"
    base_dir.mkdir()
    _write(base_dir / "BENCH_a.json",
           [{"name": "hot_loop", "attainment": 0.99, "gpu_cost": 100.0,
             "us_per_call": 30000.0}])
    _write(tmp_path / "BENCH_a.json",
           [{"name": "hot_loop", "attainment": 0.995, "gpu_cost": 90.0,
             "us_per_call": 25000.0}])
    assert check_bench.update_baselines(tmp_path, base_dir) == 0
    rows = check_bench.load_rows(base_dir / "BENCH_a.json")
    row = rows["hot_loop"]
    assert row["attainment"] == 0.995
    assert row["gpu_cost"] == 90.0
    assert row["us_per_call"] == 25000.0   # timed run refreshes canary


def test_update_keeps_canary_when_fresh_run_untimed(tmp_path):
    base_dir = tmp_path / "baselines"
    base_dir.mkdir()
    _write(base_dir / "BENCH_a.json",
           [{"name": "hot_loop", "attainment": 0.99, "gpu_cost": 100.0,
             "us_per_call": 30000.0}])
    _write(tmp_path / "BENCH_a.json",
           [{"name": "hot_loop", "attainment": 0.98, "gpu_cost": 110.0,
             "us_per_call": 0.0}])
    check_bench.update_baselines(tmp_path, base_dir)
    row = check_bench.load_rows(base_dir / "BENCH_a.json")["hot_loop"]
    assert row["attainment"] == 0.98       # metrics still refreshed
    assert row["us_per_call"] == 30000.0   # canary not zeroed


def test_update_adopts_new_rows_and_new_files(tmp_path):
    base_dir = tmp_path / "baselines"
    base_dir.mkdir()
    _write(base_dir / "BENCH_a.json",
           [{"name": "old", "attainment": 0.9, "gpu_cost": 1.0,
             "us_per_call": 0.0}])
    _write(tmp_path / "BENCH_a.json",
           [{"name": "old", "attainment": 0.9, "gpu_cost": 1.0,
             "us_per_call": 0.0},
            {"name": "new_row", "attainment": 0.95, "gpu_cost": 2.0,
             "us_per_call": 123.0}])
    _write(tmp_path / "BENCH_b.json",
           [{"name": "fresh_file", "attainment": 1.0, "gpu_cost": 3.0,
             "us_per_call": 0.0}])
    check_bench.update_baselines(tmp_path, base_dir)
    a = check_bench.load_rows(base_dir / "BENCH_a.json")
    assert set(a) == {"old", "new_row"}
    assert a["new_row"]["us_per_call"] == 123.0
    b = check_bench.load_rows(base_dir / "BENCH_b.json")
    assert b["fresh_file"]["gpu_cost"] == 3.0


def test_update_leaves_orphan_baseline_untouched(tmp_path, capsys):
    base_dir = tmp_path / "baselines"
    base_dir.mkdir()
    _write(base_dir / "BENCH_orphan.json",
           [{"name": "r", "attainment": 0.5, "gpu_cost": 9.0,
             "us_per_call": 777.0}])
    check_bench.update_baselines(tmp_path, base_dir)
    out = capsys.readouterr().out
    assert "no fresh counterpart" in out
    row = check_bench.load_rows(base_dir / "BENCH_orphan.json")["r"]
    assert row["us_per_call"] == 777.0


def test_gate_still_catches_regressions(tmp_path):
    base = tmp_path / "BENCH_a.base.json"
    fresh = tmp_path / "BENCH_a.json"
    _write(base, [{"name": "r", "attainment": 0.99, "gpu_cost": 100.0,
                   "us_per_call": 1000.0}])
    _write(fresh, [{"name": "r", "attainment": 0.90, "gpu_cost": 150.0,
                    "us_per_call": 2000.0}])
    problems = check_bench.check_file(base, fresh, attain_tol=0.01,
                                      cost_tol=0.10, time_tol=0.25)
    assert len(problems) == 3
