"""Aladdin core: placement constraints, Algorithm 1, Fig. 3, MIP reference."""
import numpy as np
import pytest

from repro.core import (DecodeModel, KVModel, PerfModel, PlacementConfig,
                        PrefillModel, Request, SLO, WorkerState,
                        best_fit_place, exact_min_workers, jsq_place)


def make_perf(kv_h=1.0, kv_j=0.0, k1=1e-4, c1=5e-3, k2=1e-6, c2=1e-3,
              c3=5e-3):
    return PerfModel(kv=KVModel(kv_h, kv_j), prefill=PrefillModel(k1, c1),
                     decode=DecodeModel(k2, c2, c3))


def make_worker(wid=0, kv_capacity=1e9, atgt=0.05, ttft=2.0, gamma=0.5,
                theta=1.0, perf=None, max_batch=512):
    cfg = PlacementConfig(gamma=gamma, theta=theta, kv_capacity=kv_capacity,
                          max_batch=max_batch)
    return WorkerState(wid, cfg, perf or make_perf(), SLO(ttft, atgt))


def test_fig3_example():
    """The principle of the paper's Fig. 3: two long-prompt requests
    (5 in / 2 out) and two long-output requests (2 in / 5 out). Pairing same
    types peaks at 14 KV tokens; mixing prompt+output peaks at 11 (the long
    prompt frees its KV before the long output peaks). With capacity 11,
    Aladdin's (e)-aware best-fit finds the 2-worker mixed placement."""
    perf = make_perf(kv_h=1.0, kv_j=0.0, k2=1e-9, c2=1e-9, c3=0.0)

    def worker_factory(n=[0]):
        n[0] += 1
        return make_worker(wid=n[0], kv_capacity=11.0, atgt=1e9, ttft=1e9,
                           gamma=1.0, perf=perf)

    reqs = [Request(l_in=5, l_pred=2), Request(l_in=5, l_pred=2),
            Request(l_in=2, l_pred=5), Request(l_in=2, l_pred=5)]

    workers = []
    for r in reqs:
        w = best_fit_place(workers, r, new_worker_factory=worker_factory)
        assert w is not None
    assert len(workers) == 2
    for w in workers:
        kinds = sorted(r.l_in for r in w.new_batch)
        assert kinds == [2, 5], "optimal placement mixes prompt/output types"
        assert w.kv_peak() <= 11.0


def test_kv_peak_profile():
    """Peak KV demand accounts for growth-until-finish, not just current."""
    w = make_worker(kv_capacity=100.0)
    r1 = Request(l_in=10, l_pred=5)     # grows to 15
    r2 = Request(l_in=2, l_pred=20)     # grows to 22
    w.place(r1)
    w.place(r2)
    # peak: just before r2 finishes, r1 already gone: kv = 22 ... but while
    # both alive at k=5: (10+5) + (2+5) = 22; max profile = max over events
    peak = w.kv_peak()
    assert peak == pytest.approx(max(15 + 7, 22), abs=1e-6)


def test_constraint_b_blocks_overload():
    perf = make_perf(k2=1e-5, c2=1e-4, c3=1e-3)
    w = make_worker(atgt=0.02, perf=perf, kv_capacity=1e12)
    budget = perf.decode.max_total_context(1, 0.02)
    r = Request(l_in=int(budget * 2), l_pred=10)
    assert not w.feasible([r])
    r2 = Request(l_in=int(budget * 0.2), l_pred=10)
    assert w.feasible([r2])


def test_constraint_c_ttft():
    perf = make_perf(k1=1e-3, c1=0.0)
    w = make_worker(ttft=1.0, perf=perf)
    assert w.feasible([Request(l_in=900, l_pred=1)])
    assert not w.feasible([Request(l_in=1100, l_pred=1)])


def test_constraint_d_preemption_budget():
    """Ongoing requests with little banked slack block big new prefills."""
    perf = make_perf(k1=1e-3, c1=0.0)
    w = make_worker(ttft=10.0, atgt=0.05, theta=1.0, perf=perf,
                    kv_capacity=1e12)
    ongoing = Request(l_in=100, l_pred=50)
    ongoing.l_out = 10
    # ATGT divides by (l_out - 1): banked slack = 0.05*(10-1) - 0.35 = 0.1s
    ongoing.t_decode_spent = 0.35
    w.ongoing.append(ongoing)
    assert w.feasible([Request(l_in=90, l_pred=10)])      # 0.09s prefill
    assert not w.feasible([Request(l_in=200, l_pred=10)])  # 0.2s prefill


def test_best_fit_uses_fewer_workers_than_jsq():
    rng = np.random.default_rng(0)
    perf = make_perf(kv_h=1.0, k2=1e-9, c2=1e-9)

    def factory_gen():
        n = [0]

        def f():
            n[0] += 1
            return make_worker(wid=n[0], kv_capacity=4096.0, atgt=1e9,
                               ttft=1e9, gamma=1.0, perf=perf, max_batch=8)
        return f

    reqs = [Request(l_in=int(rng.integers(50, 500)),
                    l_pred=int(rng.integers(50, 500))) for _ in range(64)]
    w_bf, w_jsq = [], []
    fb, fj = factory_gen(), factory_gen()
    for r in reqs:
        best_fit_place(w_bf, r, new_worker_factory=fb)
    for r in [Request(l_in=r.l_in, l_pred=r.l_pred) for r in reqs]:
        jsq_place(w_jsq, r, new_worker_factory=fj)
    assert len(w_bf) <= len(w_jsq)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_heuristic_near_optimal_vs_mip(seed):
    """Best-fit must stay within +1 worker of the exact MIP optimum."""
    rng = np.random.default_rng(seed)
    perf = make_perf(kv_h=1.0, k2=1e-9, c2=1e-9)

    def mk(i):
        return make_worker(wid=i, kv_capacity=2000.0, atgt=1e9, ttft=1e9,
                           gamma=1.0, perf=perf, max_batch=6)

    reqs = [Request(l_in=int(rng.integers(100, 900)),
                    l_pred=int(rng.integers(50, 400))) for _ in range(9)]
    opt = exact_min_workers([Request(l_in=r.l_in, l_pred=r.l_pred)
                             for r in reqs], mk, max_workers=9)
    assert opt is not None
    workers = []
    n = [100]

    def factory():
        n[0] += 1
        return mk(n[0])
    for r in reqs:
        assert best_fit_place(workers, r, new_worker_factory=factory)
    assert len(workers) <= opt + 1
