"""Regression pins for two silent-accounting bugs.

1. ``workload.generate_trace`` drew a fixed ``rate*duration*1.5`` batch of
   exponential gaps; on unlucky seeds the gaps sum below ``duration`` and the
   trace tail silently vanished — the exact bug class the coverage loop in
   ``nonhomogeneous_trace`` documents and guards against.

2. ``SimWorker.advance_to`` charged prefill stalls to ``w.ongoing`` and
   ``self.preempted`` but not to the KV-overflow victims being *resumed* by
   that very prefill: their ATGT clock stopped for the duration of their own
   re-prefill (recompute semantics say it keeps running), flattering
   attainment under KV pressure.
"""
import pytest

from repro.core.perf_model import (DecodeModel, KVModel, PerfModel,
                                   PrefillModel)
from repro.core.placement import PlacementConfig, WorkerState
from repro.core.request import Request
from repro.core.slo import SLO
from repro.serving.simulator import SimWorker
from repro.serving.workload import WorkloadConfig, generate_trace


def test_generate_trace_covers_full_horizon():
    # seed 37 at rate 0.5 draws 22 gaps summing to 24.76s < 30s: before the
    # coverage loop the window [24.76, 30) was silently empty (22 requests,
    # none after t=24.77). With it the stream extends to the horizon.
    cfg = WorkloadConfig(mean_rate=0.5, duration=30.0, seed=37)
    trace = generate_trace(cfg)
    assert len(trace) == 25
    assert trace[-1].arrival > 24.77
    arrivals = [r.arrival for r in trace]
    assert arrivals == sorted(arrivals)
    assert all(t < cfg.duration for t in arrivals)


def test_generate_trace_unaffected_when_draw_covers():
    # a seed whose first draw already covers the horizon must be bit-for-bit
    # unchanged by the coverage loop (same rng consumption order)
    cfg = WorkloadConfig(mean_rate=3.0, duration=15.0, seed=9, in_mu=5.0,
                         in_sigma=1.1, out_mu=5.3, out_sigma=0.9)
    trace = generate_trace(cfg)
    assert len(trace) == 43          # the shim-golden trace, untouched
    assert trace[-1].arrival < cfg.duration


def test_resumed_victim_atgt_clock_advances():
    # force overflow -> preempt -> resume on one worker and assert the
    # victim's decode clock ran through its own re-prefill
    perf = PerfModel(kv=KVModel(h=1.0, j=0.0),
                     prefill=PrefillModel(k1=1e-4, c1=0.02),
                     decode=DecodeModel(k2=1e-5, c2=1e-4, c3=0.01))
    pcfg = PlacementConfig(kv_capacity=151.0, max_batch=8)
    slo = SLO(ttft=10.0, atgt=10.0)
    w = WorkerState(0, pcfg, perf, slo)
    sim = SimWorker(w, perf, 0.0, split_phase=False)
    r1 = Request(l_in=100, l_pred=5, l_real=5, arrival=0.0)
    r2 = Request(l_in=50, l_pred=100, l_real=100, arrival=0.1)
    w.place(r1)
    w.place(r2)
    finished = []
    sim.advance_to(1000.0, finished, t_start=0.0)
    # after the joint prefill kv = h*(101+51) = 152 > 151: the younger r2 is
    # preempted, resumed once r1 finishes, and decodes to completion
    assert sim.preemptions == 1
    assert len(finished) == 2
    assert r2.t_finish is not None and r2.t_first_token is not None
    # clock invariant: once the first token exists, every wall-second until
    # finish is decode or stall — including the victim's own re-prefill.
    # Pre-fix r2's clock was short by exactly that prefill duration.
    for r in (r1, r2):
        assert r.t_decode_spent == pytest.approx(
            r.t_finish - r.t_first_token, rel=1e-9)
